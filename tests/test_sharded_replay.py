"""Sharded giant-world replay (DESIGN.md §16).

The contracts under test:

  * pinning (lag 0) — ``run_worlds(..., mesh=...)`` splits the worker
    axis over a device mesh and serves cross-shard partner reads through
    the permute ring, yet the final state is BITWISE the single-device
    engine replay on topology, channel, and defense worlds, on both
    kernel backends (traces allclose: the loss/consensus metrics cross
    shards via psum and reassociate, but never feed the state);
  * pinning (lag > 0) — a positive staleness floor on boundary reads is
    EXACTLY the per-event delay reference: the single-device replay of
    ``world.shard_lag_schedule(sched, NS, L)``;
  * one trace — every world batch on one (mesh, lag) shares ONE compiled
    scan (jit-cache size grows by exactly one across distinct batches);
  * ragged fallback — a worker axis the mesh cannot split evenly warns
    and falls back to the single-device flavors, bitwise;
  * host compiler — ``events.shard_partition`` serves every cross read
    the row its reader asked for, at the slot the schedule resolved.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the
full matrix (CI's forced-multi-device job); on a single device the
multi-shard cases skip and the n_shards=1 mesh path still pins.
"""
import os
import sys

# Standalone (this file alone, jax not yet imported anywhere) force an
# 8-device host so the full cross-shard matrix runs.  Inside the full
# suite another module has already imported jax — leave the platform
# alone (tier-1 stays on its native device count; the multi-device
# cases skip) and let CI's forced-multi-device job set the env itself.
if "jax" not in sys.modules:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveDefense, ByzantineEdges, ChannelModel,
                        DelayProcess, Simulator, World, params_from_graph,
                        ring_graph)
from repro.core.events import shard_lag_stale, shard_partition
from repro.core.telemetry import Telemetry, cross_shard_reads
from repro.core.world import shard_cross_reads, shard_lag_schedule
from repro.launch.mesh import make_replay_mesh
from repro.launch.mesh_replay import MeshReplay, sharded_twin

N, D, ROUNDS = 16, 24, 6
NDEV = jax.local_device_count()
NS = min(8, NDEV)
multi = pytest.mark.skipif(NDEV < 2, reason="needs >= 2 devices "
                           "(XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8)")

BACKENDS = ["ref", "pallas_interpret"]


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        g = g + (0.05 * jax.random.normal(key, x.shape)).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


def _make_sim(backend="ref", **kw):
    g = ring_graph(N)
    b = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    return Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                     gamma=0.05, backend=backend, **kw)


def _states(sim, count):
    return [sim.init(jnp.zeros(D), N, jax.random.PRNGKey(2))
            for _ in range(count)]


def _mesh(n=None):
    return MeshReplay(make_replay_mesh(NS if n is None else n))


def _assert_state_pinned(f0, f1):
    for a, c in zip(jax.tree.leaves(f0.x), jax.tree.leaves(f1.x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for a, c in zip(jax.tree.leaves(f0.x_tilde),
                    jax.tree.leaves(f1.x_tilde)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def _pin_mesh(sim, worlds, seeds, mr, **kw):
    """mesh= replay of a batch equals the single-device replay: states
    bitwise, traces allclose (metrics psum across shards)."""
    scheds = [w.compile(ROUNDS, seed=s) for w, s in zip(worlds, seeds)]
    states = _states(sim, len(scheds))
    f0, t0 = sim.run_worlds(states, scheds, **kw)
    f1, t1 = sim.run_worlds(states, scheds, mesh=mr, **kw)
    _assert_state_pinned(f0, f1)
    np.testing.assert_allclose(np.asarray(t0.loss), np.asarray(t1.loss),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(t0.consensus),
                               np.asarray(t1.consensus), rtol=1e-6)
    return f1, t1


# ------------------------------------------------------------ lag-0 pinning

@multi
@pytest.mark.parametrize("backend", BACKENDS)
def test_topology_worlds_pin(backend):
    ring = ring_graph(N)
    sim = _make_sim(backend)
    _pin_mesh(sim, [World(topology=ring), World(topology=ring)], [0, 1],
              _mesh())


@multi
@pytest.mark.parametrize("backend", BACKENDS)
def test_channel_worlds_pin(backend):
    """Delay + Byzantine + drop channels: the publisher-resolved permute
    ring serves the SAME snapshots the single-device ring read."""
    ring = ring_graph(N)
    sim = _make_sim(backend)
    _pin_mesh(sim, [
        World(topology=ring, channel=ChannelModel(
            delay=DelayProcess(horizon=2, prob=0.7))),
        World(topology=ring, channel=ChannelModel(
            adversary=ByzantineEdges(ring.edges[:2], "scale", scale=40.0,
                                     prob=0.6),
            drop_prob=0.1)),
    ], [1, 3], _mesh())


@multi
def test_defense_worlds_pin():
    """Self-healing defense: trust rows shard with the readers, the
    gathered tau sort and the psum'd integer counters are exact, so the
    defense trace pins bitwise too."""
    ring = ring_graph(N)
    sim = _make_sim(robust_rule="trim")
    byz = World(topology=ring, channel=ChannelModel(
        adversary=ByzantineEdges(ring.edges[:3], "scale", scale=60.0,
                                 prob=0.5)))
    scheds = [byz.compile(ROUNDS, seed=s) for s in (0, 1)]
    states = _states(sim, 2)
    dfs = [AdaptiveDefense(), AdaptiveDefense()]
    f0, t0 = sim.run_worlds(states, scheds, defenses=dfs)
    f1, t1 = sim.run_worlds(states, scheds, defenses=dfs, mesh=_mesh())
    _assert_state_pinned(f0, f1)
    np.testing.assert_array_equal(np.asarray(t0.defense.tau),
                                  np.asarray(t1.defense.tau))
    np.testing.assert_array_equal(np.asarray(t0.defense.rejections),
                                  np.asarray(t1.defense.rejections))
    np.testing.assert_array_equal(np.asarray(t0.defense.quarantined),
                                  np.asarray(t1.defense.quarantined))


def test_single_shard_mesh_pins():
    """An n_shards=1 mesh (always constructible) runs the sharded twins
    with an empty boundary and still pins bitwise — the degenerate case
    every device count can exercise."""
    ring = ring_graph(N)
    sim = _make_sim()
    _pin_mesh(sim, [World(topology=ring, channel=ChannelModel(
        delay=DelayProcess(horizon=2, prob=0.5)))], [2], _mesh(1))


@multi
def test_run_schedule_mesh_lift():
    """run_schedule(mesh=) lifts to a B=1 worlds replay and squeezes —
    the batched-equals-serial precedent (signed zeros aside)."""
    ring = ring_graph(N)
    sim = _make_sim()
    sch = World(topology=ring).compile(ROUNDS, seed=0)
    st = _states(sim, 1)[0]
    f0, t0 = sim.run_schedule(st, sch)
    f1, t1 = sim.run_schedule(st, sch, mesh=_mesh())
    assert t1.loss.shape == (ROUNDS,)
    for a, c in zip(jax.tree.leaves(f0.x), jax.tree.leaves(f1.x)):
        np.testing.assert_array_equal(np.abs(np.asarray(a)),
                                      np.abs(np.asarray(c)))


# ----------------------------------------------------------- lag>0 pinning

@multi
@pytest.mark.parametrize("lag", [1, 2])
def test_lagged_ring_equals_delay_reference(lag):
    """MeshReplay(lag=L) IS a ChannelModel(delay=...) on the boundary:
    bitwise the single-device replay of shard_lag_schedule(sched, NS, L)."""
    ring = ring_graph(N)
    sim = _make_sim()
    w = World(topology=ring, channel=ChannelModel(
        delay=DelayProcess(horizon=3, prob=0.5)))
    scheds = [w.compile(ROUNDS, seed=7)]
    states = _states(sim, 1)
    f1, _ = sim.run_worlds(states, scheds,
                           mesh=MeshReplay(make_replay_mesh(NS), lag=lag))
    refs = [shard_lag_schedule(s, NS, lag) for s in scheds]
    f0, _ = sim.run_worlds(states, refs)
    _assert_state_pinned(f0, f1)


@multi
def test_lagged_plain_world():
    """lag > 0 engages the ring even on a delay-free schedule (boundary
    reads become stale) and still matches the rewritten-extras reference."""
    ring = ring_graph(N)
    sim = _make_sim()
    scheds = [World(topology=ring).compile(ROUNDS, seed=3)]
    states = _states(sim, 1)
    f1, _ = sim.run_worlds(states, scheds,
                           mesh=MeshReplay(make_replay_mesh(NS), lag=2))
    f0, _ = sim.run_worlds(states,
                           [shard_lag_schedule(s, NS, 2) for s in scheds])
    _assert_state_pinned(f0, f1)


# --------------------------------------------------- trace & dispatch cost

@multi
def test_one_trace_per_mesh():
    """One trace, one dispatch: a whole world batch costs a single
    compiled scan, and every batch whose stacked stream (and permute-
    ring pool) keeps its shape rides that SAME trace — different
    matchings, keys, and states never retrace.  (A batch that changes
    the stream length or the data-dependent pool width legitimately
    costs a new trace — that is shape polymorphism, not cache misses.)"""
    ring = ring_graph(N)
    sim = _make_sim()
    mr = _mesh()
    fn = sharded_twin("channel", donate=False)
    scheds_a = [World(topology=ring).compile(ROUNDS, seed=s)
                for s in (4, 5)]
    scheds_b = [World(topology=ring).compile(ROUNDS, seed=s)
                for s in (6, 7)]
    # precondition: the two batches stack to identical stream shapes
    _, args_a = sim.worlds_executable(_states(sim, 2), scheds_a, mesh=mr)
    _, args_b = sim.worlds_executable(_states(sim, 2), scheds_b, mesh=mr)
    shp = lambda args: [getattr(l, "shape", None)
                        for l in jax.tree.leaves(args)]
    assert shp(args_a) == shp(args_b)
    base = fn._cache_size()
    sim.run_worlds(_states(sim, 2), scheds_a, mesh=mr)
    assert fn._cache_size() == base + 1      # one trace for the batch
    sim.run_worlds(_states(sim, 2), scheds_a, mesh=mr)   # fresh replay
    sim.run_worlds(_states(sim, 2), scheds_b, mesh=mr)   # fresh batch
    assert fn._cache_size() == base + 1      # ...and no more


# ----------------------------------------------------------- ragged fallback

def test_ragged_worker_axis_falls_back():
    """n % n_shards != 0 cannot shard; warn and replay single-device,
    bitwise."""
    n_odd = 15
    g = ring_graph(n_odd)
    b = jax.random.normal(jax.random.PRNGKey(1), (n_odd, D))
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                    gamma=0.05)
    scheds = [World(topology=g).compile(ROUNDS, seed=0)]
    states = [sim.init(jnp.zeros(D), n_odd, jax.random.PRNGKey(2))]
    f0, _ = sim.run_worlds(states, scheds)
    mr = MeshReplay(make_replay_mesh(min(2, NDEV)))
    if mr.n_shards == 1:  # 15 % 1 == 0: force a ragged shard count
        pytest.skip("needs a >1-shard mesh to be ragged")
    with pytest.warns(RuntimeWarning, match="not divisible"):
        f1, _ = sim.run_worlds(states, scheds, mesh=mr)
    _assert_state_pinned(f0, f1)


def test_engine_false_mesh_raises():
    ring = ring_graph(N)
    sim = _make_sim()
    scheds = [World(topology=ring).compile(ROUNDS, seed=0)]
    with pytest.raises(ValueError, match="flat-buffer engine"):
        sim.run_worlds(_states(sim, 1), scheds, engine=False,
                       mesh=_mesh(1))


# ------------------------------------------------------------- telemetry

@multi
def test_cross_shard_byte_split():
    """bytes split into intra vs cross: cross = boundary reads x the
    flat row width; intra + cross = applied bytes of the unsharded
    accounting (total conserved)."""
    ring = ring_graph(N)
    sim = _make_sim()
    tel = Telemetry(bytes_moved=True)
    w = World(topology=ring, channel=ChannelModel(drop_prob=0.2))
    scheds = [w.compile(ROUNDS, seed=5)]
    states = _states(sim, 1)
    _, t0 = sim.run_worlds(states, scheds, telemetry=tel)
    _, t1 = sim.run_worlds(states, scheds, telemetry=tel, mesh=_mesh())
    tt0, tt1 = t0.telemetry, t1.telemetry
    assert tt0.cross_reads is None and tt0.bytes_cross is None
    assert tt1.cross_reads is not None
    survived = (np.asarray(tt1.scheduled) - np.asarray(tt1.dropped)) \
        * float(tt1.row_bytes)
    np.testing.assert_array_equal(
        np.asarray(tt1.bytes_intra) + np.asarray(tt1.bytes_cross), survived)
    np.testing.assert_array_equal(np.asarray(tt0.bytes_moved),
                                  np.asarray(tt1.bytes_moved))
    np.testing.assert_array_equal(
        np.asarray(tt1.bytes_cross),
        np.asarray(tt1.cross_reads, np.float64) * tt1.row_bytes)
    # the exact count from the schedule, independent of the replay
    want = np.stack([cross_shard_reads(s.partners, s.event_mask, NS)
                     for s in scheds])
    np.testing.assert_array_equal(np.asarray(tt1.cross_reads), want)


def test_telemetry_none_stays_noop():
    """telemetry=None under mesh= adds no columns and changes nothing."""
    ring = ring_graph(N)
    sim = _make_sim()
    scheds = [World(topology=ring).compile(ROUNDS, seed=0)]
    _, tr = sim.run_worlds(_states(sim, 1), scheds, mesh=_mesh(1))
    assert tr.telemetry is None


# ------------------------------------------------------- host-side compiler

def test_shard_partition_serves_requested_rows():
    """Every cross read's (hop, pool_pos) lands on the row and slot its
    reader asked for; intra reads keep a local involution."""
    rng = np.random.default_rng(0)
    S, B, n, ns, h = 5, 2, 16, 4, 3
    ws = n // ns
    partners = np.tile(np.arange(n, dtype=np.int32), (S, B, 1))
    for s in range(S):
        for bi in range(B):
            perm = rng.permutation(n)
            for k in range(0, n, 2):
                i, j = perm[k], perm[k + 1]
                partners[s, bi, i], partners[s, bi, j] = j, i
    src_slot = rng.integers(0, h + 1, (S, B, n)).astype(np.int32)
    plan = shard_partition(partners, src_slot, ns, h)
    assert plan.shard_size == ws
    rdr = np.arange(n)
    for s in range(S):
        for bi in range(B):
            for i in range(n):
                p = partners[s, bi, i]
                if p == i:
                    assert not plan.is_cross[s, bi, i]
                    assert plan.local_partner[s, bi, i] == i % ws
                elif p // ws == i // ws:
                    assert not plan.is_cross[s, bi, i]
                    assert plan.local_partner[s, bi, i] == p % ws
                else:
                    assert plan.is_cross[s, bi, i]
                    hop = plan.hop[s, bi, i]
                    assert hop == (i // ws - p // ws) % ns
                    k = plan.pool_pos[s, bi, i]
                    assert plan.pub_row[s, p // ws, bi, k] == p % ws
                    assert plan.pub_slot[s, p // ws, bi, k] \
                        == src_slot[s, bi, i]
    np.testing.assert_array_equal(
        plan.cross_reads,
        (plan.is_cross & (partners != rdr)).sum(axis=-1))
    # intra restriction is an involution per shard
    lp = plan.local_partner
    for s in range(S):
        for bi in range(B):
            for u in range(ns):
                blk = lp[s, bi, u * ws:(u + 1) * ws]
                intra = ~plan.is_cross[s, bi, u * ws:(u + 1) * ws]
                got = blk[blk[np.arange(ws)]][intra]
                np.testing.assert_array_equal(got, np.arange(ws)[intra])


def test_shard_lag_stale_floors_cross_only():
    S, B, n, ns = 4, 1, 8, 2
    partners = np.tile(np.arange(n, dtype=np.int32), (S, B, 1))
    partners[:, 0, 0], partners[:, 0, 4] = 4, 0      # cross pair
    partners[:, 0, 1], partners[:, 0, 2] = 2, 1      # intra pair
    stale = np.zeros((S, B, n), np.int32)
    stale[:, 0, 1] = 2
    step_round = np.array([0, 1, 2, 3])
    out = shard_lag_stale(partners, stale, step_round, ns, lag=2)
    np.testing.assert_array_equal(out[:, 0, 0], [0, 1, 2, 2])  # floored
    np.testing.assert_array_equal(out[:, 0, 1], [2, 2, 2, 2])  # untouched
    np.testing.assert_array_equal(out[:, 0, 3], [0, 0, 0, 0])  # idle


def test_shard_lag_schedule_rewrites_extras():
    ring = ring_graph(N)
    sch = World(topology=ring).compile(ROUNDS, seed=0)
    out = shard_lag_schedule(sch, 4, 2)
    assert out is not sch
    from repro.core.channel import STALE_KEY
    st = out.extras_dict()[STALE_KEY]
    assert (st >= 0).all() and st.max() <= 2
    assert shard_lag_schedule(sch, 1, 2) is sch
    assert shard_lag_schedule(sch, 4, 0) is sch


def test_cross_shard_reads_counts():
    ring = ring_graph(N)
    sch = World(topology=ring).compile(ROUNDS, seed=0)
    c2 = shard_cross_reads(sch, 2)
    assert c2.shape == (ROUNDS,) and c2.dtype == np.int64
    assert (shard_cross_reads(sch, 1) == 0).all()
    # a ring of N has exactly 2 boundary edges per shard cut; every
    # matched boundary edge contributes 2 directed reads
    assert (c2 >= 0).all()


# ------------------------------------------------------------- mesh plumbing

def test_make_replay_mesh_host_aware():
    m = make_replay_mesh()
    assert m.axis_names == ("worker",)
    assert m.shape["worker"] == NDEV
    assert make_replay_mesh(1).shape["worker"] == 1
    with pytest.raises(ValueError, match="local devices"):
        make_replay_mesh(NDEV + 1)
    with pytest.raises(ValueError, match="local devices"):
        make_replay_mesh(0)


def test_replay_mesh_rules():
    from repro.launch.mesh import rules_for
    from repro import sharding
    assert rules_for(make_replay_mesh(1)) == dict(sharding.REPLAY_RULES)


def test_mesh_replay_validation():
    m = make_replay_mesh(1)
    with pytest.raises(ValueError, match="lag"):
        MeshReplay(m, lag=-1)
    with pytest.raises(ValueError, match="axis"):
        MeshReplay(m, axis="data")
    mr = MeshReplay(m, lag=3)
    assert mr.n_shards == 1
    assert hash(mr) == hash(MeshReplay(m, lag=3))
