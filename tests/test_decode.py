"""Decode-vs-prefill consistency for every mixer family (incl. ring buffers
for the long_500k sliding-window carve-out)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import Model

B, S = 2, 32


def _uncapped(cfg):
    if cfg.moe is not None:  # capacity drops differ train-vs-decode
        return cfg.with_updates(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _roundtrip(cfg, tol=2e-4):
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model))
    logits_full, _, _ = model.forward(params, inputs)
    caches = model.init_cache(B, S)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, caches = dec(params, inputs[:, t:t + 1], jnp.int32(t), caches)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_full - jnp.stack(outs, axis=1))))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-6
    assert err / scale < tol, f"decode mismatch: {err} (scale {scale})"


@pytest.mark.parametrize("arch", ["qwen3-14b", "glm4-9b", "musicgen-medium",
                                  "deepseek-v3-671b", "arctic-480b",
                                  "mamba2-780m", "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    _roundtrip(_uncapped(get_config(arch, reduced=True)))


@pytest.mark.parametrize("arch", ["qwen3-14b", "chameleon-34b"])
def test_windowed_ring_buffer_decode(arch):
    """long_500k carve-out: sliding-window variant with ring-buffer caches
    must equal the windowed full forward."""
    cfg = _uncapped(get_config(arch, reduced=True)).windowed(8)
    model = Model(cfg)
    caches = model.init_cache(B, S)
    # ring buffer is window-sized, not seq-sized
    k = jax.tree.leaves(caches[0])[0]
    assert k.shape[2] == 8
    _roundtrip(cfg)


def test_windowed_config_only_touches_attention():
    cfg = get_config("recurrentgemma-9b", reduced=True).windowed(16)
    kinds = [(b.mixer, b.window) for b in cfg.all_blocks()]
    for mixer, window in kinds:
        if mixer in ("attn", "mla"):
            assert window == 16
        else:
            assert window is None


def test_mla_cache_is_compressed():
    """MLA decode cache stores latents, not per-head K/V."""
    cfg = get_config("deepseek-v3-671b", reduced=True)
    model = Model(cfg)
    caches = model.init_cache(B, S)
    c = caches[0]["b0"]["c"]
    assert c.shape[-1] == cfg.mla.kv_lora_rank
    kr = caches[0]["b0"]["k_rope"]
    assert kr.shape[-1] == cfg.mla.qk_rope_head_dim


def test_ssm_cache_is_constant_size():
    cfg = get_config("mamba2-780m", reduced=True)
    model = Model(cfg)
    small = model.init_cache(B, 32)
    large = model.init_cache(B, 4096)
    for a, b in zip(jax.tree.leaves(small), jax.tree.leaves(large)):
        assert a.shape == b.shape  # attention-free: O(1) in context length
