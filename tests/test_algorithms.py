"""Algorithm zoo (DESIGN.md §13): distribution, closed-form rate, and
equivalence tests.

This is the repo's first *statistical* (not bitwise) claim surface, so the
tests split into three tiers:

  * distribution — the mesh trainers' in-step sampler and the compiled
    ``Schedule`` sampler are pinned against their OWN closed-form laws
    (Poisson counts, Exp gaps, Binomial thinning) and against EACH OTHER on
    the laws they genuinely share: the gradient-clock rate process and the
    per-edge event-rate *composition*.  They intentionally do NOT share a
    joint matching law (bank-categorical vs greedy-maximal — see the
    ``launch/gossip_train.py`` module docstring); the star graph, where
    every matching is a single edge, is the case where even the per-event
    law coincides.
  * closed-form rates — the zoo's arms against theory: adpsgd is bitwise
    the eta=0 baseline, DADAO's decoupled clocks collapse bitwise onto the
    coupled schedule when the rates coincide, and the accelerated/baseline
    consensus-rate ratio on the ring tracks sqrt(chi1/chi2) (Prop 3.6).
  * equivalence + serialization — engine == per-event reference on
    algorithm worlds (both backends, channel/defense composition included),
    ``Algorithm`` JSON round-trips, ``World(algorithm=None)`` is bitwise
    the legacy replay, and a mixed-algorithm ``WorldSweep`` shares ONE jit
    trace.

Every stochastic assertion uses a FIXED seed, a tolerance derived from the
law under test (KS: Kolmogorov asymptotic critical value; chi-squared:
Wilson-Hilferty cube approximation; counts: CLT z-bands), and a comment
naming the variance source.  Critical values are numpy-only (CI has no
scipy).  Flaky-surface audit: each stochastic test was re-run across 20
seeds (seed offsets 0..19) locally; worst-case margins are recorded in the
test docstrings.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveDefense, Algorithm, ByzantineEdges,
                        ChannelModel, Simulator, World, WorldSweep,
                        WorkerModel, baseline_params, params_from_graph,
                        ring_graph, star_graph)
from repro.core.a2cid2 import _ALGO_TAG
from repro.launch.gossip_train import _comms_per_step, _world_dynamics

# ------------------------------------------------------- numpy-only stats
#
# CI installs no scipy, so critical values are closed-form:
#  * KS one-sample: the asymptotic Kolmogorov critical value
#      D_crit = sqrt(-ln(alpha/2) / (2 N))
#    (exact as N -> inf; conservative-to-slightly-liberal at finite N —
#    the tests use N >= 2000 where the approximation error is < 2%).
#  * chi-squared upper quantile: Wilson-Hilferty cube
#      crit = df * (1 - 2/(9 df) + z_alpha * sqrt(2/(9 df)))**3
#    with hard-coded standard-normal quantiles (no scipy.stats.norm).

_Z = {0.05: 1.6449, 1e-2: 2.3263, 1e-3: 3.0902, 1e-4: 3.7190}


def _ks_crit(n: int, alpha: float = 1e-3) -> float:
    return float(np.sqrt(-np.log(alpha / 2.0) / (2.0 * n)))


def _ks_stat(samples: np.ndarray, cdf) -> float:
    s = np.sort(np.asarray(samples, np.float64))
    n = len(s)
    f = cdf(s)
    up = np.arange(1, n + 1, dtype=np.float64) / n
    lo = np.arange(0, n, dtype=np.float64) / n
    return float(np.max(np.maximum(up - f, f - lo)))


def _chi2_crit(df: int, alpha: float = 1e-3) -> float:
    z = _Z[alpha]
    return float(df * (1.0 - 2.0 / (9.0 * df)
                       + z * np.sqrt(2.0 / (9.0 * df))) ** 3)


def _poisson_pmf(k: np.ndarray, lam: float) -> np.ndarray:
    from math import lgamma
    k = np.asarray(k, np.float64)
    logp = -lam + k * np.log(lam) - np.array(
        [lgamma(x + 1.0) for x in k])
    return np.exp(logp)


def _edge_counts_from_schedule(graph, sched) -> np.ndarray:
    """Count per-edge comm events in a compiled Schedule."""
    eidx = {tuple(sorted(e)): i for i, e in enumerate(graph.edges)}
    counts = np.zeros(len(graph.edges), np.int64)
    partners = np.asarray(sched.partners)
    mask = np.asarray(sched.event_mask)
    n = sched.n
    idx = np.arange(n)
    for r in range(sched.rounds):
        for e in range(partners.shape[1]):
            if not mask[r, e]:
                continue
            p = partners[r, e]
            for i in idx[p != idx]:
                j = int(p[i])
                if i < j:
                    counts[eidx[(int(i), j)]] += 1
    return counts


def _edge_counts_from_trainer(graph, num_steps: int, seed: int) -> np.ndarray:
    """Count per-edge events drawn exactly the way ``StackedGossipTrainer``'s
    step does: ``categorical(log(bank_edge_rates))`` over the static
    matching bank, each drawn matching contributing all its edges."""
    from repro.core.gossip import bank_edge_rates, matching_bank
    bank = np.asarray(matching_bank(graph))                  # (M, n) partner
    probs = jnp.asarray(bank_edge_rates(graph, bank), jnp.float32)
    E = _comms_per_step(World(topology=graph))
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, num_steps)
    idxs = np.asarray(jax.vmap(
        lambda k: jax.random.categorical(k, jnp.log(probs), shape=(E,))
    )(keys)).ravel()
    eidx = {tuple(sorted(e)): i for i, e in enumerate(graph.edges)}
    counts = np.zeros(len(graph.edges), np.int64)
    arange = np.arange(graph.n)
    for m in idxs:
        p = bank[int(m)]
        for i in arange[p != arange]:
            j = int(p[i])
            if i < j:
                counts[eidx[(int(i), j)]] += 1
    return counts


def _two_sample_chi2(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample chi-squared homogeneity statistic over categories."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    tot = a + b
    pa, pb = a.sum(), b.sum()
    ea = tot * pa / (pa + pb)
    eb = tot * pb / (pa + pb)
    return float((((a - ea) ** 2) / ea).sum() + (((b - eb) ** 2) / eb).sum())


# =================================================== sampler distributions

class TestSamplerDistribution:
    """Satellite: the laws behind the trainers' in-step sampler and the
    compiled Schedule — and exactly which of them agree."""

    def test_schedule_comm_counts_poisson(self):
        """Per-round comm event counts of a compiled coupled-clock schedule
        are Poisson(comms_per_grad): chi-squared GOF over pooled bins.

        Variance source: multinomial sampling of 4000 per-round counts.
        Critical value: chi-squared df=len(bins)-1 at alpha=1e-3
        (Wilson-Hilferty).  Audit (20 seeds): stat in [1.5, 11.1] vs
        crit 22.7 — worst margin 11.6.
        """
        g = ring_graph(8)
        rounds, cpg = 4000, 1.5
        sched = World(topology=g, comms_per_grad=cpg).compile(rounds, seed=7)
        # matching EVENTS per round (event_mask), not pairwise exchanges —
        # comm_events_per_round() counts edges and a ring-8 maximal
        # matching carries 3-4 of them
        counts = np.asarray(sched.event_mask).sum(axis=1)
        # pool the tail so every expected bin count >= 5
        kmax = 6
        bins = np.arange(kmax + 1)
        pmf = _poisson_pmf(bins, cpg)
        pmf[-1] = 1.0 - pmf[:-1].sum()          # >= kmax tail
        obs = np.array([(counts == k).sum() for k in range(kmax)]
                       + [(counts >= kmax).sum()], np.float64)
        exp = pmf * rounds
        assert exp.min() >= 5.0
        stat = float((((obs - exp) ** 2) / exp).sum())
        assert stat < _chi2_crit(kmax, 1e-3), (stat, obs, exp)

    def test_schedule_event_gaps_exponential(self):
        """Inter-event gaps of the compiled comm process are Exp(cpg): the
        per-round construction (Poisson count + sorted uniforms) IS a
        Poisson process on [0, rounds], so consecutive gaps — including
        across round boundaries — are iid Exp(cpg).  One-sample KS.

        Variance source: ~6000 event gaps at seed 3.  Critical value:
        Kolmogorov asymptotic at alpha=1e-3.  Audit (20 seeds): D/crit in
        [0.27, 0.67] — worst margin 0.33 of the critical value.
        """
        g = ring_graph(8)
        rounds, cpg = 4000, 1.5
        sched = World(topology=g, comms_per_grad=cpg).compile(rounds, seed=3)
        times = np.asarray(sched.event_times, np.float64)
        mask = np.asarray(sched.event_mask)
        gaps = np.diff(np.sort(times[mask]))
        d = _ks_stat(gaps, lambda t: 1.0 - np.exp(-cpg * t))
        assert d < _ks_crit(len(gaps), 1e-3), (d, len(gaps))

    def test_trainer_gossip_gaps_convention(self):
        """The trainer's per-event mixing gaps follow the documented
        convention: ``exponential((E, n)) / E`` — iid Exp(E) per worker, so
        E events add up to one expected round of mixing time.  One-sample
        KS on the gaps drawn exactly as the step draws them, plus a CLT
        band on the per-step total.

        Variance source: 1000 steps x E=2 x n=8 = 16000 Exp draws, seed 5.
        Audit (20 seeds): KS D/crit in [0.24, 0.80]; total-mean |z| in
        [0.07, 2.03] vs band 3.09.
        """
        g = ring_graph(8)
        E = _comms_per_step(World(topology=g, comms_per_grad=2.0))
        assert E == 2
        steps, n = 1000, g.n
        keys = jax.random.split(jax.random.PRNGKey(5), steps)
        gaps = np.asarray(jax.vmap(
            lambda k: jax.random.exponential(k, (E, n)) / max(E, 1))(keys))
        d = _ks_stat(gaps.ravel(), lambda t: 1.0 - np.exp(-E * t))
        assert d < _ks_crit(gaps.size, 1e-3), d
        # per-step per-worker total mixing time: sum of E Exp(E) draws,
        # mean 1, var 1/E; CLT over steps*n totals
        totals = gaps.sum(axis=1)                # (steps, n)
        z = (totals.mean() - 1.0) / np.sqrt(1.0 / E / totals.size)
        assert abs(z) < _Z[1e-3], z

    def test_grad_clock_rates_agree(self):
        """The gradient-clock RATE process is the law the two samplers
        share: the schedule thins unit ticks with Bernoulli(rate_i), the
        trainer dilates inter-event times by 1/rate_i (Exp(1)/rate_i) — the
        same per-worker event rate.  Pins (a) schedule per-worker tick
        counts ~ Binomial(rounds, rate_i) per worker, (b) trainer mean gap
        = 1/rate_i per worker, (c) the two empirical rates agree within a
        joint CLT band.

        Variance sources: Binomial(3000, r) per worker; mean of 3000 Exp
        gaps per worker.  Bands: z at alpha=1e-3 Bonferroni over 2n=12
        per-worker checks (z(1e-4)=3.72) and the cross-sampler delta at
        the same level.  Audit (20 seeds): worst |z| 2.92 (schedule),
        2.71 (trainer), 2.34 (cross-sampler) vs 3.72.
        """
        g = ring_graph(6)
        rates = np.array([1.0, 0.8, 0.6, 0.4, 0.8, 0.5])
        rounds = 3000
        w = World(topology=g, workers=WorkerModel(grad_rates=tuple(rates)))
        sched = w.compile(rounds, seed=11)
        gs = np.asarray(sched.grad_scale())       # (rounds, n) 0/1
        counts = gs.sum(axis=0)
        # (a) schedule side: Binomial(rounds, r) per worker
        z_sched = (counts - rounds * rates) / np.sqrt(
            rounds * rates * (1 - rates) + 1e-12)
        assert np.abs(z_sched).max() < _Z[1e-4], z_sched
        # (b) trainer side: dts = Exp(1)/rate_i, mean 1/r, var 1/r^2
        graph, _, grad_rates = _world_dynamics(w, None)
        rvec = np.asarray(grad_rates)
        np.testing.assert_allclose(rvec, rates)
        keys = jax.random.split(jax.random.PRNGKey(11), rounds)
        dts = np.asarray(jax.vmap(
            lambda k: jax.random.exponential(k, (g.n,)))(keys)) / rvec
        mean_gap = dts.mean(axis=0)
        z_tr = (mean_gap - 1.0 / rvec) / (1.0 / rvec / np.sqrt(rounds))
        assert np.abs(z_tr).max() < _Z[1e-4], z_tr
        # (c) cross-sampler: empirical rates (ticks/round vs 1/mean-gap)
        delta = counts / rounds - 1.0 / mean_gap
        # var of difference ~ r(1-r)/R + r^2/R per worker
        sd = np.sqrt(rates * (1 - rates) / rounds
                     + rates ** 2 / rounds)
        assert np.abs(delta / sd).max() < _Z[1e-4], delta / sd

    def test_edge_rate_composition_ring(self):
        """Per-edge event-rate COMPOSITION agrees between the schedule's
        greedy-maximal matcher and the trainer's bank-categorical sampler:
        on the edge-transitive ring both are uniform over edges.  Two-sample
        chi-squared homogeneity over the 8 edges (the joint matching law
        differs — this pins the shared marginal composition only).

        Variance source: ~6000 schedule edge events vs ~12000 trainer edge
        events, seeds 13/17.  Critical value: chi-squared df=7 at
        alpha=1e-3.  Audit (20 seeds): stat in [0.30, 18.5] vs crit 24.5.
        """
        g = ring_graph(8)
        sched = World(topology=g, comms_per_grad=1.5).compile(2000, seed=13)
        a = _edge_counts_from_schedule(g, sched)
        b = _edge_counts_from_trainer(g, num_steps=1500, seed=17)
        stat = _two_sample_chi2(a, b)
        assert stat < _chi2_crit(len(g.edges) - 1, 1e-3), (stat, a, b)

    def test_edge_rate_composition_star_exact_per_event(self):
        """On the star graph every maximal matching is a SINGLE edge, so the
        bank-categorical and greedy-maximal samplers coincide per event —
        the case where the trainers' law matches the schedule exactly, not
        just in composition.  Asserts one-edge-per-event structurally on
        both sides, then two-sample chi-squared over edges.

        Variance source: ~3000 events per side, seeds 19/23.  Critical
        value: chi-squared df=6 at alpha=1e-3.  Audit (20 seeds): stat in
        [0.46, 12.1] vs crit 22.7.
        """
        g = star_graph(7)
        sched = World(topology=g, comms_per_grad=1.5).compile(2000, seed=19)
        partners = np.asarray(sched.partners)
        mask = np.asarray(sched.event_mask)
        idx = np.arange(g.n)
        for r, e in zip(*np.nonzero(mask)):
            assert (partners[r, e] != idx).sum() == 2  # one edge = 2 movers
        a = _edge_counts_from_schedule(g, sched)
        from repro.core.gossip import matching_bank
        bank = np.asarray(matching_bank(g))
        assert all((row != np.arange(g.n)).sum() == 2 for row in bank)
        b = _edge_counts_from_trainer(g, num_steps=3000, seed=23)
        stat = _two_sample_chi2(a, b)
        assert stat < _chi2_crit(len(g.edges) - 1, 1e-3), (stat, a, b)

    def test_dadao_gate_composes_with_straggler_thinning(self):
        """DADAO's decoupled gradient clock (Bernoulli(grad_rate) from the
        0xDADA0 stream) ANDs with straggler thinning: per-worker tick
        counts ~ Binomial(rounds, grad_rate * rate_i).  Also pins stream
        independence: the straggler draws are bitwise unchanged by the
        algorithm gate.

        Variance source: Binomial(3000, 0.48) per worker, seed 29.  Band:
        z at alpha=1e-4 (Bonferroni over n=6 workers).  Audit (20 seeds):
        worst |z| 3.29 vs 3.72 — the tightest margin in the suite.
        """
        g = ring_graph(6)
        rounds, gr, sr = 3000, 0.6, 0.8
        w = World(topology=g,
                  workers=WorkerModel(grad_rates=(sr,) * g.n),
                  algorithm=Algorithm("dadao", grad_rate=gr))
        sched = w.compile(rounds, seed=29)
        counts = np.asarray(sched.grad_scale()).sum(axis=0)
        p = gr * sr
        z = (counts - rounds * p) / np.sqrt(rounds * p * (1 - p))
        assert np.abs(z).max() < _Z[1e-4], z
        # stream independence: straggler-only mask == gated mask OR'd back
        # through an independent gate draw (the gate stream is 0xDADA0)
        w0 = dataclasses.replace(w, algorithm=None)
        m0 = np.asarray(w0.compile(rounds, seed=29).grad_scale())
        rng = np.random.default_rng(np.random.SeedSequence([29, _ALGO_TAG]))
        gate = rng.uniform(size=(rounds, g.n)) < gr
        np.testing.assert_array_equal(
            np.asarray(sched.grad_scale()), m0 * gate)


# ===================================================== closed-form rates

def _zero_grad_fn(x, key, wid):
    g = jnp.zeros_like(x)
    return jnp.asarray(0.0, x.dtype), g


def _spread_state(sim, n, d, seed):
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(100 + seed))
    x = jax.random.normal(jax.random.PRNGKey(200 + seed), (n, d))
    return st._replace(x=x, x_tilde=jnp.array(x))


def _consensus_slope(curve, floor=1e-9):
    """Least-squares slope of log(consensus) over the prefix where the
    curve is still far above the float32 noise floor."""
    c = np.asarray(curve, np.float64)
    keep = c > floor
    last = int(np.argmin(keep)) if not keep.all() else len(c)
    last = max(last, 4)
    y = np.log(c[:last])
    t = np.arange(last, dtype=np.float64)
    return float(np.polyfit(t, y, 1)[0])


class TestClosedFormRates:
    """Satellite: the zoo against theory (Prop 3.6 and the DADAO/adpsgd
    reductions)."""

    def test_adpsgd_is_bitwise_eta0_baseline(self):
        """``Algorithm("adpsgd")`` lowers to bitwise ``baseline_params``
        (eta=0, alpha=alpha_tilde=1/2, chi=chi1) — and so does the
        ``Algorithm("a2cid2", accelerated=False)`` counterfactual arm."""
        g = ring_graph(8)
        base = baseline_params(g.chi1())
        assert Algorithm("adpsgd").params_for(g) == base
        assert Algorithm("a2cid2", accelerated=False).params_for(g) == base
        assert base.eta == 0.0 and base.alpha == 0.5
        assert Algorithm("adpsgd", accelerated=True).params_for(g) == \
            params_from_graph(g, True)

    def test_adpsgd_replay_bitwise_equals_explicit_baseline(self):
        """An ``Algorithm("adpsgd")`` world replayed through
        ``run_worlds(worlds=...)`` is bit-for-bit the legacy replay with
        explicit ``baseline_params`` — same schedule, same dynamics."""
        g = ring_graph(8)
        n, d, rounds = 8, 12, 10
        sim = Simulator(_zero_grad_fn, params_from_graph(g, True), gamma=0.0)
        w = World(topology=g, algorithm=Algorithm("adpsgd"))
        sched = w.compile(rounds, seed=1)
        st = _spread_state(sim, n, d, 0)
        fin, tr = sim.run_worlds([st], [sched], worlds=[w])
        legacy = dataclasses.replace(sim, params=baseline_params(g.chi1()))
        sched0 = dataclasses.replace(w, algorithm=None).compile(rounds, seed=1)
        np.testing.assert_array_equal(np.asarray(sched.partners),
                                      np.asarray(sched0.partners))
        fin0, tr0 = legacy.run_schedule(st, sched0)
        np.testing.assert_array_equal(np.asarray(fin.x[0]),
                                      np.asarray(fin0.x))
        np.testing.assert_array_equal(np.asarray(tr.consensus[0]),
                                      np.asarray(tr0.consensus))

    def test_dadao_coupled_settings_are_bitwise_noops(self):
        """DADAO with grad_rate=1 and gossip_rate None (or == the world's
        comms_per_grad) compiles the bitwise-identical schedule: coupled
        settings touch neither the main rng stream nor the masks."""
        g = ring_graph(8)
        w0 = World(topology=g, comms_per_grad=1.5)
        for algo in (Algorithm("dadao"),
                     Algorithm("dadao", gossip_rate=1.5)):
            w = dataclasses.replace(w0, algorithm=algo)
            s0 = w0.compile(12, seed=5)
            s1 = w.compile(12, seed=5)
            np.testing.assert_array_equal(np.asarray(s0.partners),
                                          np.asarray(s1.partners))
            np.testing.assert_array_equal(np.asarray(s0.event_times),
                                          np.asarray(s1.event_times))
            np.testing.assert_array_equal(np.asarray(s0.event_mask),
                                          np.asarray(s1.event_mask))
            np.testing.assert_array_equal(s0.grad_scale(), s1.grad_scale())

    def test_dadao_decoupled_rates_change_the_right_axis(self):
        """Decoupling moves exactly one axis per knob: gossip_rate scales
        the comm event intensity (CLT band on total events), grad_rate
        thins ONLY the gradient masks (comm stream bitwise unchanged).

        Variance source: Poisson(rounds * rate) total event count, seed 7.
        Audit (20 seeds): gossip-total worst |z| 1.45, thinned-fraction
        worst |z| 2.01 vs band 3.09.
        """
        g = ring_graph(8)
        rounds = 1000
        w_fast = World(topology=g,
                       algorithm=Algorithm("dadao", gossip_rate=2.0))
        s_fast = w_fast.compile(rounds, seed=7)
        tot = int(np.asarray(s_fast.event_mask).sum())
        z = (tot - rounds * 2.0) / np.sqrt(rounds * 2.0)
        assert abs(z) < _Z[1e-3], (tot, z)
        w_thin = World(topology=g,
                       algorithm=Algorithm("dadao", grad_rate=0.5))
        s_thin = w_thin.compile(rounds, seed=7)
        s_ref = World(topology=g).compile(rounds, seed=7)
        np.testing.assert_array_equal(np.asarray(s_thin.partners),
                                      np.asarray(s_ref.partners))
        np.testing.assert_array_equal(np.asarray(s_thin.event_times),
                                      np.asarray(s_ref.event_times))
        frac = float(np.asarray(s_thin.grad_scale()).mean())
        zf = (frac - 0.5) / np.sqrt(0.25 / (rounds * g.n))
        assert abs(zf) < _Z[1e-3], frac

    def test_ring_consensus_rate_ratio_tracks_chi(self):
        """Prop 3.6 on the ring: pure-gossip (gamma=0) consensus decays at
        rate ~ 1/chi1 for the baseline and ~ 1/sqrt(chi1 chi2) accelerated,
        so the slope ratio of log-consensus tracks sqrt(chi1/chi2)
        (~3.74 on the n=16 ring).  Both arms replay the SAME schedules in
        ONE batched dispatch (worlds=...), 4 seeds.

        comms_per_grad MUST be 1.0 here: eta is tuned for the unit-rate
        event model the chi's are computed from.  Scaling gossip intensity
        without rescaling eta breaks the tuning — at cpg=2 the baseline
        rate doubles but the accelerated rate only grows ~sqrt(2), and the
        measured ratio drops to ~2.3 (observed while calibrating).

        Variance source: schedule realization (matching sequence + event
        times) — gradient noise is off; the baseline per-event slope
        matches 1/(2 chi1) almost exactly, the accelerated slope carries
        the seed variance.  Tolerance: the prediction is an asymptotic
        bound (the measured ratio sits systematically ~10-15% BELOW it),
        so the band is max(4 * seed-std, 40% systematic).  The systematic
        floor matters: a low-variance seed block can't shrink the band
        below the known asymptotic slack.  Audit (20 disjoint 4-seed
        blocks): block means in [2.54, 3.41] (prediction 3.743), stds in
        [0.10, 0.71], worst deviation/band 0.81 with the 40% floor (1.28
        with a 25% floor — that floor FAILS).  The null hypothesis (ratio
        1.0, no acceleration) sits 1.8 bands away — still rejected.
        """
        g = ring_graph(16)
        n, d, rounds = 16, 8, 300
        pred = float(np.sqrt(g.chi1() / g.chi2()))
        sim = Simulator(_zero_grad_fn, params_from_graph(g, True), gamma=0.0)
        seeds = [0, 1, 2, 3]
        w_acc = World(topology=g, comms_per_grad=1.0,
                      algorithm=Algorithm("a2cid2"))
        w_bas = World(topology=g, comms_per_grad=1.0,
                      algorithm=Algorithm("adpsgd"))
        worlds, scheds, states = [], [], []
        for s in seeds:
            sched = w_acc.compile(rounds, seed=s)   # shared by both arms
            for w in (w_acc, w_bas):
                worlds.append(w)
                scheds.append(sched)
                states.append(_spread_state(sim, n, d, s))
        fin, tr = sim.run_worlds(states, scheds, worlds=worlds)
        cons = np.asarray(tr.consensus)            # (2*seeds, rounds)
        ratios = []
        for k in range(len(seeds)):
            sl_acc = _consensus_slope(cons[2 * k])
            sl_bas = _consensus_slope(cons[2 * k + 1])
            ratios.append(sl_acc / sl_bas)
        ratios = np.asarray(ratios)
        band = max(4.0 * float(ratios.std()), 0.40 * pred)
        assert abs(float(ratios.mean()) - pred) < band, (ratios, pred, band)


# ====================================== equivalence + serialization

ALGOS = [Algorithm("a2cid2"), Algorithm("adpsgd"),
         Algorithm("dadao", grad_rate=0.7, gossip_rate=2.0)]


def _noise_grad_fn(x, key, wid):
    g = 0.1 * jax.random.normal(key, x.shape)
    return jnp.sum(g * x), g


class TestEquivalenceSerialization:
    """Satellite: algorithm worlds replay identically on every path and
    survive the JSON wire."""

    @pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
    @pytest.mark.parametrize("algo", ALGOS, ids=lambda a: a.kind)
    def test_engine_matches_reference_on_algorithm_worlds(self, backend,
                                                          algo):
        """FlatGossipEngine == per-event reference on each zoo arm, both
        kernel backends, hostile channel + defense composed in (float
        tolerance 1e-5: same numerics, different reduction order)."""
        g = ring_graph(8)
        n, d = 8, 12
        rounds = 6 if backend == "pallas_interpret" else 15
        w = World(topology=g, algorithm=algo,
                  channel=ChannelModel(
                      adversary=ByzantineEdges(g.edges[:1], "sign_flip")),
                  defense=AdaptiveDefense())
        sim = Simulator(_noise_grad_fn, w.algorithm_params(), gamma=0.05,
                        backend=backend, robust_clip=5.0)
        sched = w.compile(rounds, seed=2)
        st = _spread_state(sim, n, d, 0)
        fin_r, tr_r = sim.run_worlds([st], [sched], worlds=[w], engine=False)
        fin_e, tr_e = sim.run_worlds([st], [sched], worlds=[w], engine=True)
        np.testing.assert_allclose(fin_e.x, fin_r.x, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(fin_e.x_tilde, fin_r.x_tilde,
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(tr_e.consensus, tr_r.consensus,
                                   atol=1e-5, rtol=1e-4)

    @pytest.mark.parametrize("algo", ALGOS + [Algorithm("adpsgd",
                                                        accelerated=True)],
                             ids=lambda a: f"{a.kind}-{a.accelerated}")
    def test_algorithm_json_roundtrip(self, algo):
        """Algorithm -> JSON -> Algorithm is identity; a World carrying it
        round-trips and recompiles the bitwise-identical schedule."""
        back = Algorithm.from_json(algo.to_json())
        assert back == algo
        w = World(topology=ring_graph(8), algorithm=algo)
        w2 = World.from_json(w.to_json())
        assert w2.algorithm == algo
        s1, s2 = w.compile(8, seed=4), w2.compile(8, seed=4)
        np.testing.assert_array_equal(np.asarray(s1.partners),
                                      np.asarray(s2.partners))
        np.testing.assert_array_equal(np.asarray(s1.event_times),
                                      np.asarray(s2.event_times))
        np.testing.assert_array_equal(s1.grad_scale(), s2.grad_scale())
        # the wire format is plain JSON with the documented keys
        d = json.loads(algo.to_json())
        assert set(d) == {"kind", "accelerated", "grad_rate", "gossip_rate"}

    def test_world_algorithm_none_is_bitwise_legacy(self):
        """``World(algorithm=None)`` compiles and replays bit-for-bit the
        pre-zoo schedule: the zoo axis is strictly additive."""
        g = ring_graph(8)
        w = World(topology=g, comms_per_grad=1.5)
        sched = w.compile(10, seed=9)
        from repro.core import make_schedule
        legacy = make_schedule(g, 10, comms_per_grad=1.5, seed=9)
        np.testing.assert_array_equal(np.asarray(sched.partners),
                                      np.asarray(legacy.partners))
        np.testing.assert_array_equal(np.asarray(sched.event_times),
                                      np.asarray(legacy.event_times))
        np.testing.assert_array_equal(np.asarray(sched.grad_times),
                                      np.asarray(legacy.grad_times))
        assert "algorithm" in w.to_dict() and w.to_dict()["algorithm"] is None
        assert World.from_json(w.to_json()).algorithm is None

    def test_mixed_algorithm_sweep_single_trace(self):
        """A mixed-algorithm WorldSweep (None + all three kinds) replays as
        ONE batched dispatch: exactly one new jit trace across both the
        engine and reference caches (the test_batched_replay idiom)."""
        g = ring_graph(8)
        n, d, rounds = 8, 10, 6
        sweep = WorldSweep.over(
            World(topology=g), seeds=(0,),
            algorithm=[None] + list(ALGOS))
        scheds = sweep.compile(rounds)
        worlds = [w for w, _ in sweep.points()]
        sim = Simulator(_noise_grad_fn, params_from_graph(g, True),
                        gamma=0.05)
        states = [_spread_state(sim, n, d, i) for i in range(len(scheds))]
        before = (Simulator._run_worlds_jit._cache_size()
                  + Simulator._run_worlds_reference_jit._cache_size())
        fin, tr = sim.run_worlds(states, scheds, worlds=worlds)
        after = (Simulator._run_worlds_jit._cache_size()
                 + Simulator._run_worlds_reference_jit._cache_size())
        assert after - before == 1, (before, after)
        assert tr.consensus.shape == (len(scheds), rounds)
        # the sweep grid serializes with the algorithm column intact
        got = [w.algorithm for w in worlds]
        assert got[0] is None and [a.kind for a in got[1:]] == \
            [a.kind for a in ALGOS]
