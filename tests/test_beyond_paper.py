"""Beyond-paper framework features: heterogeneous edge rates, the TPU-native
torus topology, and comm-rate scaling — exercising machinery the paper's
theory covers (per-edge lambda_ij in Def 3.1) but its experiments do not."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Graph, Simulator, build_graph, make_schedule,
                        params_from_graph, ring_graph)


def _grad_fn(b, noise=0.05):
    def grad_fn(x, key, wid):
        g = (x - b[wid]) + noise * jax.random.normal(key, x.shape)
        return 0.5 * jnp.sum((x - b[wid]) ** 2), g
    return grad_fn


def _run_consensus(g, accel, rounds=250, d=32, rate=1.0):
    b = jax.random.normal(jax.random.PRNGKey(1), (g.n, d))
    sim = Simulator(_grad_fn(b), params_from_graph(g, accelerated=accel),
                    gamma=0.05)
    st = sim.init(jnp.zeros(d), g.n, jax.random.PRNGKey(2))
    sched = make_schedule(g, rounds=rounds, comms_per_grad=rate, seed=0)
    _, trace = sim.run_schedule(st, sched)
    return float(jnp.mean(trace.consensus[-40:]))


def test_heterogeneous_edge_rates_chi():
    """Def 3.1 supports per-edge rates: slowing half the ring's links raises
    chi1 (and the theory's acceleration parameters adapt)."""
    n = 8
    uniform = ring_graph(n)
    edges = uniform.edges
    rates = tuple(0.25 if i % 2 == 0 else 1.0 for i in range(len(edges)))
    skewed = Graph(n, edges, rates, name="ring-skewed")
    assert skewed.chi1() > uniform.chi1()
    p = params_from_graph(skewed, accelerated=True)
    assert p.eta > 0 and p.alpha_tilde >= 0.5


def test_heterogeneous_rates_acid_still_helps():
    n = 16
    edges = ring_graph(n).edges
    rates = tuple(0.3 if i % 2 == 0 else 1.0 for i in range(len(edges)))
    g = Graph(n, edges, rates, name="ring-skewed")
    base = _run_consensus(g, accel=False)
    acid = _run_consensus(g, accel=True)
    assert acid < base


def test_torus_topology():
    """2D torus = the native TPU ICI topology; much better connected than a
    ring at equal degree budget, and chi2 ~ chi1 (less A2CiD2 headroom —
    which the framework quantifies up front via params_from_graph)."""
    g = build_graph("torus", 16)
    r = build_graph("ring", 16)
    assert g.is_connected()
    assert g.chi1() < r.chi1()
    base = _run_consensus(g, accel=False, rounds=150)
    ring_base = _run_consensus(r, accel=False, rounds=150)
    assert base < ring_base  # better mixing at the same comm budget


def test_comm_rate_scaling_monotone():
    """Fig 3b: consensus improves monotonically with comms/grad."""
    g = ring_graph(16)
    c1 = _run_consensus(g, accel=False, rate=0.5)
    c2 = _run_consensus(g, accel=False, rate=1.0)
    c3 = _run_consensus(g, accel=False, rate=2.0)
    assert c3 < c2 < c1
