"""Optimizers, schedules, data pipelines, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, restore, save, save_pytree
from repro.data import LMTaskStream, SyntheticCIFAR, WorkerStream
from repro.optim import (adamw, clip_by_global_norm, cosine,
                         goyal_warmup_step_decay, sgd)


# --------------------------------------------------------------- optimizers

def test_sgd_momentum_quadratic():
    # heavy-ball spectral radius at (m=0.9, lr=0.1, lambda=1) is ~0.949:
    # need ~250 steps for 1e-3 accuracy
    opt = sgd(momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(p)
    for _ in range(250):
        g = {"w": p["w"]}  # grad of ||w||^2/2
        p, state = opt.update(g, state, p, jnp.float32(0.1))
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-3


def test_sgd_weight_decay_skips_norm_leaves():
    opt = sgd(momentum=0.0, weight_decay=0.5)
    p = {"w": jnp.ones(3), "norm1": jnp.ones(3)}
    g = {"w": jnp.zeros(3), "norm1": jnp.zeros(3)}
    state = opt.init(p)
    p2, _ = opt.update(g, state, p, jnp.float32(0.1))
    assert float(p2["w"][0]) < 1.0       # decayed
    assert float(p2["norm1"][0]) == 1.0  # exempt


def test_adamw_converges():
    opt = adamw(weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(p)
    for _ in range(300):
        g = {"w": p["w"]}
        p, state = opt.update(g, state, p, jnp.float32(0.05))
    assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2


@pytest.mark.parametrize("scale,max_norm", [
    (0.1, 10.0), (1.0, 1.0), (100.0, 0.1),
])
def test_clip_by_global_norm(scale, max_norm):
    g = {"a": scale * jnp.ones(16), "b": -scale * jnp.ones(4)}
    clipped = clip_by_global_norm(g, max_norm)
    norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                              for x in jax.tree.leaves(clipped))))
    assert norm <= max_norm * 1.01
    if scale * np.sqrt(20) <= max_norm:  # no-op when under the bound
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)


def test_goyal_schedule_shape():
    """Warmup to base*n, then /10 at each milestone (paper Sec 4.1)."""
    sched = goyal_warmup_step_decay(0.1, n_workers=8, steps_per_epoch=10,
                                    milestones=(30, 60, 80), warmup_epochs=5)
    assert float(sched(jnp.int32(0))) == pytest.approx(0.1, rel=0.05)
    assert float(sched(jnp.int32(50))) == pytest.approx(0.8, rel=0.01)
    assert float(sched(jnp.int32(400))) == pytest.approx(0.08, rel=0.01)
    assert float(sched(jnp.int32(700))) == pytest.approx(0.008, rel=0.01)
    assert float(sched(jnp.int32(850))) == pytest.approx(0.0008, rel=0.01)


def test_cosine_schedule():
    sched = cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert float(sched(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sched(jnp.int32(100))) == pytest.approx(0.1, rel=0.01)


# --------------------------------------------------------------------- data

def test_lm_stream_deterministic_and_learnable():
    s = LMTaskStream(vocab_size=64, seq_len=32, batch_size=4)
    b1 = s.sample(jax.random.PRNGKey(0))
    b2 = s.sample(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (4, 32)
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["labels"][:, :-1])
    bayes = s.bayes_ce()
    assert 0.0 < bayes < np.log(64)  # strictly below uniform entropy


def test_worker_streams_differ():
    ws = WorkerStream(base_seed=0)
    k0 = ws.key(0, 5)
    k1 = ws.key(1, 5)
    assert not np.array_equal(jax.device_get(k0), jax.device_get(k1))


def test_synthetic_cifar_shapes():
    s = SyntheticCIFAR(batch_size=8)
    b = s.sample(jax.random.PRNGKey(0))
    assert b["images"].shape == (8, 32, 32, 3)
    assert b["labels"].shape == (8,)
    assert int(b["labels"].max()) < 10


# --------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "d": [jnp.int32(7)]}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_retention_and_restore(tmp_path):
    tree = {"w": jnp.zeros(4)}
    for step in (1, 2, 3, 4, 5):
        save(str(tmp_path), step, {"w": jnp.full(4, float(step))}, keep=3)
    dirs = sorted(os.listdir(tmp_path))
    assert len(dirs) == 3
    step, out = restore(str(tmp_path), tree)
    assert step == 5
    np.testing.assert_allclose(out["w"], 5.0)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "x.msgpack")
    save_pytree(path, {"w": jnp.zeros(4)})
    with pytest.raises(ValueError):
        load_pytree(path, {"w": jnp.zeros(5)})
