"""Heterogeneous-world scenario engine: reduction-to-homogeneous equivalence,
straggler clocks, churn masks, and time-varying topologies (see DESIGN.md §8).

The contract under test: every heterogeneous axis is pure schedule data, so
(a) uniform rates reproduce the homogeneous schedule bit-for-bit, (b) a
single-phase TopologySchedule is indistinguishable from the static-Graph
path, and (c) the flat-buffer engine and the per-event reference replay any
heterogeneous schedule identically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Simulator, TopologyPhase, TopologySchedule,
                        build_graph, coalesce_schedule, concat_schedules,
                        make_schedule, make_topology_schedule,
                        params_from_graph, phase_banks, ring_graph)

SCHED_FIELDS = ("partners", "event_times", "event_mask", "grad_times")


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        # cast keeps the state dtype stable when JAX_ENABLE_X64 makes the
        # random targets f64 (this suite runs in the x64 CI job)
        g = (x - b[wid]).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


def _sim(b, g, *, accelerated=True, backend="ref", gamma=0.05):
    return Simulator(_quad_grad_fn(b), params_from_graph(g, accelerated),
                     gamma=gamma, backend=backend)


# ------------------------------------------------- reduction to homogeneous

def test_uniform_rates_reduce_bit_for_bit():
    """grad_rates=1 and edge_rates=graph.rates through the new API must
    reproduce the homogeneous schedule exactly (heterogeneity draws come
    from a separate rng stream, so the main stream is untouched)."""
    g = ring_graph(16)
    hom = make_schedule(g, rounds=40, comms_per_grad=1.5, seed=9)
    het = make_schedule(g, rounds=40, comms_per_grad=1.5, seed=9,
                        grad_rates=np.ones(16),
                        edge_rates=np.asarray(g.rates))
    for f in SCHED_FIELDS:
        np.testing.assert_array_equal(getattr(hom, f), getattr(het, f))
    assert hom.grad_mask is None and hom.alive is None
    assert het.grad_mask is not None and het.grad_mask.all()
    np.testing.assert_array_equal(hom.grad_scale(), het.grad_scale())


def test_single_phase_topology_matches_static_schedule():
    g = ring_graph(16)
    hom = make_schedule(g, rounds=30, comms_per_grad=1.0, seed=4)
    ts = make_topology_schedule(TopologySchedule((TopologyPhase(g, 30),)),
                                comms_per_grad=1.0, seed=4)
    for f in SCHED_FIELDS:
        np.testing.assert_array_equal(getattr(hom, f), getattr(ts, f))
    assert ts.alive is None


@pytest.mark.parametrize("engine", [True, False])
def test_single_phase_topology_matches_static_run(engine):
    """Same dynamics through Simulator.run_schedule on both backends."""
    n, d = 8, 12
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sim = _sim(b, g)
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(2))
    hom = make_schedule(g, rounds=15, comms_per_grad=1.0, seed=4)
    ts = make_topology_schedule(TopologySchedule((TopologyPhase(g, 15),)),
                                comms_per_grad=1.0, seed=4)
    fin_h, tr_h = sim.run_schedule(st, hom, engine=engine)
    fin_t, tr_t = sim.run_schedule(st, ts, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin_h.x), np.asarray(fin_t.x))
    np.testing.assert_array_equal(np.asarray(tr_h.consensus),
                                  np.asarray(tr_t.consensus))


def test_uniform_grad_rates_same_dynamics_through_engine():
    """StackedGossipTrainer with grad_rates=1 == grad_rates=None, same key."""
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.optim import sgd

    g = ring_graph(4)

    def grad_fn(p, batch):
        return (0.5 * jnp.sum((p["w"] - batch) ** 2), None), \
            {"w": p["w"] - batch}

    def run(grad_rates):
        tr = StackedGossipTrainer(grad_fn,
                                  sgd(momentum=0.0, weight_decay=0.0), g,
                                  params_from_graph(g, True),
                                  comms_per_step=2, backend="ref",
                                  grad_rates=grad_rates)
        state = tr.init({"w": jnp.zeros((3,), jnp.float32)},
                        jax.random.PRNGKey(0))
        batch = jnp.ones((4, 3), jnp.float32)
        state, m = jax.jit(tr.make_step())(state, batch)
        return np.asarray(state.x["w"]), float(m["loss"])

    x_none, l_none = run(None)
    x_ones, l_ones = run((1.0, 1.0, 1.0, 1.0))
    np.testing.assert_array_equal(x_none, x_ones)
    assert l_none == l_ones


# ----------------------------------------------- heterogeneous equivalence

@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_engine_matches_reference_on_hetero_world(backend):
    """The hard equivalence: straggler thinning + per-edge rates + phase
    switch + churn, replayed by the fused engine and the per-event
    reference, must agree on params, momentum, clocks, and traces."""
    n, d = 8, 12
    rounds = 6 if backend == "pallas_interpret" else 12
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    ring = ring_graph(n)
    active = np.ones(n, bool)
    active[2] = False
    ts = TopologySchedule((
        TopologyPhase(ring, rounds),
        TopologyPhase(build_graph("exponential", n), rounds, tuple(active)),
    ))
    sched = make_topology_schedule(ts, comms_per_grad=1.3, seed=5,
                                   grad_rates=np.linspace(0.3, 1.0, n),
                                   per_edge=True)
    sim = _sim(b, ring, backend=backend)
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(2))
    fin_r, tr_r = sim.run_schedule(st, sched, engine=False)
    fin_e, tr_e = sim.run_schedule(st, sched, engine=True)
    np.testing.assert_allclose(fin_e.x, fin_r.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_e.x_tilde, fin_r.x_tilde,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_e.t_last, fin_r.t_last, atol=1e-6)
    np.testing.assert_allclose(tr_e.loss, tr_r.loss, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(tr_e.consensus, tr_r.consensus,
                               atol=1e-5, rtol=1e-4)


# -------------------------------------------------- straggler + churn laws

def test_zero_rate_straggler_only_moves_by_gossip():
    """A grad_rate-0 worker never applies a gradient: with communication
    also disabled for it (churned), its row must be exactly frozen; with
    gossip on, it still moves (partners pull it) — the two differ."""
    n, d = 6, 5
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    rates = np.ones(n)
    rates[4] = 0.0
    sim = _sim(b, g)
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(2))
    sched = make_schedule(g, rounds=25, comms_per_grad=1.0, seed=0,
                          grad_rates=rates)
    assert not sched.grad_mask[:, 4].any()
    fin, _ = sim.run_schedule(st, sched)
    # gossip still moves the straggler toward its neighbors' params
    assert float(jnp.sum(jnp.abs(fin.x[4]))) > 0.0

    active = np.ones(n, bool)
    active[4] = False
    ts = TopologySchedule((TopologyPhase(g, 25, tuple(active)),))
    churned = make_topology_schedule(ts, comms_per_grad=1.0, seed=0)
    fin_c, _ = sim.run_schedule(st, churned)
    np.testing.assert_array_equal(np.asarray(fin_c.x)[4],
                                  np.asarray(st.x)[4])
    np.testing.assert_array_equal(np.asarray(fin_c.x_tilde)[4],
                                  np.asarray(st.x_tilde)[4])
    np.testing.assert_array_equal(np.asarray(fin_c.t_last)[4], 0.0)


@pytest.mark.parametrize("engine", [True, False])
def test_churned_phase_rows_are_fixed_points(engine):
    """During a churn phase the detached worker's row must not change; after
    rejoin it must move again.  Holds on both replay paths."""
    n, d = 8, 6
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    active = np.ones(n, bool)
    active[5] = False
    ts = TopologySchedule((
        TopologyPhase(g, 10),
        TopologyPhase(g, 10, tuple(active)),
        TopologyPhase(g, 10),
    ))
    sim = _sim(b, g)
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(2))
    # replay phase 1 alone, then the full three phases: worker 5's row at
    # the end of phase 2 must equal its row at the end of phase 1
    p1 = make_topology_schedule(TopologySchedule(ts.phases[:1]), seed=7)
    p12 = concat_schedules([
        make_schedule(g, 10, seed=7),
        make_schedule(g, 10, seed=8, t_offset=10.0, active=active)])
    fin1, _ = sim.run_schedule(st, p1, engine=engine)
    fin2, _ = sim.run_schedule(st, p12, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin1.x)[5],
                                  np.asarray(fin2.x)[5])
    np.testing.assert_array_equal(np.asarray(fin1.t_last)[5],
                                  np.asarray(fin2.t_last)[5])
    # full schedule: rejoined worker moves again in phase 3
    full = make_topology_schedule(ts, seed=7)
    fin3, _ = sim.run_schedule(st, full, engine=engine)
    assert not np.array_equal(np.asarray(fin3.x)[5], np.asarray(fin2.x)[5])


def test_straggler_thinning_statistics():
    """Thinned tick counts track the requested per-worker rates."""
    n = 8
    g = ring_graph(n)
    rates = np.linspace(0.1, 1.0, n)
    sched = make_schedule(g, rounds=2000, comms_per_grad=0.5, seed=0,
                          grad_rates=rates)
    freq = sched.grad_mask.mean(axis=0)
    np.testing.assert_allclose(freq, rates, atol=0.05)


def test_edge_rates_compose_with_churn():
    """edge_rates align with the FULL graph's edges; churn filters both
    together (rate override must apply before the subgraph)."""
    n = 8
    g = ring_graph(n)
    rates = np.linspace(0.2, 1.0, g.num_edges)
    active = np.ones(n, bool)
    active[0] = False
    sched = make_schedule(g, rounds=30, comms_per_grad=1.0, seed=0,
                          edge_rates=rates, active=active)
    # the detached worker never communicates, hot surviving edges still do
    assert not any(sched.partners[r, e, 0] != 0
                   for r in range(sched.rounds)
                   for e in range(sched.partners.shape[1]))
    assert sched.num_comm_events() > 0


def test_fully_churned_phase_freezes_everything():
    """An all-dead phase yields an edgeless graph (sample_matching must not
    crash) and freezes every row and clock on both backends."""
    n, d = 6, 4
    g = ring_graph(n)
    ts = TopologySchedule((TopologyPhase(g, 4, tuple([False] * n)),))
    sched = make_topology_schedule(ts, seed=0)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    sim = _sim(b, g)
    st = sim.init(jnp.ones(d, jnp.float32), n, jax.random.PRNGKey(2))
    for engine in (True, False):
        fin, _ = sim.run_schedule(st, sched, engine=engine)
        np.testing.assert_array_equal(np.asarray(fin.x), np.asarray(st.x))
        np.testing.assert_array_equal(np.asarray(fin.t_last),
                                      np.asarray(st.t_last))


# ------------------------------------------------------- topology plumbing

def test_topology_schedule_validation_and_lookup():
    g = ring_graph(8)
    ts = TopologySchedule((TopologyPhase(g, 5), TopologyPhase(g, 7)))
    assert ts.total_rounds == 12 and ts.n == 8
    assert [ts.phase_at(r) for r in (0, 4, 5, 11)] == [0, 0, 1, 1]
    with pytest.raises(ValueError):
        TopologySchedule(())
    with pytest.raises(ValueError):
        TopologySchedule((TopologyPhase(g, 5),
                          TopologyPhase(ring_graph(4), 5)))
    with pytest.raises(ValueError):
        TopologyPhase(g, 0)


def test_phase_banks_rebuild_per_phase():
    """Each phase's matching bank covers exactly its effective edge set —
    churned workers are identity in every matching of their phase."""
    g = ring_graph(8)
    active = np.ones(8, bool)
    active[0] = False
    ts = TopologySchedule((
        TopologyPhase(g, 5),
        TopologyPhase(build_graph("exponential", 8), 5, tuple(active)),
    ))
    banks = phase_banks(ts)
    assert len(banks) == 2
    for (bank, probs), ph in zip(banks, ts.phases):
        covered = set()
        for k in range(bank.shape[0]):
            assert np.all(bank[k][bank[k]] == np.arange(8))  # involutions
            for i, j in enumerate(bank[k]):
                if int(j) != i:
                    covered.add((min(i, int(j)), max(i, int(j))))
        assert covered == {tuple(sorted(e))
                           for e in ph.effective_graph().edges}
        np.testing.assert_allclose(probs.sum(), 1.0)
    # churned worker 0 is idle in every matching of phase 2
    assert np.all(banks[1][0][:, 0] == 0)


def test_multi_phase_coalesce_and_comm_counts():
    """Coalescing a concatenated multi-phase schedule preserves the per-
    worker event lists exactly (same invariant as the single-phase suite)."""
    n = 8
    active = np.ones(n, bool)
    active[1] = False
    ts = TopologySchedule((
        TopologyPhase(ring_graph(n), 12),
        TopologyPhase(build_graph("complete", n), 12, tuple(active)),
    ))
    sched = make_topology_schedule(ts, comms_per_grad=2.0, seed=3)
    cs = coalesce_schedule(sched)
    for w in range(n):
        raw = [(float(sched.event_times[r, e]), int(sched.partners[r, e, w]))
               for r in range(sched.rounds)
               for e in range(sched.partners.shape[1])
               if sched.event_mask[r, e] and sched.partners[r, e, w] != w]
        coal = [(float(cs.wtimes[r, b, w]), int(cs.partners[r, b, w]))
                for r in range(cs.rounds)
                for b in range(cs.partners.shape[1])
                if cs.batch_active[r, b] and cs.partners[r, b, w] != w]
        assert raw == coal
    # the churned worker has no events at all in the second phase
    assert not any(sched.partners[r, e, 1] != 1
                   for r in range(12, 24)
                   for e in range(sched.partners.shape[1]))
