"""End-to-end behaviour tests: decentralized LM/ResNet training improves the
loss, A2CiD2 integrates with real models, and the paper's orderings hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Simulator, build_graph, make_schedule,
                        params_from_graph)
from repro.data import LMTaskStream, SyntheticCIFAR
from repro.models import Model
from repro.models.resnet import (apply_resnet, init_resnet, resnet8_cifar,
                                 resnet_loss)


def _lm_grad_fn(model, stream):
    def grad_fn(params, key, wid):
        batch = stream.sample(jax.random.fold_in(key, wid))

        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss

        return jax.value_and_grad(loss_fn)(params)
    return grad_fn


def test_decentralized_lm_training_learns():
    """8 async workers, ring graph, A2CiD2: loss moves toward the stream's
    Bayes CE (the task is a Markov chain with known entropy rate)."""
    cfg = get_config("nano-lm", reduced=True)
    model = Model(cfg)
    stream = LMTaskStream(vocab_size=cfg.vocab_size, seq_len=32,
                          batch_size=4, concentration=0.15)
    g = build_graph("ring", 8)
    sim = Simulator(_lm_grad_fn(model, stream),
                    params_from_graph(g, accelerated=True), gamma=0.05)
    st = sim.init(model.init(jax.random.PRNGKey(0)), 8, jax.random.PRNGKey(1))
    sched = make_schedule(g, rounds=40, comms_per_grad=1.0, seed=0)
    _, trace = sim.run_schedule(st, sched)
    first, last = float(trace.loss[0]), float(jnp.mean(trace.loss[-5:]))
    bayes = stream.bayes_ce()
    assert last < first - 0.5
    assert last > bayes - 0.05  # can't beat the entropy rate


def test_decentralized_resnet_cifar_learns():
    """The paper's own workload family: ResNet on (synthetic) CIFAR with
    asynchronous gossip workers."""
    cfg = resnet8_cifar()
    stream = SyntheticCIFAR(batch_size=16, noise=0.5)

    def grad_fn(params, key, wid):
        batch = stream.sample(jax.random.fold_in(key, wid))

        def loss_fn(p):
            loss, _ = resnet_loss(p, cfg, batch)
            return loss

        return jax.value_and_grad(loss_fn)(params)

    g = build_graph("ring", 4)
    sim = Simulator(grad_fn, params_from_graph(g, accelerated=True),
                    gamma=0.08)
    st = sim.init(init_resnet(jax.random.PRNGKey(0), cfg), 4,
                  jax.random.PRNGKey(1))
    sched = make_schedule(g, rounds=45, comms_per_grad=1.0, seed=0)
    fin, trace = sim.run_schedule(st, sched)
    assert float(jnp.mean(trace.loss[-5:])) < float(trace.loss[0]) - 0.3
    # consensus model classifies synthetic CIFAR above chance (0.1)
    from repro.core import worker_mean
    params = worker_mean(fin.x)
    batch = stream.sample(jax.random.PRNGKey(7))
    _, metrics = resnet_loss(params, cfg, batch)
    assert float(metrics["acc"]) >= 0.25


def test_graph_topology_ordering_of_consensus():
    """Paper Tab 4 ordering: at equal comm rate, consensus degrades from
    complete -> exponential -> ring."""
    n, d = 16, 64
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))

    def grad_fn(x, key, wid):
        return 0.0, (x - b[wid]) + 0.05 * jax.random.normal(key, x.shape)

    out = {}
    for name in ("complete", "exponential", "ring"):
        g = build_graph(name, n)
        sim = Simulator(grad_fn, params_from_graph(g, accelerated=False),
                        gamma=0.05)
        st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
        sched = make_schedule(g, rounds=200, comms_per_grad=1.0, seed=0)
        _, trace = sim.run_schedule(st, sched)
        out[name] = float(jnp.mean(trace.consensus[-40:]))
    assert out["complete"] < out["exponential"] < out["ring"]


def test_doubling_comm_rate_comparable_to_acid():
    """Fig 1 analogue: baseline @ 2 comm/grad ~ A2CiD2 @ 1 comm/grad on the
    ring (within a factor of 2 of each other, both >> baseline @ 1)."""
    n, d = 16, 64
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))

    def grad_fn(x, key, wid):
        return 0.0, (x - b[wid]) + 0.05 * jax.random.normal(key, x.shape)

    g = build_graph("ring", n)

    def run(accel, rate, seed=0):
        sim = Simulator(grad_fn, params_from_graph(g, accelerated=accel),
                        gamma=0.05)
        st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
        sched = make_schedule(g, rounds=250, comms_per_grad=rate, seed=seed)
        _, trace = sim.run_schedule(st, sched)
        return float(jnp.mean(trace.consensus[-50:]))

    base1 = run(False, 1.0)
    base2 = run(False, 2.0)
    acid1 = run(True, 1.0)
    assert acid1 < 0.8 * base1          # acid helps at equal rate
    assert 0.4 < acid1 / base2 < 2.5    # ~ equivalent to doubling the rate
