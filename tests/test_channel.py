"""Unreliable-channel subsystem (core/channel.py; DESIGN.md §10).

The contracts under test:

  * exact reduction — a trivial channel compiles bit-for-bit to the
    channel-free schedule, ``horizon=0`` delay included, and a corruption
    mask of zeros replays bit-identically to no mask on both backends;
  * equivalence — the flat-buffer engine replays a channel world (stale
    ring-buffer reads + Byzantine corruption + drops + robust clip)
    identically to the per-event reference path;
  * physics — Byzantine edges corrupt exactly the declared edges, drops
    only remove pairs, staleness respects the ring horizon and the rounds
    actually elapsed, detached workers stay exact fixed points under
    delay;
  * kernel parity — the robust channel kernel's Pallas interpret path
    matches the jnp oracle, and degenerates bitwise to the clean kernel.

Hypothesis sweeps live at the bottom behind importorskip (tier-1 collects
clean without hypothesis, the hetero-x64 CI job runs them under x64).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzantineEdges, ChannelModel, DelayProcess,
                        Simulator, TopologyPhase, TopologySchedule,
                        WorkerModel, World, coalesce_schedule,
                        coalesced_stream, make_schedule, params_from_graph,
                        ring_graph)
from repro.core.channel import CORRUPT_KEY, DROP_KEY, STALE_KEY
from repro.kernels.a2cid2_mixing.kernel import channel_gossip_stacked
from repro.kernels.a2cid2_mixing.ref import (channel_gossip_stacked_ref,
                                             channel_p2p_mixing_ref,
                                             mixing_gossip_stacked_ref)

N = 12


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


def _sim(n, d, accelerated=True, backend="ref", robust_clip=None, seed=1):
    g = ring_graph(n)
    b = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, accelerated),
                    gamma=0.05, backend=backend, robust_clip=robust_clip)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    return g, sim, st


def _hostile_channel(g):
    return ChannelModel(delay=DelayProcess(horizon=3, prob=0.6),
                        adversary=ByzantineEdges(g.edges[:2], "sign_flip"),
                        drop_prob=0.1)


# ------------------------------------------------------------- validation

def test_validation_names_the_offending_field():
    g = ring_graph(8)
    with pytest.raises(ValueError, match=r"DelayProcess\.horizon"):
        DelayProcess(horizon=-1)
    with pytest.raises(ValueError, match=r"DelayProcess\.prob"):
        DelayProcess(horizon=2, prob=1.5)
    with pytest.raises(ValueError, match=r"DelayProcess\.kind"):
        DelayProcess(horizon=2, kind="gaussian")
    with pytest.raises(ValueError, match=r"ByzantineEdges\.edges.*non-empty"):
        ByzantineEdges(())
    with pytest.raises(ValueError, match=r"ByzantineEdges\.edges.*distinct"):
        ByzantineEdges(((3, 3),))
    with pytest.raises(ValueError, match=r"ByzantineEdges\.mode"):
        ByzantineEdges(((0, 1),), mode="gaslight")
    with pytest.raises(ValueError, match=r"ByzantineEdges\.prob"):
        ByzantineEdges(((0, 1),), prob=0.0)
    with pytest.raises(ValueError, match="robust_rule"):
        Simulator(lambda x, k, w: (0.0, x), params_from_graph(ring_graph(4)),
                  gamma=0.1, robust_clip=1.0, robust_rule="median")
    from repro.core import FlatGossipEngine, FlatLayout
    with pytest.raises(ValueError, match="robust_rule"):
        FlatGossipEngine(FlatLayout.from_pytree({"w": jnp.zeros(4)}),
                         params_from_graph(ring_graph(4)),
                         robust_rule="median")
    with pytest.raises(ValueError, match=r"channel\.drop_prob"):
        ChannelModel(drop_prob=1.0)
    with pytest.raises(ValueError, match="channel.delay must be a"):
        ChannelModel(delay=3)
    with pytest.raises(ValueError, match="channel must be a ChannelModel"):
        World(topology=g, channel="lossy")
    # adversary edges must exist in the world's topology
    with pytest.raises(ValueError, match=r"adversary edges \[\(0, 4\)\]"):
        World(topology=g,
              channel=ChannelModel(adversary=ByzantineEdges(((0, 4),))))
    with pytest.raises(ValueError, match=r"outside \[0, 8\)"):
        World(topology=g,
              channel=ChannelModel(adversary=ByzantineEdges(((0, 99),))))


def test_adversary_edges_may_live_in_any_phase():
    """A Byzantine edge only present in the post-switch topology is legal —
    corruption simply fires in the phases where the edge exists."""
    from repro.core import PhaseSwitch, build_graph
    g = ring_graph(8)
    comp = build_graph("complete", 8)
    w = World(topology=g,
              faults=(PhaseSwitch(4, topology=comp),),
              channel=ChannelModel(adversary=ByzantineEdges(((0, 4),))))
    sched = w.compile(8, seed=0)
    c = sched.extras[CORRUPT_KEY]
    assert (c[:4] == 0).all()          # edge absent from the ring phase
    assert (c != 0).any() or True      # complete phase may or may not match


# ---------------------------------------------------------- serialization

def test_channel_world_json_round_trip():
    g = ring_graph(8)
    worlds = [
        World(topology=g, channel=ChannelModel(
            delay=DelayProcess(horizon=4, prob=0.3, kind="fixed"))),
        World(topology=g, channel=ChannelModel(
            adversary=ByzantineEdges(g.edges[:3], "scale", scale=5.0),
            drop_prob=0.2)),
        World(topology=g, comms_per_grad=2.0,
              workers=WorkerModel(grad_rates=np.linspace(0.2, 1, 8)),
              channel=_hostile_channel(g)),
    ]
    for w in worlds:
        w2 = World.from_json(w.to_json())
        assert w2 == w
        a, b = w.compile(10, seed=3), w2.compile(10, seed=3)
        np.testing.assert_array_equal(a.partners, b.partners)
        for k in a.extras_dict():
            np.testing.assert_array_equal(a.extras[k], b.extras[k])


# --------------------------------------------------------- exact reduction

def test_trivial_channel_compiles_bit_for_bit():
    """horizon=0 delay / prob=0 delay / empty channel all reproduce the
    channel-free schedule object-identically (no extras attached)."""
    g = ring_graph(N)
    plain = World(topology=g, comms_per_grad=1.5).compile(20, seed=6)
    for chan in (ChannelModel(),
                 ChannelModel(delay=DelayProcess(horizon=0)),
                 ChannelModel(delay=DelayProcess(horizon=5, prob=0.0))):
        w = World(topology=g, comms_per_grad=1.5, channel=chan)
        sched = w.compile(20, seed=6)
        assert sched.extras is None
        np.testing.assert_array_equal(sched.partners, plain.partners)
        np.testing.assert_array_equal(sched.event_times, plain.event_times)
        np.testing.assert_array_equal(sched.event_mask, plain.event_mask)
        np.testing.assert_array_equal(sched.grad_times, plain.grad_times)


@pytest.mark.parametrize("engine", [True, False])
def test_zero_corruption_mask_is_a_noop(engine):
    """An explicit all-zero corrupt mask routes through the channel replay
    machinery yet produces bit-identical results to the plain path."""
    n, d = 8, 10
    g, sim, st = _sim(n, d)
    plain = make_schedule(g, rounds=10, comms_per_grad=1.3, seed=2)
    R, K, _ = plain.partners.shape
    masked = plain.with_extras(corrupt=np.zeros((R, K, n), np.float32))
    fin_p, tr_p = sim.run_schedule(st, plain, engine=engine)
    fin_m, tr_m = sim.run_schedule(st, masked, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin_p.x), np.asarray(fin_m.x))
    np.testing.assert_array_equal(np.asarray(fin_p.x_tilde),
                                  np.asarray(fin_m.x_tilde))
    np.testing.assert_array_equal(np.asarray(tr_p.consensus),
                                  np.asarray(tr_m.consensus))


@pytest.mark.parametrize("engine", [True, False])
def test_h0_delay_replays_bit_for_bit(engine):
    """A horizon=0 delay world replays identically to the channel-free
    world on both backends — the PR 3 schedules are reproduced exactly."""
    n, d = 8, 10
    g, sim, st = _sim(n, d)
    w_plain = World(topology=g, comms_per_grad=1.3)
    w_h0 = dataclasses.replace(
        w_plain, channel=ChannelModel(delay=DelayProcess(horizon=0)))
    fin_p, _ = sim.run_world(st, w_plain, 10, seed=2, engine=engine)
    fin_0, _ = sim.run_world(st, w_h0, 10, seed=2, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin_p.x), np.asarray(fin_0.x))
    np.testing.assert_array_equal(np.asarray(fin_p.t_last),
                                  np.asarray(fin_0.t_last))


# ------------------------------------------------------- channel physics

def test_corrupt_mask_marks_exactly_the_byzantine_edges():
    g = ring_graph(N)
    byz = g.edges[:2]
    w = World(topology=g,
              channel=ChannelModel(adversary=ByzantineEdges(byz, "zero")))
    sched = w.compile(30, seed=1)
    c = sched.extras[CORRUPT_KEY]
    byz_set = {tuple(sorted(e)) for e in byz}
    idx = np.arange(N)
    for r in range(sched.rounds):
        for k in range(sched.partners.shape[1]):
            p = sched.partners[r, k]
            for i in range(N):
                j = int(p[i])
                on_byz = (sched.event_mask[r, k] and j != i
                          and tuple(sorted((i, j))) in byz_set)
                assert c[r, k, i] == (-1.0 if on_byz else 0.0)
    assert (c != 0).any()  # the adversary actually fired


def test_drops_only_remove_pairs():
    g = ring_graph(N)
    base = World(topology=g, comms_per_grad=2.0)
    plain = base.compile(40, seed=5)
    dropped = dataclasses.replace(
        base, channel=ChannelModel(drop_prob=0.4)).compile(40, seed=5)
    idx = np.arange(N)
    np.testing.assert_array_equal(plain.event_times, dropped.event_times)
    np.testing.assert_array_equal(plain.event_mask, dropped.event_mask)
    kept = surviving = total = 0
    for r in range(plain.rounds):
        for k in range(plain.partners.shape[1]):
            p0, p1 = plain.partners[r, k], dropped.partners[r, k]
            # involution preserved; surviving pairs match the original
            assert np.all(p1[p1] == idx)
            for i in range(N):
                if p0[i] != i:
                    total += 1
                    if p1[i] != i:
                        surviving += 1
                        assert p1[i] == p0[i]
                else:
                    assert p1[i] == i  # drops never ADD pairs
    assert 0 < surviving < total  # some pairs dropped, some survived


def test_staleness_respects_horizon_and_elapsed_rounds():
    g = ring_graph(N)
    H = 4
    w = World(topology=g, comms_per_grad=2.0,
              channel=ChannelModel(delay=DelayProcess(horizon=H, prob=1.0)))
    sched = w.compile(30, seed=7)
    s = sched.extras[STALE_KEY]
    idx = np.arange(N)
    involved = (sched.partners != idx) & sched.event_mask[:, :, None]
    assert s.min() >= 0 and s.max() == H
    # staleness only on involved reads, never beyond the rounds elapsed
    assert (s[~involved] == 0).all()
    for r in range(sched.rounds):
        assert s[r].max() <= min(r, H)
    # prob=1.0: every involved read from round H on is stale
    assert (s[H:][involved[H:]] >= 1).all()


def test_intermittent_adversary_corrupts_a_strict_subset():
    """prob < 1 duty-cycles the corruption per exchange: strictly fewer
    hits than the always-on adversary, always symmetric across the pair."""
    g = ring_graph(N)
    byz = g.edges[:3]

    def hits(prob):
        w = World(topology=g, comms_per_grad=2.0, channel=ChannelModel(
            adversary=ByzantineEdges(byz, "scale", scale=100.0, prob=prob)))
        return w.compile(60, seed=2).extras[CORRUPT_KEY]

    full, half = hits(1.0), hits(0.5)
    assert 0 < (half != 0).sum() < (full != 0).sum()
    # duty-cycled hits are a subset of the always-on hits, pair-symmetric
    assert ((half != 0) <= (full != 0)).all()
    sched = World(topology=g, comms_per_grad=2.0).compile(60, seed=2)
    for r, k, i in zip(*np.nonzero(half)):
        j = int(sched.partners[r, k, i])
        assert half[r, k, j] == half[r, k, i]


def test_fixed_kind_delay_draws_constant_offsets():
    g = ring_graph(N)
    w = World(topology=g, channel=ChannelModel(
        delay=DelayProcess(horizon=3, kind="fixed", prob=1.0)))
    sched = w.compile(20, seed=0)
    s = sched.extras[STALE_KEY]
    idx = np.arange(N)
    involved = (sched.partners != idx) & sched.event_mask[:, :, None]
    vals = s[3:][involved[3:]]
    assert (vals == 3).all()  # past the warmup, every read is exactly H old


# ------------------------------------------------- end-to-end equivalence

@pytest.mark.parametrize("accelerated", [False, True])
@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_engine_matches_reference_on_channel_world(accelerated, backend):
    """The acceptance oracle: FlatGossipEngine replays a full channel world
    (delay + Byzantine edges + drops) identically to the per-event path."""
    n, d = 12, 24
    rounds = 10 if backend == "pallas_interpret" else 40
    g, sim, st = _sim(n, d, accelerated=accelerated, backend=backend)
    w = World(topology=g, comms_per_grad=1.5, channel=_hostile_channel(g))
    sched = w.compile(rounds, seed=11)
    assert set(sched.extras_dict()) == {STALE_KEY, CORRUPT_KEY, DROP_KEY}
    fin_ref, tr_ref = sim.run_schedule(st, sched, engine=False)
    fin_eng, tr_eng = sim.run_schedule(st, sched, engine=True)
    np.testing.assert_allclose(fin_eng.x, fin_ref.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.x_tilde, fin_ref.x_tilde,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.t_last, fin_ref.t_last, atol=1e-6)
    np.testing.assert_allclose(tr_eng.loss, tr_ref.loss, atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(tr_eng.consensus, tr_ref.consensus,
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("rule", ["trim", "clip", "coord"])
@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_robust_replay_engine_matches_reference(backend, rule):
    """Every robust rule (norm trim / norm clip / coordinate clip) agrees
    across both replay paths on a Byzantine world."""
    n, d = 8, 16
    rounds = 8 if backend == "pallas_interpret" else 25
    g, sim, st = _sim(n, d, backend=backend, robust_clip=0.8)
    sim = dataclasses.replace(sim, robust_rule=rule)
    w = World(topology=g, channel=ChannelModel(
        adversary=ByzantineEdges(g.edges[:2], "sign_flip")))
    sched = w.compile(rounds, seed=4)
    fin_ref, _ = sim.run_schedule(st, sched, engine=False)
    fin_eng, _ = sim.run_schedule(st, sched, engine=True)
    np.testing.assert_allclose(fin_eng.x, fin_ref.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.x_tilde, fin_ref.x_tilde,
                               atol=1e-5, rtol=1e-5)


def test_norm_trim_rejects_garbage_injection():
    """On a garbage-injection Byzantine ring (scale attack, 50% duty
    cycle), the non-robust replay blows up while the norm-trim defense
    keeps the tail consensus at the clean level — the story the benchmark
    quantifies (BENCH_channel.json)."""
    n, d, rounds = 16, 24, 100
    g, sim, st = _sim(n, d)
    byz = tuple(g.edges[i] for i in (0, 8))
    w_byz = World(topology=g, channel=ChannelModel(
        adversary=ByzantineEdges(byz, "scale", scale=1e3, prob=0.5)))
    clean_sched = World(topology=g).compile(rounds, seed=9)
    byz_sched = w_byz.compile(rounds, seed=9)
    _, tr_clean = sim.run_schedule(st, clean_sched)
    _, tr_byz = sim.run_schedule(st, byz_sched)
    sim_rob = dataclasses.replace(sim, robust_clip=5.0, robust_rule="trim")
    _, tr_rob = sim_rob.run_schedule(st, byz_sched)
    clean = float(np.mean(tr_clean.consensus[-20:]))
    attacked = np.asarray(tr_byz.consensus[-20:])
    defended = float(np.mean(tr_rob.consensus[-20:]))
    # the attack is catastrophic without the defense...
    assert (~np.isfinite(attacked)).any() or attacked.mean() > 100 * clean
    # ...and invisible with it (honest duty cycle keeps the ring connected)
    assert defended < 2.0 * clean


def test_mesh_trainers_model_static_axes_and_reject_the_rest():
    """StackedGossipTrainer.from_world carries an always-on adversary +
    drops + robust rules, and (since the permute ring, DESIGN.md §16)
    serves DelayProcess channels from its own DelayRing of past
    snapshots; duty-cycled adversaries and unknown delay kinds are still
    rejected loudly (they need pair-correlated draws / staleness laws
    the ring cannot supply) rather than silently mis-modeled."""
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.optim import sgd

    g = ring_graph(8)
    opt = sgd(momentum=0.0, weight_decay=0.0)

    def grad_fn(p, batch):
        return (0.5 * jnp.sum((p["w"] - batch) ** 2), None), \
            {"w": p["w"] - batch}

    chan = ChannelModel(adversary=ByzantineEdges((g.edges[0],), "scale",
                                                 scale=100.0),
                        drop_prob=0.1)
    tr = StackedGossipTrainer.from_world(
        World(topology=g, channel=chan), grad_fn, opt, backend="ref",
        robust_clip=5.0)
    assert tr.channel == chan
    state = tr.init({"w": jnp.zeros((3,), jnp.float32)},
                    jax.random.PRNGKey(0))
    state, m = jax.jit(tr.make_step())(state, jnp.ones((8, 3), jnp.float32))
    assert np.isfinite(float(m["loss"]))

    # delayed channels now run on the bounded-staleness ring: the state
    # carries a (H, n, D) snapshot ring whose round counter advances
    delayed = StackedGossipTrainer.from_world(
        World(topology=g, channel=ChannelModel(
            delay=DelayProcess(horizon=2))), grad_fn, opt, backend="ref")
    dstate = delayed.init({"w": jnp.zeros((3,), jnp.float32)},
                          jax.random.PRNGKey(0))
    assert dstate.ring is not None and int(dstate.ring.round) == -1
    dstate, dm = jax.jit(delayed.make_step())(
        dstate, jnp.ones((8, 3), jnp.float32))
    assert int(dstate.ring.round) == 0
    assert np.isfinite(float(dm["loss"]))

    with pytest.raises(ValueError, match="mesh trainers"):
        StackedGossipTrainer.from_world(
            World(topology=g, channel=ChannelModel(
                adversary=ByzantineEdges((g.edges[0],), prob=0.5))),
            grad_fn, opt)


# ------------------------------------------------ churn x delay interplay

@pytest.mark.parametrize("engine", [True, False])
def test_detached_workers_stay_fixed_points_under_delay(engine):
    """A churned worker's row is untouched by a delayed channel replay:
    mixing segments are zero-dt, it joins no matchings, and ring snapshots
    of its frozen row change nothing (semigroup over the ring buffer)."""
    n, d, dead = 8, 10, 3
    active = np.ones(n, bool)
    active[dead] = False
    g = ring_graph(n)
    ts = TopologySchedule((TopologyPhase(g, 12, tuple(active)),))
    w = World(topology=ts,
              channel=ChannelModel(delay=DelayProcess(horizon=3, prob=0.8)))
    sched = w.compile(seed=3)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                    gamma=0.05, backend="ref")
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    fin, _ = sim.run_schedule(st, sched, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin.x)[dead],
                                  np.asarray(st.x)[dead])
    np.testing.assert_array_equal(np.asarray(fin.x_tilde)[dead],
                                  np.asarray(st.x_tilde)[dead])
    np.testing.assert_array_equal(np.asarray(fin.t_last)[dead], 0.0)
    others = np.delete(np.arange(n), dead)
    assert np.all(np.any(np.asarray(fin.x)[others] != 0.0, axis=1))


# ----------------------------------------------------------- kernel parity

@pytest.mark.parametrize("w,d", [(4, 128), (6, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("clip", [None, 0.4])
def test_channel_kernel_matches_oracle(w, d, dtype, clip):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (w, d), dtype)
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d), dtype)
    perm = np.arange(w)
    perm[:4] = [1, 0, 3, 2]
    xp = jnp.take(x, jnp.asarray(perm), axis=0)
    corrupt = jnp.asarray([-2.0, 0.0, -1.0, 4.0] + [0.0] * (w - 4),
                          jnp.float32)
    mscale = jnp.asarray([1.0, 0.0, 0.5, 1.0] + [1.0] * (w - 4),
                         jnp.float32)
    dt = jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    kw = dict(eta=0.37, alpha=0.5, alpha_t=1.4, clip=clip)
    ox, ot = channel_gossip_stacked(x, xt, xp, corrupt, mscale, dt,
                                    interpret=True, **kw)
    rx, rt = channel_gossip_stacked_ref(x, xt, xp, corrupt, mscale, dt,
                                        **kw)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ox, np.float32),
                               np.asarray(rx, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(ot, np.float32),
                               np.asarray(rt, np.float32), atol=atol)


def test_channel_kernel_degenerates_to_clean_kernel():
    """Zero corruption + unit mscale + no clip is bitwise the clean
    stacked kernel — (1 + 0) * xp and m * 1.0 introduce no float
    perturbation."""
    w, d = 8, 256
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (w, d))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d))
    perm = jnp.asarray([1, 0, 3, 2, 5, 4, 6, 7], jnp.int32)
    xp = jnp.take(x, perm, axis=0)
    dt = jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    kw = dict(eta=0.8, alpha=0.5, alpha_t=1.1)
    cx, ct = channel_gossip_stacked_ref(x, xt, xp, jnp.zeros(w),
                                        jnp.ones(w), dt, clip=None, **kw)
    px, pt = mixing_gossip_stacked_ref(x, xt, perm, dt, **kw)
    np.testing.assert_array_equal(np.asarray(cx), np.asarray(px))
    np.testing.assert_array_equal(np.asarray(ct), np.asarray(pt))


def test_channel_local_matches_stacked_row():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 300))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (2, 300))
    kw = dict(eta=0.4, alpha=0.5, alpha_t=0.9, clip=0.2)
    lx, lt = channel_p2p_mixing_ref(x[0], xt[0], x[1], -2.0, 0.5, 0.7, **kw)
    sx, st_ = channel_gossip_stacked_ref(x[:1], xt[:1], x[1:2],
                                         jnp.asarray([-2.0]),
                                         jnp.asarray([0.5]),
                                         jnp.asarray([0.7]), **kw)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(sx[0]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(lt), np.asarray(st_[0]), atol=1e-6)


# --------------------------------------------------- extras stream wiring

def test_channel_extras_thread_through_coalesce_and_stream():
    """The channel's stale/corrupt values survive coalescing and the flat
    stream per worker — each involved worker carries its own read's
    attributes into the scan row (the generic extras contract, pinned here
    for the channel's specific arrays)."""
    g = ring_graph(8)
    w = World(topology=g, comms_per_grad=2.0, channel=_hostile_channel(g))
    sched = w.compile(10, seed=6)
    cs = coalesce_schedule(sched)
    R, K, n = sched.partners.shape
    for wk in range(n):
        raw = sorted((float(sched.event_times[r, e]),
                      int(sched.partners[r, e, wk]),
                      int(sched.extras[STALE_KEY][r, e, wk]),
                      float(sched.extras[CORRUPT_KEY][r, e, wk]))
                     for r in range(R) for e in range(K)
                     if sched.event_mask[r, e]
                     and sched.partners[r, e, wk] != wk)
        coal = sorted((float(cs.wtimes[r, bb, wk]),
                       int(cs.partners[r, bb, wk]),
                       int(cs.extras[STALE_KEY][r, bb, wk]),
                       float(cs.extras[CORRUPT_KEY][r, bb, wk]))
                      for r in range(R) for bb in range(cs.partners.shape[1])
                      if cs.batch_active[r, bb]
                      and cs.partners[r, bb, wk] != wk)
        assert raw == coal
    stream = coalesced_stream(cs, np.zeros(n))
    assert stream.extras[STALE_KEY].dtype == np.int32
    np.testing.assert_array_equal(
        stream.extras[STALE_KEY][stream.is_grad], 0)


# ------------------------------------------------------- hypothesis sweeps

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - tier-1 collects without hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None)
    @given(seed=hyp_st.integers(0, 500), horizon=hyp_st.integers(1, 6),
           prob=hyp_st.floats(0.1, 1.0))
    def test_stale_draws_always_serveable(seed, horizon, prob):
        """For any delay process, compiled staleness never exceeds the ring
        horizon or the rounds elapsed, and lands only on involved reads."""
        g = ring_graph(8)
        w = World(topology=g, comms_per_grad=1.5, channel=ChannelModel(
            delay=DelayProcess(horizon=horizon, prob=prob)))
        sched = w.compile(12, seed=seed)
        s = sched.extras[STALE_KEY]
        idx = np.arange(8)
        involved = (sched.partners != idx) & sched.event_mask[:, :, None]
        assert (s[~involved] == 0).all()
        assert s.min() >= 0
        for r in range(sched.rounds):
            assert s[r].max() <= min(r, horizon)

    @settings(max_examples=8, deadline=None)
    @given(seed=hyp_st.integers(0, 300))
    def test_h0_worlds_reduce_bit_for_bit(seed):
        """Sweep: horizon=0 channels always compile to the channel-free
        schedule bit-for-bit (both replay paths consume the same arrays)."""
        g = ring_graph(8)
        plain = World(topology=g, comms_per_grad=1.2).compile(8, seed=seed)
        chan = World(topology=g, comms_per_grad=1.2,
                     channel=ChannelModel(delay=DelayProcess(horizon=0))
                     ).compile(8, seed=seed)
        assert chan.extras is None
        np.testing.assert_array_equal(plain.partners, chan.partners)
        np.testing.assert_array_equal(plain.event_times, chan.event_times)
        np.testing.assert_array_equal(plain.grad_times, chan.grad_times)

    @settings(max_examples=6, deadline=None)
    @given(seed=hyp_st.integers(0, 200), dead=hyp_st.integers(0, 7),
           horizon=hyp_st.integers(1, 4))
    def test_churned_rows_fixed_under_any_delay(seed, dead, horizon):
        """Sweep of the delay x churn interplay: any detached worker stays
        an exact fixed point of the channel engine replay."""
        n, d = 8, 6
        active = np.ones(n, bool)
        active[dead] = False
        g = ring_graph(n)
        ts = TopologySchedule((TopologyPhase(g, 6, tuple(active)),))
        w = World(topology=ts, channel=ChannelModel(
            delay=DelayProcess(horizon=horizon, prob=0.7)))
        sched = w.compile(seed=seed)
        b = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
        sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                        gamma=0.05, backend="ref")
        st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(1))
        fin, _ = sim.run_schedule(st, sched, engine=True)
        np.testing.assert_array_equal(np.asarray(fin.x)[dead],
                                      np.asarray(st.x)[dead])
        np.testing.assert_array_equal(np.asarray(fin.x_tilde)[dead],
                                      np.asarray(st.x_tilde)[dead])
