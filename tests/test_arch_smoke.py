"""Per-architecture smoke tests (deliverable f): reduced variant of each
family, one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.launch.steps import TrainState, make_train_step
from repro.models import Model
from repro.optim import sgd

B, S = 2, 32


def _batch(cfg, key):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    else:
        inputs = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    if cfg.num_codebooks > 1:
        labels = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                    cfg.vocab_size, jnp.int32)
    else:
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux, h = model.forward(params, batch["inputs"])
    assert logits.shape == (B, S, cfg.padded_vocab * cfg.num_codebooks)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_train_step_no_nans(arch):
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    step_fn, optimizer = make_train_step(model, sgd(), lr=1e-2, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, optimizer.init(params))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    state2, metrics = jax.jit(step_fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    for leaf in jax.tree.leaves(state2.params):
        assert bool(jnp.isfinite(leaf).all())
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(state2.params),
                        jax.tree.leaves(state.params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen3-14b", "mamba2-780m",
                                  "recurrentgemma-9b", "deepseek-v3-671b"])
def test_reduced_loss_decreases(arch):
    """A few steps on a fixed batch must reduce the loss (learnability)."""
    cfg = get_config(arch, reduced=True)
    model = Model(cfg)
    step_fn, optimizer = make_train_step(model, sgd(momentum=0.0), lr=0.05,
                                         remat=False)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params, optimizer.init(params))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    step = jax.jit(step_fn)
    first = None
    for i in range(8):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned numbers."""
    expect = {
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                            num_kv_heads=8, d_ff=4864, vocab_size=32000),
        "mamba2-780m": dict(num_layers=48, d_model=1536, d_ff=0,
                            vocab_size=50280),
        "chameleon-34b": dict(num_layers=48, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22016, vocab_size=65536),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 vocab_size=129280),
        "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                                  num_kv_heads=1, d_ff=12288,
                                  vocab_size=256000),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936),
        "glm4-9b": dict(num_layers=40, d_model=4096, num_heads=32,
                        num_kv_heads=2, d_ff=13696, vocab_size=151552),
        "yi-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                       num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "qwen3-0.6b": dict(num_layers=28, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=3072, vocab_size=151936),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            got = getattr(cfg, k)
            assert got == v, f"{arch}.{k}: {got} != {v}"
    # moe specifics
    assert get_config("arctic-480b").moe.num_experts == 128
    assert get_config("arctic-480b").moe.top_k == 2
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.shared_expert and ds.mla is not None and ds.mtp
    assert get_config("mamba2-780m").ssm.d_state == 128
