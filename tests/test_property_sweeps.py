"""Hypothesis property sweeps, collected only when `hypothesis` is installed.

The deterministic siblings of these tests live in test_a2cid2 / test_graphs /
test_kernels / test_substrates; keeping the @given sweeps here means a clean
environment (no hypothesis) still collects and runs the whole tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import apply_mixing, mixing_coeff, ring_graph
from repro.kernels.a2cid2_mixing.kernel import mixing_p2p
from repro.kernels.a2cid2_mixing.ref import mixing_p2p_ref
from repro.optim import clip_by_global_norm


# ------------------------------------------------------------------- a2cid2

@settings(max_examples=30, deadline=None)
@given(eta=st.floats(0.01, 2.0), t1=st.floats(0.0, 3.0), t2=st.floats(0.0, 3.0))
def test_mixing_flow_semigroup(eta, t1, t2):
    """exp(t1 A) exp(t2 A) == exp((t1+t2) A) — exact flow, not an Euler step."""
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    a1, b1 = apply_mixing(*apply_mixing(x, xt, eta, t1), eta, t2)
    a2, b2 = apply_mixing(x, xt, eta, t1 + t2)
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(eta=st.floats(0.01, 5.0), t=st.floats(0.0, 10.0))
def test_mixing_preserves_sum_and_contracts(eta, t):
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    mx, mxt = apply_mixing(x, xt, eta, t)
    np.testing.assert_allclose(mx + mxt, x + xt, rtol=1e-5)
    # contraction of the difference: |mx - mxt| = e^{-2 eta t} |x - xt|
    np.testing.assert_allclose(
        np.asarray(mx - mxt),
        np.exp(-2 * eta * t) * np.asarray(x - xt), rtol=1e-4, atol=1e-5)
    c = float(mixing_coeff(eta, jnp.asarray(t)))
    assert 0.0 <= c <= 0.5


# ------------------------------------------------------------------- graphs

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 24), seed=st.integers(0, 1000))
def test_matchings_are_valid(n, seed):
    g = ring_graph(n)
    rng = np.random.default_rng(seed)
    m = g.sample_matching(rng)
    nodes = [x for e in m for x in e]
    assert len(nodes) == len(set(nodes))            # node-disjoint
    edge_set = {tuple(sorted(e)) for e in g.edges}
    for e in m:
        assert tuple(sorted(e)) in edge_set         # real edges only
    p = g.matching_to_partner(m)
    assert np.all(p[p] == np.arange(n))             # involution


# ------------------------------------------------------------------ kernels

@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 3000), eta=st.floats(0.0, 2.0),
       dt=st.floats(0.0, 5.0), alpha_t=st.floats(0.1, 3.0),
       seed=st.integers(0, 100))
def test_mixing_kernel_hypothesis_sweep(n, eta, dt, alpha_t, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n,))
    xt = jax.random.normal(ks[1], (n,))
    xp = jax.random.normal(ks[2], (n,))
    kw = dict(eta=eta, alpha=0.5, alpha_t=alpha_t)
    ox, ot = mixing_p2p(x, xt, xp, jnp.float32(dt), interpret=True, **kw)
    rx, rt = mixing_p2p_ref(x, xt, xp, dt, **kw)
    np.testing.assert_allclose(ox, rx, atol=1e-4)
    np.testing.assert_allclose(ot, rt, atol=1e-4)


# --------------------------------------------------------------- substrates

@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 100.0), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm(scale, max_norm):
    g = {"a": scale * jnp.ones(16), "b": -scale * jnp.ones(4)}
    clipped = clip_by_global_norm(g, max_norm)
    norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                              for x in jax.tree.leaves(clipped))))
    assert norm <= max_norm * 1.01
    if scale * np.sqrt(20) <= max_norm:  # no-op when under the bound
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)
