"""Hypothesis property sweeps, collected only when `hypothesis` is installed.

The deterministic siblings of these tests live in test_a2cid2 / test_graphs /
test_kernels / test_substrates; keeping the @given sweeps here means a clean
environment (no hypothesis) still collects and runs the whole tier-1 suite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import apply_mixing, mixing_coeff, ring_graph
from repro.kernels.a2cid2_mixing.kernel import mixing_p2p
from repro.kernels.a2cid2_mixing.ref import mixing_p2p_ref
from repro.optim import clip_by_global_norm


# ------------------------------------------------------------------- a2cid2

@settings(max_examples=30, deadline=None)
@given(eta=st.floats(0.01, 2.0), t1=st.floats(0.0, 3.0), t2=st.floats(0.0, 3.0))
def test_mixing_flow_semigroup(eta, t1, t2):
    """exp(t1 A) exp(t2 A) == exp((t1+t2) A) — exact flow, not an Euler step."""
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    a1, b1 = apply_mixing(*apply_mixing(x, xt, eta, t1), eta, t2)
    a2, b2 = apply_mixing(x, xt, eta, t1 + t2)
    np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(eta=st.floats(0.01, 5.0), t=st.floats(0.0, 10.0))
def test_mixing_preserves_sum_and_contracts(eta, t):
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    mx, mxt = apply_mixing(x, xt, eta, t)
    np.testing.assert_allclose(mx + mxt, x + xt, rtol=1e-5)
    # contraction of the difference: |mx - mxt| = e^{-2 eta t} |x - xt|
    np.testing.assert_allclose(
        np.asarray(mx - mxt),
        np.exp(-2 * eta * t) * np.asarray(x - xt), rtol=1e-4, atol=1e-5)
    c = float(mixing_coeff(eta, jnp.asarray(t)))
    assert 0.0 <= c <= 0.5


# ------------------------------------------------------------------- graphs

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 24), seed=st.integers(0, 1000))
def test_matchings_are_valid(n, seed):
    g = ring_graph(n)
    rng = np.random.default_rng(seed)
    m = g.sample_matching(rng)
    nodes = [x for e in m for x in e]
    assert len(nodes) == len(set(nodes))            # node-disjoint
    edge_set = {tuple(sorted(e)) for e in g.edges}
    for e in m:
        assert tuple(sorted(e)) in edge_set         # real edges only
    p = g.matching_to_partner(m)
    assert np.all(p[p] == np.arange(n))             # involution


# ------------------------------------------------------------------ kernels

@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 3000), eta=st.floats(0.0, 2.0),
       dt=st.floats(0.0, 5.0), alpha_t=st.floats(0.1, 3.0),
       seed=st.integers(0, 100))
def test_mixing_kernel_hypothesis_sweep(n, eta, dt, alpha_t, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n,))
    xt = jax.random.normal(ks[1], (n,))
    xp = jax.random.normal(ks[2], (n,))
    kw = dict(eta=eta, alpha=0.5, alpha_t=alpha_t)
    ox, ot = mixing_p2p(x, xt, xp, jnp.float32(dt), interpret=True, **kw)
    rx, rt = mixing_p2p_ref(x, xt, xp, dt, **kw)
    np.testing.assert_allclose(ox, rx, atol=1e-4)
    np.testing.assert_allclose(ot, rt, atol=1e-4)


# --------------------------------------------------- heterogeneous worlds

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), rate_lo=st.floats(0.05, 0.9),
       comms=st.floats(0.3, 2.5))
def test_hetero_coalesce_preserves_events_and_elapsed_time(seed, rate_lo,
                                                           comms):
    """Under straggler + per-edge rate heterogeneity, coalescing preserves
    the per-worker (time, partner) event multiset exactly, and the flattened
    stream's per-worker elapsed time telescopes to t_final - t0."""
    from repro.core import coalesce_schedule, coalesced_stream, make_schedule

    n = 8
    g = ring_graph(n)
    rng = np.random.default_rng(seed)
    sched = make_schedule(
        g, rounds=12, comms_per_grad=comms, seed=seed,
        grad_rates=rng.uniform(rate_lo, 1.0, size=n),
        edge_rates=rng.uniform(0.1, 1.0, size=g.num_edges))
    cs = coalesce_schedule(sched)
    for w in range(n):
        raw = [(float(sched.event_times[r, e]), int(sched.partners[r, e, w]))
               for r in range(sched.rounds)
               for e in range(sched.partners.shape[1])
               if sched.event_mask[r, e] and sched.partners[r, e, w] != w]
        coal = [(float(cs.wtimes[r, b, w]), int(cs.partners[r, b, w]))
                for r in range(cs.rounds)
                for b in range(cs.partners.shape[1])
                if cs.batch_active[r, b] and cs.partners[r, b, w] != w]
        assert raw == coal
    t0 = np.zeros(n, np.float32)
    stream = coalesced_stream(cs, t0)
    elapsed = stream.prologue + stream.dt_next.sum(axis=0)
    np.testing.assert_allclose(elapsed, stream.t_final - t0, atol=1e-3)
    # gradient multiset: grad_scale at gradient steps == thinned tick mask
    np.testing.assert_array_equal(
        stream.grad_scale[stream.is_grad], sched.grad_scale())


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 300), dead=st.integers(0, 7))
def test_churn_masked_rows_are_engine_fixed_points(seed, dead):
    """A churned worker's flat-buffer row is a fixed point of the engine
    replay, for any schedule realization and any choice of dead worker."""
    from repro.core import (Simulator, TopologyPhase, TopologySchedule,
                            make_topology_schedule, params_from_graph)

    n, d = 8, 6
    active = np.ones(n, bool)
    active[dead] = False
    g = ring_graph(n)
    sched = make_topology_schedule(
        TopologySchedule((TopologyPhase(g, 8, tuple(active)),)),
        comms_per_grad=1.0, seed=seed)
    b = jax.random.normal(jax.random.PRNGKey(seed), (n, d)).astype(
        jnp.float32)

    def grad_fn(x, key, wid):
        gr = (x - b[wid]).astype(x.dtype)
        return 0.5 * jnp.sum(gr ** 2), gr

    sim = Simulator(grad_fn, params_from_graph(g, True), gamma=0.05,
                    backend="ref")
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(1))
    fin, _ = sim.run_schedule(st, sched, engine=True)
    np.testing.assert_array_equal(np.asarray(fin.x)[dead],
                                  np.asarray(st.x)[dead])
    np.testing.assert_array_equal(np.asarray(fin.x_tilde)[dead],
                                  np.asarray(st.x_tilde)[dead])
    # everyone else took gradient steps
    others = np.delete(np.arange(n), dead)
    assert np.all(np.any(np.asarray(fin.x)[others] != 0.0, axis=1))


# --------------------------------------------------------------- substrates

@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 100.0), max_norm=st.floats(0.1, 10.0))
def test_clip_by_global_norm(scale, max_norm):
    g = {"a": scale * jnp.ones(16), "b": -scale * jnp.ones(4)}
    clipped = clip_by_global_norm(g, max_norm)
    norm = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                              for x in jax.tree.leaves(clipped))))
    assert norm <= max_norm * 1.01
    if scale * np.sqrt(20) <= max_norm:  # no-op when under the bound
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)
