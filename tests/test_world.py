"""Declarative World API (core/world.py; DESIGN.md §9).

The contract under test: ``make_schedule`` / ``make_topology_schedule`` are
thin wrappers over ``World(...).compile(...)`` and stay bit-for-bit identical
to the pre-World sampler under the same seed, across homogeneous, straggler,
per-edge, and multi-phase-churn worlds, on both replay backends.  On top of
that: construction-time validation with actionable errors, JSON round-trips,
the per-event extras channel, Poisson churn compilation, and the
bandwidth-aware link model.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChurnProcess, LinkModel, PhaseSwitch, Simulator,
                        TopologyPhase, TopologySchedule, WorkerModel, World,
                        build_graph, coalesce_schedule, coalesced_stream,
                        concat_schedules, make_schedule,
                        make_topology_schedule, matching_bank,
                        params_from_graph, ring_graph, world_banks)

SCHED_FIELDS = ("partners", "event_times", "event_mask", "grad_times")


def _assert_schedules_identical(a, b):
    for f in SCHED_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    np.testing.assert_array_equal(a.grad_scale(), b.grad_scale())
    np.testing.assert_array_equal(a.alive_arr(), b.alive_arr())


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


# --------------------------------------------------- compatibility contract

N = 12


def _compat_cases():
    g = ring_graph(N)
    active = np.ones(N, bool)
    active[3] = False
    return {
        "homogeneous": (g, {}, World(topology=g, comms_per_grad=1.5)),
        "straggler": (
            g, dict(grad_rates=np.linspace(0.2, 1.0, N)),
            World(topology=g, comms_per_grad=1.5,
                  workers=WorkerModel(grad_rates=np.linspace(0.2, 1.0, N)))),
        "per_edge": (
            g, dict(edge_rates=np.linspace(0.2, 1.2, g.num_edges)),
            World(topology=g, comms_per_grad=1.5,
                  links=LinkModel(rates=np.linspace(0.2, 1.2,
                                                    g.num_edges)))),
        "static_churn": (
            g, dict(active=active),
            World(topology=g, comms_per_grad=1.5,
                  workers=WorkerModel(active=active))),
        "offset_no_jitter": (
            g, dict(t_offset=7.0, jitter_grad_times=False),
            World(topology=g, comms_per_grad=1.5, t_offset=7.0,
                  jitter_grad_times=False)),
    }


@pytest.mark.parametrize("case", sorted(_compat_cases()))
def test_make_schedule_equals_world_compile(case):
    """make_schedule(**kw) must be bit-for-bit World(...).compile() — the
    World here is constructed EXPLICITLY (not through the wrapper), so this
    pins the kwarg->World lowering, not just wrapper self-consistency."""
    g, kw, world = _compat_cases()[case]
    for seed in (0, 11):
        a = make_schedule(g, rounds=25, comms_per_grad=1.5, seed=seed, **kw)
        b = world.compile(25, seed=seed)
        _assert_schedules_identical(a, b)


def test_topology_schedule_equals_world_compile():
    """Multi-phase churn world: the tsched wrapper, the World(topology=ts)
    form, and the PhaseSwitch-fault form all compile identically."""
    g = ring_graph(N)
    exp = build_graph("exponential", N)
    active = np.ones(N, bool)
    active[1] = False
    ts = TopologySchedule((
        TopologyPhase(g, 8),
        TopologyPhase(g, 8, tuple(active)),
        TopologyPhase(exp, 8),
    ))
    rates = np.linspace(0.3, 1.0, N)
    a = make_topology_schedule(ts, comms_per_grad=1.2, seed=5,
                               grad_rates=rates, per_edge=True)
    b = World(topology=ts, comms_per_grad=1.2,
              workers=WorkerModel(grad_rates=rates),
              links=LinkModel(per_edge=True)).compile(seed=5)
    c = World(topology=g, comms_per_grad=1.2,
              workers=WorkerModel(grad_rates=rates),
              links=LinkModel(per_edge=True),
              faults=(PhaseSwitch(8, active=tuple(active)),
                      PhaseSwitch(16, topology=exp))).compile(24, seed=5)
    _assert_schedules_identical(a, b)
    _assert_schedules_identical(a, c)


@pytest.mark.parametrize("engine", [True, False])
def test_world_replay_matches_wrapper_on_both_backends(engine):
    """Replaying a World-compiled hetero schedule must equal replaying the
    wrapper-built one on BOTH replay paths (engine and per-event ref)."""
    n, d = 8, 10
    g = ring_graph(n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    rates = np.linspace(0.4, 1.0, n)
    kw = dict(comms_per_grad=1.3, grad_rates=rates,
              edge_rates=np.linspace(0.5, 1.5, g.num_edges))
    sched_a = make_schedule(g, rounds=12, seed=2, **kw)
    world = World(topology=g, comms_per_grad=1.3,
                  workers=WorkerModel(grad_rates=rates),
                  links=LinkModel(rates=kw["edge_rates"]))
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True), gamma=0.05,
                    backend="ref")
    st = sim.init(jnp.zeros(d, jnp.float32), n, jax.random.PRNGKey(2))
    fin_a, tr_a = sim.run_schedule(st, sched_a, engine=engine)
    fin_b, tr_b = sim.run_world(st, world, 12, seed=2, engine=engine)
    np.testing.assert_array_equal(np.asarray(fin_a.x), np.asarray(fin_b.x))
    np.testing.assert_array_equal(np.asarray(fin_a.t_last),
                                  np.asarray(fin_b.t_last))
    np.testing.assert_array_equal(np.asarray(tr_a.consensus),
                                  np.asarray(tr_b.consensus))


# ------------------------------------------------------ validation contract

def test_validation_names_the_offending_field():
    g = ring_graph(8)
    with pytest.raises(ValueError, match=r"workers\.grad_rates.*\(8,\)"):
        World(topology=g, workers=WorkerModel(grad_rates=np.ones(5)))
    with pytest.raises(ValueError, match=r"workers\.grad_rates.*\[0, 1\]"):
        WorkerModel(grad_rates=[0.5, 2.0])
    with pytest.raises(ValueError, match=r"workers\.grad_rates.*1-D"):
        WorkerModel(grad_rates=np.ones((4, 2)))
    with pytest.raises(ValueError, match=r"workers\.active.*\(8,\)"):
        World(topology=g, workers=WorkerModel(active=[True] * 3))
    with pytest.raises(ValueError, match=r"links\.rates.*\(8,\)"):
        World(topology=g, links=LinkModel(rates=np.ones(3)))
    with pytest.raises(ValueError, match="not both"):
        LinkModel(rates=[1.0], bandwidth_bytes_per_s=1e9, msg_bytes=4.0)
    with pytest.raises(ValueError, match="msg_bytes"):
        LinkModel(bandwidth_bytes_per_s=1e9)
    with pytest.raises(ValueError, match=r"links\.msg_bytes"):
        LinkModel(bandwidth_bytes_per_s=1e9, msg_bytes=0.0)
    with pytest.raises(ValueError, match="fail_rate"):
        ChurnProcess(-0.1, 0.2)
    with pytest.raises(ValueError, match="at_round"):
        PhaseSwitch(0)
    with pytest.raises(ValueError, match="strictly increasing"):
        World(topology=g, faults=(PhaseSwitch(5), PhaseSwitch(5)))
    ts = TopologySchedule((TopologyPhase(g, 4),))
    with pytest.raises(ValueError, match="TopologySchedule already encodes"):
        World(topology=ts, faults=(PhaseSwitch(2),))
    with pytest.raises(ValueError, match=r"ChurnProcess\.workers.*\[0, 8\)"):
        World(topology=g, faults=(ChurnProcess(0.1, 0.1, workers=(99,)),))
    with pytest.raises(ValueError, match="topology must be a Graph"):
        World(topology="ring")
    with pytest.raises(ValueError, match=r"needs compile\(rounds=\.\.\.\)"):
        World(topology=g).compile()
    with pytest.raises(ValueError, match="does not match"):
        World(topology=ts).compile(9)
    # the wrapper inherits World's validation
    with pytest.raises(ValueError, match=r"workers\.grad_rates"):
        make_schedule(g, rounds=5, grad_rates=np.ones(3))


def test_per_edge_link_models_need_static_topology():
    g = ring_graph(8)
    with pytest.raises(ValueError, match="single static"):
        World(topology=g, links=LinkModel(rates=np.ones(8)),
              faults=(PhaseSwitch(3, topology=build_graph("complete", 8)),))
    # scalar bandwidth composes with phase switches fine
    World(topology=g,
          links=LinkModel(bandwidth_bytes_per_s=1e9, msg_bytes=4.0),
          faults=(PhaseSwitch(3, topology=build_graph("complete", 8)),)
          ).compile(6, seed=0)


# --------------------------------------------------------- json round-trips

def test_world_json_round_trip():
    g = ring_graph(8)
    ts = TopologySchedule((TopologyPhase(g, 6),
                           TopologyPhase(build_graph("exponential", 8), 6,
                                         (True,) * 7 + (False,))))
    worlds = [
        World(topology=g),
        World(topology=g, comms_per_grad=2.0, jitter_grad_times=False,
              t_offset=3.5,
              workers=WorkerModel(grad_rates=np.linspace(0.1, 1, 8),
                                  active=[True] * 7 + [False]),
              links=LinkModel(rates=np.linspace(0.5, 1.5, 8),
                              per_edge=True),
              faults=(ChurnProcess(0.1, 0.3, workers=(0, 2)),)),
        World(topology=g,
              links=LinkModel(bandwidth_bytes_per_s=(1e9,) * 8,
                              msg_bytes=256.0, grad_seconds=1e-6),
              faults=(PhaseSwitch(4, active=(True,) * 7 + (False,)),)),
        World(topology=ts,
              links=LinkModel(bandwidth_bytes_per_s=5e8, msg_bytes=64.0)),
    ]
    for w in worlds:
        s = w.to_json()
        json.loads(s)  # valid JSON
        w2 = World.from_json(s)
        assert w2 == w
        rounds = None if isinstance(w.topology, TopologySchedule) else 10
        _assert_schedules_identical(w.compile(rounds, seed=3),
                                    w2.compile(rounds, seed=3))


def test_fault_from_dict_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        World.from_dict({"topology": {"kind": "graph",
                                      **ring_graph(4).to_dict()},
                         "faults": [{"kind": "meteor"}]})


# ----------------------------------------------------------- poisson churn

def test_churn_process_compiles_deterministically():
    g = ring_graph(10)
    w = World(topology=g, faults=(ChurnProcess(0.05, 0.3),))
    a = w.compile(30, seed=4)
    b = w.compile(30, seed=4)
    _assert_schedules_identical(a, b)
    c = w.compile(30, seed=5)
    assert not np.array_equal(a.alive_arr(), c.alive_arr()) \
        or not np.array_equal(a.partners, c.partners)


def test_churn_stationary_alive_fraction():
    """The per-worker chain's stationary alive probability is
    repair/(fail+repair) in hazard terms; check the realized fraction."""
    proc = ChurnProcess(fail_rate=0.1, repair_rate=0.3)
    alive = proc.sample_alive(4000, 16, seed=0)
    p_fail = 1 - np.exp(-0.1)
    p_rep = 1 - np.exp(-0.3)
    target = p_rep / (p_fail + p_rep)
    assert abs(alive[2000:].mean() - target) < 0.05
    assert alive[0].all()  # round 0 starts all-alive


def test_churn_respects_worker_subset_and_schedule_semantics():
    g = ring_graph(8)
    w = World(topology=g, faults=(ChurnProcess(0.5, 0.1, workers=(2, 5)),))
    sched = w.compile(40, seed=1)
    alive = sched.alive_arr()
    # only the eligible workers ever die
    always_up = np.ones(8, bool)
    always_up[[2, 5]] = False
    assert alive[:, always_up].all()
    assert not alive[:, [2, 5]].all()
    # dead workers join no matchings and take no gradient ticks
    gs = sched.grad_scale()
    for r in range(sched.rounds):
        for i in (2, 5):
            if not alive[r, i]:
                assert gs[r, i] == 0.0
                assert (sched.partners[r, :, i] == i).all()
    # segmentation lines up with the compiled aliveness
    segs = w.segments(40, seed=1)
    assert sum(s.rounds for s in segs) == 40
    assert len(world_banks(w, 40, seed=1)) == len(segs)


def test_zero_rate_churn_reduces_to_plain_world():
    """A ChurnProcess that never fires compiles bit-for-bit like no churn
    at all (one segment, untouched event stream) — the exact-reduction
    discipline every heterogeneous axis follows."""
    g = ring_graph(8)
    plain = World(topology=g).compile(20, seed=6)
    churned = World(topology=g,
                    faults=(ChurnProcess(0.0, 0.5),)).compile(20, seed=6)
    _assert_schedules_identical(plain, churned)
    assert churned.alive is None


# ------------------------------------------------------ bandwidth-aware links

def test_uniform_bandwidth_reproduces_builder_rates():
    for name in ("ring", "torus", "complete", "hypercube"):
        g = build_graph(name, 16)
        lm = LinkModel(bandwidth_bytes_per_s=50e9, msg_bytes=1024.0)
        np.testing.assert_allclose(lm.edge_rates(g), np.asarray(g.rates),
                                   rtol=1e-12)


def test_heterogeneous_bandwidth_rates_proportional_and_per_edge():
    g = ring_graph(8)
    bw = np.full(g.num_edges, 8e9)
    bw[0] = 1e9  # one slow link
    lm = LinkModel(bandwidth_bytes_per_s=tuple(bw), msg_bytes=128.0)
    er = lm.edge_rates(g)
    np.testing.assert_allclose(er[1:] / er[0], bw[1:] / bw[0])
    # mean worker rate normalized to 1
    np.testing.assert_allclose(2 * er.sum() / g.n, 1.0)
    # non-uniform rates auto-select the Def 3.1 per-edge path: the slow
    # link fires ~8x less often than the fast ones
    sched = World(topology=g, links=lm).compile(600, seed=0)
    from repro.core import empirical_laplacian
    L = empirical_laplacian(sched)
    i, j = g.edges[0]
    k, l = g.edges[1]
    assert -L[i, j] < 0.4 * -L[k, l]


def test_round_seconds_single_link():
    """n=2 world: one link, so wall time per round is grad_seconds plus
    events-in-round x msg/bw exactly."""
    g = ring_graph(2)
    lm = LinkModel(bandwidth_bytes_per_s=1e6, msg_bytes=1e3,
                   grad_seconds=0.5)
    w = World(topology=g, links=lm, comms_per_grad=2.0)
    sched = w.compile(12, seed=3)
    per_event = 1e3 / 1e6
    expect = 0.5 + sched.comm_events_per_round() * per_event
    np.testing.assert_allclose(w.round_seconds(sched), expect)


def test_round_seconds_spans_phase_switch():
    """Wall clock applies each segment's own graph (ring -> complete)."""
    g = ring_graph(8)
    lm = LinkModel(bandwidth_bytes_per_s=1e9, msg_bytes=4e3)
    w = World(topology=g, links=lm,
              faults=(PhaseSwitch(5, topology=build_graph("complete", 8)),))
    sched = w.compile(10, seed=0)
    rs = w.round_seconds(sched)
    assert rs.shape == (10,)
    assert (rs >= 0).all() and rs.max() > 0


def test_seconds_per_event_requires_bandwidth():
    with pytest.raises(ValueError, match="bandwidth"):
        LinkModel(rates=(1.0, 1.0)).seconds_per_event(ring_graph(2))


# --------------------------------------------------------- extras channel

def test_with_extras_validates_and_broadcasts():
    g = ring_graph(6)
    sched = make_schedule(g, rounds=5, seed=0)
    R, K, n = sched.partners.shape
    with pytest.raises(ValueError, match=r"extras\['corrupt'\]"):
        sched.with_extras(corrupt=np.zeros((R, K + 1, n)))
    s2 = sched.with_extras(stale=np.ones((R, K)))  # per-event scalar
    assert s2.extras["stale"].shape == (R, K, n)
    assert sched.extras is None  # original untouched
    s3 = s2.with_extras(corrupt=np.zeros((R, K, n), bool))
    assert set(s3.extras_dict()) == {"stale", "corrupt"}


def test_extras_survive_concat_with_padding():
    g = ring_graph(6)
    a = make_schedule(g, rounds=4, seed=0, comms_per_grad=2.0)
    b = make_schedule(g, rounds=4, seed=1, t_offset=4.0)
    Ra, Ka, n = a.partners.shape
    a = a.with_extras(corrupt=np.ones((Ra, Ka, n), np.float32))
    cat = concat_schedules([a, b])
    ext = cat.extras["corrupt"]
    assert ext.shape == cat.partners.shape
    # schedule-a rows keep their values (K-padding is zero)...
    np.testing.assert_array_equal(ext[:4, :Ka], 1.0)
    np.testing.assert_array_equal(ext[:4, Ka:], 0.0)
    # ...and schedule b (no extras) contributes zero rows
    np.testing.assert_array_equal(ext[4:], 0.0)


def test_extras_thread_through_coalesce_and_stream():
    """Every (time, partner, extra) triple a worker sees in the raw schedule
    survives coalescing, and the flattened stream carries extras rows with
    zeros at gradient ticks."""
    g = ring_graph(8)
    sched = make_schedule(g, rounds=6, seed=2, comms_per_grad=2.0)
    R, K, n = sched.partners.shape
    rng = np.random.default_rng(0)
    sched = sched.with_extras(
        tag=rng.uniform(1.0, 2.0, size=(R, K, n)).astype(np.float32))
    cs = coalesce_schedule(sched)
    assert cs.extras["tag"].shape == cs.partners.shape
    for wk in range(n):
        raw = sorted((float(sched.event_times[r, e]),
                      int(sched.partners[r, e, wk]),
                      float(sched.extras["tag"][r, e, wk]))
                     for r in range(R) for e in range(K)
                     if sched.event_mask[r, e]
                     and sched.partners[r, e, wk] != wk)
        coal = sorted((float(cs.wtimes[r, bb, wk]),
                       int(cs.partners[r, bb, wk]),
                       float(cs.extras["tag"][r, bb, wk]))
                      for r in range(R) for bb in range(cs.partners.shape[1])
                      if cs.batch_active[r, bb]
                      and cs.partners[r, bb, wk] != wk)
        assert raw == coal
    stream = coalesced_stream(cs, np.zeros(n))
    tag = stream.extras["tag"]
    assert tag.shape == (stream.steps, n)
    np.testing.assert_array_equal(tag[stream.is_grad], 0.0)
    # involved workers carry their event's value, idle workers read 0
    involved = stream.partners != np.arange(n)
    assert (tag[involved] >= 1.0).all()
    np.testing.assert_array_equal(tag[~involved], 0.0)


# ----------------------------------------------------- trainers and banks

def test_static_world_banks_and_trainer_from_world():
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.optim import sgd

    g = ring_graph(8)
    w = World(topology=g,
              workers=WorkerModel(grad_rates=np.full(8, 0.5)))
    banks = world_banks(w, rounds=5)
    assert len(banks) == 1
    np.testing.assert_array_equal(banks[0][0], matching_bank(g))

    def grad_fn(p, batch):
        return (0.5 * jnp.sum((p["w"] - batch) ** 2), None), \
            {"w": p["w"] - batch}

    tr = StackedGossipTrainer.from_world(w, grad_fn,
                                         sgd(momentum=0.0, weight_decay=0.0),
                                         backend="ref")
    assert tr.graph == g
    assert tr.grad_rates == (0.5,) * 8
    assert tr.comms_per_step == 1  # inherited from world.comms_per_grad
    assert tr.acid == params_from_graph(g, accelerated=True)
    # one step runs end to end
    state = tr.init({"w": jnp.zeros((3,), jnp.float32)},
                    jax.random.PRNGKey(0))
    batch = jnp.ones((8, 3), jnp.float32)
    state, m = jax.jit(tr.make_step())(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_trainer_from_world_rejects_phased_worlds():
    from repro.launch.gossip_train import GossipTrainer
    from repro.optim import sgd

    g = ring_graph(8)
    w = World(topology=g, faults=(ChurnProcess(0.1, 0.1),))
    with pytest.raises(ValueError, match="static_graph"):
        GossipTrainer.from_world(w, lambda p, b: (0.0, {}),
                                 sgd(momentum=0.0, weight_decay=0.0))
    # a static churn mask would leave isolated nodes -> chi1 = inf ->
    # degenerate A2CiD2 parameters, so it must be rejected too
    w2 = World(topology=g,
               workers=WorkerModel(active=[False] + [True] * 7))
    with pytest.raises(ValueError, match="all workers attached"):
        GossipTrainer.from_world(w2, lambda p, b: (0.0, {}),
                                 sgd(momentum=0.0, weight_decay=0.0))


def test_trainer_from_world_honors_comms_per_grad():
    """The declared communication rate must reach the trainer: integer
    rates map to comms_per_step, fractional ones fail loudly."""
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.optim import sgd

    g = ring_graph(8)
    opt = sgd(momentum=0.0, weight_decay=0.0)
    grad = lambda p, b: ((0.0, {}), p)
    tr = StackedGossipTrainer.from_world(World(topology=g, comms_per_grad=3),
                                         grad, opt)
    assert tr.comms_per_step == 3
    # explicit override wins — even on a fractional-rate world
    tr = StackedGossipTrainer.from_world(World(topology=g, comms_per_grad=3),
                                         grad, opt, comms_per_step=5)
    assert tr.comms_per_step == 5
    tr = StackedGossipTrainer.from_world(World(topology=g,
                                               comms_per_grad=1.5),
                                         grad, opt, comms_per_step=2)
    assert tr.comms_per_step == 2
    with pytest.raises(ValueError, match="not an integer"):
        StackedGossipTrainer.from_world(World(topology=g,
                                              comms_per_grad=1.5), grad, opt)
