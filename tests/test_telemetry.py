"""Compiled per-round telemetry — the flight recorder's in-scan side
(core/telemetry.py, DESIGN.md §15).

The contracts under test:

  * no-op pin — ``telemetry=None`` replays bitwise identically to a
    telemetry-enabled replay of the same schedule (the spec only ADDS
    columns, it never changes a replayed number), on both kernel
    backends, serial + world-batched, channel + self-healing flavors;
  * column truth — engine and per-event reference flavors agree on the
    counts; schedule columns satisfy the conservation identities
    (scheduled = applied + dropped with no rejections, participation and
    staleness histograms resum to scheduled, bytes = applied x row);
  * one-trace invariant — a telemetry-enabled ``WorldSweep`` grid still
    costs ONE jit trace and re-dispatches with zero new traces (the spec
    is a static argument, not per-world data);
  * spec plumbing — Telemetry is hashable, validates its buckets,
    round-trips JSON standalone and on ``World``;
  * AOT hook — ``Simulator.worlds_executable`` returns the exact jitted
    twin + args of the batched dispatch, lowerable without a replay.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveDefense, ChannelModel, DelayProcess,
                        Simulator, Telemetry, TelemetryTrace, World,
                        WorldSweep, params_from_graph, ring_graph,
                        trace_summary)

N, D, ROUNDS = 8, 24, 7

BACKENDS = ["ref", "pallas_interpret"]

CHANNEL = ChannelModel(delay=DelayProcess(horizon=2, prob=0.4),
                       drop_prob=0.2)


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        g = g + (0.05 * jax.random.normal(key, x.shape)).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


def _make_sim(backend="ref", robust_rule="trim"):
    g = ring_graph(N)
    b = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    return Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                     gamma=0.05, backend=backend, robust_rule=robust_rule)


def _state(sim):
    return sim.init(jnp.zeros(D), N, jax.random.PRNGKey(2))


def _assert_same_replay(a, b):
    """Final states and replayed trace columns are bitwise identical."""
    fa, ta = a
    fb, tb = b
    for la, lb in zip(jax.tree.leaves(fa.x), jax.tree.leaves(fb.x)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    np.testing.assert_array_equal(np.asarray(ta.loss), np.asarray(tb.loss))
    np.testing.assert_array_equal(np.asarray(ta.consensus),
                                  np.asarray(tb.consensus))


# ------------------------------------------------------------- no-op pins

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", [True, False])
def test_telemetry_is_bitwise_noop_serial(backend, engine):
    """Serial channel replay: telemetry on vs off never changes a
    replayed number, on either path flavor and either kernel backend."""
    sim = _make_sim(backend)
    world = World(topology=ring_graph(N), channel=CHANNEL)
    sched = world.compile(ROUNDS, seed=3)
    off = sim.run_schedule(_state(sim), sched, engine=engine)
    on = sim.run_schedule(_state(sim), sched, engine=engine,
                          telemetry=Telemetry())
    _assert_same_replay(off, on)
    assert off[1].telemetry is None
    assert on[1].telemetry is not None


@pytest.mark.parametrize("engine", [True, False])
def test_telemetry_is_bitwise_noop_batched(engine):
    """World-batched replay over a channel + defense grid: the spec adds
    columns to every world without touching the replayed dynamics."""
    sim = _make_sim()
    clean = World(topology=ring_graph(N))
    lossy = dataclasses.replace(clean, channel=CHANNEL)
    worlds = [clean, lossy, lossy]
    defs = [None, None, AdaptiveDefense(adaptive_tau=True)]
    scheds = [w.compile(ROUNDS, seed=s) for s, w in enumerate(worlds)]
    states = [_state(sim) for _ in worlds]
    off = sim.run_worlds(states, scheds, defenses=defs, engine=engine)
    on = sim.run_worlds(states, scheds, defenses=defs, engine=engine,
                        telemetry=Telemetry())
    _assert_same_replay(off, on)
    tt = on[1].telemetry
    assert tt.applied.shape == (len(worlds), ROUNDS)
    assert tt.stale_hist.shape == (len(worlds), ROUNDS,
                                   len(Telemetry().staleness_buckets) + 2)


def test_distinct_specs_same_numbers():
    """Changing WHAT is recorded (buckets, moments off) never changes the
    replay itself — only the emitted columns."""
    sim = _make_sim()
    world = World(topology=ring_graph(N), channel=CHANNEL)
    sched = world.compile(ROUNDS, seed=0)
    a = sim.run_schedule(_state(sim), sched, telemetry=Telemetry())
    b = sim.run_schedule(_state(sim), sched,
                         telemetry=Telemetry(staleness_buckets=(1, 3),
                                             norm_moments=False,
                                             bytes_moved=False))
    _assert_same_replay(a, b)
    assert b[1].telemetry.norm_sum is None
    assert b[1].telemetry.bytes_moved is None
    np.testing.assert_array_equal(np.asarray(a[1].telemetry.applied),
                                  np.asarray(b[1].telemetry.applied))


# ---------------------------------------------------------- column truth

def test_engine_and_reference_columns_agree():
    """Both path flavors meter the SAME channel: integer counts match
    exactly, the norm moments to float tolerance (different reduction
    orders over identical admitted deltas)."""
    sim = _make_sim()
    world = World(topology=ring_graph(N), channel=CHANNEL)
    sched = world.compile(ROUNDS, seed=5)
    tel = Telemetry()
    te = sim.run_schedule(_state(sim), sched, engine=True,
                          telemetry=tel)[1].telemetry
    tr = sim.run_schedule(_state(sim), sched, engine=False,
                          telemetry=tel)[1].telemetry
    np.testing.assert_array_equal(np.asarray(te.applied),
                                  np.asarray(tr.applied))
    np.testing.assert_array_equal(np.asarray(te.rejected),
                                  np.asarray(tr.rejected))
    np.testing.assert_array_equal(np.asarray(te.bytes_moved),
                                  np.asarray(tr.bytes_moved))
    np.testing.assert_allclose(np.asarray(te.norm_sum),
                               np.asarray(tr.norm_sum), rtol=1e-5)


def test_columns_satisfy_conservation():
    """Hand-countable identities on a lossy (but non-robust) world:
    every scheduled read is either applied or dropped; participation and
    the staleness histogram re-sum to the scheduled counts; the bytes
    column is applied x flat-row bytes (D f32 lanes here)."""
    sim = _make_sim()
    world = World(topology=ring_graph(N), channel=CHANNEL)
    sched = world.compile(ROUNDS, seed=7)
    tt = sim.run_schedule(_state(sim), sched,
                          telemetry=Telemetry())[1].telemetry
    applied = np.asarray(tt.applied, np.int64)
    dropped = np.asarray(tt.dropped, np.int64)
    sched_col = np.asarray(tt.scheduled, np.int64)
    assert sched_col.sum() > 0 and dropped.sum() > 0
    np.testing.assert_array_equal(applied + dropped, sched_col)
    np.testing.assert_array_equal(np.asarray(tt.rejected), 0)
    # participation + staleness bucket only the SURVIVING reads
    np.testing.assert_array_equal(tt.participation.sum(axis=-1), applied)
    np.testing.assert_array_equal(tt.stale_hist.sum(axis=-1), applied)
    assert tt.row_bytes == D * 4
    np.testing.assert_array_equal(np.asarray(tt.bytes_moved),
                                  applied * tt.row_bytes)


def test_defense_rejections_show_up_in_columns():
    """An active defense's rejected reads land in the rejected column and
    leave the applied+rejected+dropped = scheduled budget balanced."""
    sim = _make_sim()
    world = World(topology=ring_graph(N), channel=CHANNEL)
    scheds = [world.compile(ROUNDS, seed=1)]
    tt = sim.run_worlds([_state(sim)], scheds,
                        defenses=[AdaptiveDefense(adaptive_tau=True,
                                                  tau0=1e-6)],
                        telemetry=Telemetry())[1].telemetry
    applied = np.asarray(tt.applied, np.int64)
    rejected = np.asarray(tt.rejected, np.int64)
    assert rejected.sum() > 0  # the tiny tau0 actually rejects
    np.testing.assert_array_equal(
        applied + rejected + np.asarray(tt.dropped, np.int64),
        np.asarray(tt.scheduled, np.int64))


# ------------------------------------------------------ one-trace invariant

def test_sweep_grid_keeps_one_trace_with_telemetry():
    """A telemetry-enabled WorldSweep grid costs ONE jit trace, and a
    re-dispatch with the same spec costs ZERO new traces."""
    sim = _make_sim()
    base = World(topology=ring_graph(N), channel=CHANNEL)
    sweep = WorldSweep.over(
        base, channel=[dataclasses.replace(CHANNEL, drop_prob=p)
                       for p in (0.0, 0.1, 0.2)])
    worlds = list(sweep.worlds)
    scheds = sweep.compile(ROUNDS)
    tel = Telemetry()
    before = Simulator._run_worlds_channel_jit._cache_size()
    out1 = sim.run_worlds([_state(sim) for _ in worlds], scheds,
                          telemetry=tel)
    assert Simulator._run_worlds_channel_jit._cache_size() - before == 1
    out2 = sim.run_worlds([_state(sim) for _ in worlds], scheds,
                          telemetry=tel)
    assert Simulator._run_worlds_channel_jit._cache_size() - before == 1
    _assert_same_replay(out1, out2)


# ------------------------------------------------------------ spec plumbing

def test_spec_validation_and_roundtrip():
    t = Telemetry(staleness_buckets=(1, 2, 8), norm_moments=False)
    assert Telemetry.from_json(t.to_json()) == t
    assert hash(t) == hash(Telemetry.from_json(t.to_json()))
    assert {t: 1}[Telemetry(staleness_buckets=(1, 2, 8),
                            norm_moments=False)] == 1
    with pytest.raises(ValueError):
        Telemetry(staleness_buckets=(2, 1))
    with pytest.raises(ValueError):
        Telemetry(staleness_buckets=(0,))
    with pytest.raises(ValueError):
        Telemetry(staleness_buckets=("fresh",))


def test_world_carries_telemetry_through_json():
    w = World(topology=ring_graph(N), channel=CHANNEL,
              telemetry=Telemetry(staleness_buckets=(1, 4)))
    w2 = World.from_json(w.to_json())
    assert w2 == w and w2.telemetry == w.telemetry
    with pytest.raises(ValueError):
        World(topology=ring_graph(N), telemetry="yes please")


def test_trace_summary_survives_diverged_norms():
    """A diverged arm's inf/nan norm rounds are masked out of the digest
    instead of nulling it; the finite fraction is reported."""
    R = 4
    tt = TelemetryTrace(
        applied=np.full(R, 2.0), rejected=np.zeros(R),
        norm_sum=np.array([1.0, 2.0, np.inf, np.nan]),
        norm_sq_sum=np.ones(R), scheduled=np.full(R, 2),
        dropped=np.zeros(R, np.int64), stale_hist=None,
        participation=None, bytes_moved=np.full(R, 2.0 * 96),
        row_bytes=96)
    digest = trace_summary(tt)
    assert digest["admitted_norm_mean"] == pytest.approx(3.0 / 4.0)
    assert digest["norm_finite_frac"] == pytest.approx(0.5)
    assert np.isfinite(digest["admitted_norm_mean"])


# ---------------------------------------------------------------- AOT hook

def test_worlds_executable_is_the_dispatched_twin():
    """``worlds_executable`` hands back the class-level jit twin + full
    argument tuple of the batched dispatch: calling it reproduces
    ``run_worlds`` bitwise, and it AOT-lowers without a replay (the hook
    the benchmark cost rows use — ``jax.jit`` of a ``run_worlds``
    closure would trip on the host-side batching)."""
    sim = _make_sim()
    worlds = [World(topology=ring_graph(N)) for _ in range(2)]
    scheds = [w.compile(ROUNDS, seed=s) for s, w in enumerate(worlds)]
    states = [_state(sim) for _ in worlds]
    fn, args = sim.worlds_executable(states, scheds)
    _assert_same_replay(fn(*args), sim.run_worlds(states, scheds))
    hlo = fn.lower(*args).compile().as_text()
    assert "ENTRY" in hlo or "HloModule" in hlo

    # the channel flavor (telemetry forces it) lowers too, spec static
    lossy = World(topology=ring_graph(N), channel=CHANNEL)
    lscheds = [lossy.compile(ROUNDS, seed=0)]
    cfn, cargs = sim.worlds_executable([_state(sim)], lscheds,
                                       telemetry=Telemetry())
    assert cargs[-1] == Telemetry()
    assert cfn.lower(*cargs).compile().as_text()
