"""Gossip-serving fleet (launch/fleet.py, DESIGN.md §14).

Pins the subsystem's three contracts: the fleet's gossip side IS the
simulator's channel replay (bitwise bank equality on a lossy world), a
mid-serve churn kill degrades but never loses requests, and with gossip
and drift off every replica's token streams are exactly the sequential
``generate`` ones.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.nano_lm import train_bench
from repro.core import (Algorithm, ChannelModel, DelayProcess, PhaseSwitch,
                        SERVE_ARRIVE_KEY, ServeLoad, SimState, World,
                        ring_graph)
from repro.launch.fleet import GossipFleet
from repro.launch.serve import generate
from repro.models import Model

LOAD = ServeLoad(rate=0.8, prompt_len=(2, 4), gen_len=(2, 5))


def _model_params(seed=0):
    model = Model(train_bench())
    return model, model.init(jax.random.PRNGKey(seed))


def test_fleet_bank_is_the_channel_replay_bitwise():
    """Round-by-round fleet gossip == one run_schedule scan on the same
    lossy schedule: identical final (W, D) bank and consensus trace."""
    model, params = _model_params()
    world = World(topology=ring_graph(4), algorithm=Algorithm("a2cid2"),
                  channel=ChannelModel(delay=DelayProcess(horizon=2,
                                                          prob=0.4),
                                       drop_prob=0.1),
                  serve=LOAD)
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="perturb", drift_scale=0.02)
    rep = fleet.run(rounds=12, seed=3)

    sched = world.compile(12, seed=3)
    state = SimState(x=fleet._bank0, x_tilde=jnp.array(fleet._bank0),
                     t_last=jnp.zeros((4,)), key=jax.random.PRNGKey(3))
    out, trace = fleet.sim.run_schedule(state, sched, engine=False)
    assert np.array_equal(np.asarray(rep.final_bank), np.asarray(out.x))
    # consensus keeps recording through the drain phase: the scheduled
    # prefix is the replay's trace bitwise, the drain tail is the frozen
    # bank's (constant) consensus
    assert rep.consensus.size == rep.rounds + rep.drain_rounds
    assert np.array_equal(rep.consensus[:rep.rounds],
                          np.asarray(trace.consensus, np.float64))
    if rep.drain_rounds:
        tail = rep.consensus[rep.rounds:]
        assert np.all(tail == tail[0])


def test_churn_kill_readmits_without_loss():
    """Killing a replica mid-serve evicts its queued + in-flight requests
    to survivors: every request still completes (restarts, not loss)."""
    model, params = _model_params()
    world = World(topology=ring_graph(3),
                  faults=(PhaseSwitch(6, active=(True, True, False)),),
                  serve=ServeLoad(rate=1.5, prompt_len=(3, 5),
                                  gen_len=(4, 8), arrive_frac=0.8))
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="perturb", drift_scale=0.02)
    rep = fleet.run(rounds=14, seed=0)
    assert rep.requests_total > 0
    assert rep.lost == 0
    assert len(rep.completed) == rep.requests_total
    assert rep.restarted >= 1  # the kill caught work in flight
    assert all(q.done and len(q.out) == q.max_new for q in rep.completed)


def test_gossip_off_fleet_matches_sequential_generate():
    """comms_per_grad=0 + drift='none' freezes the bank, so each replica
    is a plain decode server: every request's tokens must be bitwise the
    single-model ``generate`` stream."""
    model, params = _model_params()
    world = World(topology=ring_graph(3), algorithm=Algorithm("adpsgd"),
                  comms_per_grad=0.0, serve=LOAD)
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="none")
    rep = fleet.run(rounds=10, seed=1)
    assert np.array_equal(np.asarray(rep.final_bank),
                          np.asarray(fleet._bank0))
    assert rep.lost == 0 and rep.requests_total > 0
    for q in rep.completed:
        ref = generate(model, params, jnp.asarray(q.prompt)[None, :],
                       q.max_new)
        assert q.out == jax.device_get(
            ref[0, len(q.prompt):]).tolist(), q.uid


def test_stalled_replicas_keep_inflight_caches_intact():
    """A replica paying comm debt is fed through the vmapped step as
    all-padding (tokens 0, pos 0, active False); its in-flight slots' KV
    rows and recurrent states must survive the stall.  Identical initial
    banks + drift='none' make gossip a no-op on the parameters, so every
    completed stream must still be bitwise ``generate``'s."""
    model, params = _model_params()
    world = World(topology=ring_graph(3), algorithm=Algorithm("adpsgd"),
                  serve=LOAD)
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="none", stall_per_event=1.0)
    rep = fleet.run(rounds=12, seed=1)
    assert rep.stall_skips > 0  # stalls actually happened mid-serve
    assert rep.lost == 0 and rep.requests_total > 0
    assert np.array_equal(np.asarray(rep.final_bank),
                          np.asarray(fleet._bank0))
    for q in rep.completed:
        ref = generate(model, params, jnp.asarray(q.prompt)[None, :],
                       q.max_new)
        assert q.out == jax.device_get(
            ref[0, len(q.prompt):]).tolist(), q.uid


def test_whole_fleet_dead_reports_loss_without_drain_spin():
    """When every replica is dead at the end of the schedule, parked
    requests are unrecoverable: the drain loop must report them lost
    immediately instead of spinning max_drain_rounds no-op iterations."""
    model, params = _model_params()
    world = World(topology=ring_graph(2),
                  faults=(PhaseSwitch(2, active=(False, False)),),
                  serve=ServeLoad(rate=1.0, prompt_len=(2, 3),
                                  gen_len=(2, 3)))
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="none")
    rep = fleet.run(rounds=8, seed=0)
    assert rep.requests_total > 0
    assert rep.lost > 0           # honest accounting, not silent hang
    assert rep.drain_rounds == 0  # no no-op spin


def test_fleet_ttft_breakdown_sums_and_bounds():
    """Per-request TTFT splits exactly into admission wait + decode time,
    never exceeds the end-to-end latency, and rides the summary with its
    percentiles.  A tracer + metrics registry attached to the same run
    produce a schema-valid trace and a parseable exposition whose
    request counter matches the report."""
    from repro.analysis import (MetricsRegistry, SpanTracer,
                                parse_exposition, validate_trace)
    model, params = _model_params()
    world = World(topology=ring_graph(3), algorithm=Algorithm("adpsgd"),
                  serve=ServeLoad(rate=1.2, prompt_len=(2, 4),
                                  gen_len=(2, 5)))
    fleet = GossipFleet(model, params, world, max_batch=2, max_len=16,
                        drift="perturb", drift_scale=0.02)
    tracer = SpanTracer("fleet-test")
    registry = MetricsRegistry()
    rep = fleet.run(rounds=12, seed=2, tracer=tracer, metrics=registry)

    assert rep.ttft.size == len(rep.completed) > 0
    np.testing.assert_array_equal(rep.ttft_wait + rep.ttft_decode,
                                  rep.ttft)
    assert np.all(rep.ttft >= 1)
    assert np.all(rep.ttft <= rep.latencies)
    s = rep.summary()
    assert s["ttft_p50"] <= s["ttft_p95"] <= s["ttft_p99"]
    assert s["ttft_wait_mean"] + s["ttft_decode_mean"] == \
        pytest.approx(s["ttft_mean"])

    validate_trace(tracer.to_dict())
    assert any(e["name"] == "fleet.round" for e in tracer.events)
    parsed = parse_exposition(registry.exposition())
    assert parsed["fleet_requests_total"][""] == rep.requests_total
    assert parsed["fleet_ttft_rounds_count"][""] == len(rep.completed)


def test_serveload_trace_is_shared_and_serializes():
    """Every world built from the same ServeLoad + seed compiles the
    identical arrival extras (the one-trace comparison contract), and the
    serve axis rides World JSON round-trips."""
    load = ServeLoad(rate=1.2, prompt_len=(3, 6), gen_len=(4, 10))
    clean = World(topology=ring_graph(4), serve=load)
    lossy = dataclasses.replace(
        clean, channel=ChannelModel(delay=DelayProcess(horizon=2, prob=0.3),
                                    drop_prob=0.1))
    a = clean.compile(20, seed=5).extras_dict()[SERVE_ARRIVE_KEY]
    b = lossy.compile(20, seed=5).extras_dict()[SERVE_ARRIVE_KEY]
    assert np.array_equal(a, b)
    t1, t2 = load.sample_trace(20, 5), load.sample_trace(20, 5)
    assert np.array_equal(t1.arrival_round, t2.arrival_round)
    assert np.array_equal(t1.prompt_len, t2.prompt_len)
    assert np.array_equal(t1.gen_len, t2.gen_len)
    assert t1.num_requests == int(a[:, 0, 0].sum())

    w2 = World.from_json(lossy.to_json())
    assert w2 == lossy and w2.to_dict() == lossy.to_dict()
    c = w2.compile(20, seed=5).extras_dict()[SERVE_ARRIVE_KEY]
    assert np.array_equal(a, c)
