"""Continuous-batching serving scheduler."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.batching import ContinuousBatcher, Request
from repro.models import Model


def test_continuous_batcher_drains_mixed_requests():
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, params, max_batch=4, max_len=64)
    key = jax.random.PRNGKey(1)
    reqs = []
    for uid, (plen, gen) in enumerate([(4, 6), (8, 3), (2, 10), (5, 5),
                                       (3, 4), (6, 2)]):  # > max_batch
        prompt = jax.random.randint(jax.random.fold_in(key, uid), (plen,),
                                    0, cfg.vocab_size, jnp.int32)
        r = Request(uid, prompt, gen)
        reqs.append(r)
        b.submit(r)
    done = b.run_until_drained()
    assert len(done) == len(reqs)
    for r in reqs:
        assert r.done
        assert len(r.out) == r.max_new
        assert all(0 <= t < cfg.vocab_size for t in r.out)
