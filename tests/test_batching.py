"""Continuous-batching serving scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.batching import (ContinuousBatcher, Request,
                                   SlotScheduler)
from repro.launch.serve import generate
from repro.models import Model


def test_continuous_batcher_drains_mixed_requests():
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, params, max_batch=4, max_len=64)
    key = jax.random.PRNGKey(1)
    reqs = []
    for uid, (plen, gen) in enumerate([(4, 6), (8, 3), (2, 10), (5, 5),
                                       (3, 4), (6, 2)]):  # > max_batch
        prompt = jax.random.randint(jax.random.fold_in(key, uid), (plen,),
                                    0, cfg.vocab_size, jnp.int32)
        r = Request(uid, prompt, gen)
        reqs.append(r)
        b.submit(r)
    done = b.run_until_drained()
    assert len(done) == len(reqs)
    for r in reqs:
        assert r.done
        assert len(r.out) == r.max_new
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_staggered_admission_matches_sequential_generate():
    """The acceptance pin: requests admitted mid-flight into a running
    batch (each slot at its OWN position) produce token streams bitwise
    equal to what sequential ``generate`` gives each request alone."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = ContinuousBatcher(model, params, max_batch=2, max_len=32)
    key = jax.random.PRNGKey(3)
    reqs = [Request(uid, jax.random.randint(jax.random.fold_in(key, uid),
                                            (plen,), 0, cfg.vocab_size,
                                            jnp.int32), gen)
            for uid, (plen, gen) in enumerate([(5, 6), (3, 8), (4, 5)])]
    b.submit(reqs[0])
    b.step()
    b.step()                      # req 0 is mid-prompt at pos 2...
    b.submit(reqs[1])             # ...when req 1 joins the batch
    b.submit(reqs[2])             # req 2 waits for a slot to free up
    b.run_until_drained()
    for r in reqs:
        assert r.done and len(r.out) == r.max_new
        ref = generate(model, params, r.prompt[None, :], r.max_new)
        assert r.out == jax.device_get(
            ref[0, len(r.prompt):]).tolist(), r.uid


def test_submit_rejects_request_exceeding_max_len():
    """A request that cannot finish with its full max_new inside max_len
    is rejected at submit() instead of silently truncated (mirrors the
    GossipFleet ServeLoad range check)."""
    s = SlotScheduler(max_batch=2, max_len=8)
    s.submit(Request(0, np.arange(3, dtype=np.int32), 4))  # 3+4+1 == 8: ok
    with pytest.raises(ValueError, match="max_len"):
        s.submit(Request(1, np.arange(4, dtype=np.int32), 4))  # 4+4+1 > 8


def test_slot_scheduler_invariants():
    """Under ANY interleaving of submissions and steps, every request
    finishes exactly once with exactly max_new tokens — no loss, no
    duplicates, no starvation."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                    min_size=1, max_size=12),
           st.lists(st.booleans(), max_size=40),
           st.integers(1, 4))
    def run(specs, interleave, max_batch):
        s = SlotScheduler(max_batch, max_len=16)
        reqs = [Request(i, np.arange(p, dtype=np.int32), g)
                for i, (p, g) in enumerate(specs)]
        waiting = list(reversed(reqs))
        choices = iter(interleave)
        for _ in range(1000):
            if not waiting and not s.pending():
                break
            if waiting and (next(choices, False) or not s.pending()):
                s.submit(waiting.pop())
            else:
                toks, pos, act = s.prepare()
                s.absorb(np.full(max_batch, 7, np.int32))
        assert not waiting and not s.pending()
        assert sorted(r.uid for r in s.finished) == list(range(len(reqs)))
        assert all(r.done and len(r.out) == r.max_new for r in reqs)

    run()
