"""Event schedules + the discrete-event simulator (the faithful repro)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Simulator, allreduce_sgd, empirical_laplacian,
                        make_schedule, params_from_graph, ring_graph,
                        worker_mean)


def _quadratic_grad_fn(b, noise=0.0):
    def grad_fn(x, key, wid):
        g = (x - b[wid])
        if noise:
            g = g + noise * jax.random.normal(key, x.shape)
        return 0.5 * jnp.sum((x - b[wid]) ** 2), g
    return grad_fn


def test_schedule_comm_count_matches_trace_lambda():
    """Expected #communications = Tr(Lambda)/2 * T (Prop 3.6 bookkeeping)."""
    g = ring_graph(16)
    T = 300
    sched = make_schedule(g, rounds=T, comms_per_grad=1.0, seed=0)
    expected = g.total_rate() * T
    assert sched.num_comm_events() == pytest.approx(expected, rel=0.15)


def test_empirical_laplacian_matches_expected():
    """The paper's App E.2 check: realized matchings ~ uniform over edges."""
    g = ring_graph(8)
    sched = make_schedule(g, rounds=600, comms_per_grad=1.0, seed=1)
    L_emp = empirical_laplacian(sched)
    L = g.laplacian()
    # same sparsity pattern, rates within 25%
    assert np.all((np.abs(L_emp) > 1e-9) == (np.abs(L) > 1e-9))
    nz = np.abs(L) > 1e-9
    assert np.allclose(L_emp[nz], L[nz], rtol=0.3)


def test_tracker_identity_exact_at_common_clock():
    """mean(x) == mean(x~) at synchronized measurement times (Eq 5)."""
    n, d = 8, 8
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sched = make_schedule(g, rounds=60, comms_per_grad=1.0, seed=0,
                          jitter_grad_times=False)
    sim = Simulator(_quadratic_grad_fn(b), params_from_graph(g, True),
                    gamma=0.05)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    fin, _ = sim.run_schedule(st, sched)
    xbar, tbar = worker_mean(fin.x), worker_mean(fin.x_tilde)
    np.testing.assert_allclose(xbar, tbar, atol=1e-5)


def test_simulator_converges_to_consensus_optimum():
    n, d = 8, 16
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    x_star = jnp.mean(b, axis=0)
    g = ring_graph(n)
    sched = make_schedule(g, rounds=300, comms_per_grad=1.0, seed=0)
    sim = Simulator(_quadratic_grad_fn(b, noise=0.02),
                    params_from_graph(g, True), gamma=0.05)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    fin, trace = sim.run_schedule(st, sched)
    err = float(jnp.sum((worker_mean(fin.x) - x_star) ** 2))
    assert err < 1e-2
    assert float(trace.loss[-1]) < float(trace.loss[0])


def test_acid_beats_baseline_consensus_on_ring():
    """The paper's central claim at equal comm rate: A2CiD2 lowers consensus
    distance vs the asynchronous baseline on the poorly-connected ring."""
    n, d = 16, 32
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sched = make_schedule(g, rounds=300, comms_per_grad=1.0, seed=0)
    results = {}
    for accel in (False, True):
        sim = Simulator(_quadratic_grad_fn(b, noise=0.05),
                        params_from_graph(g, accelerated=accel), gamma=0.05)
        st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
        _, trace = sim.run_schedule(st, sched)
        results[accel] = float(jnp.mean(trace.consensus[-50:]))
    assert results[True] < 0.75 * results[False]


def test_allreduce_baseline_converges():
    n, d = 8, 8
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    x, losses = allreduce_sgd(_quadratic_grad_fn(b), 0.1, jnp.zeros(d), n,
                              200, jax.random.PRNGKey(0))
    np.testing.assert_allclose(x, jnp.mean(b, 0), atol=1e-3)
    assert float(losses[-1]) < float(losses[0])
