"""Serving decode path: chunked prefill + per-slot decode positions.

Pins the two decode-side rewrites the batching scheduler depends on:
``Model.prefill`` (one jitted scan over the prompt) is bitwise the old
token-by-token loop, and ``decode_step`` honors a per-slot (B,) position
vector — each batch row decodes at its OWN cache position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import Model


def _naive_generate(model, params, prompts, gen):
    """The pre-prefill reference: feed the prompt one token at a time."""
    cfg = model.cfg
    B, P = prompts.shape
    caches = model.init_cache(B, P + gen)
    dec = jax.jit(model.decode_step)
    logits = None
    for t in range(P):
        logits, caches = dec(params, prompts[:, t:t + 1], jnp.int32(t),
                             caches)
    out = [prompts]
    for t in range(P, P + gen):
        cur = jnp.argmax(logits[:, 0, :cfg.vocab_size], axis=-1)
        cur = cur[:, None].astype(jnp.int32)
        out.append(cur)
        logits, caches = dec(params, cur, jnp.int32(t), caches)
    return jnp.concatenate(out, axis=1)


@pytest.mark.parametrize("window", [None, 8])
def test_chunked_prefill_ids_match_token_loop(window):
    cfg = get_config("qwen3-0.6b", reduced=True)
    if window:
        cfg = cfg.windowed(window)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 7), 0,
                                 cfg.vocab_size, jnp.int32)
    got = generate(model, params, prompts, gen=6)
    ref = _naive_generate(model, params, prompts, gen=6)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_decode_step_per_slot_positions():
    """A (B,) position vector decodes each row at its own position: row 0
    at pos 5 and row 1 at pos 2 in ONE batch must equal two independent
    single-row decodes, bitwise."""
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dec = jax.jit(model.decode_step)
    k = jax.random.PRNGKey(2)
    t = jax.random.randint(k, (6,), 0, cfg.vocab_size, jnp.int32)
    u = jax.random.randint(jax.random.fold_in(k, 1), (3,), 0,
                           cfg.vocab_size, jnp.int32)

    # references at the SAME batch shape (both rows duplicated, scalar
    # pos) so every per-row float reduction is the identical XLA program
    def duo(stream):
        caches = model.init_cache(2, 16)
        for i, tok in enumerate(stream):
            logits, caches = dec(params, jnp.full((2, 1), tok, jnp.int32),
                                 jnp.int32(i), caches)
        return logits

    ref_a, ref_b = duo(t), duo(u)

    # batched: row 1 finishes its stream early and re-feeds its last token
    # at its frozen position while row 0 keeps advancing — exactly what a
    # staggered slot batch does between absorb steps
    caches = model.init_cache(2, 16)
    for i in range(6):
        j = min(i, 2)
        toks = jnp.stack([t[i], u[j]])[:, None]
        pos = jnp.asarray([i, j], jnp.int32)
        logits, caches = dec(params, toks, pos, caches)
    assert np.array_equal(np.asarray(logits[0]), np.asarray(ref_a[0]))
    assert np.array_equal(np.asarray(logits[1]), np.asarray(ref_b[0]))
