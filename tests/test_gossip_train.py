"""Gossip trainers: functional convergence (single device) + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus_distance, params_from_graph, ring_graph
from repro.launch.gossip_train import StackedGossipTrainer
from repro.optim import sgd


def _setup(accelerated, lr=0.1, comms=1, n=8, d=16):
    g = ring_graph(n)
    acid = params_from_graph(g, accelerated=accelerated)
    b = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)), jnp.float32)

    def grad_fn(params, batch):
        err = params["w"] - batch
        return (0.5 * jnp.sum(err ** 2), None), {"w": err}

    tr = StackedGossipTrainer(grad_fn, sgd(momentum=0.0, weight_decay=0.0),
                              g, acid, lr=lr, comms_per_step=comms)
    state = tr.init({"w": jnp.zeros((d,))}, jax.random.PRNGKey(0))
    return tr, state, b


def test_stacked_trainer_converges_to_mean_target():
    tr, state, b = _setup(accelerated=True)
    step = jax.jit(tr.make_step())
    for _ in range(300):
        state, m = step(state, b)
    xbar = jnp.mean(state.x["w"], axis=0)
    assert float(jnp.max(jnp.abs(xbar - jnp.mean(b, 0)))) < 0.05


def test_stacked_trainer_acid_beats_baseline_consensus():
    results = {}
    for accel in (False, True):
        tr, state, b = _setup(accelerated=accel, n=16, d=32)
        step = jax.jit(tr.make_step())
        cons = []
        for i in range(200):
            state, m = step(state, b)
            if i >= 150:
                cons.append(float(consensus_distance(state.x)))
        results[accel] = float(np.mean(cons))
    assert results[True] < results[False]


def test_stacked_trainer_gossip_preserves_mean():
    """With lr=0 the global mean must be exactly invariant (tracker, Eq 5)."""
    tr, state, b = _setup(accelerated=True, lr=0.0, comms=3)
    # de-synchronize the workers first
    state = state._replace(
        x={"w": jax.random.normal(jax.random.PRNGKey(1), state.x["w"].shape)})
    state = state._replace(x_tilde=jax.tree.map(jnp.copy, state.x))
    mean0 = jnp.mean(state.x["w"], axis=0)
    step = jax.jit(tr.make_step())
    for _ in range(50):
        state, _ = step(state, b)
    np.testing.assert_allclose(jnp.mean(state.x["w"], axis=0), mean0,
                               atol=1e-4)
