"""Self-healing gossip defense (core/defense.py; DESIGN.md §12).

The contracts under test:

  * exact reduction — ``defense=None`` and neutral knobs replay bit-for-bit
    as the PR 4/5 static paths, serially and in the world batch;
  * the sign-flip gap — the scenario where a static trim provably passes
    the attack (corrupted norm 2||x|| under tau) while the adaptive
    quantile-tracking tau contains it at the clean consensus level;
  * the control loop — quarantine convicts a persistent attacker, heals
    after the attack stops, and the estimator cannot ratchet itself shut;
  * equivalence — engine vs per-event reference, serial vs batched, jnp
    oracle vs Pallas interpret kernel (the new rejection-mask output);
  * one trace — a mixed none/static/adaptive grid rides the batched
    replay as a single compiled dispatch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveDefense, ByzantineEdges, ChannelModel,
                        DelayProcess, Simulator, World, degradation_profile,
                        params_from_graph, ring_graph)
from repro.core.channel import CORRUPT_KEY
from repro.kernels.a2cid2_mixing.kernel import channel_gossip_stacked
from repro.kernels.a2cid2_mixing.ref import channel_gossip_stacked_ref


def _quad_grad_fn(b, noise=0.0):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        if noise:
            g = g + noise * jax.random.normal(key, g.shape).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn

def _sim(n, d, backend="ref", robust_clip=None, noise=0.0, seed=1,
         shared=False):
    g = ring_graph(n)
    b = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    if shared:  # consensus objective: every worker pulls to the same optimum
        b = jnp.broadcast_to(b[0], (n, d))
    sim = Simulator(_quad_grad_fn(b, noise), params_from_graph(g),
                    gamma=0.05, backend=backend, robust_clip=robust_clip)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    return g, sim, st


# ------------------------------------------------------------- validation

def test_validation_names_the_offending_field():
    for kw in ({"q": 0.0}, {"quantile": 0.0}, {"quantile": 1.5},
               {"beta": 0.0}, {"tau0": -1.0}, {"rho": 2.0},
               {"trust_floor": 1.0}, {"heal": -0.1},
               {"comm_lo": 0.0}, {"comm_lo": 0.9, "comm_hi": 0.5},
               {"comm_degrade": -1.0}):
        with pytest.raises(ValueError):
            AdaptiveDefense(**kw)
    with pytest.raises(ValueError, match="defense must be"):
        World(topology=ring_graph(8), defense="paranoid")


def test_defense_requires_trim_rule():
    """The feedback loop reasons about whole-delta accept/reject; clip and
    coord rescale instead, so an active defense demands the trim rule."""
    g, sim, st = _sim(8, 6, robust_clip=1.0)
    sim = dataclasses.replace(sim, robust_rule="clip")
    w = World(topology=g, defense=AdaptiveDefense())
    with pytest.raises(ValueError, match="trim"):
        sim.run_world(st, w, 4, seed=0)
    with pytest.raises(ValueError, match="trim"):
        sim.run_worlds([st], [w.compile(4, seed=0)],
                       defenses=[AdaptiveDefense()])


# ---------------------------------------------------------- serialization

def test_defense_json_round_trip():
    specs = [AdaptiveDefense(),
             AdaptiveDefense(tau0=2.5, q=4.0, quantile=0.75, beta=0.1),
             AdaptiveDefense(adaptive_tau=False, trust=True, rho=0.5),
             AdaptiveDefense(comm_lo=0.5, comm_hi=2.0, comm_degrade=1.0)]
    for d in specs:
        d2 = AdaptiveDefense.from_json(d.to_json())
        assert d2 == d
    # inf tau0 has no JSON literal: round-trips through None
    assert AdaptiveDefense().to_dict()["tau0"] is None
    assert AdaptiveDefense.from_dict({"tau0": None}).tau0 == float("inf")


def test_defense_world_json_round_trip():
    g = ring_graph(8)
    w = World(topology=g,
              channel=ChannelModel(adversary=ByzantineEdges(g.edges[:2])),
              defense=AdaptiveDefense(tau0=3.0, comm_lo=0.5, comm_hi=1.0))
    w2 = World.from_json(w.to_json())
    assert w2 == w
    a, b = w.compile(10, seed=3), w2.compile(10, seed=3)
    np.testing.assert_array_equal(a.partners, b.partners)
    for k in a.extras_dict():
        np.testing.assert_array_equal(a.extras[k], b.extras[k])
    # a defense-free world keeps the old wire format readable both ways
    plain = World(topology=g)
    assert World.from_json(plain.to_json()) == plain


# --------------------------------------------------------- exact reduction

def _attack_world(g, mode="scale", scale=1e3, prob=0.5, frac=None):
    E = len(g.edges)
    k = max(1, int(round((frac or 0.1) * E)))
    picks = np.linspace(0, E, k, endpoint=False).astype(int)
    edges = tuple(g.edges[i] for i in picks)
    return World(topology=g, channel=ChannelModel(
        adversary=ByzantineEdges(edges, mode, scale=scale, prob=prob)))


def test_defense_none_is_bitwise_the_static_path():
    """defense=None (serial and batched) replays bit-for-bit as the PR 4/5
    paths and attaches no DefenseTrace."""
    n, d = 8, 10
    g, sim, st = _sim(n, d, robust_clip=5.0)
    sched = _attack_world(g).compile(15, seed=0)
    fin0, tr0 = sim.run_schedule(st, sched)
    fin1, tr1 = sim.run_schedule(st, sched, defense=None)
    assert tr0.defense is None and tr1.defense is None
    np.testing.assert_array_equal(np.asarray(fin0.x), np.asarray(fin1.x))
    np.testing.assert_array_equal(np.asarray(tr0.consensus),
                                  np.asarray(tr1.consensus))
    # batched: an explicit all-None defenses kwarg routes through the
    # PR 5 dispatch untouched
    _, trb = sim.run_worlds([st, st], [sched, sched])
    _, trn = sim.run_worlds([st, st], [sched, sched], defenses=[None, None])
    assert trb.defense is None and trn.defense is None
    np.testing.assert_array_equal(np.asarray(trb.consensus),
                                  np.asarray(trn.consensus))
    np.testing.assert_array_equal(np.asarray(trb.consensus[0]),
                                  np.asarray(tr0.consensus))


def test_neutral_arms_in_defense_grid_are_bitwise_static():
    """Inside an ACTIVE defense grid, the none/static arms still reproduce
    their serial static replays bit-for-bit — the neutral knobs degenerate
    to the static trim arithmetic, not merely approximate it."""
    n, d = 8, 10
    g, sim, st = _sim(n, d)
    sched = _attack_world(g).compile(20, seed=0)
    _, trb = sim.run_worlds([st] * 3, [sched] * 3,
                            robust_clips=[None, 5.0, 5.0],
                            defenses=[None, None, AdaptiveDefense()])
    _, tr_plain = sim.run_schedule(st, sched)
    sim5 = dataclasses.replace(sim, robust_clip=5.0)
    _, tr_static = sim5.run_schedule(st, sched)
    np.testing.assert_array_equal(np.asarray(trb.consensus[0]),
                                  np.asarray(tr_plain.consensus))
    np.testing.assert_array_equal(np.asarray(trb.consensus[1]),
                                  np.asarray(tr_static.consensus))
    # defense trace rows exist for every arm; the neutral arms never
    # quarantine and count only their static trim rejections
    assert np.asarray(trb.defense.quarantined[:2]).sum() == 0.0
    assert np.isinf(np.asarray(trb.defense.tau[0])).all()
    assert (np.asarray(trb.defense.tau[1]) == 5.0).all()


def test_gamma_and_clip_lift_bitwise():
    """Satellite: per-world gammas / robust_clips reproduce the serial
    replays bit-for-bit through the batched dispatch."""
    n, d = 8, 10
    g, sim, st = _sim(n, d)
    sched = _attack_world(g).compile(12, seed=1)
    _, trb = sim.run_worlds([st, st], [sched, sched], gammas=[0.05, 0.11],
                            robust_clips=[None, 4.0])
    _, tr0 = sim.run_schedule(st, sched)
    simc = dataclasses.replace(sim, gamma=0.11, robust_clip=4.0)
    _, tr1 = simc.run_schedule(st, sched)
    np.testing.assert_array_equal(np.asarray(trb.consensus[0]),
                                  np.asarray(tr0.consensus))
    np.testing.assert_array_equal(np.asarray(trb.consensus[1]),
                                  np.asarray(tr1.consensus))


# ------------------------------------------------------- the sign-flip gap

def test_adaptive_tau_contains_the_sign_flip_attack():
    """THE tentpole scenario.  A sign-flip adversary (received value
    negated) emits deltas of norm ||x + xp|| ~ 2||x||, under any static
    tau loose enough for honest traffic — here tau=5 vs 2||b|| ~ 3.4, so
    the static arm is BITWISE the undefended arm.  The adaptive tau tracks
    the honest median toward the noise floor and throws the attack out."""
    n, d, rounds = 32, 32, 150
    g = ring_graph(n)
    b = np.broadcast_to(0.3 * np.ones(d, np.float32), (n, d))
    sim = Simulator(_quad_grad_fn(jnp.asarray(b), noise=0.05),
                    params_from_graph(g), gamma=0.05, backend="ref")
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    w_att = _attack_world(g, mode="sign_flip", scale=1.0, prob=1.0)
    sched = w_att.compile(rounds, seed=0)
    clean = World(topology=g).compile(rounds, seed=0)
    _, tr = sim.run_worlds([st] * 4, [clean, sched, sched, sched],
                           robust_clips=[None, None, 5.0, 5.0],
                           defenses=[None, None, None, AdaptiveDefense()])
    cons = np.asarray(tr.consensus)
    tails = np.nanmean(cons[:, -30:], axis=1)
    # static trim provably passes the attack: bitwise the undefended arm
    np.testing.assert_array_equal(cons[1], cons[2])
    assert np.asarray(tr.defense.rejections[2]).sum() == 0.0
    # the attack visibly breaks consensus, adaptive restores it
    assert tails[1] > 20.0 * tails[0]
    assert tails[3] < 3.0 * tails[0]
    # the loop did its job through both controllers
    assert np.asarray(tr.defense.rejections[3]).sum() > 0
    assert np.asarray(tr.defense.quarantined[3]).sum() > 0
    assert np.asarray(tr.defense.tau[3])[-1] < 5.0


def test_adaptive_matches_static_on_garbage_injection():
    """Where the static trim IS sufficient (scale-1e3 garbage), adaptive
    keeps the same containment — the cold-start tau is never looser than
    the static threshold, so round 0 cannot poison the estimator seed."""
    n, d, rounds = 16, 16, 60
    g, sim, st = _sim(n, d, noise=0.01)
    sched = _attack_world(g, mode="scale", scale=1e3, prob=0.5
                          ).compile(rounds, seed=0)
    _, tr = sim.run_worlds([st] * 3, [sched] * 3,
                           robust_clips=[None, 5.0, 5.0],
                           defenses=[None, None, AdaptiveDefense()])
    tails = np.nanmean(np.asarray(tr.consensus)[:, -15:], axis=1)
    assert not np.isfinite(tails[0]) or tails[0] > 1e3 * tails[1]
    assert tails[2] < 10.0 * tails[1]


# ------------------------------------------------------- the control loop

def test_quarantine_convicts_then_heals_after_probation():
    """A persistently corrupt edge (prob=1 — duty-cycle attackers are
    rejected per-event but deliberately NOT convicted, their trust hovers
    at the duty ratio) is quarantined; once the attack stops (corrupt
    extras zeroed mid-schedule) probation healing re-admits it and the
    tail runs quarantine-free."""
    n, d, rounds, stop = 8, 16, 120, 30
    g, sim, st = _sim(n, d, robust_clip=5.0, noise=0.01, shared=True)
    sched = _attack_world(g, mode="scale", scale=1e3, prob=1.0,
                          frac=1 / len(g.edges)).compile(rounds, seed=0)
    c = np.array(sched.extras[CORRUPT_KEY])
    c[stop:] = 0.0
    sched = dataclasses.replace(sched,
                                extras={**sched.extras, CORRUPT_KEY: c})
    _, tr = sim.run_schedule(st, sched, defense=AdaptiveDefense())
    quar = np.asarray(tr.defense.quarantined)
    assert quar[:stop].sum() > 0          # convicted during the attack
    assert quar[-30:].sum() == 0.0        # healed once it went honest
    assert float(np.asarray(tr.consensus)[-1]) < 1e-2


def test_estimator_does_not_ratchet_shut_on_clean_traffic():
    """The failure mode the admitted-norms estimator exists to prevent:
    on a long CLEAN run the adaptive tau must keep (nearly) all honest
    exchanges admitted rather than shrinking its own input distribution
    until everything is rejected."""
    n, d, rounds = 16, 16, 120
    g, sim, st = _sim(n, d, noise=0.05)
    sched = World(topology=g).compile(rounds, seed=0)
    _, tr = sim.run_schedule(st, sched, defense=AdaptiveDefense())
    rej = np.asarray(tr.defense.rejections)
    quar = np.asarray(tr.defense.quarantined)
    events_per_round = (np.asarray(sched.partners)
                        != np.arange(n)).sum() / rounds
    assert quar.sum() == 0.0
    assert rej[-60:].mean() < 0.10 * events_per_round
    _, tr_plain = sim.run_schedule(st, sched)
    tail = float(np.mean(np.asarray(tr.consensus)[-20:]))
    tail_plain = float(np.mean(np.asarray(tr_plain.consensus)[-20:]))
    assert tail < 3.0 * tail_plain


# ------------------------------------------------------- comm controller

def test_comm_control_thins_the_compiled_schedule():
    g = ring_graph(8)
    base = World(topology=g, comms_per_grad=2.0)
    ctl = AdaptiveDefense(adaptive_tau=False, trust=False,
                          comm_lo=0.5, comm_hi=2.0)
    w = dataclasses.replace(base, comms_per_grad=1.0, defense=ctl)
    plain = dataclasses.replace(base, comms_per_grad=2.0).compile(40, seed=3)
    thin = w.compile(40, seed=3)
    idx = np.arange(8)

    def pairs(s):
        return (np.asarray(s.partners) != idx).sum()

    # samples at the comm_hi rate, then keeps a lo -> hi ramp of it
    assert 0 < pairs(thin) < pairs(plain)
    early = (np.asarray(thin.partners[:10]) != idx).sum()
    late = (np.asarray(thin.partners[-10:]) != idx).sum()
    assert early < late
    # gated slots are exact no-ops: identity partners, masked, zero extras
    for s in (plain, thin):
        assert np.all(np.asarray(s.partners)[~np.asarray(s.event_mask)]
                      == idx)
    # no controller fields -> the schedule object passes through untouched
    noop = AdaptiveDefense()
    sched = base.compile(10, seed=0)
    assert noop.apply_comm_control(sched) is sched


def test_degradation_derates_the_comm_rate():
    g = ring_graph(8)
    chan = ChannelModel(delay=DelayProcess(horizon=3, prob=1.0))
    clean = World(topology=g, comms_per_grad=2.0).compile(30, seed=1)
    lossy = World(topology=g, comms_per_grad=2.0,
                  channel=chan).compile(30, seed=1)
    assert degradation_profile(clean).max() == 0.0
    prof = degradation_profile(lossy)
    assert prof.shape == (30,)
    # prob=1 delays: every involved read past the warmup is stale (rounds
    # whose sampler drew no matchings at all score 0 by convention)
    busy = (np.asarray(lossy.partners) != np.arange(8)).any(axis=(1, 2))
    assert prof[3:][busy[3:]].min() > 0.9
    ctl = AdaptiveDefense(adaptive_tau=False, trust=False, comm_degrade=0.5)
    mult_clean = ctl.comm_multipliers(30, degradation_profile(clean))
    mult_lossy = ctl.comm_multipliers(30, prof)
    assert (mult_lossy <= mult_clean).all()
    assert mult_lossy[5:][busy[5:]].max() < 1.0


# ------------------------------------------------ end-to-end equivalence

@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_defense_engine_matches_reference(backend):
    """The acceptance oracle: the fused-scan defense replay agrees with
    the per-event reference path, counters included, on a hostile world."""
    n, d = 8, 16
    rounds = 10 if backend == "pallas_interpret" else 40
    g, sim, st = _sim(n, d, backend=backend, robust_clip=5.0, noise=0.01)
    w = dataclasses.replace(_attack_world(g), defense=AdaptiveDefense())
    fin_ref, tr_ref = sim.run_world(st, w, rounds, seed=4, engine=False)
    fin_eng, tr_eng = sim.run_world(st, w, rounds, seed=4, engine=True)
    np.testing.assert_allclose(fin_eng.x, fin_ref.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.x_tilde, fin_ref.x_tilde,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tr_eng.consensus, tr_ref.consensus,
                               atol=1e-6, rtol=1e-4)
    np.testing.assert_allclose(tr_eng.defense.tau, tr_ref.defense.tau,
                               atol=1e-6, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(tr_eng.defense.rejections),
                                  np.asarray(tr_ref.defense.rejections))
    np.testing.assert_array_equal(np.asarray(tr_eng.defense.quarantined),
                                  np.asarray(tr_ref.defense.quarantined))


@pytest.mark.parametrize("engine", [True, False])
def test_batched_defense_matches_serial(engine):
    n, d, rounds = 8, 10, 20
    g, sim, st = _sim(n, d, noise=0.01)
    sched = _attack_world(g).compile(rounds, seed=2)
    defense = AdaptiveDefense()
    _, trb = sim.run_worlds([st, st], [sched, sched],
                            robust_clips=[5.0, 5.0],
                            defenses=[defense, defense], engine=engine)
    _, trs = sim.run_schedule(st, sched, defense=defense, engine=engine)
    np.testing.assert_allclose(np.asarray(trb.consensus[0]),
                               np.asarray(trs.consensus),
                               atol=1e-6, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(trb.defense.rejections[0]),
                                  np.asarray(trs.defense.rejections))
    # identical arms agree with each other exactly
    np.testing.assert_array_equal(np.asarray(trb.consensus[0]),
                                  np.asarray(trb.consensus[1]))


def test_mixed_defense_grid_is_one_trace():
    """ISSUE acceptance: none / static / adaptive / attack arms share ONE
    compiled dispatch — the knobs are (B,) data, never trace constants."""
    n, d = 8, 10
    g, sim, st = _sim(n, d)
    sched_att = _attack_world(g).compile(10, seed=0)
    sched_cln = World(topology=g).compile(10, seed=0)
    fn = type(sim)._run_worlds_defense_jit
    before = fn._cache_size()
    sim.run_worlds([st] * 4, [sched_cln, sched_att, sched_att, sched_att],
                   robust_clips=[None, None, 5.0, 5.0],
                   gammas=[0.05, 0.05, 0.05, 0.07],
                   defenses=[None, None, None, AdaptiveDefense()])
    assert fn._cache_size() - before == 1
    # a second same-shape grid with DIFFERENT knob values reuses the trace
    sim.run_worlds([st] * 4, [sched_att] * 4,
                   robust_clips=[3.0, 7.0, None, 1.0],
                   defenses=[AdaptiveDefense(q=5.0, rho=0.5), None,
                             AdaptiveDefense(adaptive_tau=False), None])
    assert fn._cache_size() - before == 1


# ----------------------------------------------------------- kernel parity

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_channel_kernel_rejection_mask_parity(dtype):
    """want_rej adds the (W,) rejection mask as a third output; the Pallas
    interpret path matches the oracle and the 2-output form is unchanged."""
    w, d = 6, 256
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (w, d), dtype)
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d), dtype)
    perm = jnp.asarray([1, 0, 3, 2, 4, 5], jnp.int32)
    xp = jnp.take(x, perm, axis=0)
    corrupt = jnp.asarray([-2.0, 0.0, -1.0, 4.0, 0.0, 0.0], jnp.float32)
    mscale = jnp.asarray([0.0, 1.0, 0.0, 1.0, 1.0, 1.0], jnp.float32)
    dt = jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    kw = dict(eta=0.37, alpha=0.5, alpha_t=1.4, clip=None)
    ox, ot, orj = channel_gossip_stacked(x, xt, xp, corrupt, mscale, dt,
                                         want_rej=True, interpret=True,
                                         **kw)
    rx, rt, rrj = channel_gossip_stacked_ref(x, xt, xp, corrupt, mscale,
                                             dt, want_rej=True, **kw)
    np.testing.assert_array_equal(np.asarray(orj), np.asarray(rrj))
    np.testing.assert_array_equal(np.asarray(rrj),
                                  np.asarray(mscale == 0.0, np.float32))
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ox, np.float32),
                               np.asarray(rx, np.float32), atol=atol)
    # the two-output arity is untouched
    ox2, ot2 = channel_gossip_stacked(x, xt, xp, corrupt, mscale, dt,
                                      interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(ox2), np.asarray(ox))
    np.testing.assert_array_equal(np.asarray(ot2), np.asarray(ot))
