"""Batched many-worlds replay (DESIGN.md §11).

The contracts under test:

  * pinning — ``Simulator.run_worlds`` replays every world of a batch
    bit-for-bit identically to its serial per-world replay, per flavor
    (engine vs per-event reference, plain vs channel), across ragged
    stream lengths, channel worlds with DISTINCT staleness horizons, and
    both kernel backends (jnp oracle + interpret-mode Pallas);
  * alignment — ``events.stack_streams`` pads each round to the per-round
    max batch count with identity groups, so ``is_grad``/``grad_pos`` are
    shared across the batch and padding is an exact replay no-op;
  * per-world dynamics — baseline (eta 0) and accelerated worlds share
    ONE batched dispatch via the dynamic (B,) parameter arrays and still
    pin to their serial static-scalar replays;
  * sweep API — ``WorldSweep`` builds/validates/serializes grids and
    compiles them host-side, one schedule per (world, seed) point;
  * donation — ``Simulator(donate=True)`` consumes the input state
    (buffers reused for the scan carries) and produces the same replay.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzantineEdges, ChannelModel, DelayProcess,
                        Simulator, World, WorldSweep, build_graph,
                        coalesce_schedule, params_from_graph, ring_graph,
                        stack_schedules, stack_streams)

N, D, ROUNDS = 8, 24, 7

BACKENDS = ["ref", "pallas_interpret"]


def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        g = (x - b[wid]).astype(x.dtype)
        g = g + (0.05 * jax.random.normal(key, x.shape)).astype(x.dtype)
        return 0.5 * jnp.sum(g ** 2), g
    return grad_fn


def _make_sim(backend="ref", robust_clip=None, robust_rule="trim",
              donate=False, accelerated=True):
    g = ring_graph(N)
    b = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    return Simulator(_quad_grad_fn(b), params_from_graph(g, accelerated),
                     gamma=0.05, backend=backend, robust_clip=robust_clip,
                     robust_rule=robust_rule, donate=donate)


def _states(sim, count):
    return [sim.init(jnp.zeros(D), N, jax.random.PRNGKey(2))
            for _ in range(count)]


def _assert_world_pinned(sim, fin, tr, i, serial_fin, serial_tr):
    """World i of a batched replay equals its serial replay bit-for-bit
    (states AND per-round traces)."""
    for bl, sl in zip(jax.tree.leaves(fin.x), jax.tree.leaves(serial_fin.x)):
        np.testing.assert_array_equal(np.asarray(bl[i]), np.asarray(sl))
    for bl, sl in zip(jax.tree.leaves(fin.x_tilde),
                      jax.tree.leaves(serial_fin.x_tilde)):
        np.testing.assert_array_equal(np.asarray(bl[i]), np.asarray(sl))
    np.testing.assert_array_equal(np.asarray(fin.t_last[i]),
                                  np.asarray(serial_fin.t_last))
    np.testing.assert_array_equal(np.asarray(tr.loss[i]),
                                  np.asarray(serial_tr.loss))
    np.testing.assert_array_equal(np.asarray(tr.consensus[i]),
                                  np.asarray(serial_tr.consensus))


def _pin_batch(sim, worlds_params_seeds, engine):
    """Run the batch through run_worlds and pin every world to its serial
    replay on the same flavor."""
    scheds = [w.compile(ROUNDS, seed=s) for w, _, s in worlds_params_seeds]
    plist = [p for _, p, _ in worlds_params_seeds]
    states = _states(sim, len(scheds))
    fin, tr = sim.run_worlds(states, scheds, params=plist, engine=engine)
    assert tr.consensus.shape == (len(scheds), ROUNDS)
    for i, (st, sch, p) in enumerate(zip(states, scheds, plist)):
        serial = dataclasses.replace(sim, params=p)
        sfin, str_ = serial.run_schedule(st, sch, engine=engine)
        _assert_world_pinned(sim, fin, tr, i, sfin, str_)


# ---------------------------------------------------------------- pinning

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", [True, False])
def test_batched_equals_serial_ragged_mixed_params(backend, engine):
    """Ragged stream lengths (comms_per_grad grid + a different topology)
    and mixed baseline/accelerated params, one batch, every world pinned."""
    ring = ring_graph(N)
    comp = build_graph("complete", N)
    sim = _make_sim(backend=backend)
    batch = [
        (World(topology=ring, comms_per_grad=0.5),
         params_from_graph(ring, True), 0),
        (World(topology=ring, comms_per_grad=0.5),
         params_from_graph(ring, False), 0),
        (World(topology=ring, comms_per_grad=2.5),
         params_from_graph(ring, True), 1),
        (World(topology=comp),
         params_from_graph(comp, True), 2),
    ]
    _pin_batch(sim, batch, engine)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", [True, False])
def test_batched_channel_distinct_horizons(backend, engine):
    """Channel worlds with DISTINCT delay horizons (plus a clean world and
    a Byzantine/drop world) share one batched channel replay; each pins to
    its serial replay — the shared ring depth H = max horizon serves every
    world the same snapshots its own-depth serial ring would."""
    ring = ring_graph(N)
    acid = params_from_graph(ring, True)
    base = params_from_graph(ring, False)
    sim = _make_sim(backend=backend)
    batch = [
        (World(topology=ring), acid, 0),   # clean: exact no-op extras
        (World(topology=ring, channel=ChannelModel(
            delay=DelayProcess(horizon=2, prob=0.7))), acid, 1),
        (World(topology=ring, channel=ChannelModel(
            delay=DelayProcess(horizon=5, prob=1.0))), base, 2),
        (World(topology=ring, channel=ChannelModel(
            adversary=ByzantineEdges(ring.edges[:2], "scale", scale=40.0,
                                     prob=0.6),
            drop_prob=0.1)), acid, 3),
    ]
    _pin_batch(sim, batch, engine)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("rule", ["trim", "clip", "coord"])
def test_batched_robust_rules_pin(backend, rule):
    """Robust aggregation (all three rules) on a Byzantine batch pins to
    the serial robust replay on both kernel backends."""
    ring = ring_graph(N)
    acid = params_from_graph(ring, True)
    sim = _make_sim(backend=backend, robust_clip=4.0, robust_rule=rule)
    byz = World(topology=ring, channel=ChannelModel(
        adversary=ByzantineEdges(ring.edges[:3], "scale", scale=60.0,
                                 prob=0.5)))
    batch = [(byz, acid, 0), (byz, acid, 1),
             (World(topology=ring), acid, 0)]
    _pin_batch(sim, batch, True)


def test_batched_hetero_worlds_pin():
    """Stragglers and churned (statically detached) workers ride the batch
    axis unchanged: grad_scale/alive are per-world stream data."""
    from repro.core import WorkerModel
    ring = ring_graph(N)
    acid = params_from_graph(ring, True)
    rates = np.where(np.arange(N) % 2 == 0, 1.0, 0.25)
    active = np.ones(N, bool)
    active[0] = False
    sim = _make_sim()
    batch = [
        (World(topology=ring, workers=WorkerModel(grad_rates=rates)),
         acid, 0),
        (World(topology=ring, workers=WorkerModel(active=active)), acid, 1),
        (World(topology=ring), acid, 2),
    ]
    for engine in (True, False):
        _pin_batch(sim, batch, engine)


# -------------------------------------------------------------- alignment

def test_stack_streams_alignment_and_padding():
    ring = ring_graph(N)
    scheds = [World(topology=ring, comms_per_grad=c).compile(ROUNDS, seed=s)
              for c, s in ((0.5, 0), (3.0, 1), (1.0, 2))]
    css = [coalesce_schedule(s) for s in scheds]
    t0 = np.zeros((3, N), np.float32)
    bs = stack_streams(css, t0)
    counts = np.stack([cs.batch_active.sum(axis=1) for cs in css])
    # shared skeleton: one grad tick per round + per-round max comm steps
    assert bs.is_grad.sum() == ROUNDS
    assert bs.steps == int(counts.max(axis=0).sum()) + ROUNDS
    assert np.array_equal(np.nonzero(bs.is_grad)[0], np.asarray(bs.grad_pos))
    # padding slots are identity groups (self partners); the mixing
    # segment to the next event migrates onto the last pad of a run, so
    # per-worker elapsed time is preserved exactly
    idx = np.arange(N)
    from repro.core import coalesced_stream
    for b, cs in enumerate(css):
        comm = ~bs.is_grad
        pad_rows = (bs.partners[comm, b] == idx).all(axis=1)
        assert pad_rows.sum() == int((counts.max(axis=0) - counts[b]).sum())
        solo = coalesced_stream(cs, t0[b])
        np.testing.assert_array_equal(bs.t_final[b], solo.t_final)
        np.testing.assert_allclose(
            bs.prologue[b].astype(np.float64)
            + bs.dt_next[:, b].sum(axis=0, dtype=np.float64),
            solo.prologue.astype(np.float64)
            + solo.dt_next.sum(axis=0, dtype=np.float64), rtol=1e-5)


def test_stack_streams_validates_frame():
    ring = ring_graph(N)
    s1 = coalesce_schedule(World(topology=ring).compile(4, seed=0))
    s2 = coalesce_schedule(World(topology=ring).compile(5, seed=0))
    with pytest.raises(ValueError, match="share one frame"):
        stack_streams([s1, s2], np.zeros((2, N), np.float32))
    with pytest.raises(ValueError, match="t0 must be"):
        stack_streams([s1], np.zeros((2, N), np.float32))


def test_stack_schedules_pads_and_unions_extras():
    ring = ring_graph(N)
    clean = World(topology=ring, comms_per_grad=0.5).compile(ROUNDS, seed=0)
    chan = World(topology=ring, comms_per_grad=2.0,
                 channel=ChannelModel(delay=DelayProcess(horizon=3))
                 ).compile(ROUNDS, seed=1)
    b = stack_schedules([clean, chan])
    kmax = max(clean.partners.shape[1], chan.partners.shape[1])
    assert b.partners.shape == (ROUNDS, 2, kmax, N)
    from repro.core.channel import STALE_KEY
    assert set(b.extras) == {STALE_KEY}
    assert (b.extras[STALE_KEY][:, 0] == 0).all()    # clean world: zeros
    assert (b.extras[STALE_KEY][:, 1] > 0).any()
    with pytest.raises(ValueError, match="share one frame"):
        stack_schedules([clean, World(topology=ring_graph(4)).compile(
            ROUNDS, seed=0)])


# -------------------------------------------------------------- sweep API

def test_world_sweep_over_and_points():
    ring = ring_graph(N)
    sweep = WorldSweep.over(World(topology=ring), seeds=(0, 1),
                            comms_per_grad=[0.5, 1.0, 2.0])
    assert sweep.size == 6 and len(sweep.worlds) == 3
    pts = sweep.points()
    assert [s for _, s in pts] == [0, 1, 0, 1, 0, 1]
    assert [w.comms_per_grad for w, _ in pts] == [.5, .5, 1., 1., 2., 2.]
    scheds = sweep.compile(5)
    assert len(scheds) == 6 and all(s.rounds == 5 for s in scheds)
    # point i of compile() is point i of points()
    ref = pts[3][0].compile(5, seed=pts[3][1])
    np.testing.assert_array_equal(scheds[3].partners, ref.partners)


def test_world_sweep_validation_and_json():
    ring = ring_graph(N)
    with pytest.raises(ValueError, match="at least one world"):
        WorldSweep(())
    with pytest.raises(ValueError, match="at least one seed"):
        WorldSweep((World(topology=ring),), seeds=())
    with pytest.raises(ValueError, match="share one worker count"):
        WorldSweep((World(topology=ring), World(topology=ring_graph(4))))
    with pytest.raises(ValueError, match="unknown World field"):
        WorldSweep.over(World(topology=ring), warp_factor=[1, 2])
    sweep = WorldSweep.over(
        World(topology=ring), seeds=(3,),
        channel=[None, ChannelModel(delay=DelayProcess(horizon=2))])
    s = sweep.to_json()
    back = WorldSweep.from_json(s)
    assert back == sweep and back.to_json() == s


def test_run_worlds_validates_batch():
    sim = _make_sim()
    ring = ring_graph(N)
    scheds = [World(topology=ring).compile(3, seed=i) for i in range(2)]
    states = _states(sim, 3)
    with pytest.raises(ValueError, match="3 worlds but 2 schedules"):
        sim.run_worlds(states, scheds)
    with pytest.raises(ValueError, match="one entry per world"):
        sim.run_worlds(states[:2], scheds, params=[sim.params])


# --------------------------------------------------------------- donation

def test_donating_replay_consumes_state_and_matches():
    ring = ring_graph(N)
    sch = World(topology=ring).compile(ROUNDS, seed=0)
    plain = _make_sim()
    st = plain.init(jnp.zeros(D), N, jax.random.PRNGKey(2))
    ref_fin, ref_tr = plain.run_schedule(st, sch)

    dsim = _make_sim(donate=True)
    dst = dsim.init(jnp.zeros(D), N, jax.random.PRNGKey(2))
    leaf = jax.tree.leaves(dst.x)[0]
    fin, tr = dsim.run_schedule(dst, sch)
    jax.block_until_ready(fin)
    np.testing.assert_array_equal(np.asarray(tr.consensus),
                                  np.asarray(ref_tr.consensus))
    # CPU (and TPU) honor donation: the input buffer is gone, its memory
    # rehomed into the scan carries
    assert leaf.is_deleted()


def test_donating_batched_replay_consumes_state_and_matches():
    ring = ring_graph(N)
    scheds = [World(topology=ring).compile(ROUNDS, seed=s)
              for s in range(3)]
    plain = _make_sim()
    states = _states(plain, 3)
    ref_fin, ref_tr = plain.run_worlds(states, scheds)

    dsim = _make_sim(donate=True)
    batched = dsim.batch_states(_states(dsim, 3))
    leaf = jax.tree.leaves(batched.x)[0]
    fin, tr = dsim.run_worlds(batched, scheds)
    jax.block_until_ready(fin)
    np.testing.assert_array_equal(np.asarray(tr.consensus),
                                  np.asarray(ref_tr.consensus))
    assert leaf.is_deleted()


# ----------------------------------------------------------- trace counts

def test_one_trace_per_family_shape():
    """A whole grid — baseline + accelerated across a comms grid and
    seeds — retraces the batched jit exactly once; replaying the same
    family shape again adds no trace."""
    ring = ring_graph(N)
    sweep = WorldSweep.over(World(topology=ring), seeds=(0, 1),
                            comms_per_grad=[0.5, 2.0])
    scheds = sweep.compile(ROUNDS)
    acid = params_from_graph(ring, True)
    base = params_from_graph(ring, False)
    plist = [acid, base] * 2
    sim = _make_sim()
    before = Simulator._run_worlds_jit._cache_size()
    fin, tr = sim.run_worlds(_states(sim, 4), scheds, params=plist)
    jax.block_until_ready(fin)
    mid = Simulator._run_worlds_jit._cache_size()
    fin, tr = sim.run_worlds(_states(sim, 4), scheds, params=plist)
    jax.block_until_ready(fin)
    after = Simulator._run_worlds_jit._cache_size()
    assert mid - before == 1
    assert after == mid
