"""The Pallas flash-attention kernel as a drop-in model attention impl."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model


@pytest.mark.parametrize("arch,window", [("qwen3-14b", None),
                                         ("recurrentgemma-9b", 32)])
def test_pallas_attention_matches_xla_in_model(arch, window):
    cfg = get_config(arch, reduced=True)
    if window:
        cfg = cfg.windowed(window)
    model_xla = Model(cfg)
    model_pl = Model(cfg.with_updates(attention_impl="pallas"))
    params = model_xla.init(jax.random.PRNGKey(0))
    B, S = 2, 128
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size, jnp.int32)
    lx, _, _ = model_xla.forward(params, toks)
    lp, _, _ = model_pl.forward(params, toks)
    scale = float(jnp.max(jnp.abs(lx))) + 1e-6
    assert float(jnp.max(jnp.abs(lx - lp))) / scale < 2e-4
