"""Flight recorder, host side: span tracer + metrics registry
(analysis/tracing.py, analysis/metrics.py, DESIGN.md §15).

The contracts under test:

  * golden schema — a tracer used the way the fleet/benchmarks use it
    (spans, explicit-timestamp spans, counters, instants, multiple
    processes/lanes) emits a trace that ``validate_trace`` accepts, that
    survives a JSON write/``load_trace`` round-trip, and whose metadata
    events announce every process/lane exactly once;
  * schema gate actually gates — each malformed-event family raises;
  * metrics semantics — counters are monotonic, histograms expose
    Prometheus cumulative le-buckets, kind collisions are errors;
  * exposition round-trip — ``parse_exposition(reg.exposition())``
    recovers every sample value, labels and +Inf buckets included.
"""
import json
import math

import numpy as np
import pytest

from repro.analysis import (MetricsRegistry, SpanTracer, load_trace,
                            parse_exposition, validate_trace)


def _bench_shaped_tracer():
    """Exercise the tracer the way fleet.run / benchmarks/run.py do."""
    tr = SpanTracer("bench", metadata={"family": "serve", "seed": 0})
    with tr.span("bench.serve", lane="bench", args={"seed": 0}):
        for r in range(3):
            t0 = tr.now_us()
            with tr.span("fleet.decode", process="fleet", lane="decode",
                         args={"round": r, "active_slots": np.int64(2)}):
                pass
            tr.complete("fleet.round", t0, tr.now_us() - t0,
                        process="fleet", lane="rounds",
                        args={"round": r, "alive": 4})
            tr.counter("fleet.queue", {"queue_depth": r,
                                       "slot_occupancy": np.float32(0.5)},
                       process="fleet")
        tr.instant("churn.kill", process="fleet", lane="churn",
                   args={"worker": 1, "round": 2})
    return tr


def test_trace_schema_golden(tmp_path):
    tr = _bench_shaped_tracer()
    obj = tr.to_dict()
    validate_trace(obj)  # does not raise
    assert obj["displayTimeUnit"] == "ms"
    assert obj["metadata"] == {"family": "serve", "seed": 0}

    names = [e["name"] for e in obj["traceEvents"]]
    for expected in ("bench.serve", "fleet.round", "fleet.decode",
                     "fleet.queue", "churn.kill"):
        assert expected in names

    # processes/lanes announced exactly once, as metadata events
    procs = [e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"]
    assert sorted(procs) == ["bench", "fleet"]
    lanes = [e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"]
    assert sorted(lanes) == ["bench", "churn", "decode", "rounds"]

    # numpy leaked into args must already be plain JSON types
    path = tmp_path / "TRACE_test.json"
    tr.write(str(path))
    loaded = load_trace(str(path))
    assert loaded == json.loads(json.dumps(obj))


def test_span_timestamps_nest_and_order():
    tr = _bench_shaped_tracer()
    spans = [e for e in tr.events if e["ph"] == "X"]
    outer = [e for e in spans if e["name"] == "bench.serve"]
    assert len(outer) == 1
    o = outer[0]
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0
        if e is not o:  # every other span closed inside the outer one
            assert e["ts"] >= o["ts"]
            assert e["ts"] + e["dur"] <= o["ts"] + o["dur"] + 1e-6


@pytest.mark.parametrize("mutate, message", [
    (lambda ev: ev.update(ph="B"), "unknown phase"),
    (lambda ev: ev.update(name=""), "name"),
    (lambda ev: ev.update(pid="fleet"), "pid"),
    (lambda ev: ev.pop("dur"), "dur"),
    (lambda ev: ev.update(args=[1, 2]), "args"),
])
def test_validate_trace_rejects(mutate, message):
    tr = SpanTracer("t")
    with tr.span("ok"):
        pass
    obj = tr.to_dict()
    ev = [e for e in obj["traceEvents"] if e["ph"] == "X"][0]
    mutate(ev)
    with pytest.raises(ValueError, match=message):
        validate_trace(obj)


def test_validate_trace_rejects_bad_counter_and_shape():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"events": []})
    with pytest.raises(ValueError, match="JSON object"):
        validate_trace([])
    tr = SpanTracer("t")
    tr.counter("q", {"depth": 3})
    obj = tr.to_dict()
    [c] = [e for e in obj["traceEvents"] if e["ph"] == "C"]
    c["args"] = {"depth": "three"}
    with pytest.raises(ValueError, match="numeric"):
        validate_trace(obj)


# ----------------------------------------------------------------- metrics

def test_counter_monotonic_and_kind_collision():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests seen")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    # same name, same labels -> the SAME child; different labels -> new
    assert reg.counter("requests_total") is c
    assert reg.counter("requests_total", labels={"arm": "a"}) is not c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("requests_total")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")


def test_histogram_cumulative_buckets():
    reg = MetricsRegistry()
    h = reg.histogram("ttft_rounds", "time to first token",
                      buckets=(1, 2, 4))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    assert h.count == 4 and h.sum == pytest.approx(104.5)
    assert h.cumulative() == [2, 2, 3, 4]  # le=1, le=2, le=4, +Inf
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("bad_hist", buckets=(2, 1))


def test_exposition_roundtrip():
    reg = MetricsRegistry()
    reg.counter("fleet_requests_total", "admitted",
                labels={"fleet": "ring"}).inc(7)
    reg.gauge("fleet_drain_rounds", "drain tail").set(33)
    h = reg.histogram("fleet_ttft_rounds", "ttft", buckets=(1, 2, 4))
    for v in (0.5, 3.0, 9.0):
        h.observe(v)

    text = reg.exposition()
    assert "# TYPE fleet_requests_total counter" in text
    assert "# HELP fleet_ttft_rounds ttft" in text

    parsed = parse_exposition(text)
    assert parsed["fleet_requests_total"]['{fleet="ring"}'] == 7
    assert parsed["fleet_drain_rounds"][""] == 33
    buckets = parsed["fleet_ttft_rounds_bucket"]
    assert buckets['{le="1"}'] == 1
    assert buckets['{le="4"}'] == 2
    assert buckets['{le="+Inf"}'] == 3
    assert parsed["fleet_ttft_rounds_count"][""] == 3
    assert parsed["fleet_ttft_rounds_sum"][""] == pytest.approx(12.5)

    with pytest.raises(ValueError):
        parse_exposition("just words without value structure {")


def test_snapshot_is_jsonable_and_complete():
    reg = MetricsRegistry()
    reg.counter("a_total").inc(2)
    reg.histogram("lat", buckets=(1.0,)).observe(0.5)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["a_total"]["series"]["{}"] == 2
    assert snap["lat"]["series"]["{}"]["buckets"] == {"1": 1, "+Inf": 1}
    assert snap["lat"]["series"]["{}"]["count"] == 1


def test_exposition_handles_inf_and_label_escaping():
    reg = MetricsRegistry()
    reg.gauge("edge_case", labels={"path": 'a\\b says "hi"'}).set(math.inf)
    text = reg.exposition()
    assert "+Inf" in text
    parsed = parse_exposition(text)
    [(labels, value)] = parsed["edge_case"].items()
    assert value == math.inf
    assert '\\\\' in labels and '\\"' in labels
