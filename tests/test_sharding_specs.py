"""Sharding machinery: logical hints, divisibility fallbacks, param specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import shardings as S


class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


RULES = {"batch": "data", "heads": "model", "ffn": "model",
         "vocab": "model", "expert": "model", "fsdp": "data", "tp": "model"}


def test_param_spec_2d_rules():
    mesh = FakeMesh()
    assert S.param_spec("groups/0/b0/mixer/wq", FakeLeaf((28, 1024, 2048)),
                        mesh, RULES) == P(None, "data", "model")
    assert S.param_spec("embed/tok", FakeLeaf((152064, 1024)),
                        mesh, RULES) == P("model", "data")
    assert S.param_spec("groups/0/b0/mlp/w_down", FakeLeaf((28, 3072, 1024)),
                        mesh, RULES) == P(None, "model", "data")


def test_param_spec_moe_3d():
    mesh = FakeMesh()
    spec = S.param_spec("groups/1/b0/mlp/moe_up",
                        FakeLeaf((58, 256, 7168, 2048)), mesh, RULES)
    assert spec == P(None, "model", "data", None)


def test_param_spec_divisibility_fallback():
    mesh = FakeMesh()
    # out dim 100 not divisible by 16 -> replicated on that dim
    spec = S.param_spec("head/w", FakeLeaf((1024, 100)), mesh, RULES)
    assert spec == P("data", None)


def test_param_spec_1d_replicated():
    mesh = FakeMesh()
    assert S.param_spec("groups/0/b0/norm1", FakeLeaf((28, 1024)),
                        mesh, RULES) == P()


def test_cache_spec_kv_and_state():
    mesh = FakeMesh()
    assert S.cache_spec("groups/0/b0/k", FakeLeaf((28, 128, 32768, 8, 128)),
                        mesh, RULES) == P(None, "data", "model", None, None)
    assert S.cache_spec("groups/0/b0/slot_pos", FakeLeaf((32768,)),
                        mesh, RULES) == P()
    # conv cache: channel dim over model
    assert S.cache_spec("groups/0/b0/conv", FakeLeaf((48, 128, 3, 3328)),
                        mesh, RULES) == P(None, "data", None, "model")
    # batch=1 (long_500k): batch falls back to replicated
    assert S.cache_spec("groups/0/b0/k", FakeLeaf((28, 1, 4096, 8, 128)),
                        mesh, RULES) == P(None, None, "model", None, None)
