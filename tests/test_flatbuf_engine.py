"""Flat-buffer fused gossip-event engine: equivalence vs the per-event
reference path, conservation laws, and layout round-trips (see DESIGN.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FlatGossipEngine, FlatLayout, Simulator,
                        coalesce_schedule, make_schedule, params_from_graph,
                        ring_graph)
from repro.kernels.a2cid2_mixing.kernel import mixing_gossip_stacked
from repro.kernels.a2cid2_mixing.ref import (mixing_gossip_stacked_ref,
                                             p2p_mixing_ref)


def _mixed_dtype_tree(w=None):
    """Pytree with mixed dtypes/shapes; optionally worker-stacked."""
    key = jax.random.PRNGKey(0)

    def leaf(k, shape, dtype):
        s = ((w,) + shape) if w else shape
        return jax.random.normal(jax.random.fold_in(key, k), s).astype(dtype)

    return {
        "dense": {"w": leaf(0, (7, 5), jnp.float32),
                  "b": leaf(1, (5,), jnp.bfloat16)},
        "scale": leaf(2, (), jnp.float32),
        "embed": [leaf(3, (11, 3), jnp.float16), leaf(4, (130,), jnp.float32)],
    }


# ------------------------------------------------------------------- layout

@pytest.mark.parametrize("stacked", [False, True])
def test_pack_unpack_roundtrip_exact_mixed_dtypes(stacked):
    tree = _mixed_dtype_tree(w=4 if stacked else None)
    layout = FlatLayout.from_pytree(tree, stacked=stacked)
    assert layout.d % 128 == 0 and layout.d >= layout.d_real
    buf = layout.pack(tree) if stacked else layout.pack_local(tree)
    out = layout.unpack(buf) if stacked else layout.unpack_local(buf)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # padding columns are zero (reductions over the buffer need no masking)
    flat = buf if buf.ndim == 1 else buf[0]
    np.testing.assert_array_equal(flat[layout.d_real:], 0.0)


def test_layout_rejects_lossy_dtypes():
    with pytest.raises(TypeError):
        FlatLayout.from_pytree({"i": jnp.zeros(3, jnp.int32)})


# ------------------------------------------------------------- fused kernel

@pytest.mark.parametrize("w,d", [(4, 128), (16, 1000), (6, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stacked_kernel_matches_oracle(w, d, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (w, d), dtype)
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d), dtype)
    perm = np.arange(w)
    perm[:4] = [1, 0, 3, 2]                     # two pairs, rest idle
    partner = jnp.asarray(perm, jnp.int32)
    dt = jax.random.uniform(jax.random.fold_in(key, 2), (w,))
    kw = dict(eta=0.37, alpha=0.5, alpha_t=1.4)
    ox, ot = mixing_gossip_stacked(x, xt, partner, dt, interpret=True, **kw)
    rx, rt = mixing_gossip_stacked_ref(x, xt, partner, dt, **kw)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ox, np.float32),
                               np.asarray(rx, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(ot, np.float32),
                               np.asarray(rt, np.float32), atol=atol)


def test_stacked_kernel_idle_workers_untouched():
    w, d = 8, 256
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (w, d))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d))
    partner = jnp.asarray([1, 0] + list(range(2, w)), jnp.int32)
    dt = jnp.zeros((w,))                        # no mixing either
    ox, ot = mixing_gossip_stacked(x, xt, partner, dt, interpret=True,
                                   eta=0.5, alpha=0.5, alpha_t=0.9)
    np.testing.assert_allclose(ox[2:], x[2:], atol=1e-6)
    np.testing.assert_allclose(ot[2:], xt[2:], atol=1e-6)


def test_mixing_conserves_buffer_sum():
    """exp(dt*A) is doubly stochastic: x + x~ is invariant elementwise, for
    both the standalone mix pass and the fused batch with alpha==alpha_t==0."""
    engine = FlatGossipEngine.for_pytree(
        {"w": jnp.zeros((4, 300))}, params_from_graph(ring_graph(4), True),
        stacked=True, backend="ref")
    key = jax.random.PRNGKey(3)
    bx = jax.random.normal(key, (4, 384))
    bxt = jax.random.normal(jax.random.fold_in(key, 1), (4, 384))
    dt = jax.random.uniform(jax.random.fold_in(key, 2), (4,))
    mx, mxt = engine.mix(bx, bxt, dt)
    np.testing.assert_allclose(mx + mxt, bx + bxt, atol=1e-5)
    fx, fxt = p2p_mixing_ref(bx, bxt, bx, 1.3, eta=0.8, alpha=0.0,
                             alpha_t=0.0)
    np.testing.assert_allclose(fx + fxt, bx + bxt, atol=1e-5)


def test_p2p_batch_conserves_global_mean():
    """A coalesced p2p batch moves mass only inside pairs: the worker-mean of
    x (and of x~) is exactly preserved."""
    w, d = 8, 256
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (w, d))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (w, d))
    partner = jnp.asarray([1, 0, 3, 2, 5, 4, 7, 6], jnp.int32)
    rx, rt = mixing_gossip_stacked_ref(x, xt, partner, jnp.zeros((w,)),
                                       eta=0.0, alpha=0.5, alpha_t=1.1)
    np.testing.assert_allclose(jnp.mean(rx, 0), jnp.mean(x, 0), atol=1e-6)
    np.testing.assert_allclose(jnp.mean(rt, 0), jnp.mean(xt, 0), atol=1e-6)


# -------------------------------------------------------------- coalescing

def test_coalesce_preserves_events_and_times():
    g = ring_graph(16)
    sched = make_schedule(g, rounds=80, comms_per_grad=2.0, seed=7)
    cs = coalesce_schedule(sched)
    idx = np.arange(16)
    # per-worker (time, partner) event lists are identical
    for w in range(16):
        raw = [(float(sched.event_times[r, e]),
                int(sched.partners[r, e, w]))
               for r in range(sched.rounds)
               for e in range(sched.partners.shape[1])
               if sched.event_mask[r, e] and sched.partners[r, e, w] != w]
        coal = [(float(cs.wtimes[r, b, w]), int(cs.partners[r, b, w]))
                for r in range(cs.rounds)
                for b in range(cs.partners.shape[1])
                if cs.batch_active[r, b] and cs.partners[r, b, w] != w]
        assert raw == coal
    # every batch is an involution and strictly fewer sweeps than raw slots
    for r in range(cs.rounds):
        for b in range(cs.partners.shape[1]):
            p = cs.partners[r, b]
            assert np.all(p[p] == idx)
    assert cs.num_batches() <= int(sched.event_mask.sum())
    assert cs.num_batches() < sched.rounds * sched.partners.shape[1]


def test_coalesce_merges_disjoint_events():
    """Hand-built schedule: two sequential events on disjoint pairs must
    collapse into one batch carrying each worker's own event time."""
    from repro.core.events import Schedule
    partners = np.asarray([[[1, 0, 2, 3], [0, 1, 3, 2]]], np.int32)
    times = np.asarray([[0.25, 0.75]], np.float32)
    mask = np.ones((1, 2), bool)
    grad = np.full((1, 4), 1.0, np.float32)
    cs = coalesce_schedule(Schedule(partners, times, mask, grad))
    assert cs.partners.shape[1] == 1 and bool(cs.batch_active[0, 0])
    np.testing.assert_array_equal(cs.partners[0, 0], [1, 0, 3, 2])
    np.testing.assert_allclose(cs.wtimes[0, 0], [0.25, 0.25, 0.75, 0.75])


# ------------------------------------------------- end-to-end equivalence

def _quad_grad_fn(b):
    def grad_fn(x, key, wid):
        return 0.5 * jnp.sum((x - b[wid]) ** 2), x - b[wid]
    return grad_fn


@pytest.mark.parametrize("accelerated", [False, True])
@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_engine_matches_per_event_reference(accelerated, backend):
    """Same schedule through the coalesced/fused engine and the per-event
    reference path: final params, momentum buffers, and traces agree."""
    n, d = 16, 48
    rounds = 12 if backend == "pallas_interpret" else 60
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, accelerated),
                    gamma=0.05, backend=backend)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    sched = make_schedule(g, rounds=rounds, comms_per_grad=1.5, seed=11)
    fin_ref, tr_ref = sim.run_schedule(st, sched, engine=False)
    fin_eng, tr_eng = sim.run_schedule(st, sched, engine=True)
    np.testing.assert_allclose(fin_eng.x, fin_ref.x, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.x_tilde, fin_ref.x_tilde,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(fin_eng.t_last, fin_ref.t_last, atol=1e-6)
    np.testing.assert_allclose(tr_eng.loss, tr_ref.loss, atol=1e-5,
                               rtol=1e-5)
    np.testing.assert_allclose(tr_eng.consensus, tr_ref.consensus,
                               atol=1e-5, rtol=1e-4)


def test_stacked_trainer_zero_comms_is_noop():
    """comms_per_step=0 must be a clean gossip no-op, not a crash."""
    from repro.launch.gossip_train import StackedGossipTrainer
    from repro.optim import sgd
    g = ring_graph(4)
    def grad_fn(p, batch):
        return (0.5 * jnp.sum((p["w"] - batch) ** 2), None), {"w": p["w"] - batch}
    tr = StackedGossipTrainer(grad_fn, sgd(momentum=0.0, weight_decay=0.0),
                              g, params_from_graph(g, True),
                              comms_per_step=0)
    state = tr.init({"w": jnp.zeros((3,))}, jax.random.PRNGKey(0))
    batch = jnp.ones((4, 3))
    state, m = jax.jit(tr.make_step())(state, batch)
    assert state.x["w"].shape == (4, 3) and jnp.isfinite(m["loss"])


def test_run_schedule_handles_f64_state():
    """float64 state (x64 mode) worked on the per-event path; the engine
    default must keep working (the layout infers an f64 buffer)."""
    from jax.experimental import enable_x64
    with enable_x64():
        n, d = 4, 8
        b = jax.random.normal(jax.random.PRNGKey(1), (n, d))

        def grad_fn(x, key, wid):
            g = x - b[wid]
            return 0.5 * jnp.sum(g ** 2), g

        g = ring_graph(n)
        sim = Simulator(grad_fn, params_from_graph(g, True), gamma=0.05)
        st = sim.init(jnp.zeros(d, jnp.float64), n, jax.random.PRNGKey(2))
        # event times are f32 schedule data regardless of x64 mode
        st = st._replace(t_last=jnp.zeros((n,), jnp.float32))
        sched = make_schedule(g, rounds=5, comms_per_grad=1.0, seed=0)
        fin, tr = sim.run_schedule(st, sched)
        assert fin.x.dtype == jnp.float64
        assert np.isfinite(float(tr.loss[-1]))


def test_layout_infers_native_dtype_for_uniform_trees():
    """A uniform-bf16 pytree must pack at bf16 (a gossip event is the unit of
    communication cost — it must not silently double its bytes)."""
    tree = {"w": jnp.zeros((4, 8), jnp.bfloat16),
            "b": jnp.zeros((3,), jnp.bfloat16)}
    layout = FlatLayout.from_pytree(tree)
    assert layout.buf_dtype == jnp.dtype(jnp.bfloat16)
    assert layout.pack_local(tree).dtype == jnp.bfloat16
    # mixed sub-f32 floats widen to f32, not further (explicit f32 leaf so
    # the assertion is mode-independent under JAX_ENABLE_X64)
    mixed = {"w": jnp.zeros((2,), jnp.bfloat16),
             "b": jnp.zeros((2,), jnp.float32)}
    assert FlatLayout.from_pytree(mixed).buf_dtype == jnp.dtype(jnp.float32)


def test_engine_tracker_identity_at_common_clock():
    """mean(x) == mean(x~) at synchronized measurement times (Eq 5) holds
    through the fused path too."""
    from repro.core import worker_mean
    n, d = 8, 8
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sched = make_schedule(g, rounds=60, comms_per_grad=1.0, seed=0,
                          jitter_grad_times=False)
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True), gamma=0.05,
                    backend="ref")
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    fin, _ = sim.run_schedule(st, sched)
    np.testing.assert_allclose(worker_mean(fin.x), worker_mean(fin.x_tilde),
                               atol=1e-5)
