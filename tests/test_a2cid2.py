"""A2CiD2 dynamics: mixing-ODE flow properties and event updates (Sec 3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (acid_params, apply_mixing, baseline_params,
                        consensus_distance, matched_p2p_update, mixing_coeff,
                        p2p_event, params_from_graph, ring_graph)


def test_prop36_parameters():
    chi1, chi2 = 13.0, 1.0
    p = acid_params(chi1, chi2)
    assert p.eta == pytest.approx(1.0 / (2 * np.sqrt(chi1 * chi2)))
    assert p.alpha == 0.5
    assert p.alpha_tilde == pytest.approx(0.5 * np.sqrt(chi1 / chi2))
    assert p.chi == pytest.approx(np.sqrt(chi1 * chi2))
    b = baseline_params(chi1)
    assert b.eta == 0.0 and b.alpha == b.alpha_tilde == 0.5
    assert b.chi == chi1


def test_mixing_flow_semigroup():
    """exp(t1 A) exp(t2 A) == exp((t1+t2) A) — exact flow, not an Euler step.
    (The randomized sweep lives in test_property_sweeps.py.)"""
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    for eta, t1, t2 in [(0.5, 0.3, 1.1), (2.0, 0.0, 3.0), (0.01, 2.5, 0.7)]:
        a1, b1 = apply_mixing(*apply_mixing(x, xt, eta, t1), eta, t2)
        a2, b2 = apply_mixing(x, xt, eta, t1 + t2)
        np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-6)


def test_mixing_preserves_sum_and_contracts():
    x = jnp.asarray([1.0, -2.0, 0.5])
    xt = jnp.asarray([0.3, 4.0, -1.0])
    for eta, t in [(0.05, 0.5), (1.0, 2.0), (5.0, 10.0)]:
        mx, mxt = apply_mixing(x, xt, eta, t)
        np.testing.assert_allclose(mx + mxt, x + xt, rtol=1e-5)
        # contraction of the difference: |mx - mxt| = e^{-2 eta t} |x - xt|
        np.testing.assert_allclose(
            np.asarray(mx - mxt),
            np.exp(-2 * eta * t) * np.asarray(x - xt), rtol=1e-4, atol=1e-5)
        c = float(mixing_coeff(eta, jnp.asarray(t)))
        assert 0.0 <= c <= 0.5


def test_mixing_infinite_time_averages():
    x = jnp.asarray([2.0])
    xt = jnp.asarray([0.0])
    mx, mxt = apply_mixing(x, xt, 1.0, 50.0)
    np.testing.assert_allclose(mx, 1.0, atol=1e-5)
    np.testing.assert_allclose(mxt, 1.0, atol=1e-5)


def test_p2p_event_preserves_global_mean():
    """x_i -= a m, x_j += a m (and alpha_t for x~) keeps both means fixed."""
    g = ring_graph(8)
    p = params_from_graph(g, accelerated=True)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 5))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (8, 5))
    partner = jnp.asarray(g.matching_to_partner(
        g.sample_matching(np.random.default_rng(0))))
    nx, nxt = matched_p2p_update(x, xt, partner, p)
    np.testing.assert_allclose(jnp.mean(nx, 0), jnp.mean(x, 0), atol=1e-6)
    np.testing.assert_allclose(jnp.mean(nxt, 0), jnp.mean(xt, 0), atol=1e-6)


def test_p2p_event_with_alpha_half_averages_pair():
    g = ring_graph(4)
    p = baseline_params(g.chi1())
    x = jnp.asarray([[0.0], [2.0], [10.0], [20.0]])
    partner = jnp.asarray([1, 0, 3, 2])
    nx, _ = matched_p2p_update(x, x, partner, p)
    np.testing.assert_allclose(nx, [[1.0], [1.0], [15.0], [15.0]])


def test_p2p_event_reduces_consensus_distance():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (8, 16))
    g = ring_graph(8)
    p = baseline_params(g.chi1())
    partner = jnp.asarray(g.matching_to_partner(
        g.sample_matching(np.random.default_rng(1))))
    before = float(consensus_distance(x))
    nx, _ = matched_p2p_update(x, x, partner, p)
    assert float(consensus_distance(nx)) < before


def test_p2p_event_two_sided_symmetry():
    """p2p_event applied from both ends agrees with matched update."""
    p = acid_params(4.0, 1.0)
    xi, xj = jnp.asarray([1.0, 2.0]), jnp.asarray([3.0, -1.0])
    ti, tj = jnp.asarray([0.5, 0.5]), jnp.asarray([-0.5, 1.5])
    ni, nti = p2p_event(xi, ti, xj, p)
    nj, ntj = p2p_event(xj, tj, xi, p)
    x = jnp.stack([xi, xj])
    t = jnp.stack([ti, tj])
    nx, nt = matched_p2p_update(x, t, jnp.asarray([1, 0]), p)
    np.testing.assert_allclose(nx, jnp.stack([ni, nj]), rtol=1e-6)
    np.testing.assert_allclose(nt, jnp.stack([nti, ntj]), rtol=1e-6)
