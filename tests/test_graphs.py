"""Graph/Laplacian invariants + the paper's App E.1 chi values."""
import numpy as np
import pytest

from repro.core import (build_graph, complete_graph, exponential_graph,
                        hypercube_graph, ring_graph)


GRAPHS = ["complete", "ring", "exponential", "star", "hypercube"]


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("n", [4, 8, 16])
def test_laplacian_properties(name, n):
    g = build_graph(name, n)
    L = g.laplacian()
    assert np.allclose(L, L.T)
    assert np.allclose(L @ np.ones(n), 0.0)         # rows sum to zero
    lam = np.linalg.eigvalsh(L)
    assert lam[0] == pytest.approx(0.0, abs=1e-9)
    assert lam[1] > 0                                # connected
    assert g.is_connected()


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("n", [8, 16])
def test_chi2_le_chi1(name, n):
    g = build_graph(name, n)
    assert g.chi2() <= g.chi1() + 1e-9


def test_paper_appendix_e1_chi_values():
    """App E.1: (chi1, chi2) at n=16, 1 comm/grad ~ (1,1), (2,1), (13,1)."""
    assert complete_graph(16).chi1() == pytest.approx(1.0, abs=0.2)
    assert complete_graph(16).chi2() == pytest.approx(1.0, abs=0.2)
    assert exponential_graph(16).chi1() == pytest.approx(2.0, abs=0.4)
    assert exponential_graph(16).chi2() == pytest.approx(1.0, abs=0.3)
    assert ring_graph(16).chi1() == pytest.approx(13.0, abs=1.0)
    assert ring_graph(16).chi2() == pytest.approx(1.0, abs=0.3)


def test_ring_chi1_grows_quadratically():
    """chi1(ring) = Theta(n^2) — the regime where A2CiD2 wins sqrt(n)."""
    c8, c16, c32 = (ring_graph(n).chi1() for n in (8, 16, 32))
    assert 3.0 < c16 / c8 < 5.0
    assert 3.0 < c32 / c16 < 5.0


def test_total_rate_is_trace_over_two():
    for name in GRAPHS:
        g = build_graph(name, 16, rate_per_worker=2.0)
        assert g.total_rate() == pytest.approx(
            np.trace(g.laplacian()) / 2.0)


@pytest.mark.parametrize("n,seed", [(4, 0), (9, 17), (16, 3), (24, 101)])
def test_matchings_are_valid(n, seed):
    """Deterministic spot-check; the randomized sweep lives in
    test_property_sweeps.py."""
    g = ring_graph(n)
    rng = np.random.default_rng(seed)
    m = g.sample_matching(rng)
    nodes = [x for e in m for x in e]
    assert len(nodes) == len(set(nodes))            # node-disjoint
    edge_set = {tuple(sorted(e)) for e in g.edges}
    for e in m:
        assert tuple(sorted(e)) in edge_set         # real edges only
    p = g.matching_to_partner(m)
    assert np.all(p[p] == np.arange(n))             # involution


# ------------------------------------------------- closed-form chi values
#
# With the builders' per-worker rate normalization, chi1 = 1/lambda_2 of the
# rate-weighted Laplacian has a closed form per family, and chi2 (half the
# max effective resistance over edges) follows from Foster's theorem for
# edge-transitive graphs: all |E| edge resistances are equal and sum to
# (n-1)/r, so chi2 = (n-1) / (2 |E| r) for uniform edge rate r.

@pytest.mark.parametrize("n", [8, 16, 64])
def test_ring_chi_closed_form(n):
    g = ring_graph(n)  # edge rate 1/2 => lambda_2 = 2r(1-cos(2pi/n))
    assert g.chi1() == pytest.approx(1.0 / (1.0 - np.cos(2 * np.pi / n)),
                                     rel=1e-9)
    assert g.chi2() == pytest.approx((n - 1) / n, rel=1e-6)


@pytest.mark.parametrize("n", [8, 16, 64])
def test_complete_chi_closed_form(n):
    g = complete_graph(n)  # edge rate 1/(n-1) => lambda_2 = n/(n-1)
    assert g.chi1() == pytest.approx((n - 1) / n, rel=1e-9)
    assert g.chi2() == pytest.approx((n - 1) / n, rel=1e-6)


@pytest.mark.parametrize("dim", [3, 4, 6])
def test_hypercube_chi_closed_form(dim):
    g = hypercube_graph(dim)  # edge rate 1/d => lambda_2 = 2/d
    n = 1 << dim
    assert g.n == n and g.num_edges == dim * n // 2
    assert g.chi1() == pytest.approx(dim / 2.0, rel=1e-9)
    assert g.chi2() == pytest.approx((n - 1) / n, rel=1e-6)
    # Laplacian spectrum is {2k/d * d choose-k multiplicities}: check the
    # extreme eigenvalue too
    lam = np.linalg.eigvalsh(g.laplacian())
    assert lam[-1] == pytest.approx(2.0, rel=1e-9)


def test_hetero_empirical_laplacian_matches_def31():
    """A long per-edge heterogeneous schedule realizes the rate-weighted
    instantaneous Laplacian of Def 3.1 (the scenario-engine counterpart of
    the paper's App E.2 uniformity check)."""
    from repro.core import empirical_laplacian, make_schedule

    g = ring_graph(8)
    rates = np.linspace(0.2, 1.0, g.num_edges)
    sched = make_schedule(g, rounds=1500, comms_per_grad=1.0, seed=1,
                          edge_rates=rates)
    L_emp = empirical_laplacian(sched)
    L = g.with_rates(rates).laplacian()
    nz = np.abs(L) > 1e-9
    assert np.all((np.abs(L_emp) > 1e-9) == nz)
    np.testing.assert_allclose(L_emp[nz], L[nz], rtol=0.3)
    # and the hot edge really does gossip more than the cold one
    e_cold, e_hot = g.edges[0], g.edges[-1]
    assert -L_emp[e_hot[0], e_hot[1]] > 2.0 * -L_emp[e_cold[0], e_cold[1]]


def test_subgraph_and_with_rates():
    g = ring_graph(8)
    h = g.with_rates(np.arange(1, 9, dtype=float))
    assert h.edges == g.edges and h.rates == tuple(float(r)
                                                   for r in range(1, 9))
    active = np.ones(8, bool)
    active[0] = False
    s = g.subgraph(active)
    assert s.n == 8 and all(0 not in e for e in s.edges)
    # relabeled: ring minus one node is a 7-node path — still connected,
    # and chi1/chi2 are finite (what TopologyPhase.chis computes)
    r = g.subgraph(active, relabel=True)
    assert r.n == 7 and r.is_connected()
    assert 0 < r.chi2() <= r.chi1() < np.inf


def test_matching_bank_covers_all_edges():
    from repro.core import matching_bank
    for name in GRAPHS:
        g = build_graph(name, 16)
        bank = matching_bank(g)
        covered = set()
        for k in range(bank.shape[0]):
            for i, j in enumerate(bank[k]):
                if int(j) != i:
                    covered.add((min(i, int(j)), max(i, int(j))))
            # each bank row is an involution (valid matching)
            assert np.all(bank[k][bank[k]] == np.arange(16))
        assert covered == {tuple(sorted(e)) for e in g.edges}
