"""Graph/Laplacian invariants + the paper's App E.1 chi values."""
import numpy as np
import pytest

from repro.core import build_graph, complete_graph, exponential_graph, ring_graph


GRAPHS = ["complete", "ring", "exponential", "star"]


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("n", [4, 8, 16])
def test_laplacian_properties(name, n):
    g = build_graph(name, n)
    L = g.laplacian()
    assert np.allclose(L, L.T)
    assert np.allclose(L @ np.ones(n), 0.0)         # rows sum to zero
    lam = np.linalg.eigvalsh(L)
    assert lam[0] == pytest.approx(0.0, abs=1e-9)
    assert lam[1] > 0                                # connected
    assert g.is_connected()


@pytest.mark.parametrize("name", GRAPHS)
@pytest.mark.parametrize("n", [8, 16])
def test_chi2_le_chi1(name, n):
    g = build_graph(name, n)
    assert g.chi2() <= g.chi1() + 1e-9


def test_paper_appendix_e1_chi_values():
    """App E.1: (chi1, chi2) at n=16, 1 comm/grad ~ (1,1), (2,1), (13,1)."""
    assert complete_graph(16).chi1() == pytest.approx(1.0, abs=0.2)
    assert complete_graph(16).chi2() == pytest.approx(1.0, abs=0.2)
    assert exponential_graph(16).chi1() == pytest.approx(2.0, abs=0.4)
    assert exponential_graph(16).chi2() == pytest.approx(1.0, abs=0.3)
    assert ring_graph(16).chi1() == pytest.approx(13.0, abs=1.0)
    assert ring_graph(16).chi2() == pytest.approx(1.0, abs=0.3)


def test_ring_chi1_grows_quadratically():
    """chi1(ring) = Theta(n^2) — the regime where A2CiD2 wins sqrt(n)."""
    c8, c16, c32 = (ring_graph(n).chi1() for n in (8, 16, 32))
    assert 3.0 < c16 / c8 < 5.0
    assert 3.0 < c32 / c16 < 5.0


def test_total_rate_is_trace_over_two():
    for name in GRAPHS:
        g = build_graph(name, 16, rate_per_worker=2.0)
        assert g.total_rate() == pytest.approx(
            np.trace(g.laplacian()) / 2.0)


@pytest.mark.parametrize("n,seed", [(4, 0), (9, 17), (16, 3), (24, 101)])
def test_matchings_are_valid(n, seed):
    """Deterministic spot-check; the randomized sweep lives in
    test_property_sweeps.py."""
    g = ring_graph(n)
    rng = np.random.default_rng(seed)
    m = g.sample_matching(rng)
    nodes = [x for e in m for x in e]
    assert len(nodes) == len(set(nodes))            # node-disjoint
    edge_set = {tuple(sorted(e)) for e in g.edges}
    for e in m:
        assert tuple(sorted(e)) in edge_set         # real edges only
    p = g.matching_to_partner(m)
    assert np.all(p[p] == np.arange(n))             # involution


def test_matching_bank_covers_all_edges():
    from repro.core import matching_bank
    for name in GRAPHS:
        g = build_graph(name, 16)
        bank = matching_bank(g)
        covered = set()
        for k in range(bank.shape[0]):
            for i, j in enumerate(bank[k]):
                if int(j) != i:
                    covered.add((min(i, int(j)), max(i, int(j))))
            # each bank row is an involution (valid matching)
            assert np.all(bank[k][bank[k]] == np.arange(16))
        assert covered == {tuple(sorted(e)) for e in g.edges}
