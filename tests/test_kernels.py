"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
shape/dtype sweeps (the hypothesis sweep lives in test_property_sweeps.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.a2cid2_mixing.kernel import mixing_p2p
from repro.kernels.a2cid2_mixing.ref import mixing_p2p_ref
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_2d
from repro.kernels.rmsnorm.ref import rmsnorm_ref


# ------------------------------------------------------------ a2cid2_mixing

@pytest.mark.parametrize("n", [128, 4096, 70_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixing_kernel_matches_oracle(n, dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n,), dtype)
    xt = jax.random.normal(ks[1], (n,), dtype)
    xp = jax.random.normal(ks[2], (n,), dtype)
    kw = dict(eta=0.3, alpha=0.5, alpha_t=1.8)
    ox, ot = mixing_p2p(x, xt, xp, jnp.float32(0.7), interpret=True, **kw)
    rx, rt = mixing_p2p_ref(x, xt, xp, 0.7, **kw)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(ox, np.float32),
                               np.asarray(rx, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(ot, np.float32),
                               np.asarray(rt, np.float32), atol=atol)


@pytest.mark.parametrize("n,eta,dt,alpha_t", [
    (3, 0.0, 0.0, 0.1), (777, 1.3, 2.2, 1.8), (3000, 2.0, 5.0, 3.0),
])
def test_mixing_kernel_param_sweep(n, eta, dt, alpha_t):
    key = jax.random.PRNGKey(n)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (n,))
    xt = jax.random.normal(ks[1], (n,))
    xp = jax.random.normal(ks[2], (n,))
    kw = dict(eta=eta, alpha=0.5, alpha_t=alpha_t)
    ox, ot = mixing_p2p(x, xt, xp, jnp.float32(dt), interpret=True, **kw)
    rx, rt = mixing_p2p_ref(x, xt, xp, dt, **kw)
    np.testing.assert_allclose(ox, rx, atol=1e-4)
    np.testing.assert_allclose(ot, rt, atol=1e-4)


def test_mixing_kernel_preserves_buffer_sum():
    """Invariant: with alpha = alpha_t the update keeps x + x~ - (alpha+
    alpha_t) m consistent; specifically mixing alone preserves x + x~."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1000,))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (1000,))
    # alpha = alpha_t = 0: pure mixing => x + x~ invariant
    ox, ot = mixing_p2p(x, xt, x, jnp.float32(1.3), eta=0.7, alpha=0.0,
                        alpha_t=0.0, interpret=True)
    np.testing.assert_allclose(ox + ot, x + xt, atol=1e-5)


# ---------------------------------------------------------- flash attention

@pytest.mark.parametrize("S,T,hd,causal,window", [
    (128, 128, 64, True, None),
    (256, 256, 64, True, None),
    (256, 256, 128, False, None),
    (200, 200, 64, True, 64),       # unaligned seq + window
    (130, 384, 64, False, None),    # cross attention shape
])
def test_flash_attention_matches_oracle(S, T, hd, causal, window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, S, hd))
    k = jax.random.normal(ks[1], (2, T, hd))
    v = jax.random.normal(ks[2], (2, T, hd))
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 64), dtype)
    k = jax.random.normal(ks[1], (1, 256, 64), dtype)
    v = jax.random.normal(ks[2], (1, 256, 64), dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_attention_gqa_wrapper_matches_model_sdpa():
    """The ops wrapper (B,S,H,hd with GQA broadcast) must match the model's
    XLA attention path."""
    from repro.models.attention import _sdpa, causal_mask
    from repro.configs import get_config
    cfg = get_config("qwen3-14b", reduced=True)
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 128, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    ref = _sdpa(q, k, v, causal_mask(S), cfg).reshape(B, S, H, hd)
    out = flash_attention(q, k, v, causal=True, force_pallas=True,
                          interpret=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("T,D,dtype", [
    (64, 512, jnp.float32), (128, 1024, jnp.bfloat16),
    (130, 768, jnp.float32), (1, 256, jnp.float32),
])
def test_rmsnorm_kernel_matches_oracle(T, D, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, D), dtype)
    sc = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (D,), dtype)
    out = rmsnorm_2d(x, sc, interpret=True)
    ref = rmsnorm_ref(x, sc)
    atol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_rmsnorm_output_is_unit_rms():
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(0), (32, 512))
    out = rmsnorm_2d(x, jnp.zeros(512), interpret=True)
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)
