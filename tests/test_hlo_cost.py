"""HLO cost model: trip-count-aware FLOPs/collectives vs unrolled references
(XLA's own cost_analysis counts while bodies once — the reason this exists)."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import cost_from_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


M = jax.ShapeDtypeStruct((128, 128), jnp.float32)


def test_scan_trip_count_counted():
    def f(x):
        def body(c, _):
            return c @ x, None
        return jax.lax.scan(body, x, None, length=8)[0]

    hc = cost_from_hlo(_compile(f, M).as_text())
    assert hc.flops == pytest.approx(8 * 2 * 128 ** 3)


def test_unrolled_matches_scan():
    def f(x):
        def body(c, _):
            return c @ x, None
        return jax.lax.scan(body, x, None, length=6)[0]

    def g(x):
        for _ in range(6):
            x = x @ x
        return x

    a = cost_from_hlo(_compile(f, M).as_text()).flops
    b = cost_from_hlo(_compile(g, M).as_text()).flops
    assert a == pytest.approx(b)


def test_nested_scans_multiply():
    def h(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            return jax.lax.scan(inner, c, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    hc = cost_from_hlo(_compile(h, M).as_text())
    assert hc.flops == pytest.approx(12 * 2 * 128 ** 3)


def test_write_bytes_scale_with_trips():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        return jax.lax.scan(body, x, None, length=10)[0]

    def g(x):
        def body(c, _):
            return jnp.tanh(c @ x), None
        return jax.lax.scan(body, x, None, length=5)[0]

    a = cost_from_hlo(_compile(f, M).as_text()).write_bytes
    b = cost_from_hlo(_compile(g, M).as_text()).write_bytes
    assert a > 1.5 * b


def test_einsum_flops():
    def f(a, b):
        return jnp.einsum("ij,jk->ik", a, b)

    A = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    B = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    hc = cost_from_hlo(_compile(f, A, B).as_text())
    assert hc.flops == pytest.approx(2 * 64 * 256 * 32)
