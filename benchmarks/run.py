"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Where a paper artifact is a
convergence/accuracy result (Tab 4/5, Fig 1/3/4/5), the benchmark runs the
CPU-scale analogue via the event simulator and reports the decisive derived
quantity; timing-style artifacts (Tab 2/3/6) are measured or analytically
derived from the event model.

    PYTHONPATH=src python -m benchmarks.run                    # all
    PYTHONPATH=src python -m benchmarks.run --only table2
    PYTHONPATH=src python -m benchmarks.run --only topology --seed 7
    PYTHONPATH=src python -m benchmarks.run --only topology --small  # CI
    PYTHONPATH=src python -m benchmarks.run --only sweep       # batched vs serial

``--seed`` threads into every world compilation; ``--only topology`` emits
``BENCH_topology.json`` with a serialized ``World`` spec and a wall-clock
axis (bandwidth-aware LinkModel) per curve.  The sweep families
(``topology``, ``channel``) replay as batched many-worlds scans
(``Simulator.run_worlds``, DESIGN.md §11) — one jit trace + one dispatch
per family — and ``--only sweep`` emits ``BENCH_sweep.json``, the
batched-vs-serial wall-clock artifact the CI perf gate reads.  Timing
helpers block on results and report cold (compile-inclusive) and warm.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, repeats=3):
    """(cold_us, warm_us) of ``fn`` with results BLOCKED before the clock
    is read — jax dispatch is async, so timing an unblocked call measures
    enqueue latency, not work.  Cold includes compilation; warm is the
    steady-state mean over ``repeats``."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    cold = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return cold, (time.perf_counter() - t0) / repeats * 1e6


def _parse_only(arg):
    return [s.strip() for s in arg.split(",") if s.strip()]


# ------------------------------------------------------- artifact emission

_MAX_CURVE_POINTS = 48  # per-curve cap in the emitted JSON artifacts


def _curve_indices(length: int, max_points: int = _MAX_CURVE_POINTS):
    """Evenly spaced sample indices keeping first and last points."""
    if length <= max_points:
        return np.arange(length)
    return np.unique(np.linspace(0, length - 1, max_points).round()
                     .astype(int))


def _downsample_entry(entry: dict, keys: tuple) -> dict:
    """Downsample a curve entry's per-round arrays on SHARED indices (the
    x-axis and every consensus curve stay aligned); scalars, world specs,
    and anything not listed pass through untouched."""
    lengths = [len(entry[k]) for k in keys if k in entry]
    if not lengths:
        return entry
    idxs = _curve_indices(max(lengths))
    out = dict(entry)
    for k in keys:
        if k in entry:
            arr = entry[k]
            out[k] = [arr[i] for i in idxs if i < len(arr)]
    return out


def _finite_or_none(x: float):
    """JSON-safe scalar: divergent (nan/inf) values become null."""
    x = float(x)
    return x if np.isfinite(x) else None


def _sanitize_json(obj):
    """Recursively null out NaN/Inf floats so every bench writer is safe
    against a diverged curve (json with allow_nan=False would otherwise
    throw away a whole completed sweep at write time)."""
    if isinstance(obj, dict):
        return {k: _sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize_json(v) for v in obj]
    if isinstance(obj, float):
        return _finite_or_none(obj)
    return obj


def _artifact_path(name: str) -> str:
    """Repo-root path of a BENCH_*/TRACE_* artifact."""
    import os
    return os.path.join(os.path.dirname(__file__), "..", name)


def _dump_json(path_base: str, name: str, report: dict) -> None:
    """Compact-writer for every BENCH_*.json artifact: no indentation
    whitespace (the topology artifact was ~17k lines indented) and
    NaN/Inf-free (``_sanitize_json``)."""
    import json
    with open(_artifact_path(name), "w") as f:
        json.dump(_sanitize_json(report), f, separators=(",", ":"),
                  allow_nan=False)
        f.write("\n")


# ------------------------------------------------- flight recorder (host)
# One SpanTracer per --only family (set by main()): every bench family
# writes TRACE_<name>.json beside its BENCH_<name>.json (DESIGN.md §15).
_TRACER = None


def _exec_cost(tag: str, jitted, *args) -> dict:
    """Per-executable cost row: FLOPs, HBM write bytes, collective bytes
    and the roofline bottleneck of ONE jitted callable, derived AOT from
    its compiled HLO (``lower -> compile -> as_text``; never executed).

    Degrades to an ``{"executable", "error"}`` row instead of failing the
    bench — cost accounting must never take down an artifact.  When a
    family tracer is live, the compile is recorded as a ``jit.compile``
    span annotated with the cost row.
    """
    try:
        from repro.analysis import cost_from_hlo
        from repro.analysis.roofline import (HBM_BW, ICI_BW,
                                             PEAK_FLOPS_BF16)
        t0 = _TRACER.now_us() if _TRACER is not None else 0.0
        compiled = jitted.lower(*args).compile()
        cost = cost_from_hlo(compiled.as_text())
        ca = {}
        try:
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
        except Exception:
            ca = {}
        terms = {"compute": cost.flops / PEAK_FLOPS_BF16,
                 "memory": cost.write_bytes / HBM_BW,
                 "collective": cost.collective_bytes / ICI_BW}
        row = {
            "executable": tag, "method": "hlo",
            "flops": cost.flops,
            "write_bytes": cost.write_bytes,
            "collective_bytes": cost.collective_bytes,
            "collective_detail": cost.collective_detail,
            "xla_flops": float(ca.get("flops", 0.0)),
            "xla_bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "compute_s": terms["compute"], "memory_s": terms["memory"],
            "collective_s": terms["collective"],
            "bottleneck": max(terms, key=terms.get),
        }
        if _TRACER is not None:
            _TRACER.complete(
                f"jit.compile.{tag}", t0, _TRACER.now_us() - t0,
                lane="compile",
                args={k: row[k] for k in ("flops", "write_bytes",
                                          "collective_bytes",
                                          "bottleneck")})
        return row
    except Exception as e:  # pragma: no cover - platform-dependent paths
        return {"executable": tag, "method": "hlo", "error": str(e)}


def _schedule_compiler(rounds):
    """World-schedule compiler memoized per unique (world object, seed) —
    a sweep grid replays the identical schedule across its baseline/
    accelerated (and robust/non-robust) arms, so each point compiles
    once."""
    cache = {}

    def compiled(w, s):
        key = (id(w), s)
        if key not in cache:
            cache[key] = w.compile(rounds, seed=s)
        return cache[key]

    return compiled


def _quad_grad_fn(b, noise=0.05):
    def grad_fn(x, key, wid):
        g = (x - b[wid]) + noise * jax.random.normal(key, x.shape)
        return 0.5 * jnp.sum((x - b[wid]) ** 2), g
    return grad_fn


def _sim_consensus(graph_name, n, accel, rate, rounds=250, d=64, seed=0):
    """(cold_us, warm_us, tail_consensus) of one serial world replay.

    The replay result is blocked on before the clock is read (the old
    timing measured async DISPATCH, not the replay); cold includes the
    jit trace, warm is a second identical call.
    """
    from repro.core import Simulator, World, build_graph, params_from_graph
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = build_graph(graph_name, n)
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, accelerated=accel),
                    gamma=0.05)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    # compile host-side BEFORE the timer: the us column measures the replay
    # only, comparable with pre-World artifacts
    sched = World(topology=g, comms_per_grad=rate).compile(rounds, seed=seed)
    out = {}

    def run():
        _, out["trace"] = sim.run_schedule(st, sched)
        return out["trace"]

    cold, warm = _timeit(run, repeats=1)
    return cold, warm, float(jnp.mean(out["trace"].consensus[-50:]))


# ----------------------------------------------------------- paper artifacts

def bench_table2_comm_rates(seed: int = 0) -> list[str]:
    """Tab 2: #communications per time unit for A2CiD2's rate condition
    sqrt(chi1 chi2)=O(1), per graph (analytic, from the Laplacian)."""
    from repro.core import build_graph
    rows = []
    for name in ("star", "ring", "complete"):
        n = 16
        g = build_graph(name, n)
        chi1, chi2 = g.chi1(), g.chi2()
        # scale Lambda by sqrt(chi1 chi2) => comm rate Tr(scaled)/2 (App D)
        scale = np.sqrt(chi1 * chi2)
        t0 = time.perf_counter()
        rate = scale * g.total_rate()
        us = (time.perf_counter() - t0) * 1e6
        rows.append(f"table2_comm_rate_{name},{us:.1f},{rate:.1f}")
    return rows


def bench_table3_training_time(seed: int = 0) -> list[str]:
    """Tab 3/6: async event timeline vs synchronous barriers — derived idle
    fraction of the slowest worker under jittered step durations."""
    rng = np.random.default_rng(seed)
    n, steps = 16, 200
    # per-step durations: lognormal jitter around 1 (stragglers)
    dur = rng.lognormal(mean=0.0, sigma=0.15, size=(steps, n))
    t0 = time.perf_counter()
    sync_time = dur.max(axis=1).sum()          # barrier per step
    async_time = dur.sum(axis=0).max()         # each worker free-runs
    us = (time.perf_counter() - t0) * 1e6
    speedup = sync_time / async_time
    return [f"table3_async_speedup,{us:.1f},{speedup:.3f}"]


def bench_table4_cifar_topologies(seed: int = 0) -> list[str]:
    """Tab 4 analogue: final consensus distance per topology, w/ and w/o
    A2CiD2 (ring shows the gap; complete does not)."""
    rows = []
    for name in ("complete", "ring"):
        for accel in (False, True):
            cold, warm, cons = _sim_consensus(name, 16, accel, 1.0,
                                              seed=seed)
            tag = "acid" if accel else "base"
            rows.append(f"table4_consensus_{name}_{tag},{warm:.0f},"
                        f"{cons:.4f};cold_us={cold:.0f}")
    return rows


def bench_fig1_virtual_doubling(seed: int = 0) -> list[str]:
    """Fig 1 / Fig 5b: A2CiD2 @ rate 1 vs baseline @ rate 2 on the ring."""
    c1, us1, base1 = _sim_consensus("ring", 16, False, 1.0, seed=seed)
    c2, us2, base2 = _sim_consensus("ring", 16, False, 2.0, seed=seed)
    c3, us3, acid1 = _sim_consensus("ring", 16, True, 1.0, seed=seed)
    ratio = acid1 / base2
    return [
        f"fig1_base_rate1,{us1:.0f},{base1:.4f};cold_us={c1:.0f}",
        f"fig1_base_rate2,{us2:.0f},{base2:.4f};cold_us={c2:.0f}",
        f"fig1_acid_rate1,{us3:.0f},{acid1:.4f};cold_us={c3:.0f}",
        f"fig1_acid_vs_doubled_ratio,0.0,{ratio:.3f}",
    ]


def bench_table5_worker_scaling(seed: int = 0) -> list[str]:
    """Tab 5 trend: ring-graph consensus degradation with n, and A2CiD2's
    recovery (n = 16, 32)."""
    rows = []
    for n in (16, 32):
        _, _, base = _sim_consensus("ring", n, False, 1.0, seed=seed)
        _, _, acid = _sim_consensus("ring", n, True, 1.0, seed=seed)
        rows.append(f"table5_ring_n{n}_gain,0.0,{base / max(acid, 1e-9):.3f}")
    return rows


# --------------------------------------------------------- systems benchmarks

def bench_kernels(seed: int = 0) -> list[str]:
    """Microbenchmarks of the Pallas kernels' oracle paths (CPU timing).

    The a2cid2_mixing rows report the FULL HBM traffic of one gossip event
    at f32: unfused (mix pass + p2p pass) moves 6 reads + 4 writes of
    parameter-sized tensors, the fused kernel 3 reads + 2 writes.  A timed
    interpret-mode Pallas row sits next to the jnp oracle as a smoke check
    (interpret timings are NOT hardware-representative).
    """
    from repro.kernels.a2cid2_mixing.kernel import mixing_p2p
    from repro.kernels.a2cid2_mixing.ref import mixing_p2p_ref
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.rmsnorm.ref import rmsnorm_ref

    key = jax.random.PRNGKey(0)
    n = 1 << 20
    x = jax.random.normal(key, (n,))
    xt = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    xp = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    gb = n * 4 / 1e9
    kw = dict(eta=0.2, alpha=0.5, alpha_t=1.3)
    jf = jax.jit(lambda: mixing_p2p_ref(x, xt, xp, 0.5, **kw)[0])
    cold_f, warm_f = _timeit(jf)
    rows = [
        f"kernel_a2cid2_mixing_1M_unfused_traffic,0.0,"
        f"{6 * gb:.3f}GB_read+{4 * gb:.3f}GB_write",
        f"kernel_a2cid2_mixing_1M,{warm_f:.0f},"
        f"{3 * gb:.3f}GB_read+{2 * gb:.3f}GB_write_fused"
        f";cold_us={cold_f:.0f}",
    ]
    jp = jax.jit(lambda: mixing_p2p(x, xt, xp, jnp.float32(0.5),
                                    interpret=True, **kw)[0])
    cold_p, warm_p = _timeit(jp, 1)
    rows.append(f"kernel_a2cid2_mixing_1M_pallas_interpret,{warm_p:.0f},"
                f"{3 * gb:.3f}GB_read+{2 * gb:.3f}GB_write_fused"
                f";cold_us={cold_p:.0f}")

    q = jax.random.normal(key, (4, 512, 64))
    jg = jax.jit(lambda: attention_ref(q, q, q))
    cold_g, warm_g = _timeit(jg)
    rows.append(f"kernel_flash_attention_ref_4x512,{warm_g:.0f},"
                f"causal;cold_us={cold_g:.0f}")

    xx = jax.random.normal(key, (4096, 1024))
    sc = jnp.zeros(1024)
    jh = jax.jit(lambda: rmsnorm_ref(xx, sc))
    cold_h, warm_h = _timeit(jh)
    rows.append(f"kernel_rmsnorm_ref_4096x1024,{warm_h:.0f},"
                f"fused;cold_us={cold_h:.0f}")
    return rows


_SIM_BENCH = {"n": 16, "d": 256, "rounds": 100, "comms_per_grad": 1.0}


def _sim_setup(seed=0):
    from repro.core import (Simulator, coalesce_schedule, make_schedule,
                            params_from_graph, ring_graph)
    n, d, rounds = _SIM_BENCH["n"], _SIM_BENCH["d"], _SIM_BENCH["rounds"]
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    g = ring_graph(n)
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True), gamma=0.05)
    st = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    sched = make_schedule(g, rounds=rounds,
                          comms_per_grad=_SIM_BENCH["comms_per_grad"],
                          seed=seed)
    cs = coalesce_schedule(sched)
    ref_arrays = sim.reference_arrays(sched)
    eng_arrays = sim.coalesced_arrays(st, sched, cs=cs)
    return sim, st, sched, cs, ref_arrays, eng_arrays


def bench_simulator_throughput(seed: int = 0) -> list[str]:
    """Event-simulator throughput (rounds/s) — the repro's own hot loop,
    on the flat-buffer coalesced/fused engine path (the default)."""
    sim, st, _, _, _, eng_arrays = _sim_setup(seed)
    run = lambda: sim.run_coalesced(st, eng_arrays)[1].loss
    cold, warm = _timeit(run, repeats=1)
    dt = warm / 1e6
    return [f"simulator_100rounds_n16,{warm:.0f},{100/dt:.0f}_rounds_per_s"
            f";cold_us={cold:.0f}"]


def bench_gossip_engine(seed: int = 0) -> list[str]:
    """Fused flat-buffer event engine vs the per-event reference path on the
    same schedule (100 rounds, n=16, d=256), plus the event-coalescing and
    HBM-traffic accounting.  Emits BENCH_gossip.json next to the repo root.

    Traffic accounting (state-tensor units, (n, D) each): the per-event
    reference sweeps every schedule SLOT (masked or not) with an unfused
    mix pass (2R+2W) + p2p pass (4R+2W incl. the partner gather); the engine
    sweeps only coalesced BATCHES, each one fused pass of 3 reads + 2 writes
    (x self + x partner rows + x~ self; the trailing mix rides along free).
    """
    sim, st, sched, cs, ref_arrays, eng_arrays = _sim_setup(seed)
    ref = lambda: sim.run(st, ref_arrays)[1].loss
    eng = lambda: sim.run_coalesced(st, eng_arrays)[1].loss
    cold_ref, us_ref = _timeit(ref, repeats=7)
    cold_eng, us_eng = _timeit(eng, repeats=7)
    speedup = us_ref / us_eng

    raw_slots = int(sched.partners.shape[0] * sched.partners.shape[1])
    batches = cs.num_batches()
    active_events = int(sched.event_mask.sum())
    # per-sweep state-tensor traffic: reference (mix + p2p unfused) vs fused
    ref_rw = (6, 4)
    fused_rw = (3, 2)
    report = {
        "config": dict(_SIM_BENCH),
        "simulator_100rounds_n16": {
            "seed_us": round(us_ref, 1),       # per-event path = seed code
            "engine_us": round(us_eng, 1),
            "speedup": round(speedup, 3),
            "seed_cold_us": round(cold_ref, 1),
            "engine_cold_us": round(cold_eng, 1),
        },
        "event_sweeps": {
            "raw_slots": raw_slots,
            "active_events": active_events,
            "coalesced_batches": batches,
            "sweep_reduction": round(raw_slots / max(batches, 1), 3),
        },
        "state_traffic_per_sweep": {
            "reference_reads_writes": ref_rw,
            "fused_reads_writes": fused_rw,
        },
        "executables": [
            _exec_cost("gossip_engine_replay", jax.jit(eng)),
            _exec_cost("gossip_reference_replay", jax.jit(ref)),
        ],
    }
    _dump_json(__file__, "BENCH_gossip.json", report)
    return [
        f"gossip_ref_100rounds_n16,{us_ref:.0f},{1e8/us_ref:.0f}_rounds_per_s",
        f"gossip_engine_100rounds_n16,{us_eng:.0f},"
        f"{1e8/us_eng:.0f}_rounds_per_s",
        f"gossip_engine_speedup,0.0,{speedup:.2f}x",
        f"gossip_event_sweeps,0.0,raw={raw_slots};active={active_events};"
        f"coalesced={batches}",
        f"gossip_traffic_per_sweep,0.0,ref={ref_rw[0]}R+{ref_rw[1]}W;"
        f"fused={fused_rw[0]}R+{fused_rw[1]}W",
    ]


_TOPO_BENCH = {"n": 64, "d": 32, "rounds": 150, "comms_per_grad": 1.0,
               "gamma": 0.05, "noise": 0.05, "seeds": 3,
               "families": ["ring", "torus", "hypercube", "complete"]}


def bench_topology_sweep(seed: int = 0) -> list[str]:
    """Paper-figure-shaped artifact: consensus-distance-vs-communication
    curves, accelerated vs baseline, across the paper's topology families at
    n=64 (Tab 4/5 + Fig 4 regime: the ring/torus gains, the complete-graph
    wash), plus heterogeneous-world scenarios (straggler clocks, a
    ring->hypercube phase switch with churn, Poisson failure/repair churn,
    and a bandwidth-degraded ring).  Emits BENCH_topology.json.

    The WHOLE artifact — every family x {baseline, accelerated} x seed,
    plus every scenario — is ONE batched replay (``Simulator.run_worlds``,
    DESIGN.md §11): per-world A2CiD2 params ride the batch axis, so the
    sweep costs one jit trace and one device dispatch instead of one per
    point.  Family curves carry mean +- std bands over ``seeds`` seeds.

    Every curve is described by a declarative ``World`` (core/world.py);
    its serialized spec is embedded next to the curve so the artifact names
    the exact scenario that produced it, and each world carries a
    bandwidth-aware ``LinkModel`` (TPU ICI bandwidth from
    ``analysis/roofline.py``) giving the curves a wall-clock x-axis.
    Curves are downsampled to <= 48 points (shared indices per entry) and
    the JSON is written compact; world specs stay intact.
    """
    from repro.analysis.roofline import HBM_BW, ICI_BW
    from repro.core import (ChurnProcess, LinkModel, PhaseSwitch, Simulator,
                            WorkerModel, World, build_graph,
                            params_from_graph)

    n, d = _TOPO_BENCH["n"], _TOPO_BENCH["d"]
    rounds, rate = _TOPO_BENCH["rounds"], _TOPO_BENCH["comms_per_grad"]
    seeds = [seed + i for i in range(_TOPO_BENCH["seeds"])]
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    grad_fn = _quad_grad_fn(b, noise=_TOPO_BENCH["noise"])
    # one p2p message = the d-float replica; a gradient tick reads + writes
    # the replica through HBM (the memory term of the roofline)
    msg_bytes = float(d * 4)
    grad_seconds = 2 * msg_bytes / HBM_BW

    def link_model(bandwidth=ICI_BW):
        return LinkModel(bandwidth_bytes_per_s=bandwidth,
                         msg_bytes=msg_bytes, grad_seconds=grad_seconds)

    ring = build_graph("ring", n)

    # -------- declare the grid: (key, world, chi_graph, accel, seed) per
    # point; families sweep seeds, scenarios replay at the base seed.
    # Worlds are constructed ONCE per curve and shared across the
    # baseline/accelerated arms, so each (world, seed) schedule compiles
    # once (the arms replay the identical schedule).
    points = []
    family_graphs = {}
    family_worlds = {}
    for name in _TOPO_BENCH["families"]:
        g = build_graph(name, n)
        family_graphs[name] = g
        family_worlds[name] = World(topology=g, links=link_model(),
                                    comms_per_grad=rate)
        for accel in (False, True):
            for s in seeds:
                points.append((("families", name), family_worlds[name],
                               g, accel, s))

    grad_rates = np.where(np.arange(n) % 2 == 0, 1.0, 0.25)
    scen_worlds = {"ring_stragglers": World(
        topology=ring, workers=WorkerModel(grad_rates=grad_rates),
        links=link_model(), comms_per_grad=rate)}
    active = np.ones(n, bool)
    active[: n // 8] = False
    scen_worlds["ring_churn_hypercube"] = World(
        topology=ring, links=link_model(),
        faults=(PhaseSwitch(rounds // 3, active=tuple(active)),
                PhaseSwitch(2 * (rounds // 3),
                            topology=build_graph("hypercube", n))),
        comms_per_grad=rate)
    scen_worlds["ring_poisson_churn"] = World(
        topology=ring, links=link_model(),
        faults=(ChurnProcess(fail_rate=0.02, repair_rate=0.2),),
        comms_per_grad=rate)
    bw = np.full(ring.num_edges, ICI_BW)
    bw[::8] /= 8.0
    scen_worlds["ring_degraded_links"] = World(
        topology=ring,
        links=LinkModel(bandwidth_bytes_per_s=tuple(bw),
                        msg_bytes=msg_bytes, grad_seconds=grad_seconds),
        comms_per_grad=rate)
    for sname, w in scen_worlds.items():
        for accel in (False, True):
            points.append((("scenarios", sname), w, ring, accel, seed))

    # -------- compile the grid host-side (one compile per unique
    # (world, seed) — both accel arms share it), replay in ONE dispatch
    compiled = _schedule_compiler(rounds)
    scheds = [compiled(w, s) for _, w, _, _, s in points]
    plist = [params_from_graph(g, accelerated=a)
             for _, _, g, a, _ in points]
    sim = Simulator(grad_fn, plist[0], gamma=_TOPO_BENCH["gamma"])
    states = [sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
              for _ in points]
    traces = Simulator._run_worlds_jit._cache_size()
    out = {}

    def replay():
        out["trace"] = sim.run_worlds(states, scheds, params=plist)[1]
        return out["trace"]

    cold_us, warm_us = _timeit(replay, repeats=1)
    trace = out["trace"]
    traces = Simulator._run_worlds_jit._cache_size() - traces
    cons = np.asarray(trace.consensus, np.float64)  # (B, rounds)

    def curves_for(key, accel):
        idx = [i for i, (k, _, _, a, _) in enumerate(points)
               if k == key and a == accel]
        return cons[idx], [scheds[i] for i in idx]

    def curve_entry(key, world):
        """Mean +- std bands over the key's seeds (scenarios: one seed,
        std 0), x-axes from the first seed's schedule."""
        base, schs = curves_for(key, False)
        acid, _ = curves_for(key, True)
        sched = schs[0]
        tail_b = float(base.mean(axis=0)[-30:].mean())
        tail_a = float(acid.mean(axis=0)[-30:].mean())
        wall = world.round_seconds(sched)
        entry = {
            "world": world.to_dict(),
            "seeds": seeds if base.shape[0] > 1 else [seed],
            "cumulative_comm_events":
                np.cumsum(sched.comm_events_per_round()).tolist(),
            "wall_clock_seconds": np.cumsum(wall).tolist(),
            "consensus_baseline": base.mean(axis=0).tolist(),
            "consensus_baseline_std": base.std(axis=0).tolist(),
            "consensus_acid": acid.mean(axis=0).tolist(),
            "consensus_acid_std": acid.std(axis=0).tolist(),
            "tail_consensus_baseline": tail_b,
            "tail_consensus_acid": tail_a,
            "acid_gain": tail_b / max(tail_a, 1e-12),
        }
        return _downsample_entry(entry, ("cumulative_comm_events",
                                         "wall_clock_seconds",
                                         "consensus_baseline",
                                         "consensus_baseline_std",
                                         "consensus_acid",
                                         "consensus_acid_std")), sched

    rows, report = [], {"config": dict(_TOPO_BENCH), "seed": seed,
                        "families": {}, "scenarios": {},
                        "batched_replay": {
                            "num_worlds": len(points),
                            "cold_us": round(cold_us, 1),
                            "warm_us": round(warm_us, 1),
                            "jit_traces": traces,
                        }}
    for name in _TOPO_BENCH["families"]:
        g = family_graphs[name]
        entry, _ = curve_entry(("families", name), family_worlds[name])
        entry.update(chi1=g.chi1(), chi2=g.chi2())
        report["families"][name] = entry
        rows.append(f"topology_{name}_n{n},0.0,"
                    f"gain={entry['acid_gain']:.3f};chi1={g.chi1():.1f}")

    for sname, w in scen_worlds.items():
        entry, sched = curve_entry(("scenarios", sname), w)
        if sname == "ring_churn_hypercube":
            entry["phases"] = [
                {"graph": ph.graph.name, "rounds": ph.rounds,
                 "active_workers": int(ph.active_mask().sum()),
                 "chi1": ph.chis()[0], "chi2": ph.chis()[1]}
                for ph in w.phase_plan(rounds, seed).phases]
        elif sname == "ring_poisson_churn":
            entry["mean_alive_fraction"] = float(sched.alive_arr().mean())
            entry["num_segments"] = len(w.segments(rounds, seed))
        elif sname == "ring_degraded_links":
            entry["slow_links"] = int((bw < ICI_BW).sum())
        report["scenarios"][sname] = entry

    cost_fn, cost_args = sim.worlds_executable(states, scheds, params=plist)
    report["executables"] = [_exec_cost("topology_grid_replay",
                                        cost_fn, *cost_args)]
    _dump_json(__file__, "BENCH_topology.json", report)
    rows.append(f"topology_batched_dispatch,{warm_us:.0f},"
                f"worlds={len(points)};traces={traces};"
                f"cold_us={cold_us:.0f}")
    rows.append("topology_scenarios,0.0,"
                f"stragglers_gain="
                f"{report['scenarios']['ring_stragglers']['acid_gain']:.3f};"
                f"churn_alive="
                f"{report['scenarios']['ring_poisson_churn']['mean_alive_fraction']:.3f}")
    return rows


_CHAN_BENCH = {
    "n": 32, "d": 32, "rounds": 150, "comms_per_grad": 1.0,
    "gamma": 0.05, "noise": 0.05,
    "horizons": [0, 2, 4, 8],          # staleness sweep (ring-buffer depth)
    "stale_prob": 1.0,
    "byz_fracs": [0.0, 0.05, 0.1, 0.2],  # fraction of ring edges Byzantine
    "byz_mode": "scale", "byz_scale": 1e3, "byz_prob": 0.5,
    "byz_seeds": 3,                    # variance bands over >= 3 seeds
    "robust_clip": 5.0, "robust_rule": "trim",
}


def bench_channel_sweep(seed: int = 0) -> list[str]:
    """Unreliable-channel artifact (DESIGN.md §10): consensus + breakdown
    curves vs staleness horizon and vs the fraction of Byzantine edges on
    the ring, accelerated vs baseline, with the robust-aggregation (norm
    trim) replay next to the non-robust one.  Emits BENCH_channel.json.

    Each family runs as ONE batched replay (DESIGN.md §11): every
    (point, baseline/accelerated, seed) world shares a single jit trace
    and device dispatch per replay config — the staleness family is one
    dispatch and, since the robust tau became per-world ``(B,)`` data
    (DESIGN.md §12), the Byzantine family's non-robust AND robust arms
    ride one dispatch too.  Batching makes multi-seed cheap: the
    Byzantine family carries mean +- std bands over ``byz_seeds`` >= 3
    seeds.

    The Byzantine family is a garbage-injection adversary (``scale`` mode
    at 1e3, 50% duty cycle — an intermittent compromised link): without
    the defense the replay diverges outright; with ``robust_rule='trim'``
    the corrupted exchanges are rejected wholesale while the honest duty
    cycle keeps the ring connected, so the accelerated gain survives.
    The headline numbers are ``summary.gain_retention_at_0.1`` (robust
    gain on the 10%-Byzantine ring over the clean-channel gain; the
    acceptance bar is >= 0.8) and the divergent non-robust tails.

    Every curve embeds its serialized ``World`` spec — channel included —
    and NaN/Inf values of diverged non-robust replays are emitted as null
    plus a ``diverged`` flag (the compact/NaN-safe writer contract).
    """
    from repro.core import (ByzantineEdges, ChannelModel, DelayProcess,
                            Simulator, World, build_graph,
                            params_from_graph)

    cfg = _CHAN_BENCH
    n, d, rounds = cfg["n"], cfg["d"], cfg["rounds"]
    rate = cfg["comms_per_grad"]
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    grad_fn = _quad_grad_fn(b, noise=cfg["noise"])
    ring = build_graph("ring", n)
    p_acid = params_from_graph(ring, accelerated=True)
    p_base = params_from_graph(ring, accelerated=False)

    compiled = _schedule_compiler(rounds)

    cost_fns = {}

    def run_family(worlds_accels_seeds, clips=None, cost_tag=None):
        """Replay a family grid in ONE batched dispatch; ``clips`` lifts
        the robust tau to per-world data (None = non-robust arm).
        Returns the (B, rounds) consensus curves + dispatch wall time.
        ``cost_tag`` stashes the replay closure for the per-executable
        cost rows embedded in the artifact."""
        sim = Simulator(grad_fn, p_acid, gamma=cfg["gamma"],
                        robust_rule=cfg["robust_rule"])
        scheds = [compiled(w, s) for w, _, s in worlds_accels_seeds]
        plist = [p_acid if a else p_base for _, a, _ in worlds_accels_seeds]
        states = [sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
                  for _ in scheds]
        if cost_tag is not None:
            cost_fns[cost_tag] = sim.worlds_executable(
                states, scheds, params=plist, robust_clips=clips)
        t0 = time.perf_counter()
        _, trace = sim.run_worlds(states, scheds, params=plist,
                                  robust_clips=clips)
        jax.block_until_ready(trace)
        us = (time.perf_counter() - t0) * 1e6
        return np.asarray(trace.consensus, np.float64), us

    def nantail(curve):
        tail = curve[-30:]
        if not np.isfinite(tail).any():
            return float("nan")
        return float(np.nanmean(tail))

    def band(curves):
        """(mean, std) curves over seeds, NaN-tolerant (a seed that
        diverged at round r contributes nothing there onward)."""
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return np.nanmean(curves, axis=0), np.nanstd(curves, axis=0)

    def curve_entry(world, robust, base_curves, acid_curves, seeds_used):
        base, base_std = band(base_curves)
        acid, acid_std = band(acid_curves)
        tail_b = nantail(base)
        tail_a = nantail(acid)
        diverged = not (np.isfinite(base_curves).all()
                        and np.isfinite(acid_curves).all())
        gain = tail_b / max(tail_a, 1e-12) if np.isfinite(tail_b) \
            and np.isfinite(tail_a) else float("nan")
        entry = {
            "world": world.to_dict(),
            "robust": bool(robust),
            "seeds": list(seeds_used),
            "consensus_baseline": [_finite_or_none(v) for v in base],
            "consensus_acid": [_finite_or_none(v) for v in acid],
            "consensus_baseline_std": [_finite_or_none(v)
                                       for v in base_std],
            "consensus_acid_std": [_finite_or_none(v) for v in acid_std],
            "tail_consensus_baseline": _finite_or_none(tail_b),
            "tail_consensus_acid": _finite_or_none(tail_a),
            "acid_gain": _finite_or_none(gain),
            "diverged": diverged,
        }
        return _downsample_entry(entry, ("consensus_baseline",
                                         "consensus_acid",
                                         "consensus_baseline_std",
                                         "consensus_acid_std"))

    def fmt(g):  # sanitized gains are None when a replay diverged
        return "None" if g is None else f"{g:.3f}"

    rows = []
    report = {"config": dict(cfg), "seed": seed,
              "staleness": {}, "byzantine": {}, "summary": {}}

    # family 1: staleness horizon sweep (all reads stale, uniform in
    # [1, H]; H=0 is the clean exact-reduction anchor) — one dispatch
    stale_worlds = {}
    for h in cfg["horizons"]:
        delay = DelayProcess(horizon=int(h), prob=cfg["stale_prob"])
        stale_worlds[h] = World(topology=ring, comms_per_grad=rate,
                                channel=None if h == 0
                                else ChannelModel(delay=delay))
    grid = [(w, a, seed) for w in stale_worlds.values()
            for a in (False, True)]
    cons, us_stale = run_family(grid, cost_tag="channel_stale_family")
    for i, h in enumerate(cfg["horizons"]):
        entry = curve_entry(stale_worlds[h], False,
                            cons[2 * i:2 * i + 1], cons[2 * i + 1:2 * i + 2],
                            [seed])
        report["staleness"][f"h{h}"] = entry
        rows.append(f"channel_stale_h{h}_n{n},0.0,"
                    f"gain={fmt(entry['acid_gain'])}")
    rows.append(f"channel_stale_dispatch,{us_stale:.0f},"
                f"worlds={len(grid)};dispatches=1")

    # family 2: Byzantine-edge fraction sweep, non-robust vs robust arms
    # TOGETHER in one dispatch (per-world robust_clips), mean +- std
    # bands over byz_seeds seeds per point
    E = ring.num_edges
    byz_seeds = [seed + i for i in range(cfg["byz_seeds"])]
    byz_worlds = {}
    for frac in cfg["byz_fracs"]:
        k = int(round(frac * E))
        if k == 0:
            byz_worlds[frac] = World(topology=ring, comms_per_grad=rate)
        else:
            picks = np.linspace(0, E, k, endpoint=False).astype(int)
            adversary = ByzantineEdges(
                tuple(ring.edges[i] for i in picks), cfg["byz_mode"],
                scale=cfg["byz_scale"], prob=cfg["byz_prob"])
            byz_worlds[frac] = World(topology=ring, comms_per_grad=rate,
                                     channel=ChannelModel(
                                         adversary=adversary))
    grid = [(w, a, s) for w in byz_worlds.values()
            for a in (False, True) for s in byz_seeds]

    def rows_for(cons, frac_i, accel):
        off = frac_i * 2 * len(byz_seeds) + (len(byz_seeds) if accel else 0)
        return cons[off:off + len(byz_seeds)]

    both = grid + grid
    clips = [None] * len(grid) + [cfg["robust_clip"]] * len(grid)
    cons_both, us_byz = run_family(both, clips=clips)
    entries = {}
    for robust in (False, True):
        cons = cons_both[len(grid):] if robust else cons_both[:len(grid)]
        for i, frac in enumerate(cfg["byz_fracs"]):
            entries[(frac, robust)] = curve_entry(
                byz_worlds[frac], robust, rows_for(cons, i, False),
                rows_for(cons, i, True), byz_seeds)
    for frac in cfg["byz_fracs"]:
        k = int(round(frac * E))
        tag = f"f{frac:g}"
        nonrobust = entries[(frac, False)]
        robust = entries[(frac, True)]
        report["byzantine"][tag] = {"edge_fraction": k / E,
                                    "num_byzantine_edges": k,
                                    "nonrobust": nonrobust,
                                    "robust": robust}
        gains = (nonrobust["acid_gain"], robust["acid_gain"])
        rows.append(
            f"channel_byz_{tag}_n{n},0.0,"
            f"gain_nonrobust={gains[0]};gain_robust={gains[1]};"
            f"diverged={nonrobust['diverged']}")
    rows.append(f"channel_byz_dispatch,{us_byz:.0f},"
                f"worlds={len(both)};dispatches=1;"
                f"seeds={len(byz_seeds)}")

    clean_gain = report["byzantine"]["f0"]["nonrobust"]["acid_gain"]
    summary = {"clean_gain": clean_gain}
    for frac in cfg["byz_fracs"]:
        if frac == 0.0:
            continue
        cell = report["byzantine"][f"f{frac:g}"]
        rg = cell["robust"]["acid_gain"]
        summary[f"gain_retention_at_{frac:g}"] = (
            None if rg is None or not clean_gain
            else rg / clean_gain)
        summary[f"nonrobust_diverged_at_{frac:g}"] = \
            cell["nonrobust"]["diverged"]
    report["summary"] = summary
    report["executables"] = [_exec_cost(tag, fn, *fargs)
                             for tag, (fn, fargs) in cost_fns.items()]
    _dump_json(__file__, "BENCH_channel.json", report)
    nonzero = [f for f in cfg["byz_fracs"] if f > 0]
    headline = min(nonzero, key=lambda f: abs(f - 0.1)) if nonzero else None
    retention = summary.get(f"gain_retention_at_{headline:g}") \
        if headline is not None else None
    rows.append(f"channel_summary,0.0,clean_gain={fmt(clean_gain)};"
                f"retention_at_{headline:g}="
                f"{retention if retention is None else round(retention, 3)}")
    return rows


_SWEEP_BENCH = {
    "n": 32, "d": 32, "rounds": 150, "comms_per_grad": 1.0,
    "gamma": 0.05, "noise": 0.05,
    # B = 16 grid: the two channel axes of BENCH_channel.json crossed
    "horizons": [0, 2, 4, 8], "stale_prob": 1.0,
    "byz_fracs": [0.0, 0.05, 0.1, 0.2],
    "byz_mode": "scale", "byz_scale": 1e3, "byz_prob": 0.5,
    "robust_clip": 5.0, "robust_rule": "trim",
}


def bench_batched_sweep(seed: int = 0) -> list[str]:
    """Batched-vs-serial replay of one sweep family — the perf artifact of
    the many-worlds subsystem (DESIGN.md §11).  Emits BENCH_sweep.json.

    The family is the channel grid: ``horizons`` x ``byz_fracs`` ring
    worlds (staleness crossed with Byzantine fraction, B = 16 at full
    size) under the robust accelerated replay (robust keeps every curve
    finite, so timings measure arithmetic, not NaN propagation).  Serial
    replays the B points one ``run_schedule`` at a time — every distinct
    stream shape AND every distinct ring horizon (a static arg of the
    channel scan) pays its own jit trace; batched replays them as ONE
    ``run_worlds`` scan at the shared ring depth H = max horizon.  Both
    are reported cold (first call, compiles included — the number a sweep
    actually costs) and warm (steady state), with jit trace counts from
    the cache deltas: the batched family compiles EXACTLY ONCE per family
    shape.
    """
    from repro.core import (ByzantineEdges, ChannelModel, DelayProcess,
                            Simulator, World, build_graph,
                            params_from_graph)

    cfg = _SWEEP_BENCH
    n, d, rounds = cfg["n"], cfg["d"], cfg["rounds"]
    b = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    grad_fn = _quad_grad_fn(b, noise=cfg["noise"])
    ring = build_graph("ring", n)
    p = params_from_graph(ring, accelerated=True)
    E = ring.num_edges

    worlds = []
    for h in cfg["horizons"]:
        delay = None if h == 0 else DelayProcess(horizon=int(h),
                                                 prob=cfg["stale_prob"])
        for frac in cfg["byz_fracs"]:
            k = int(round(frac * E))
            adversary = None
            if k:
                picks = np.linspace(0, E, k, endpoint=False).astype(int)
                adversary = ByzantineEdges(
                    tuple(ring.edges[i] for i in picks), cfg["byz_mode"],
                    scale=cfg["byz_scale"], prob=cfg["byz_prob"])
            channel = None if delay is None and adversary is None \
                else ChannelModel(delay=delay, adversary=adversary)
            worlds.append(World(topology=ring,
                                comms_per_grad=cfg["comms_per_grad"],
                                channel=channel))
    # every grid point replays under its own rng stream — the multi-seed
    # variance-band regime the batcher exists for (and what keeps the
    # serial arm honest: stream shapes are ragged across points, so serial
    # pays a jit trace per distinct (shape, horizon), not one total)
    point_seeds = [seed + i for i in range(len(worlds))]
    scheds = [w.compile(rounds, seed=s)
              for w, s in zip(worlds, point_seeds)]
    B = len(scheds)

    sim = Simulator(grad_fn, p, gamma=cfg["gamma"],
                    robust_clip=cfg["robust_clip"],
                    robust_rule=cfg["robust_rule"])
    states = [sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
              for _ in scheds]

    # serial: one replay per point (the pre-batching bench structure);
    # trace count = distinct compiled shapes across the grid
    serial_traces = Simulator._run_channel_jit._cache_size()

    def serial():
        out = None
        for st, sch in zip(states, scheds):
            _, tr = sim.run_schedule(st, sch)
            out = tr
        jax.block_until_ready(out)

    t0 = time.perf_counter()
    serial()
    serial_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    serial()
    serial_warm = time.perf_counter() - t0
    serial_traces = Simulator._run_channel_jit._cache_size() - serial_traces

    # batched: the whole grid in one scan
    batched_traces = Simulator._run_worlds_channel_jit._cache_size()

    def batched():
        _, tr = sim.run_worlds(states, scheds)
        jax.block_until_ready(tr)

    t0 = time.perf_counter()
    batched()
    batched_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched()
    batched_warm = time.perf_counter() - t0
    batched_traces = (Simulator._run_worlds_channel_jit._cache_size()
                      - batched_traces)

    cost_fn, cost_args = sim.worlds_executable(states, scheds)
    report = {
        "config": dict(cfg), "seed": seed,
        "family": "channel_grid_horizons_x_byz_fracs",
        "sweep": {"worlds": [w.to_dict() for w in worlds],
                  "point_seeds": point_seeds},
        "num_worlds": B,
        "serial": {
            "wall_s_cold": round(serial_cold, 4),
            "wall_s_warm": round(serial_warm, 4),
            "jit_traces": serial_traces,
        },
        "batched": {
            "wall_s_cold": round(batched_cold, 4),
            "wall_s_warm": round(batched_warm, 4),
            "jit_traces": batched_traces,
        },
        "speedup_cold": round(serial_cold / batched_cold, 3),
        "speedup_warm": round(serial_warm / batched_warm, 3),
        "executables": [_exec_cost("sweep_batched_replay",
                                   cost_fn, *cost_args)],
    }
    _dump_json(__file__, "BENCH_sweep.json", report)
    return [
        f"sweep_serial_B{B},{serial_warm * 1e6:.0f},"
        f"cold_us={serial_cold * 1e6:.0f};traces={serial_traces}",
        f"sweep_batched_B{B},{batched_warm * 1e6:.0f},"
        f"cold_us={batched_cold * 1e6:.0f};traces={batched_traces}",
        f"sweep_speedup,0.0,cold={report['speedup_cold']:.2f}x;"
        f"warm={report['speedup_warm']:.2f}x",
    ]


_DEF_BENCH = {
    "n": 32, "d": 32, "rounds": 150, "comms_per_grad": 1.0,
    "gamma": 0.05, "noise": 0.05, "target": 0.3,
    "byz_frac": 0.1,                  # fraction of ring edges compromised
    # the two adversaries the control loop must separate: garbage
    # injection (norm 1e3 — static trim catches it) and sign flips at
    # honest scale (norm ~2||x|| < static tau — only adaptive tau does)
    "attacks": {
        "scale": {"mode": "scale", "scale": 1e3, "prob": 0.5},
        "sign_flip": {"mode": "sign_flip", "scale": 1.0, "prob": 1.0},
    },
    "robust_clip": 5.0, "robust_rule": "trim",
    "seeds": 3,
    # comm-controller demo: a lossy world thinned by the degradation-
    # aware scheduler (host-side — separate from the in-scan grid)
    "comm": {"horizon": 4, "stale_prob": 1.0,
             "lo": 0.5, "hi": 1.0, "degrade": 0.5},
}


def bench_defense(seed: int = 0) -> list[str]:
    """Self-healing gossip artifact (DESIGN.md §12): the static-trim vs
    adaptive-defense grid under Byzantine attacks, and the degradation-
    aware comm controller on a lossy ring.  Emits BENCH_defense.json.

    The headline grid is (clean + {scale, sign_flip} x {none, static,
    adaptive}) x {baseline, accelerated} x seeds — every arm a declared
    ``World`` (defense included), replayed as ONE ``run_worlds`` batch:
    one device dispatch, and the row asserts exactly one fresh jit trace
    (the per-world defense knobs are (B,) data, DESIGN.md §12).

    The story the summary tells: static trim already retains the clean
    accelerated gain under garbage injection (norms 1e3 >> tau), but a
    sign-flip adversary at honest scale (||corrupted|| ~ 2||x|| < tau)
    passes the static threshold BITWISE — ``static`` equals ``none`` on
    that family — while the adaptive quantile-tracking tau learns the
    honest-norm floor and rejects it.  Acceptance bars: adaptive
    retention >= 0.95 of the clean accelerated gain at 10% Byzantine
    edges on BOTH attacks, adaptive sign-flip tail < 3x clean while the
    static tail is > 10x clean (unbounded drift).

    The comm-control section replays the same lossy world with and
    without the controller and reports the kept-event fraction and the
    consensus cost of communicating less.
    """
    from repro.core import (AdaptiveDefense, ByzantineEdges, ChannelModel,
                            DelayProcess, Simulator, Telemetry, World,
                            build_graph, params_from_graph, trace_summary)

    cfg = _DEF_BENCH
    n, d, rounds = cfg["n"], cfg["d"], cfg["rounds"]
    # shared target: every worker pulls toward the same point, so the
    # equilibrium consensus floor is the noise floor and a sign-flipped
    # delta has norm ~2||x|| — comfortably under the static tau
    b = jnp.broadcast_to(cfg["target"] * jnp.ones(d), (n, d))
    grad_fn = _quad_grad_fn(b, noise=cfg["noise"])
    ring = build_graph("ring", n)
    p_acid = params_from_graph(ring, accelerated=True)
    p_base = params_from_graph(ring, accelerated=False)
    compiled = _schedule_compiler(rounds)
    sim = Simulator(grad_fn, p_acid, gamma=cfg["gamma"],
                    robust_rule=cfg["robust_rule"])
    state = sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))
    seeds = [seed + i for i in range(cfg["seeds"])]

    E = ring.num_edges
    k = max(1, int(round(cfg["byz_frac"] * E)))
    picks = np.linspace(0, E, k, endpoint=False).astype(int)
    edges = tuple(ring.edges[i] for i in picks)
    channels = {
        name: ChannelModel(adversary=ByzantineEdges(
            edges, a["mode"], scale=a["scale"], prob=a["prob"]))
        for name, a in cfg["attacks"].items()}

    # arm = (tag, channel, robust_clip, defense); clean anchor first
    tau = cfg["robust_clip"]
    arms = [("clean", None, None, None)]
    for name, ch in channels.items():
        arms += [(f"{name}/none", ch, None, None),
                 (f"{name}/static", ch, tau, None),
                 (f"{name}/adaptive", ch, tau, AdaptiveDefense())]

    worlds, scheds, states, plist, clips, defs = [], [], [], [], [], []
    for tag, ch, clip, dfn in arms:
        for accel in (False, True):
            for s in seeds:
                w = World(topology=ring, comms_per_grad=cfg["comms_per_grad"],
                          channel=ch, defense=dfn)
                worlds.append(w)
                scheds.append(compiled(w, s))
                states.append(state)
                plist.append(p_acid if accel else p_base)
                clips.append(clip)
                defs.append(dfn)

    # flight recorder: the compiled per-round telemetry columns ride the
    # SAME batched scan (one trace, one dispatch — asserted below)
    tel = Telemetry()
    before = Simulator._run_worlds_defense_jit._cache_size()
    t_span = _TRACER.now_us() if _TRACER is not None else 0.0
    t0 = time.perf_counter()
    _, trace = sim.run_worlds(states, scheds, params=plist,
                              robust_clips=clips, defenses=defs,
                              telemetry=tel)
    jax.block_until_ready(trace)
    us_grid = (time.perf_counter() - t0) * 1e6
    traces = Simulator._run_worlds_defense_jit._cache_size() - before
    if _TRACER is not None:
        _TRACER.complete("dispatch.defense_grid", t_span, us_grid,
                         lane="dispatch",
                         args={"worlds": len(worlds),
                               "jit_traces": int(traces)})
    cons = np.asarray(trace.consensus, np.float64)
    rejn = np.asarray(trace.defense.rejections, np.float64)
    quarn = np.asarray(trace.defense.quarantined, np.float64)

    def nantail(curve):
        t = curve[-30:]
        return float(np.nanmean(t)) if np.isfinite(t).any() else float("nan")

    def band(curves):
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            return np.nanmean(curves, axis=0), np.nanstd(curves, axis=0)

    S = len(seeds)
    entries, i = {}, 0
    for tag, ch, clip, dfn in arms:
        rows_b = slice(i, i + S)
        rows_a = slice(i + S, i + 2 * S)
        i += 2 * S
        base, base_std = band(cons[rows_b])
        acid, acid_std = band(cons[rows_a])
        tail_b, tail_a = nantail(base), nantail(acid)
        gain = tail_b / max(tail_a, 1e-12) if np.isfinite(tail_b) \
            and np.isfinite(tail_a) else float("nan")
        entry = {
            "world": worlds[rows_a.start].to_dict(),
            "robust_clip": clip,
            "seeds": seeds,
            "consensus_baseline": [_finite_or_none(v) for v in base],
            "consensus_acid": [_finite_or_none(v) for v in acid],
            "consensus_baseline_std": [_finite_or_none(v)
                                       for v in base_std],
            "consensus_acid_std": [_finite_or_none(v) for v in acid_std],
            "tail_consensus_baseline": _finite_or_none(tail_b),
            "tail_consensus_acid": _finite_or_none(tail_a),
            "acid_gain": _finite_or_none(gain),
            "diverged": not np.isfinite(cons[rows_b.start:i]).all(),
            "rejections_per_round": float(np.mean(rejn[rows_b.start:i])),
            "quarantined_per_round": float(np.mean(quarn[rows_b.start:i])),
        }
        entries[tag] = _downsample_entry(
            entry, ("consensus_baseline", "consensus_acid",
                    "consensus_baseline_std", "consensus_acid_std"))

    clean = entries["clean"]
    clean_gain = clean["acid_gain"]
    clean_tail = clean["tail_consensus_acid"]
    summary = {"clean_gain": clean_gain,
               "byz_edge_fraction": k / E,
               "num_byzantine_edges": k,
               "grid_worlds": len(worlds),
               "grid_traces": int(traces)}
    for name in cfg["attacks"]:
        for arm in ("none", "static", "adaptive"):
            e = entries[f"{name}/{arm}"]
            g = e["acid_gain"]
            summary[f"{name}_retention_{arm}"] = (
                None if g is None or not clean_gain else g / clean_gain)
            t = e["tail_consensus_acid"]
            summary[f"{name}_tail_vs_clean_{arm}"] = (
                None if t is None or not clean_tail else t / clean_tail)
    adaptive_ok = all(
        (summary[f"{name}_retention_adaptive"] or 0.0) >= 0.95
        for name in cfg["attacks"])
    summary["adaptive_retention_ok"] = adaptive_ok
    summary["signflip_adaptive_contained"] = \
        (summary["sign_flip_tail_vs_clean_adaptive"] or np.inf) < 3.0
    summary["signflip_static_fails"] = \
        (summary["sign_flip_tail_vs_clean_static"] or np.inf) > 10.0

    rows = [f"defense_grid_dispatch,{us_grid:.0f},"
            f"worlds={len(worlds)};dispatches=1;traces={traces};"
            f"seeds={S}"]
    for tag, e in entries.items():
        label = tag.replace("/", "_")
        g = e["acid_gain"]
        rows.append(
            f"defense_{label}_n{n},0.0,"
            f"gain={'None' if g is None else f'{g:.3f}'};"
            f"rej_per_round={e['rejections_per_round']:.2f};"
            f"quar_per_round={e['quarantined_per_round']:.2f};"
            f"diverged={e['diverged']}")

    # ------------------------------------------- comm controller demo
    cc = cfg["comm"]
    lossy = ChannelModel(delay=DelayProcess(horizon=cc["horizon"],
                                            prob=cc["stale_prob"]))
    ctrl = AdaptiveDefense(adaptive_tau=False, trust=False,
                           comm_lo=cc["lo"], comm_hi=cc["hi"],
                           comm_degrade=cc["degrade"])
    w_full = World(topology=ring, comms_per_grad=cfg["comms_per_grad"],
                   channel=lossy)
    w_ctrl = dataclasses.replace(w_full, defense=ctrl)
    s_full = compiled(w_full, seed)
    s_ctrl = compiled(w_ctrl, seed)
    kept = (int(np.sum(np.asarray(s_ctrl.event_mask)))
            / max(int(np.sum(np.asarray(s_full.event_mask))), 1))
    t0 = time.perf_counter()
    _, tr_cc = sim.run_worlds([state, state], [s_full, s_ctrl],
                              params=[p_acid, p_acid])
    jax.block_until_ready(tr_cc)
    us_cc = (time.perf_counter() - t0) * 1e6
    cc_cons = np.asarray(tr_cc.consensus, np.float64)
    tail_full, tail_ctrl = nantail(cc_cons[0]), nantail(cc_cons[1])
    report_cc = {
        "world_full": w_full.to_dict(), "world_controlled": w_ctrl.to_dict(),
        "kept_event_fraction": kept,
        "tail_consensus_full": _finite_or_none(tail_full),
        "tail_consensus_controlled": _finite_or_none(tail_ctrl),
        "consensus_cost_ratio": _finite_or_none(
            tail_ctrl / max(tail_full, 1e-12)),
    }
    rows.append(f"defense_comm_control,{us_cc:.0f},"
                f"kept_fraction={kept:.3f};"
                f"cost_ratio={report_cc['consensus_cost_ratio']:.3f}")

    tel_digest = trace_summary(trace.telemetry)
    rows.append(
        f"defense_telemetry,0.0,"
        f"applied={tel_digest['applied_total']:.0f};"
        f"rejected={tel_digest['rejected_total']:.0f};"
        f"dropped={tel_digest['dropped_total']:.0f};"
        f"bytes={tel_digest['bytes_moved_total']:.3e}")
    cost_fn, cost_args = sim.worlds_executable(
        states, scheds, params=plist, robust_clips=clips, defenses=defs,
        telemetry=tel)
    report = {"config": _sanitize_json(dict(cfg)), "seed": seed,
              "arms": entries, "comm_control": report_cc,
              "summary": summary,
              "telemetry": {"spec": tel.to_dict(),
                            "summary": tel_digest},
              "executables": [_exec_cost("defense_grid_replay",
                                         cost_fn, *cost_args)]}
    _dump_json(__file__, "BENCH_defense.json", report)
    fmt = lambda v: "None" if v is None else f"{v:.3f}"  # noqa: E731
    rows.append(
        f"defense_summary,0.0,clean_gain={fmt(clean_gain)};"
        f"scale_retention_adaptive={fmt(summary['scale_retention_adaptive'])};"
        f"signflip_retention_adaptive="
        f"{fmt(summary['sign_flip_retention_adaptive'])};"
        f"signflip_static_tail_x="
        f"{fmt(summary['sign_flip_tail_vs_clean_static'])};"
        f"adaptive_ok={adaptive_ok}")
    return rows


def bench_roofline_summary(seed: int = 0) -> list[str]:
    """Roofline terms from the dry-run artifacts (if present)."""
    import json
    import os
    rows = []
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_single.json")
    if not os.path.exists(path):
        return ["roofline_summary,0,missing_dryrun_json"]
    data = json.load(open(path))
    for r in data:
        if not r.get("ok"):
            continue
        rows.append(
            f"roofline_{r['arch']}_{r['shape']},0.0,"
            f"bottleneck={r['bottleneck']}"
            f";compute_s={r['compute_s']:.3e}"
            f";memory_s={r['memory_s']:.3e}"
            f";collective_s={r['collective_s']:.3e}")
    return rows


_TRAIN_BENCH = {
    "n": 64, "seeds": 3, "gamma": 0.05,
    "topologies": ["ring", "hypercube"],
    # DADAO decoupled clocks: gradients thinned to 3/4 rate, gossip at 2x
    "dadao_grad_rate": 0.75, "dadao_gossip_rate": 2.0,
    "tail_frac": 0.25,                  # tail window = last quarter rounds
    # workers start from a NOISY BROADCAST of one shared init (no initial
    # all-reduce): per-parameter N(0, init_sigma^2) on top of params0.
    # The consensus axis then exercises the accelerated TRANSIENT Prop 3.6
    # actually bounds.  From an exact-consensus start with iid worker data
    # the tail sits at the gradient-noise equilibrium, where acceleration
    # is neutral — momentum amplifies injected noise by the same factor it
    # speeds contraction (measured while calibrating: ring-16 gain 1.03
    # +- 0.03 from a consensus start vs ~3 from a spread start; the PR 5
    # topology bench sees gain 3.3 from a consensus start only because its
    # quad workers have HETEROGENEOUS optima — persistent drift, not
    # noise).
    "init_sigma": 0.05,
    "families": {
        "resnet8_cifar": {"rounds": 16, "batch_size": 1},
        "nano_lm_bench": {"rounds": 150, "batch_size": 2, "seq_len": 32},
    },
}


def _train_family_setups():
    """(name, grad_fn, params0) per model family of the train bench —
    lazy imports so the other benches don't pay for model code."""
    from repro.configs.nano_lm import train_bench
    from repro.data import LMTaskStream, SyntheticCIFAR
    from repro.models import Model
    from repro.models.resnet import init_resnet, resnet8_cifar, resnet_loss

    fams = {}
    if "resnet8_cifar" in _TRAIN_BENCH["families"]:
        rcfg = resnet8_cifar()
        rconf = _TRAIN_BENCH["families"]["resnet8_cifar"]
        rstream = SyntheticCIFAR(batch_size=rconf["batch_size"], noise=0.5)

        def resnet_grad(params, key, wid):
            batch = rstream.sample(jax.random.fold_in(key, wid))

            def loss_fn(p):
                loss, _ = resnet_loss(p, rcfg, batch)
                return loss

            return jax.value_and_grad(loss_fn)(params)

        fams["resnet8_cifar"] = (resnet_grad,
                                 init_resnet(jax.random.PRNGKey(0), rcfg))
    if "nano_lm_bench" in _TRAIN_BENCH["families"]:
        lcfg = train_bench()
        model = Model(lcfg)
        lconf = _TRAIN_BENCH["families"]["nano_lm_bench"]
        lstream = LMTaskStream(vocab_size=lcfg.vocab_size,
                               seq_len=lconf["seq_len"],
                               batch_size=lconf["batch_size"],
                               concentration=0.15)

        def lm_grad(params, key, wid):
            batch = lstream.sample(jax.random.fold_in(key, wid))

            def loss_fn(p):
                loss, _ = model.loss(p, batch)
                return loss

            return jax.value_and_grad(loss_fn)(params)

        fams["nano_lm_bench"] = (lm_grad, model.init(jax.random.PRNGKey(0)))
    return fams


def bench_train(seed: int = 0) -> list[str]:
    """The paper's actual claim, end-to-end (Tab 4/5 regime): REAL models
    (ResNet-8/CIFAR-like and the nano-lm transformer) trained by the
    asynchronous algorithm zoo on n=64 ring and hypercube worlds —
    {a2cid2, adpsgd, dadao} x {base, accelerated} x seeds — emitting
    BENCH_train.json with consensus + loss curves, mean +- std bands, and
    the ring-gain trend the CI gate reads.

    The zoo is per-world DATA (DESIGN.md §13): each arm is a declarative
    ``World(algorithm=...)`` and the entire family grid replays as ONE
    batched ``run_worlds`` dispatch — dynamics columns (eta, alpha_t, chi)
    ride the (B,) parameter arrays, DADAO's decoupled clocks ride the
    schedule masks/intensities.  The artifact asserts the dispatch count
    (one per model family) and the jit-trace delta.

    Coupled-clock arms (a2cid2/adpsgd x base/accel) share one compiled
    schedule per (topology, seed); the dadao arms share the decoupled one.
    a2cid2-base and adpsgd-base carry identical dynamics by construction
    (Prop 3.6 eta=0 == AD-PSGD) — both are emitted; their bitwise equality
    is pinned in tests/test_algorithms.py, and here they must agree to the
    float tolerance of a shared batched scan.

    Workers start from a noisy broadcast of one shared init (no initial
    all-reduce; ``init_sigma`` in the config comment explains why the
    consensus gain is measured on this transient, not on the iid-noise
    equilibrium), so the ring-gain trend tracks the accelerated decay of
    Prop 3.6 and the loss curves still show real training progress.
    """
    from repro.core import Algorithm, Simulator, World, build_graph

    n = _TRAIN_BENCH["n"]
    gamma = _TRAIN_BENCH["gamma"]
    seeds = [seed + i for i in range(_TRAIN_BENCH["seeds"])]
    arms = [
        ("a2cid2_base", Algorithm("a2cid2", accelerated=False)),
        ("a2cid2_accel", Algorithm("a2cid2", accelerated=True)),
        ("adpsgd_base", Algorithm("adpsgd", accelerated=False)),
        ("adpsgd_accel", Algorithm("adpsgd", accelerated=True)),
        ("dadao_base", Algorithm(
            "dadao", accelerated=False,
            grad_rate=_TRAIN_BENCH["dadao_grad_rate"],
            gossip_rate=_TRAIN_BENCH["dadao_gossip_rate"])),
        ("dadao_accel", Algorithm(
            "dadao", accelerated=True,
            grad_rate=_TRAIN_BENCH["dadao_grad_rate"],
            gossip_rate=_TRAIN_BENCH["dadao_gossip_rate"])),
    ]
    graphs = {t: build_graph(t, n) for t in _TRAIN_BENCH["topologies"]}

    rows = []
    report = {"config": dict(_TRAIN_BENCH), "seed": seed,
              "arms": [name for name, _ in arms],
              "dispatches": 0, "families": {}}
    dispatches = 0

    for fam, (grad_fn, params0) in _train_family_setups().items():
        rounds = _TRAIN_BENCH["families"][fam]["rounds"]
        tail = max(2, int(rounds * _TRAIN_BENCH["tail_frac"]))
        num_params = int(sum(p.size for p in jax.tree.leaves(params0)))

        # -------- declare the grid: every (topology, arm, seed) point is a
        # World; schedules compile once per (topology, clock-group, seed)
        # because base/accel and a2cid2/adpsgd share the coupled clock
        points, worlds, scheds, states = [], [], [], []
        sim = Simulator(grad_fn, None, gamma=gamma)
        arm_worlds = {
            (t, name): World(topology=g, algorithm=algo)
            for t, g in graphs.items() for name, algo in arms}
        for t, g in graphs.items():
            for s in seeds:
                sched_coupled = arm_worlds[(t, "a2cid2_accel")].compile(
                    rounds, seed=s)
                sched_dadao = arm_worlds[(t, "dadao_accel")].compile(
                    rounds, seed=s)
                # noisy broadcast (see _TRAIN_BENCH["init_sigma"]): every
                # arm of a seed starts from the SAME spread state
                st = sim.init(params0, n, jax.random.PRNGKey(1000 + s))
                sigma = _TRAIN_BENCH["init_sigma"]
                leaves, treedef = jax.tree_util.tree_flatten(st.x)
                keys = jax.random.split(jax.random.PRNGKey(3000 + s),
                                        len(leaves))
                spread = jax.tree_util.tree_unflatten(treedef, [
                    l + sigma * jax.random.normal(k, l.shape, l.dtype)
                    for l, k in zip(leaves, keys)])
                st = st._replace(x=spread, x_tilde=spread)
                for name, algo in arms:
                    w = arm_worlds[(t, name)]
                    points.append((t, name, s))
                    worlds.append(w)
                    scheds.append(sched_dadao if algo.kind == "dadao"
                                  else sched_coupled)
                    states.append(st)
        sim = dataclasses.replace(sim, params=worlds[0].algorithm_params())

        # -------- ONE batched dispatch for the whole family grid.  The
        # trace delta counts BOTH run_worlds caches: the engine path falls
        # back to the per-event reference when FlatLayout rejects the
        # model's pytree, and that fallback must still be one dispatch.
        before = (Simulator._run_worlds_jit._cache_size()
                  + Simulator._run_worlds_reference_jit._cache_size())
        # single timed call (cold, compile-inclusive): real-model grids are
        # minutes-per-dispatch on CPU, so the warm re-run the other benches
        # afford would double the bench for one redundant number
        t0 = time.perf_counter()
        trace = sim.run_worlds(states, scheds, worlds=worlds)[1]
        jax.block_until_ready(trace.consensus)
        cold_us = (time.perf_counter() - t0) * 1e6
        traces = (Simulator._run_worlds_jit._cache_size()
                  + Simulator._run_worlds_reference_jit._cache_size()
                  - before)
        dispatches += 1
        cons = np.asarray(trace.consensus, np.float64)   # (B, rounds)
        loss = np.asarray(trace.loss, np.float64)

        fam_entry = {"params": num_params, "rounds": rounds,
                     "batched_replay": {"num_worlds": len(points),
                                        "cold_us": round(cold_us, 1),
                                        "jit_traces": traces},
                     "topologies": {}}

        def rows_for(t, name):
            idx = [i for i, (pt, pn, _) in enumerate(points)
                   if pt == t and pn == name]
            return cons[idx], loss[idx]           # (seeds, rounds)

        for t, g in graphs.items():
            topo_entry = {"chi1": g.chi1(), "chi2": g.chi2(), "arms": {}}
            for name, _ in arms:
                c, l = rows_for(t, name)
                entry = {
                    "world": arm_worlds[(t, name)].to_dict(),
                    "seeds": seeds,
                    "consensus_mean": c.mean(axis=0).tolist(),
                    "consensus_std": c.std(axis=0).tolist(),
                    "loss_mean": l.mean(axis=0).tolist(),
                    "loss_std": l.std(axis=0).tolist(),
                    "tail_consensus": float(c.mean(axis=0)[-tail:].mean()),
                    "tail_loss": float(l.mean(axis=0)[-tail:].mean()),
                }
                topo_entry["arms"][name] = _downsample_entry(
                    entry, ("consensus_mean", "consensus_std",
                            "loss_mean", "loss_std"))
            # ring-gain trend: accelerated A2CiD2 vs the async baseline,
            # per seed, so the band is a real noise floor
            c_bas, _ = rows_for(t, "adpsgd_base")
            c_acc, _ = rows_for(t, "a2cid2_accel")
            per_seed = (c_bas[:, -tail:].mean(axis=1)
                        / np.maximum(c_acc[:, -tail:].mean(axis=1), 1e-30))
            gain_mean = float(per_seed.mean())
            gain_std = float(per_seed.std())
            topo_entry["gain"] = {
                "per_seed": per_seed.tolist(),
                "mean": gain_mean, "std": gain_std,
                "predicted_sqrt_chi_ratio":
                    float(np.sqrt(g.chi1() / g.chi2())),
                "exceeds_baseline_by_band": bool(
                    gain_mean - gain_std > 1.0),
            }
            fam_entry["topologies"][t] = topo_entry
            rows.append(
                f"train_{fam}_{t},0.0,"
                f"gain={gain_mean:.3f}+-{gain_std:.3f};"
                f"tail_loss="
                f"{topo_entry['arms']['a2cid2_accel']['tail_loss']:.4f}")

        # cost row: analytic, not HLO — AOT-lowering a real-model grid a
        # second time would double a minutes-long compile for one number.
        # 6ND train FLOPs over the grid, parameter-row read+write traffic
        # per round, gossip bytes from the compiled schedules' event count
        from repro.analysis.roofline import (HBM_BW, ICI_BW,
                                             PEAK_FLOPS_BF16)
        from repro.analysis import model_flops
        conf = _TRAIN_BENCH["families"][fam]
        tokens = (rounds * n * conf.get("batch_size", 1)
                  * conf.get("seq_len", 1))
        grid_flops = (model_flops(num_params, 0, tokens, "train")
                      * len(points))
        total_events = sum(int(np.asarray(s.event_mask).sum())
                           for s in scheds)
        coll_bytes = 2.0 * total_events * num_params * 4
        write_bytes = float(len(points)) * rounds * n * num_params * 4 * 2
        terms = {"compute": grid_flops / PEAK_FLOPS_BF16,
                 "memory": write_bytes / HBM_BW,
                 "collective": coll_bytes / ICI_BW}
        fam_entry["executables"] = [{
            "executable": f"train_{fam}_grid", "method": "analytic",
            "flops": grid_flops, "write_bytes": write_bytes,
            "collective_bytes": coll_bytes,
            "compute_s": terms["compute"], "memory_s": terms["memory"],
            "collective_s": terms["collective"],
            "bottleneck": max(terms, key=terms.get)}]

        report["families"][fam] = fam_entry
        rows.append(f"train_{fam}_dispatch,{cold_us:.0f},"
                    f"worlds={len(points)};traces={traces};"
                    f"params={num_params}")

    # the batching contract the artifact asserts: one dispatch per family
    assert dispatches == len(report["families"]), \
        (dispatches, list(report["families"]))
    report["dispatches"] = dispatches
    _dump_json(__file__, "BENCH_train.json", report)
    return rows


# --------------------------------------------------------------------- serve
# Gossip-serving fleet (DESIGN.md §14): {no-gossip, base async, A²CiD²} x
# {clean ring, lossy ring, churn} fleets serving ONE shared request trace.

_SERVE_BENCH = {
    "replicas": 8, "rounds": 120, "max_batch": 4, "max_len": 24,
    "rate": 1.2, "prompt_len": (3, 6), "gen_len": (4, 10),
    "arrive_frac": 0.55,
    # drift/stall physics: every replica random-walks by drift_scale per
    # round (online fine-tuning stand-in); each gossip event costs its
    # replica stall_per_event decode-rounds of debt (communication steals
    # compute) — what makes the p95-retention gate a real claim
    "drift_scale": 0.02, "stall_per_event": 0.03,
    "delay_horizon": 2, "delay_prob": 0.3, "drop_prob": 0.1,
    "kill_round_frac": 0.33,   # churn scenario: one replica dies here
    "tail_frac": 0.25,
    "p95_retention_max": 1.15,
}


def bench_serve(seed: int = 0) -> list[str]:
    """The millions-of-users scenario: a continuous-batching inference
    fleet whose replicas never stop averaging.  Every fleet admits the
    IDENTICAL request trace (``ServeLoad``'s dedicated rng stream) and
    reports throughput, p50/p95/p99 latency, request loss, and consensus
    distance — the latency cost and consensus benefit of gossip, measured
    under one workload.

    Arms: {none (comms_per_grad=0), adpsgd, a2cid2} x {clean ring, lossy
    ring (stale reads + drops), churn (one replica killed mid-serve)}.
    CI gates (ci.yml): the A²CiD² clean-ring fleet holds p95 latency
    within ``p95_retention_max`` of the no-gossip fleet while its final
    consensus distance stays a small fraction of the no-gossip drift; the
    churn fleets complete EVERY request (re-admission, zero loss).
    """
    import jax
    import jax.numpy as jnp

    from repro.configs.nano_lm import train_bench
    from repro.core import (Algorithm, ChannelModel, DelayProcess,
                            PhaseSwitch, ServeLoad, World, ring_graph)
    from repro.core.flatbuf import FlatLayout
    from repro.launch.fleet import GossipFleet, make_fleet_step
    from repro.models import Model

    c = _SERVE_BENCH
    W, rounds = c["replicas"], c["rounds"]
    cfg = train_bench()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    load = ServeLoad(rate=c["rate"], prompt_len=tuple(c["prompt_len"]),
                     gen_len=tuple(c["gen_len"]),
                     arrive_frac=c["arrive_frac"])
    base = World(topology=ring_graph(W), serve=load)
    lossy = ChannelModel(delay=DelayProcess(horizon=c["delay_horizon"],
                                            prob=c["delay_prob"]),
                         drop_prob=c["drop_prob"])
    kill_round = max(1, int(c["kill_round_frac"] * rounds))
    kill_mask = tuple(i != W - 1 for i in range(W))
    algos = {
        "none": dict(algorithm=Algorithm("adpsgd"), comms_per_grad=0.0),
        "adpsgd": dict(algorithm=Algorithm("adpsgd")),
        "a2cid2": dict(algorithm=Algorithm("a2cid2")),
    }
    scenarios = {
        "clean": dict(),
        "lossy": dict(channel=lossy),
        "churn": dict(faults=(PhaseSwitch(kill_round, active=kill_mask),)),
    }

    # one decode executable for all 9 arms (they differ only in schedule
    # data), packed over the shared (W, D) layout
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (W,) + a.shape),
                           params)
    layout = FlatLayout.from_pytree(stacked, stacked=True)
    step_fn = jax.jit(make_fleet_step(model, layout))

    # roofline-annotated cost of the one decode executable all arms share
    bank0 = layout.pack(stacked)
    caches0 = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (W,) + a.shape),
        model.init_cache(c["max_batch"], c["max_len"]))
    executables = [_exec_cost(
        "fleet_decode_step", step_fn, bank0, caches0,
        jnp.zeros((W, c["max_batch"], 1), jnp.int32),
        jnp.zeros((W, c["max_batch"]), jnp.int32),
        jnp.zeros((W, c["max_batch"]), bool))]

    from repro.analysis import MetricsRegistry
    registry = MetricsRegistry()
    rows: list[str] = []
    fleets: dict = {}
    for aname, akw in algos.items():
        for sname, skw in scenarios.items():
            world = dataclasses.replace(base, **akw, **skw)
            fleet = GossipFleet(model, params, world,
                                max_batch=c["max_batch"],
                                max_len=c["max_len"], drift="perturb",
                                drift_scale=c["drift_scale"],
                                stall_per_event=c["stall_per_event"],
                                decode_step_fn=step_fn)
            if aname == "a2cid2" and sname == "clean":
                # cost the compiled gossip round once, on the arm whose
                # schedule actually communicates
                from functools import partial as _partial
                arrays, horizon = fleet.sim.channel_reference_arrays(
                    world.compile(rounds, seed))
                ring0 = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (horizon,) + a.shape),
                    fleet._bank0) if horizon else None
                executables.append(_exec_cost(
                    "fleet_gossip_round",
                    jax.jit(_partial(fleet.sim._round_channel, horizon)),
                    (fleet._bank0, jnp.array(fleet._bank0),
                     jnp.zeros((W,)), ring0, jax.random.PRNGKey(0)),
                    tuple(jnp.asarray(np.asarray(a)[0]) for a in arrays)))
            rep = fleet.run(rounds, seed=seed, tracer=_TRACER,
                            metrics=registry)
            summ = rep.summary()
            idxs = _curve_indices(len(rep.consensus))
            # gossip stops at rep.rounds: gates read the scheduled prefix
            # so the constant drain tail can't dilute tail statistics
            prefix = rep.consensus[:rep.rounds]
            pidx = _curve_indices(len(prefix))
            fleets[f"{aname}/{sname}"] = {
                "world": world.to_dict(),
                **summ,
                "round_axis": [int(i) for i in idxs],
                "consensus": [float(rep.consensus[i]) for i in idxs],
                "consensus_scheduled": [float(prefix[i]) for i in pidx],
                "consensus_final_scheduled":
                    float(prefix[-1]) if prefix.size else 0.0,
            }
            rows.append(
                f"serve_{aname}_{sname},"
                f"{1e6 * rep.wall_seconds / max(rounds, 1):.0f},"
                f"p95={summ['latency_p95']:.1f};lost={summ['lost']};"
                f"ttft_p50={summ['ttft_p50']:.1f};"
                f"tok_per_round={summ['throughput_tokens_per_round']:.2f}")

    trace = load.sample_trace(rounds, seed)

    def tail_ratio(entry):
        # scheduled prefix only: the drain tail is constant by
        # construction (gossip stopped) and would flatten the statistic
        cur = np.asarray(entry["consensus_scheduled"])
        k = max(1, int(len(cur) * c["tail_frac"]))
        mid = np.mean(cur[len(cur) // 2: len(cur) // 2 + k])
        return float(np.mean(cur[-k:]) / max(mid, 1e-12))

    acid, nog = fleets["a2cid2/clean"], fleets["none/clean"]
    churn_arms = {k: v for k, v in fleets.items() if k.endswith("/churn")}
    gates = {
        "p95_retention": acid["latency_p95"] / max(nog["latency_p95"], 1e-9),
        "p95_retention_max": c["p95_retention_max"],
        "consensus_ratio_vs_nogossip":
            acid["consensus_final_scheduled"]
            / max(nog["consensus_final_scheduled"], 1e-12),
        "consensus_tail_over_mid": tail_ratio(acid),
        "churn_lost": {k: v["lost"] for k, v in churn_arms.items()},
        "churn_restarted": {k: v["restarted"]
                            for k, v in churn_arms.items()},
    }
    gates["p95_retention_ok"] = \
        gates["p95_retention"] <= c["p95_retention_max"]
    # bounded consensus: gossip holds the fleet at a small fraction of the
    # unmixed random-walk drift AND its own tail has stopped growing the
    # way the no-gossip walk does (linear => tail/mid ~ 2 at these sizes)
    gates["consensus_bounded_ok"] = (
        gates["consensus_ratio_vs_nogossip"] <= 0.25
        and gates["consensus_tail_over_mid"] <= 1.75)
    gates["churn_zero_loss_ok"] = all(
        v["lost"] == 0 for v in churn_arms.values())

    report = {
        "config": {k: list(v) if isinstance(v, tuple) else v
                   for k, v in c.items()},
        "model": {"config": cfg.name, "params": model.param_count(params),
                  "flat_dim": int(layout.d)},
        "trace": {"requests": trace.num_requests, "rounds": rounds,
                  "kill_round": kill_round},
        "fleets": fleets,
        "gates": gates,
        "executables": executables,
        "metrics": registry.snapshot(),
    }
    _dump_json(__file__, "BENCH_serve.json", report)
    rows.append(f"serve_gates,0,p95_retention="
                f"{gates['p95_retention']:.3f};zero_loss="
                f"{gates['churn_zero_loss_ok']}")
    return rows


# --------------------------------------------------------------------------
# Sharded giant-world replay: weak scaling over the worker mesh
# (DESIGN.md §16)
# --------------------------------------------------------------------------

_SCALE_BENCH = {
    # one giant fixed world split over ever-more shards: the curve is
    # events/s vs workers-per-shard (n / n_shards)
    "n": 4096, "d": 64, "rounds": 12,
    "shards": [1, 2, 4, 8],
    # staleness probe: replay the max-shard point again with the permute
    # ring's boundary reads floored at this lag
    "lag": 2,
    "repeats": 3,
}


def bench_scale(seed: int = 0) -> list[str]:
    """Sharded giant-world scaling artifact (DESIGN.md §16).

    Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
    CI forced-multi-device job does); shard counts above the live device
    count are skipped, so the family degrades to a single-shard row on a
    plain host.

    ONE giant world (n = 4096 workers full-size) is compiled once, then
    replayed with its worker axis split over 1, 2, 4, 8 shards — the
    curve is events/s vs workers-per-shard.  The timed region is the
    jitted sharded replay only: ``worlds_executable(..., mesh=...)``
    arguments are committed to the mesh with ``MeshReplay.place_args``
    first, so the clock never sees host prep or input resharding.
    Efficiency is t(1 shard) / t(ns shards).  On real accelerators the
    split divides the per-device work, so flat time (efficiency 1.0)
    is the FLOOR of the win; on a forced-host mesh every "device" shares
    the same cores, total work is constant, and the ideal is exactly
    flat — efficiency there isolates the cost the sharding machinery
    adds (the per-step boundary all_gather + SPMD partitioning), which
    is what the CI gate pins on the --small config.

    Each row also carries the wire split the flight recorder assigns the
    permute ring — cross-shard bytes = boundary rows x flat-row width vs
    intra-shard bytes (schedule-exact, DESIGN.md §15/§16) — and the
    compiled replay's HLO cost row (collective bytes = the ring's
    exchange traffic).  A final row replays the widest mesh with
    ``lag > 0`` to price bounded staleness against the lag-0 exchange.
    Emits BENCH_scale.json.
    """
    from repro.core import Simulator, Telemetry, World, params_from_graph, \
        ring_graph, trace_summary
    from repro.launch.mesh import make_replay_mesh
    from repro.launch.mesh_replay import MeshReplay, sharded_twin

    cfg = _SCALE_BENCH
    n, d, rounds = cfg["n"], cfg["d"], cfg["rounds"]
    avail = jax.local_device_count()
    shard_counts = [s for s in cfg["shards"] if s <= avail]
    skipped = [s for s in cfg["shards"] if s > avail]
    if skipped:
        print(f"# scale: {avail} local devices — skipping shard counts "
              f"{skipped} (force more with XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8)")

    g = ring_graph(n)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    sim = Simulator(_quad_grad_fn(b), params_from_graph(g, True),
                    gamma=0.05)
    sched = World(topology=g).compile(rounds, seed=seed)
    states = [sim.init(jnp.zeros(d), n, jax.random.PRNGKey(2))]
    tel = Telemetry(norm_moments=False, participation=False)

    def arm(ns, lag):
        """One scaling point of the SAME world: (row dict, fn, args)."""
        mr = MeshReplay(make_replay_mesh(ns), lag=lag)
        fn, args = sim.worlds_executable(states, [sched], telemetry=tel,
                                         mesh=mr)
        args = mr.place_args(args)
        stream_len = int(args[5][1].shape[0])
        _, trace = sim.run_worlds(states, [sched], telemetry=tel, mesh=mr)
        summary = trace_summary(trace.telemetry)
        row = {"n_shards": ns, "lag": lag, "n": n,
               "workers_per_shard": n // ns,
               "stream_len": stream_len, "rounds": rounds,
               "scheduled_total": summary["scheduled_total"],
               "cross_reads_total": summary.get("cross_reads_total", 0),
               "bytes_intra_total": summary.get("bytes_intra_total"),
               "bytes_cross_total": summary.get("bytes_cross_total"),
               "row_bytes": summary["row_bytes"]}
        return row, fn, args

    rows_out, report_rows, t1_warm = [], [], None
    flavor = sharded_twin("channel", donate=False)
    executables = []
    for ns in shard_counts:
        row, fn, args = arm(ns, 0)
        before = flavor._cache_size()
        cold, warm = _timeit(lambda: fn(*args), repeats=cfg["repeats"])
        row.update(us_cold=cold, us_warm=warm,
                   jit_traces=flavor._cache_size() - before,
                   events_per_s=row["stream_len"] / (warm * 1e-6),
                   reads_per_s=row["scheduled_total"] / (warm * 1e-6))
        if t1_warm is None:
            t1_warm = warm
        row["efficiency"] = t1_warm / warm
        executables.append(_exec_cost(f"scale_replay_ns{ns}", fn, *args))
        report_rows.append(row)
        rows_out.append(
            f"scale_ns{ns}_wps{row['workers_per_shard']},{warm:.0f},"
            f"events_per_s={row['events_per_s']:.0f};"
            f"eff={row['efficiency']:.2f};"
            f"cross_reads={row['cross_reads_total']}")

    lag_row = None
    if cfg["lag"] > 0 and shard_counts and shard_counts[-1] > 1:
        ns = shard_counts[-1]
        lag_row, fn, args = arm(ns, cfg["lag"])
        cold, warm = _timeit(lambda: fn(*args), repeats=cfg["repeats"])
        lag0 = report_rows[-1]
        lag_row.update(us_cold=cold, us_warm=warm,
                       events_per_s=lag_row["stream_len"] / (warm * 1e-6),
                       speedup_vs_lag0=lag0["us_warm"] / warm)
        executables.append(
            _exec_cost(f"scale_replay_ns{ns}_lag{cfg['lag']}", fn, *args))
        rows_out.append(
            f"scale_lag{cfg['lag']}_ns{ns},{warm:.0f},"
            f"vs_lag0={lag_row['speedup_vs_lag0']:.2f}x")

    eff_at_max = report_rows[-1]["efficiency"] if report_rows else None
    report = {
        "config": {k: list(v) if isinstance(v, list) else v
                   for k, v in cfg.items()},
        "seed": seed, "devices": avail,
        "shard_counts": shard_counts, "skipped_shard_counts": skipped,
        "rows": report_rows, "lag_probe": lag_row,
        "efficiency_at_max_shards": eff_at_max,
        "executables": executables,
    }
    _dump_json(__file__, "BENCH_scale.json", report)
    if eff_at_max is not None:
        rows_out.append(f"scale_efficiency,0,"
                        f"at_{shard_counts[-1]}_shards="
                        f"{eff_at_max:.2f}")
    return rows_out


BENCHES = {
    "table2": bench_table2_comm_rates,
    "table3": bench_table3_training_time,
    "table4": bench_table4_cifar_topologies,
    "table5": bench_table5_worker_scaling,
    "fig1": bench_fig1_virtual_doubling,
    "kernels": bench_kernels,
    "simulator": bench_simulator_throughput,
    "gossip": bench_gossip_engine,
    "topology": bench_topology_sweep,
    "channel": bench_channel_sweep,
    "defense": bench_defense,
    "sweep": bench_batched_sweep,
    "train": bench_train,
    "serve": bench_serve,
    "roofline": bench_roofline_summary,
    "scale": bench_scale,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated bench names, e.g. kernels,simulator")
    ap.add_argument("--seed", type=int, default=0,
                    help="rng seed threaded into every world compilation "
                         "(schedules, scenario sampling)")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized sweeps (n=16, fewer rounds/families/"
                         "channel points) — for the scenario-smoke jobs")
    args = ap.parse_args()
    if args.small:
        _TOPO_BENCH.update(n=16, rounds=60, seeds=2,
                           families=["ring", "complete"])
        # cap the channel family too: 2 horizons + 2 Byzantine fractions at
        # n=16/60 rounds keeps the CI smoke step inside its current budget
        # (byz_seeds stays 3 — the variance-band contract)
        _CHAN_BENCH.update(n=16, rounds=60, horizons=[0, 2],
                           byz_fracs=[0.0, 0.125])
        # B = 8 batched-vs-serial grid for the CI perf gate
        _SWEEP_BENCH.update(n=16, rounds=60, horizons=[0, 2, 4, 8],
                            byz_fracs=[0.0, 0.125])
        # defense grid at n=16/80 rounds, 2 seeds: the sign-flip physics
        # still holds (||corrupted|| ~ 2*0.3*sqrt(16) = 2.4 < tau = 5,
        # so the static arm stays bitwise-blind to the attack)
        _DEF_BENCH.update(n=16, d=16, rounds=80, seeds=2)
        # train smoke: n=16 keeps both topologies valid (hypercube needs a
        # power of two) and the ring gain still clears the gate
        # (sqrt(chi1/chi2) ~ 3.7 at n=16).  The nano family keeps 60
        # rounds — the gate reads ITS ring gain, and the noisy-broadcast
        # transient needs that long to separate from the adpsgd baseline
        # (measured 4.00 +- 0.68 at 60 rounds); the resnet family is the
        # expensive one, so it shrinks to a 6-round schema/dispatch check
        _TRAIN_BENCH.update(n=16, seeds=2)
        _TRAIN_BENCH["families"] = {
            "resnet8_cifar": {"rounds": 6, "batch_size": 1},
            "nano_lm_bench": {"rounds": 60, "batch_size": 1,
                              "seq_len": 16},
        }
        # serve smoke: 4 replicas, fewer rounds — the retention and
        # zero-loss gates still bind (the trace shrinks with the rounds)
        _SERVE_BENCH.update(replicas=4, rounds=60, max_batch=2)
        # scale smoke: a fixed n=1024 world keeps the per-step mixing
        # heavy enough that the forced-host ideal (flat time — total work
        # is constant, cores are shared) is measurable against the
        # per-step exchange overhead — the CI gate reads efficiency
        # (t1/t8) at 8 shards
        _SCALE_BENCH.update(n=1024, d=128, rounds=10, repeats=5)
    names = _parse_only(args.only) if args.only else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; choose from {list(BENCHES)}")
    from repro.analysis import SpanTracer
    global _TRACER
    print("name,us_per_call,derived")
    for name in names:
        # one trace file per family: TRACE_<name>.json beside the
        # BENCH_<name>.json it narrates (Perfetto-loadable)
        _TRACER = SpanTracer("bench", metadata={
            "family": name, "seed": args.seed, "small": bool(args.small)})
        try:
            with _TRACER.span(f"bench.{name}", lane="bench",
                              args={"seed": args.seed}):
                rows = BENCHES[name](seed=args.seed)
            _TRACER.write(_artifact_path(f"TRACE_{name}.json"))
        finally:
            _TRACER = None
        for row in rows:
            print(row)


if __name__ == "__main__":
    main()
