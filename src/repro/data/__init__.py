"""Data pipelines."""
from .pipeline import (LMTaskStream, SyntheticCIFAR, WorkerStream,
                       lm_batch_specs, make_lm_stream)

__all__ = ["LMTaskStream", "SyntheticCIFAR", "WorkerStream",
           "lm_batch_specs", "make_lm_stream"]
