"""Synthetic, shardable data pipelines.

Two properties the paper's setup requires:
  * every worker sees the whole dataset, shuffled with its own seed
    (Sec 4.1 — the asynchronous methods do not re-shard per epoch), which we
    realize with per-worker PRNG streams (`WorkerStream`);
  * deterministic, learnable structure, so the convergence comparisons in
    EXPERIMENTS.md measure optimization (not data noise).  The LM stream is a
    order-k Markov chain over the vocabulary; the image stream is a Gaussian
    class-prototype mixture — both have known Bayes losses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------- LM streams

@dataclasses.dataclass(frozen=True)
class LMTaskStream:
    """Order-1 Markov-chain token stream (fixed random transition matrix)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    concentration: float = 0.3  # lower = more predictable
    seed: int = 1234

    def transition_logits(self) -> jax.Array:
        rng = np.random.default_rng(self.seed)
        logits = rng.gumbel(size=(self.vocab_size, self.vocab_size))
        return jnp.asarray(logits / self.concentration, jnp.float32)

    def sample(self, key: jax.Array) -> dict:
        """Returns {"inputs": (B,S) int32, "labels": (B,S) int32}."""
        logits = self.transition_logits()
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (self.batch_size,), 0, self.vocab_size)

        def step(tok, k):
            nxt = jax.random.categorical(k, logits[tok], axis=-1)
            return nxt, nxt

        keys = jax.random.split(k1, self.seq_len)
        _, toks = jax.lax.scan(step, first, keys)
        toks = jnp.moveaxis(toks, 0, 1)                       # (B, S)
        seq = jnp.concatenate([first[:, None], toks], axis=1)  # (B, S+1)
        return {"inputs": seq[:, :-1].astype(jnp.int32),
                "labels": seq[:, 1:].astype(jnp.int32)}

    def bayes_ce(self) -> float:
        """Entropy rate of the chain = minimum achievable CE."""
        logits = np.asarray(self.transition_logits())
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        # stationary distribution via power iteration
        pi = np.full(self.vocab_size, 1.0 / self.vocab_size)
        for _ in range(200):
            pi = pi @ p
        h = -np.sum(pi[:, None] * p * np.log(np.maximum(p, 1e-12)))
        return float(h)


def make_lm_stream(cfg, seq_len: int, batch_size: int, seed: int = 1234
                   ) -> LMTaskStream:
    return LMTaskStream(vocab_size=cfg.vocab_size, seq_len=seq_len,
                        batch_size=batch_size, seed=seed)


def lm_batch_specs(vocab: int, batch: int, seq: int) -> dict:
    return {"inputs": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


# ------------------------------------------------------------ image streams

@dataclasses.dataclass(frozen=True)
class SyntheticCIFAR:
    """CIFAR-like stream: Gaussian class prototypes + noise (32x32x3)."""

    num_classes: int = 10
    batch_size: int = 128
    noise: float = 0.6
    seed: int = 7

    def prototypes(self) -> jax.Array:
        rng = np.random.default_rng(self.seed)
        return jnp.asarray(rng.normal(size=(self.num_classes, 32, 32, 3)),
                           jnp.float32)

    def sample(self, key: jax.Array) -> dict:
        k0, k1 = jax.random.split(key)
        labels = jax.random.randint(k0, (self.batch_size,), 0,
                                    self.num_classes)
        protos = self.prototypes()
        imgs = protos[labels] + self.noise * jax.random.normal(
            k1, (self.batch_size, 32, 32, 3))
        return {"images": imgs, "labels": labels}


# ------------------------------------------------------------- worker views

@dataclasses.dataclass(frozen=True)
class WorkerStream:
    """Per-worker data stream: same task, worker-specific PRNG stream.

    Mirrors the paper's protocol: all workers access the same dataset but
    shuffle with different seeds — i.i.d. in distribution, independent in
    realization.  ``heterogeneity`` optionally skews class/token frequencies
    per worker (for the FL-style heterogeneous setting the paper defers to
    future work — kept here as a framework feature)."""

    base_seed: int = 0

    def key(self, worker_id, step) -> jax.Array:
        k = jax.random.PRNGKey(self.base_seed)
        k = jax.random.fold_in(k, worker_id)
        return jax.random.fold_in(k, step)
