"""Self-healing gossip defense (DESIGN.md §12).

PR 4's robust m-term is a STATIC threshold: ``robust_clip`` picks one tau
for the whole replay, and the ClippedGossip analysis shows why that is not
enough — a sign-flip adversary (received value negated, scale ~ 1) emits
corrupted deltas whose norm ``||x - (1+c)xp|| = ||x + xp|| ~ 2||x||`` sits
in the honest range whenever the workers are far from consensus, so any
tau loose enough to pass honest traffic passes the attack too.  This
module closes the loop: the defense becomes per-round FEEDBACK computed
from replay statistics carried in the scan state.

Three controllers, all declaratively configured as
``World(defense=AdaptiveDefense(...))`` and all exact no-ops when off:

  * adaptive tau — an EMA of a quantile (default: 0.75, headroom for
    heterogeneous-objective spread) of the admitted delta norms, updated
    once per round at the gradient tick;
    ``tau_r = q * quantile_est`` tracks the consensus-tightening
    trajectory, so as honest norms shrink toward the gradient-noise floor
    the threshold shrinks with them and the sign-flip deltas (pinned near
    2||x||) fall outside.  The estimator learns from every admitted
    non-gross exchange — borderline rejections included, because an
    accepted-only estimator is a one-way ratchet (a tight tau shrinks its
    own input until honest reads are rejected wholesale), while gross
    violations (beyond ``margin * tau``) are excluded, because a sparse
    round dominated by an attacked edge would otherwise hand an
    attack-scale norm straight to the per-round quantile.  Quarantined
    edges' norms are excluded too — conviction removes an attacker from
    the estimator entirely.  Cold start uses ``min(tau0, static tau)``
    until the first admitted norms seed the estimator, so a scale-1e3
    burst at round 0 cannot poison the seed when a static threshold
    exists.
  * edge trust + quarantine — per directed edge (reader i, partner j) an
    EMA trust score in [0, 1]: accepted exchanges pull it toward 1 at
    rate ``rho``, rejections toward 0.  Below ``trust_floor`` the edge is
    QUARANTINED: its exchanges are zeroed in-scan (mscale 0 — the same
    rejection mechanism, so clocks and mixing still advance exactly like
    a rejected event) while trust heals toward re-admission at rate
    ``heal`` (probation).  A still-corrupt edge is re-rejected on
    re-admission and falls straight back (backoff); a transiently corrupt
    one (duty-cycle adversary that went honest) re-earns trust and stays.
  * degradation-aware comm control — ``comms_per_grad`` becomes a
    host-side controller: the World samples at the ``comm_hi`` rate and
    the controller thins each round's matchings to a keep-fraction that
    ramps from ``comm_lo``/``comm_hi`` up as training progresses and is
    scaled down by ``comm_degrade`` times the round's channel-degradation
    score (fraction of involved reads that are stale or corrupted).
    Gated matchings are rewritten to identity with their extras zeroed —
    exactly the PR 4 drop mechanism, so every replay path (engine,
    reference, batched) consumes the thinned schedule unchanged.

The in-scan state (``DefenseState``) and knobs (``DefenseKnobs``) are
plain NamedTuples of f32 leaves so the whole control loop rides a single
``lax.scan`` carry, vmaps over a world batch, and — crucially — NEUTRAL
knobs (adapt 0, rho 0, floor -1) degenerate BITWISE to the static
trim/plain channel arithmetic: ``mscale = (nrm <= tau)`` with tau static
(or +inf).  That is what lets a static-vs-adaptive-vs-attack grid ride
the PR 5 batched replay as ONE jit trace (tests/test_defense.py pins it).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdaptiveDefense:
    """Declarative self-healing defense spec (a ``World`` field).

    adaptive_tau — enable the quantile-tracking threshold; ``q`` is the
      multiplier on the quantile estimate (tau_r = q * qest), ``quantile``
      the tracked order statistic of admitted norms (0.5 = median, robust
      to <50% contamination of a round's exchanges), ``beta`` the EMA
      rate of the estimator, ``tau0`` the cold-start threshold used until
      the estimator has seen its first admitted norms (the effective cold
      tau is min(tau0, static tau); inf + no static tau = accept all,
      letting the median seed itself from majority-honest traffic).
    trust — enable edge trust/quarantine; ``rho`` the trust EMA rate,
      ``trust_floor`` the quarantine threshold (low floors tolerate
      duty-cycle edges that are honest half the time), ``heal`` the
      probation re-admission rate while quarantined, ``margin`` the
      conviction margin: trust is only damaged by GROSS violations
      (nrm > margin * tau).  A rejection just above tau still zeroes the
      exchange but leaves trust intact — honest tail norms land there,
      and rejecting an honest edge is self-reinforcing (no averaging =>
      larger future deltas), so borderline rejections must never feed
      the conviction loop.  Real attacks sit orders of magnitude out.
    comm_lo/comm_hi/comm_degrade — the communication controller (host
      side): keep-fraction ramps comm_lo -> comm_hi over the replay and
      is derated by ``comm_degrade`` x the round's degradation score.
      All three at their defaults = controller off (schedule untouched).
    """

    adaptive_tau: bool = True
    q: float = 3.0
    quantile: float = 0.75
    beta: float = 0.2
    tau0: float = float("inf")
    trust: bool = True
    rho: float = 0.25
    trust_floor: float = 0.25
    heal: float = 0.02
    margin: float = 3.0
    comm_lo: float = 1.0
    comm_hi: float = 1.0
    comm_degrade: float = 0.0

    def __post_init__(self):
        if not self.q > 0:
            raise ValueError(f"q must be > 0, got {self.q}")
        if not 0.0 < self.quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got "
                             f"{self.quantile}")
        if not 0.0 < self.beta <= 1.0:
            raise ValueError(f"beta must be in (0, 1], got {self.beta}")
        if not self.tau0 > 0:
            raise ValueError(f"tau0 must be > 0, got {self.tau0}")
        if not 0.0 <= self.rho <= 1.0:
            raise ValueError(f"rho must be in [0, 1], got {self.rho}")
        if not self.trust_floor < 1.0:
            raise ValueError(f"trust_floor must be < 1, got "
                             f"{self.trust_floor}")
        if not 0.0 <= self.heal <= 1.0:
            raise ValueError(f"heal must be in [0, 1], got {self.heal}")
        if not self.margin >= 1.0:
            raise ValueError(f"margin must be >= 1, got {self.margin}")
        if not 0.0 < self.comm_lo <= self.comm_hi:
            raise ValueError("need 0 < comm_lo <= comm_hi, got "
                             f"({self.comm_lo}, {self.comm_hi})")
        if self.comm_degrade < 0:
            raise ValueError(f"comm_degrade must be >= 0, got "
                             f"{self.comm_degrade}")

    @property
    def is_active(self) -> bool:
        """True when the IN-SCAN loop must run (adaptive tau or trust);
        the comm controller alone is a host-side schedule transform."""
        return self.adaptive_tau or self.trust

    @property
    def has_comm_control(self) -> bool:
        return (self.comm_lo != 1.0 or self.comm_hi != 1.0
                or self.comm_degrade != 0.0)

    # ------------------------------------------------- comm controller
    def comm_multipliers(self, rounds: int,
                         degradation: np.ndarray) -> np.ndarray:
        """(R,) keep-fraction per round: a comm_lo -> comm_hi ramp over
        the replay (communication pays off most once the consensus error
        is small), derated by the channel-degradation score."""
        prog = (np.arange(rounds, dtype=np.float64) + 1.0) / max(rounds, 1)
        ramp = self.comm_lo + (self.comm_hi - self.comm_lo) * prog
        derate = np.clip(1.0 - self.comm_degrade
                         * np.asarray(degradation, np.float64), 0.0, 1.0)
        return np.clip(ramp * derate / self.comm_hi, 0.0, 1.0)

    def apply_comm_control(self, schedule):
        """Thin a compiled schedule to the controller's per-round rate.

        The World samples matchings at the ``comm_hi`` rate; this pass
        keeps the first ceil(frac_r * K_active) active matchings of round
        r and gates the rest — partners rewritten to identity AND the
        event masked AND every extras row zeroed, so a gated slot is an
        exact no-op on all replay paths (the reference path applies p2p
        unconditionally, which is why identity-rewrite is mandatory).
        """
        if not self.has_comm_control:
            return schedule
        from .channel import degradation_profile
        frac = self.comm_multipliers(schedule.rounds,
                                     degradation_profile(schedule))
        partners = np.array(schedule.partners)
        mask = np.array(schedule.event_mask)
        extras = {k: np.array(v) for k, v in schedule.extras_dict().items()}
        R, K, n = partners.shape
        idx = np.arange(n, dtype=partners.dtype)
        for r in range(R):
            active = np.flatnonzero(mask[r]
                                    & (partners[r] != idx).any(axis=1))
            keep = int(math.ceil(frac[r] * active.size))
            for k in active[keep:]:
                partners[r, k] = idx
                mask[r, k] = False
                for a in extras.values():
                    a[r, k] = 0
        out = dataclasses.replace(schedule, partners=partners,
                                  event_mask=mask)
        return out.with_extras(**extras) if extras else out

    # ------------------------------------------------- serialization
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # JSON has no inf literal; None round-trips to the default
        if math.isinf(d["tau0"]):
            d["tau0"] = None
        return d

    @staticmethod
    def from_dict(d: dict) -> "AdaptiveDefense":
        d = dict(d)
        if d.get("tau0") is None:
            d["tau0"] = float("inf")
        return AdaptiveDefense(**d)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "AdaptiveDefense":
        return AdaptiveDefense.from_dict(json.loads(s))


# ------------------------------------------------------------ scan-side IR
# The jit'd replay impls never see AdaptiveDefense itself: the spec lowers
# to a DefenseKnobs of f32 leaves (scalars serially, (B,) world-batched),
# so every defense configuration — including "no defense", lowered to the
# NEUTRAL knobs — shares one trace.

class DefenseKnobs(NamedTuple):
    adapt: jax.Array   # > 0 enables adaptive tau
    q: jax.Array       # tau multiplier on the quantile estimate
    p: jax.Array       # tracked quantile of accepted norms
    beta: jax.Array    # quantile-estimator EMA rate
    tau0: jax.Array    # cold-start tau while the estimator is unseeded
    tau_s: jax.Array   # static tau (adapt == 0 arms; inf = accept all)
    rho: jax.Array     # trust EMA rate (0 freezes trust)
    floor: jax.Array   # quarantine threshold (-1 disables quarantine)
    heal: jax.Array    # probation re-admission rate
    margin: jax.Array  # conviction margin: trust damage needs nrm > m*tau


class DefenseState(NamedTuple):
    qest: jax.Array      # scalar quantile estimate (0 = unseeded)
    trust: jax.Array     # (n, n) directed edge trust, init 1
    lastn: jax.Array     # (n,) this round's last accepted positive norm
    lastv: jax.Array     # (n,) bool: lastn valid
    rej_acc: jax.Array   # scalar, norm-rejections accumulated this round
    quar_acc: jax.Array  # scalar, quarantined exchanges this round


class DefenseTrace(NamedTuple):
    """Per-round control-loop trace riding SimTrace.defense: the tau in
    effect, norm-rejection count, and quarantined-exchange count (each
    (R,) serial / (B, R) batched)."""
    tau: jax.Array
    rejections: jax.Array
    quarantined: jax.Array


_NEUTRAL = {"adapt": 0.0, "q": 1.0, "p": 0.5, "beta": 1.0,
            "tau0": float("inf"), "rho": 0.0, "floor": -1.0, "heal": 0.0,
            "margin": 1.0}


def defense_knobs(defense: AdaptiveDefense | None,
                  static_tau: float | None) -> tuple:
    """Lower one (defense, static robust tau) arm to plain knob floats.

    ``defense=None`` (or trust/adaptive arms switched off) lowers to the
    neutral values, under which the scan arithmetic is BITWISE the static
    path: tau constant (``static_tau`` or +inf -> mscale == (nrm <= tau)
    == all-ones when non-robust), trust frozen at 1, no quarantine.
    """
    tau_s = float("inf") if static_tau is None else float(static_tau)
    if defense is None:
        k = dict(_NEUTRAL)
    else:
        # Cold-start tau never looser than the static threshold: until the
        # quantile estimator seeds, an explicit tau0 or the static tau_s
        # screens the first exchanges (an unscreened round-0 read of a
        # scale-1e3 corruption would poison the estimator's own seed).
        k = {"adapt": 1.0 if defense.adaptive_tau else 0.0,
             "q": defense.q, "p": defense.quantile, "beta": defense.beta,
             "tau0": min(defense.tau0, tau_s),
             "rho": defense.rho if defense.trust else 0.0,
             "floor": defense.trust_floor if defense.trust else -1.0,
             "heal": defense.heal if defense.trust else 0.0,
             "margin": defense.margin}
    return (k["adapt"], k["q"], k["p"], k["beta"], k["tau0"], tau_s,
            k["rho"], k["floor"], k["heal"], k["margin"])


def knobs_single(defense: AdaptiveDefense | None,
                 static_tau: float | None) -> DefenseKnobs:
    """Serial-replay knobs: f32 scalars."""
    vals = defense_knobs(defense, static_tau)
    return DefenseKnobs(*(jnp.float32(v) for v in vals))


def knobs_worlds(defenses, static_taus) -> DefenseKnobs:
    """World-batched knobs: (B,) f32 arrays, one row per arm."""
    rows = [defense_knobs(d, t) for d, t in zip(defenses, static_taus)]
    cols = np.asarray(rows, np.float32).T
    return DefenseKnobs(*(jnp.asarray(c) for c in cols))


def defense_init(n: int, batch: int | None = None) -> DefenseState:
    """Fresh control-loop state (all trust 1, estimator unseeded)."""
    lead = () if batch is None else (batch,)
    return DefenseState(
        qest=jnp.zeros(lead, jnp.float32),
        trust=jnp.ones(lead + (n, n), jnp.float32),
        lastn=jnp.zeros(lead + (n,), jnp.float32),
        lastv=jnp.zeros(lead + (n,), bool),
        rej_acc=jnp.zeros(lead, jnp.float32),
        quar_acc=jnp.zeros(lead, jnp.float32))


def _tau_of(k: DefenseKnobs, ds: DefenseState) -> jax.Array:
    """The round's threshold: q * qest once seeded, tau0 while cold,
    the static tau on adapt == 0 arms."""
    return jnp.where(k.adapt > 0,
                     jnp.where(ds.qest > 0, k.q * ds.qest, k.tau0),
                     k.tau_s)


def defense_comm(k: DefenseKnobs, ds: DefenseState, partner: jax.Array,
                 involved: jax.Array, nrm: jax.Array
                 ) -> tuple[jax.Array, jax.Array, DefenseState]:
    """One comm step of the control loop (unbatched; vmap for worlds).

    partner/involved/nrm are (n,) per-reader rows (nrm the delta norm of
    the exchange, 0 on idle rows).  Returns the (n,) f32 mscale for the
    fused channel kernel, the (n,) bool quarantine mask, and the updated
    state.  Neutral knobs reproduce the static trim mscale bitwise.

    Order-invariance within a coalesced batch: the engine path applies
    this once per FUSED batch where the reference path applies it once
    per event — equivalent because a batch merges only disjoint
    matchings, so each reader row (and its trust entry) is touched by at
    most one event per batch and the row updates commute.
    """
    idx = jnp.arange(partner.shape[0])
    tau = _tau_of(k, ds)
    accept = nrm <= tau
    tr = ds.trust[idx, partner]
    quar = (tr < k.floor) & involved
    mscale = (accept & ~quar).astype(jnp.float32)
    # trust EMA on involved edges; quarantined edges observe nothing (the
    # exchange was suppressed) and instead heal toward re-admission.
    # The margin splits rejections into BORDERLINE (tau < nrm <= margin *
    # tau: honest tail norms, transient growth) and GROSS (attacks, orders
    # of magnitude out).  Conviction counts only gross violations — an
    # honest edge that stops averaging only drifts further (rejection is
    # self-reinforcing), so borderline rejections must never feed the
    # conviction loop.
    fine = nrm <= k.margin * tau
    obs = fine.astype(jnp.float32)
    upd = jnp.where(quar, tr + k.heal * (1.0 - tr),
                    (1.0 - k.rho) * tr + k.rho * obs)
    trust = ds.trust.at[idx, partner].set(jnp.where(involved, upd, tr))
    # norm record for the quantile estimator: every admitted NON-GROSS
    # exchange, accepted or borderline-rejected.  Borderline rejections
    # must count — recording only accepted norms lets a tight tau shrink
    # its own estimator, a one-way ratchet ending in wholesale rejection
    # of honest reads.  Gross violations must NOT count — the per-round
    # quantile is taken over the workers involved that round, and a
    # sparse round dominated by an attacked edge would hand a scale-1e3
    # norm straight to the estimator.  Quarantined reads are excluded
    # (their edge is already convicted), as are idle rows (self-read ->
    # nrm 0).
    rec = involved & ~quar & fine & (nrm > 0)
    return mscale, quar, ds._replace(
        trust=trust,
        lastn=jnp.where(rec, nrm, ds.lastn),
        lastv=ds.lastv | rec)


def defense_absorb(ds: DefenseState, rej: jax.Array, quar: jax.Array,
                   involved: jax.Array) -> DefenseState:
    """Fold the kernel's per-event rejection mask (mscale == 0) into the
    round counters; quarantine-induced zeros are counted separately."""
    rejn = jnp.sum(jnp.where(involved & ~quar, rej, 0.0))
    return ds._replace(rej_acc=ds.rej_acc + rejn,
                       quar_acc=ds.quar_acc
                       + jnp.sum(quar.astype(jnp.float32)))


def defense_grad(k: DefenseKnobs, ds: DefenseState
                 ) -> tuple[DefenseState, tuple]:
    """The gradient-tick controller update (unbatched; vmap for worlds).

    Folds the round's admitted norms into the quantile EMA and resets the
    per-round records.  Returns the new state and the (tau, rejections,
    quarantined) trace row — tau is the threshold that was IN EFFECT this
    round.  Learns only from strictly positive norms, so an all-idle
    round leaves the estimate untouched and a cold estimator cannot lock
    itself at tau = 0.
    """
    n = ds.lastn.shape[0]
    tau = _tau_of(k, ds)
    s = jnp.sort(jnp.where(ds.lastv, ds.lastn, jnp.inf))
    m = jnp.sum(ds.lastv.astype(jnp.int32))
    iq = jnp.clip(jnp.ceil(k.p * m.astype(jnp.float32)).astype(jnp.int32)
                  - 1, 0, n - 1)
    quant = s[iq]
    upd = (m > 0) & (k.adapt > 0) & jnp.isfinite(quant)
    seeded = jnp.where(ds.qest > 0,
                       (1.0 - k.beta) * ds.qest + k.beta * quant, quant)
    # pressure valve against the low-side freeze: a round that rejected
    # exchanges yet recorded NOTHING means every admitted read was gross —
    # with a minority-Byzantine channel that is a miscalibrated tau (e.g.
    # seeded from a degenerate near-zero consensus), not an attack, so
    # grow the estimate by the margin factor until honest norms land back
    # inside the recordable band.  An attacker would need to dominate
    # nearly every round to ratchet tau upward through this path, and any
    # honest admission immediately resumes EMA tracking.
    starve = (m == 0) & (k.adapt > 0) & (ds.qest > 0) & (ds.rej_acc > 0)
    grown = jnp.where(starve, ds.qest * k.margin, ds.qest)
    out = (tau, ds.rej_acc, ds.quar_acc)
    return ds._replace(qest=jnp.where(upd, seeded, grown),
                       lastn=jnp.zeros_like(ds.lastn),
                       lastv=jnp.zeros_like(ds.lastv),
                       rej_acc=jnp.zeros_like(ds.rej_acc),
                       quar_acc=jnp.zeros_like(ds.quar_acc)), out
