"""Core library: the paper's contribution (A2CiD2) as composable JAX modules."""
from .a2cid2 import (A2CiD2Params, Algorithm, acid_params, apply_mixing,
                     baseline_params, consensus_distance, gradient_event,
                     matched_p2p_update, mixing_coeff, p2p_event,
                     params_from_graph, worker_mean)
from .channel import (ByzantineEdges, ChannelModel, DelayProcess,
                      degradation_profile)
from .defense import AdaptiveDefense, DefenseTrace
from .engine import FlatGossipEngine, mix_flat
from .events import (BatchedSchedule, BatchedStream, CoalescedSchedule,
                     EventStream, Schedule, coalesce_schedule,
                     coalesced_stream, concat_schedules,
                     empirical_laplacian, make_schedule,
                     make_topology_schedule, stack_schedules, stack_streams)
from .flatbuf import FlatLayout, LeafSpec
from .gossip import GossipMixer, matching_bank, phase_banks, world_banks
from .graphs import (Graph, TopologyPhase, TopologySchedule, build_graph,
                     complete_graph, exponential_graph, hypercube_graph,
                     ring_graph, star_graph, torus_graph)
from .simulator import SimState, SimTrace, Simulator, allreduce_sgd
from .telemetry import (Telemetry, TelemetryTrace, row_bytes_of,
                        trace_summary)
from .world import (SERVE_ARRIVE_KEY, ChurnProcess, LinkModel, PhaseSwitch,
                    RequestTrace, ServeLoad, WorkerModel, World, WorldSweep)

__all__ = [
    "ByzantineEdges", "ChannelModel", "DelayProcess", "degradation_profile",
    "AdaptiveDefense", "DefenseTrace",
    "ChurnProcess", "LinkModel", "PhaseSwitch", "RequestTrace",
    "SERVE_ARRIVE_KEY", "ServeLoad", "WorkerModel", "World", "WorldSweep",
    "A2CiD2Params", "Algorithm", "acid_params", "apply_mixing",
    "baseline_params",
    "consensus_distance", "gradient_event", "matched_p2p_update",
    "mixing_coeff", "p2p_event", "params_from_graph", "worker_mean",
    "BatchedSchedule", "BatchedStream", "CoalescedSchedule", "EventStream",
    "Schedule", "coalesce_schedule", "coalesced_stream", "concat_schedules",
    "empirical_laplacian", "make_schedule", "make_topology_schedule",
    "stack_schedules", "stack_streams",
    "FlatGossipEngine", "mix_flat", "FlatLayout", "LeafSpec",
    "GossipMixer", "matching_bank", "phase_banks", "world_banks",
    "Graph", "TopologyPhase", "TopologySchedule", "build_graph",
    "complete_graph", "exponential_graph", "hypercube_graph",
    "ring_graph", "star_graph", "torus_graph",
    "SimState", "SimTrace", "Simulator", "allreduce_sgd",
    "Telemetry", "TelemetryTrace", "row_bytes_of", "trace_summary",
]
