"""A2CiD2 continuous momentum — the paper's core contribution (Sec 3.2, Algo 1).

Each worker holds two buffers: the parameters ``x`` and a momentum copy
``x_tilde``.  Between events they follow the mixing ODE

    dx/dt      = eta (x_tilde - x)
    dx_tilde/dt = eta (x - x_tilde)

whose flow is the doubly-stochastic 2x2 matrix

    exp(t*A) = 1/2 [[1+e, 1-e], [1-e, 1+e]],   e = exp(-2 eta t),
    A = [[-eta, eta], [eta, -eta]].

Events:
  * gradient event (rate 1 / worker):  x -= gamma*g ; x_tilde -= gamma*g   (Eq 4)
  * p2p event on edge (i,j) (rate lambda_ij):  with m = x_i - x_j,
        x_i -= alpha*m ; x_tilde_i -= alpha_t*m
        x_j += alpha*m ; x_tilde_j += alpha_t*m

Prop 3.6 hyper-parameters:
  * baseline (no acceleration): eta = 0, alpha = alpha_t = 1/2, chi = chi_1
  * A2CiD2: eta = 1/(2 sqrt(chi1 chi2)), alpha = 1/2,
            alpha_t = 1/2 sqrt(chi1/chi2), chi = sqrt(chi1 chi2)

All update functions operate on arbitrary pytrees and are jit/vmap friendly.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class A2CiD2Params:
    """Scalar hyper-parameters of the dynamic (Eq 4 / Prop 3.6)."""

    eta: float
    alpha: float
    alpha_tilde: float
    chi: float  # effective chi entering the rate: chi1 (baseline) or sqrt(chi1 chi2)

    @property
    def accelerated(self) -> bool:
        return self.eta > 0.0


def baseline_params(chi1: float) -> A2CiD2Params:
    """The non-accelerated asynchronous baseline (a refined AD-PSGD)."""
    return A2CiD2Params(eta=0.0, alpha=0.5, alpha_tilde=0.5, chi=chi1)


def acid_params(chi1: float, chi2: float) -> A2CiD2Params:
    """Accelerated parameters from Prop 3.6."""
    if not (0.0 < chi2 <= chi1 + 1e-9):
        raise ValueError(f"need 0 < chi2 <= chi1, got chi1={chi1}, chi2={chi2}")
    root = math.sqrt(chi1 * chi2)
    return A2CiD2Params(
        eta=1.0 / (2.0 * root),
        alpha=0.5,
        alpha_tilde=0.5 * math.sqrt(chi1 / chi2),
        chi=root,
    )


def params_from_graph(graph, accelerated: bool = True) -> A2CiD2Params:
    chi1 = graph.chi1()
    if not accelerated:
        return baseline_params(chi1)
    return acid_params(chi1, graph.chi2())


# ------------------------------------------------------------- algorithm zoo

#: Known algorithm kinds and whether their canonical form runs the
#: accelerated (eta > 0) dynamics.  Every kind lowers onto the SAME scan —
#: the zoo is per-world (B,) dynamics data plus clock structure, never a
#: new engine (DESIGN.md §13):
#:   a2cid2  — the paper's dynamic (Prop 3.6), coupled unit-rate clocks
#:   adpsgd  — the asynchronous baseline the paper compares against
#:             (Eq 6 ≈ AD-PSGD, Lian et al. 2018): eta = 0, alpha = 1/2,
#:             no momentum — bitwise `baseline_params(chi1)`
#:   dadao   — DADAO-style DECOUPLED gradient/gossip Poisson clocks
#:             (Nabli & Oyallon 2022): independent event-rate axes for the
#:             two point processes, realized as schedule data
ALGORITHM_KINDS = ("a2cid2", "adpsgd", "dadao")
_KIND_ACCELERATED = {"a2cid2": True, "adpsgd": False, "dadao": True}

# rng-stream tag for the algorithm's decoupled gradient clock: like the
# straggler (0x48455) and channel (0xC4A77) streams, algorithm draws come
# from their own SeedSequence child so a coupled algorithm leaves the main
# schedule stream — and hence the schedule — bit-for-bit untouched
_ALGO_TAG = 0xDADA0


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """Declarative algorithm spec — a World axis, serialized like
    ``ChannelModel``/``AdaptiveDefense`` and lowered at compile time.

    The spec splits into two orthogonal parts:

    * **dynamics column** — ``params_for(graph)`` resolves the kind +
      ``accelerated`` flag to the scalar ``A2CiD2Params`` that ride the
      per-world (B,) arrays of the batched replay (``world_params``).
      ``accelerated=None`` takes the kind's canonical form (a2cid2/dadao
      accelerated, adpsgd base); setting it overrides — e.g.
      ``Algorithm("adpsgd", accelerated=True)`` is the "what if AD-PSGD
      had the momentum" counterfactual arm benchmarks sweep.
    * **clock structure** — only ``kind="dadao"`` has one: independent
      Poisson rates for the gradient (``grad_rate``, Bernoulli thinning
      of the unit tick process, same realization as straggler
      ``grad_rates``) and gossip (``gossip_rate``, replaces
      ``comms_per_grad`` as the comm-event intensity) processes.  When
      the rates coincide with the coupled defaults (grad_rate = 1,
      gossip_rate = None) the schedule is bitwise the coupled one —
      asserted in tests/test_algorithms.py.
    """

    kind: str = "a2cid2"
    accelerated: bool | None = None
    grad_rate: float = 1.0
    gossip_rate: float | None = None

    def __post_init__(self):
        if self.kind not in ALGORITHM_KINDS:
            raise ValueError(f"Algorithm.kind must be one of "
                             f"{ALGORITHM_KINDS}, got {self.kind!r}")
        if self.accelerated is not None and \
                not isinstance(self.accelerated, bool):
            raise ValueError("Algorithm.accelerated must be None or bool, "
                             f"got {self.accelerated!r}")
        gr = self.grad_rate
        if not (isinstance(gr, (int, float)) and 0.0 < float(gr) <= 1.0):
            raise ValueError("Algorithm.grad_rate must be a float in "
                             f"(0, 1], got {gr!r}")
        if self.gossip_rate is not None:
            g = self.gossip_rate
            if not (isinstance(g, (int, float)) and float(g) > 0.0
                    and math.isfinite(float(g))):
                raise ValueError("Algorithm.gossip_rate must be None or a "
                                 f"finite float > 0, got {g!r}")
        if self.kind != "dadao" and (float(gr) != 1.0
                                     or self.gossip_rate is not None):
            raise ValueError(
                f"decoupled clocks (grad_rate/gossip_rate) are a "
                f"kind='dadao' axis; kind={self.kind!r} must keep "
                f"grad_rate=1.0 and gossip_rate=None")

    # ------------------------------------------------------ dynamics column
    @property
    def is_accelerated(self) -> bool:
        if self.accelerated is not None:
            return self.accelerated
        return _KIND_ACCELERATED[self.kind]

    def params_for(self, graph) -> A2CiD2Params:
        """Lower to the scalar dynamics column for ``graph``.

        The adpsgd base arm is bitwise ``baseline_params(graph.chi1())``
        (eta = 0, alpha = alpha_tilde = 1/2) because ``params_from_graph``
        routes through exactly that constructor — the closed-form pin in
        tests/test_algorithms.py.
        """
        return params_from_graph(graph, accelerated=self.is_accelerated)

    # ------------------------------------------------------ clock structure
    @property
    def decoupled(self) -> bool:
        """True iff the spec carries a non-trivial decoupled clock."""
        return self.kind == "dadao" and (
            float(self.grad_rate) != 1.0 or self.gossip_rate is not None)

    def comm_rate(self, comms_per_grad: float) -> float:
        """Effective comm-event intensity: the independent gossip clock
        when set, the coupled ``comms_per_grad`` otherwise."""
        if self.kind == "dadao" and self.gossip_rate is not None:
            return float(self.gossip_rate)
        return float(comms_per_grad)

    def apply_grad_clock(self, schedule, seed: int):
        """Thin gradient ticks by the decoupled gradient rate.

        Bernoulli(grad_rate) per (round, worker) — the same tick-thinning
        realization of a slower Poisson clock that straggler ``grad_rates``
        use (DESIGN.md §8), drawn from the algorithm's own rng stream so a
        unit rate returns ``schedule`` unchanged (bitwise reduction)."""
        rate = float(self.grad_rate)
        if self.kind != "dadao" or rate == 1.0:
            return schedule
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), _ALGO_TAG]))
        gate = rng.uniform(size=(schedule.rounds, schedule.n)) < rate
        return schedule.with_grad_gate(gate)

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"kind": self.kind, "accelerated": self.accelerated,
                "grad_rate": float(self.grad_rate),
                "gossip_rate": None if self.gossip_rate is None
                else float(self.gossip_rate)}

    @staticmethod
    def from_dict(d: dict) -> "Algorithm":
        return Algorithm(kind=d.get("kind", "a2cid2"),
                         accelerated=d.get("accelerated"),
                         grad_rate=d.get("grad_rate", 1.0),
                         gossip_rate=d.get("gossip_rate"))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @staticmethod
    def from_json(s: str) -> "Algorithm":
        return Algorithm.from_dict(json.loads(s))


# ----------------------------------------------------------------- mixing ODE

def mixing_coeff(eta: float | jax.Array, dt: jax.Array) -> jax.Array:
    """Off-diagonal weight of exp(dt*A): (1 - exp(-2 eta dt)) / 2 in [0, 1/2)."""
    return 0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))


def apply_mixing(x: PyTree, x_tilde: PyTree, eta: float, dt) -> tuple[PyTree, PyTree]:
    """Lazily apply the continuous mixing for an elapsed time ``dt``.

    Exact closed-form flow of the ODE; preserves x + x_tilde identically.
    ``dt`` may be a scalar or any array broadcastable against the leaves
    (e.g. per-worker elapsed times with leaves shaped (n_workers, ...)).
    """
    if eta == 0.0:
        return x, x_tilde
    dt = jnp.asarray(dt)

    def mix(a, b):
        c = mixing_coeff(eta, dt).astype(a.dtype)
        c = jnp.reshape(c, c.shape + (1,) * (a.ndim - c.ndim))  # broadcast workers
        d = b - a
        return a + c * d, b - c * d

    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    mixed = [mix(a, b) for a, b in zip(flat_x, flat_t)]
    new_x = treedef.unflatten([m[0] for m in mixed])
    new_t = treedef.unflatten([m[1] for m in mixed])
    return new_x, new_t


# -------------------------------------------------------------- event updates

def gradient_event(x: PyTree, x_tilde: PyTree, grads: PyTree, gamma) -> tuple[PyTree, PyTree]:
    """Apply a gradient event: both buffers take the step (Eq 4)."""
    new_x = jax.tree.map(lambda p, g: p - gamma * g, x, grads)
    new_t = jax.tree.map(lambda p, g: p - gamma * g, x_tilde, grads)
    return new_x, new_t


def p2p_event(x_i: PyTree, x_tilde_i: PyTree, x_j: PyTree,
              params: A2CiD2Params) -> tuple[PyTree, PyTree]:
    """One side of a pairwise averaging event on edge (i, j).

    m = x_i - x_j;  x_i -= alpha*m ; x_tilde_i -= alpha_tilde*m.
    The j side is obtained by calling with roles swapped (m flips sign).
    With alpha = 1/2 the x-update is exact pairwise averaging.
    """
    def upd(a, at, b):
        m = a - b
        return a - params.alpha * m, at - params.alpha_tilde * m

    flat_i, treedef = jax.tree_util.tree_flatten(x_i)
    flat_ti = treedef.flatten_up_to(x_tilde_i)
    flat_j = treedef.flatten_up_to(x_j)
    out = [upd(a, at, b) for a, at, b in zip(flat_i, flat_ti, flat_j)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def matched_p2p_update(x: PyTree, x_tilde: PyTree, partner: jax.Array,
                       params: A2CiD2Params) -> tuple[PyTree, PyTree]:
    """Apply one matching round to stacked worker states.

    Leaves of ``x``/``x_tilde`` have a leading worker axis (n, ...).
    ``partner[i] = j`` (with partner[j] = i) for matched pairs, ``i`` for idle
    workers — idle workers see m = x_i - x_i = 0, a clean no-op.
    """
    def upd(a, at):
        b = jnp.take(a, partner, axis=0)
        m = a - b
        return a - params.alpha * m, at - params.alpha_tilde * m

    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    out = [upd(a, at) for a, at in zip(flat_x, flat_t)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# ---------------------------------------------------------------- diagnostics

def consensus_distance(x: PyTree) -> jax.Array:
    """||pi x||_F^2 / n = mean squared distance of workers to the mean.

    Leaves have a leading worker axis. This is the quantity tracked in the
    paper's Fig 5b.
    """
    def per_leaf(a):
        mean = jnp.mean(a, axis=0, keepdims=True)
        return jnp.sum((a - mean) ** 2) / a.shape[0]

    leaves = jax.tree.leaves(x)
    return sum(per_leaf(a) for a in leaves)


def worker_mean(x: PyTree) -> PyTree:
    return jax.tree.map(lambda a: jnp.mean(a, axis=0), x)
