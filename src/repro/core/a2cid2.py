"""A2CiD2 continuous momentum — the paper's core contribution (Sec 3.2, Algo 1).

Each worker holds two buffers: the parameters ``x`` and a momentum copy
``x_tilde``.  Between events they follow the mixing ODE

    dx/dt      = eta (x_tilde - x)
    dx_tilde/dt = eta (x - x_tilde)

whose flow is the doubly-stochastic 2x2 matrix

    exp(t*A) = 1/2 [[1+e, 1-e], [1-e, 1+e]],   e = exp(-2 eta t),
    A = [[-eta, eta], [eta, -eta]].

Events:
  * gradient event (rate 1 / worker):  x -= gamma*g ; x_tilde -= gamma*g   (Eq 4)
  * p2p event on edge (i,j) (rate lambda_ij):  with m = x_i - x_j,
        x_i -= alpha*m ; x_tilde_i -= alpha_t*m
        x_j += alpha*m ; x_tilde_j += alpha_t*m

Prop 3.6 hyper-parameters:
  * baseline (no acceleration): eta = 0, alpha = alpha_t = 1/2, chi = chi_1
  * A2CiD2: eta = 1/(2 sqrt(chi1 chi2)), alpha = 1/2,
            alpha_t = 1/2 sqrt(chi1/chi2), chi = sqrt(chi1 chi2)

All update functions operate on arbitrary pytrees and are jit/vmap friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class A2CiD2Params:
    """Scalar hyper-parameters of the dynamic (Eq 4 / Prop 3.6)."""

    eta: float
    alpha: float
    alpha_tilde: float
    chi: float  # effective chi entering the rate: chi1 (baseline) or sqrt(chi1 chi2)

    @property
    def accelerated(self) -> bool:
        return self.eta > 0.0


def baseline_params(chi1: float) -> A2CiD2Params:
    """The non-accelerated asynchronous baseline (a refined AD-PSGD)."""
    return A2CiD2Params(eta=0.0, alpha=0.5, alpha_tilde=0.5, chi=chi1)


def acid_params(chi1: float, chi2: float) -> A2CiD2Params:
    """Accelerated parameters from Prop 3.6."""
    if not (0.0 < chi2 <= chi1 + 1e-9):
        raise ValueError(f"need 0 < chi2 <= chi1, got chi1={chi1}, chi2={chi2}")
    root = math.sqrt(chi1 * chi2)
    return A2CiD2Params(
        eta=1.0 / (2.0 * root),
        alpha=0.5,
        alpha_tilde=0.5 * math.sqrt(chi1 / chi2),
        chi=root,
    )


def params_from_graph(graph, accelerated: bool = True) -> A2CiD2Params:
    chi1 = graph.chi1()
    if not accelerated:
        return baseline_params(chi1)
    return acid_params(chi1, graph.chi2())


# ----------------------------------------------------------------- mixing ODE

def mixing_coeff(eta: float | jax.Array, dt: jax.Array) -> jax.Array:
    """Off-diagonal weight of exp(dt*A): (1 - exp(-2 eta dt)) / 2 in [0, 1/2)."""
    return 0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))


def apply_mixing(x: PyTree, x_tilde: PyTree, eta: float, dt) -> tuple[PyTree, PyTree]:
    """Lazily apply the continuous mixing for an elapsed time ``dt``.

    Exact closed-form flow of the ODE; preserves x + x_tilde identically.
    ``dt`` may be a scalar or any array broadcastable against the leaves
    (e.g. per-worker elapsed times with leaves shaped (n_workers, ...)).
    """
    if eta == 0.0:
        return x, x_tilde
    dt = jnp.asarray(dt)

    def mix(a, b):
        c = mixing_coeff(eta, dt).astype(a.dtype)
        c = jnp.reshape(c, c.shape + (1,) * (a.ndim - c.ndim))  # broadcast workers
        d = b - a
        return a + c * d, b - c * d

    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    mixed = [mix(a, b) for a, b in zip(flat_x, flat_t)]
    new_x = treedef.unflatten([m[0] for m in mixed])
    new_t = treedef.unflatten([m[1] for m in mixed])
    return new_x, new_t


# -------------------------------------------------------------- event updates

def gradient_event(x: PyTree, x_tilde: PyTree, grads: PyTree, gamma) -> tuple[PyTree, PyTree]:
    """Apply a gradient event: both buffers take the step (Eq 4)."""
    new_x = jax.tree.map(lambda p, g: p - gamma * g, x, grads)
    new_t = jax.tree.map(lambda p, g: p - gamma * g, x_tilde, grads)
    return new_x, new_t


def p2p_event(x_i: PyTree, x_tilde_i: PyTree, x_j: PyTree,
              params: A2CiD2Params) -> tuple[PyTree, PyTree]:
    """One side of a pairwise averaging event on edge (i, j).

    m = x_i - x_j;  x_i -= alpha*m ; x_tilde_i -= alpha_tilde*m.
    The j side is obtained by calling with roles swapped (m flips sign).
    With alpha = 1/2 the x-update is exact pairwise averaging.
    """
    def upd(a, at, b):
        m = a - b
        return a - params.alpha * m, at - params.alpha_tilde * m

    flat_i, treedef = jax.tree_util.tree_flatten(x_i)
    flat_ti = treedef.flatten_up_to(x_tilde_i)
    flat_j = treedef.flatten_up_to(x_j)
    out = [upd(a, at, b) for a, at, b in zip(flat_i, flat_ti, flat_j)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def matched_p2p_update(x: PyTree, x_tilde: PyTree, partner: jax.Array,
                       params: A2CiD2Params) -> tuple[PyTree, PyTree]:
    """Apply one matching round to stacked worker states.

    Leaves of ``x``/``x_tilde`` have a leading worker axis (n, ...).
    ``partner[i] = j`` (with partner[j] = i) for matched pairs, ``i`` for idle
    workers — idle workers see m = x_i - x_i = 0, a clean no-op.
    """
    def upd(a, at):
        b = jnp.take(a, partner, axis=0)
        m = a - b
        return a - params.alpha * m, at - params.alpha_tilde * m

    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    out = [upd(a, at) for a, at in zip(flat_x, flat_t)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


# ---------------------------------------------------------------- diagnostics

def consensus_distance(x: PyTree) -> jax.Array:
    """||pi x||_F^2 / n = mean squared distance of workers to the mean.

    Leaves have a leading worker axis. This is the quantity tracked in the
    paper's Fig 5b.
    """
    def per_leaf(a):
        mean = jnp.mean(a, axis=0, keepdims=True)
        return jnp.sum((a - mean) ** 2) / a.shape[0]

    leaves = jax.tree.leaves(x)
    return sum(per_leaf(a) for a in leaves)


def worker_mean(x: PyTree) -> PyTree:
    return jax.tree.map(lambda a: jnp.mean(a, axis=0), x)
