"""Unreliable-channel subsystem: stale reads, Byzantine edges, drops
(DESIGN.md §10).

The paper's asynchronous p2p averaging assumes honest, instantaneous
pairwise exchanges.  Production regimes are exactly the opposite: messages
arrive late (AD-PSGD-style overlap makes stale partner reads the common
case, not the exception), links silently lose packets, and a subset of
edges may be adversarial.  A :class:`ChannelModel` is the declarative,
serializable description of one such channel:

    ChannelModel(delay=DelayProcess(horizon=4, prob=0.5),
                 adversary=ByzantineEdges(((0, 1), (5, 6)), "sign_flip"),
                 drop_prob=0.02)

It plugs into ``World(..., channel=...)`` and compiles — through the
generic ``Schedule.extras`` machinery (PR 3) — to per-event arrays the
replay engines consume without any new scan branch:

  * ``extras["stale"]``  (R, K, n) int32 — staleness offset of worker i's
    READ at event (r, k): 0 = fresh (the partner's current value), s >= 1 =
    the partner's flat state snapshotted at the end of round ``r - s``.
    The engines maintain a ring buffer of the last ``H`` flat states
    (rotated at each gradient tick) to serve these reads.
  * ``extras["corrupt"]`` (R, K, n) float32 — multiplier OFFSET applied to
    the received partner value: the engine reads ``(1 + corrupt) * x_p``,
    so the zero-filled padding that concat/coalesce/stream produce means
    "honest" (multiplier 1).  ``sign_flip`` is offset -2, ``zero`` is -1,
    ``scale`` is ``scale - 1``.
  * message drops rewrite the partner involution itself (the dropped pair
    reverts to identity partners), so a drop needs no engine support at
    all — both replay paths already treat identity rows as no-ops.

All channel randomness comes from a dedicated rng stream
(``SeedSequence([seed, _CHANNEL_TAG, substream])``), independent of the
schedule's main stream and of the straggler/churn streams — a trivial
channel (``horizon=0``, no adversary, ``drop_prob=0``) therefore leaves a
compiled schedule bit-for-bit identical to the channel-free world.

The *defense* against a hostile channel — the clipped/trimmed p2p delta in
the fused kernel's m-term — is a replay knob (``Simulator(robust_clip=...)``
/ ``FlatGossipEngine(robust_clip=...)``), not channel data: the same world
replays with and without robust aggregation so benchmarks can show what
the defense buys (``benchmarks/run.py --only channel``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# rng-stream tag for channel draws — independent of the schedule's main
# stream and of the straggler (0x48455) / churn (0xC50C4) streams
_CHANNEL_TAG = 0xC4A77
# canonical Schedule.extras keys the channel compiles to (reserved by
# ROADMAP since PR 3; both replay paths key on exactly these names)
STALE_KEY = "stale"
CORRUPT_KEY = "corrupt"
# telemetry marker (DESIGN.md §15): a drop rewrites the partner involution
# to identity, which is indistinguishable from "never scheduled" in the
# surviving arrays — this extras key records WHERE the erasures happened so
# the flight recorder can report dropped-read counts.  Host-only data: the
# replay engines never lower it into scan inputs (dispatch and
# ``_channel_extras`` key on stale/corrupt alone), so attaching it leaves
# every compiled replay bit-for-bit unchanged.
DROP_KEY = "drop"

# corrupt-value multipliers per adversary mode: the receiver sees
# multiplier * x_partner instead of x_partner
_MODE_MULTIPLIER = {"sign_flip": -1.0, "zero": 0.0}


@dataclasses.dataclass(frozen=True)
class DelayProcess:
    """Per-read message staleness.

    Each directed read (worker i receiving from its matched partner j) is
    independently stale with probability ``prob``; a stale read returns the
    partner's flat state snapshotted ``s`` rounds ago, with ``s`` drawn
    from ``kind``:

      * ``"uniform"`` — s ~ Uniform{1, ..., horizon}
      * ``"fixed"``   — s = horizon

    Offsets are clamped to the rounds actually elapsed (round r can look
    back at most r snapshots), so the ring buffer is never read before it
    is written.  ``horizon=0`` disables delay entirely — the exact
    reduction every channel axis must honor.
    """

    horizon: int
    prob: float = 1.0
    kind: str = "uniform"

    def __post_init__(self):
        if not isinstance(self.horizon, (int, np.integer)) \
                or isinstance(self.horizon, bool) or self.horizon < 0:
            raise ValueError("DelayProcess.horizon must be an int >= 0, "
                             f"got {self.horizon!r}")
        object.__setattr__(self, "horizon", int(self.horizon))
        if not (np.isfinite(self.prob) and 0.0 <= self.prob <= 1.0):
            raise ValueError(f"DelayProcess.prob must lie in [0, 1], "
                             f"got {self.prob}")
        if self.kind not in ("uniform", "fixed"):
            raise ValueError("DelayProcess.kind must be 'uniform' or "
                             f"'fixed', got {self.kind!r}")

    @property
    def is_trivial(self) -> bool:
        return self.horizon == 0 or self.prob == 0.0

    def sample_offsets(self, shape, rng: np.random.Generator) -> np.ndarray:
        """Raw (unclamped) staleness draws; 0 where the read is fresh."""
        hit = rng.uniform(size=shape) < self.prob
        if self.kind == "fixed":
            offs = np.full(shape, self.horizon, np.int32)
        else:
            offs = rng.integers(1, self.horizon + 1, size=shape,
                                dtype=np.int32)
        return np.where(hit, offs, 0).astype(np.int32)

    def to_dict(self) -> dict:
        return {"horizon": self.horizon, "prob": self.prob,
                "kind": self.kind}

    @staticmethod
    def from_dict(d: dict) -> "DelayProcess":
        return DelayProcess(horizon=d["horizon"], prob=d.get("prob", 1.0),
                            kind=d.get("kind", "uniform"))


@dataclasses.dataclass(frozen=True)
class ByzantineEdges:
    """Adversarial partners on a fixed subset of edges.

    A message crossing a listed edge is corrupted — with duty cycle
    ``prob`` per exchange (both directions of the exchange together: the
    fault sits on the link) — before the receiver applies its p2p update:

      * ``"sign_flip"`` — the receiver sees ``-x_partner``
      * ``"zero"``      — the receiver sees ``0`` (null-message attack)
      * ``"scale"``     — the receiver sees ``scale * x_partner`` (large
        scales model garbage injection; the norm-trim robust rule rejects
        exactly these)

    ``prob < 1`` models an intermittent fault (flaky NIC, duty-cycled
    adversary evading detection): the honest fraction of exchanges keeps
    the edge — and hence the topology — alive under a trimming defense.
    The honest workers incident to a Byzantine edge still transmit their
    true state on their OTHER edges — corruption is a property of the
    edge, not the worker (the robust-aggregation threat model), so the
    robust m-term trim/clip can contain the damage locally.
    """

    edges: tuple[tuple[int, int], ...]
    mode: str = "sign_flip"
    scale: float = 1.0
    prob: float = 1.0

    def __post_init__(self):
        try:
            edges = tuple((int(i), int(j)) for i, j in self.edges)
        except (TypeError, ValueError):
            raise ValueError("ByzantineEdges.edges must be (i, j) pairs, "
                             f"got {self.edges!r}") from None
        if not edges:
            raise ValueError("ByzantineEdges.edges must be non-empty — an "
                             "edgeless adversary is ChannelModel(adversary="
                             "None)")
        for (i, j) in edges:
            if i == j or i < 0 or j < 0:
                raise ValueError("ByzantineEdges.edges entries must pair two "
                                 f"distinct workers, got ({i}, {j})")
        object.__setattr__(
            self, "edges", tuple((min(i, j), max(i, j)) for i, j in edges))
        if self.mode not in ("sign_flip", "zero", "scale"):
            raise ValueError("ByzantineEdges.mode must be 'sign_flip', "
                             f"'zero', or 'scale', got {self.mode!r}")
        if not np.isfinite(self.scale):
            raise ValueError(f"ByzantineEdges.scale must be finite, "
                             f"got {self.scale}")
        if not (np.isfinite(self.prob) and 0.0 < self.prob <= 1.0):
            raise ValueError(f"ByzantineEdges.prob must lie in (0, 1], "
                             f"got {self.prob}")

    def multiplier(self) -> float:
        """The received-value multiplier this mode applies."""
        return _MODE_MULTIPLIER.get(self.mode, self.scale)

    def corrupt_offset(self) -> float:
        """Multiplier offset stored in ``extras["corrupt"]`` (honest = 0)."""
        return self.multiplier() - 1.0

    def edge_set(self) -> frozenset:
        return frozenset(self.edges)

    def lookup(self, n: int) -> np.ndarray:
        """(n, n) bool adjacency of the Byzantine edge set."""
        out = np.zeros((n, n), dtype=bool)
        for (i, j) in self.edges:
            if j >= n:
                raise ValueError(f"ByzantineEdges edge ({i}, {j}) names a "
                                 f"worker outside [0, {n})")
            out[i, j] = out[j, i] = True
        return out

    def to_dict(self) -> dict:
        return {"edges": [list(e) for e in self.edges], "mode": self.mode,
                "scale": self.scale, "prob": self.prob}

    @staticmethod
    def from_dict(d: dict) -> "ByzantineEdges":
        return ByzantineEdges(edges=tuple((int(i), int(j))
                                          for i, j in d["edges"]),
                              mode=d.get("mode", "sign_flip"),
                              scale=d.get("scale", 1.0),
                              prob=d.get("prob", 1.0))


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Declarative unreliable-channel model: delay + adversary + drops.

    ``apply(schedule, seed)`` compiles the channel onto an already-sampled
    event schedule (drops rewrite partner pairs; delay/adversary attach
    the ``stale``/``corrupt`` extras arrays).  A trivial channel returns
    the schedule object unchanged — the exact-reduction contract.
    """

    delay: DelayProcess | None = None
    adversary: ByzantineEdges | None = None
    drop_prob: float = 0.0

    def __post_init__(self):
        if self.delay is not None and not isinstance(self.delay,
                                                     DelayProcess):
            raise ValueError("channel.delay must be a DelayProcess, "
                             f"got {type(self.delay).__name__}")
        if self.adversary is not None and not isinstance(self.adversary,
                                                         ByzantineEdges):
            raise ValueError("channel.adversary must be ByzantineEdges, "
                             f"got {type(self.adversary).__name__}")
        if not (np.isfinite(self.drop_prob)
                and 0.0 <= self.drop_prob < 1.0):
            raise ValueError(f"channel.drop_prob must lie in [0, 1), "
                             f"got {self.drop_prob}")

    @property
    def is_trivial(self) -> bool:
        return ((self.delay is None or self.delay.is_trivial)
                and self.adversary is None and self.drop_prob == 0.0)

    @property
    def horizon(self) -> int:
        """Ring-buffer depth the replay needs for this channel."""
        if self.delay is None or self.delay.is_trivial:
            return 0
        return self.delay.horizon

    def validate_for(self, n: int, edge_sets=()) -> None:
        """Check adversary edges against a world: worker ids in [0, n) and,
        when candidate edge sets are known, membership in at least one."""
        if self.adversary is None:
            return
        self.adversary.lookup(n)  # id range check
        sets = [s for s in edge_sets if s]
        if sets:
            known = frozenset().union(*sets)
            missing = sorted(e for e in self.adversary.edges
                             if e not in known)
            if missing:
                raise ValueError(
                    f"channel.adversary edges {missing} are not edges of "
                    "this world's topology (an adversary needs a link to "
                    "corrupt)")

    # --------------------------------------------------------------- compile
    def apply(self, schedule, seed: int = 0):
        """Compile the channel onto one ``events.Schedule``.

        Host-side numpy, like every schedule stage: drops first (a dropped
        message produces neither a stale read nor a corruption), then the
        ``stale``/``corrupt`` extras over the surviving pairs.  Draws come
        from per-axis substreams of the channel's own rng stream, so each
        axis is reproducible independently of the others.
        """
        if self.is_trivial:
            return schedule
        partners = schedule.partners
        R, K, n = partners.shape
        idx = np.arange(n)

        def pair_anchor(p):
            """Each pair keyed once, at its smaller endpoint: True at
            (r, k, i) iff p[r, k, i] = j with j > i on an unmasked event.
            Per-pair draws (drops, duty cycles) index a full (R, K, n)
            uniform array through this mask — vectorized, and both
            endpoints share one draw by construction."""
            return (p > idx) & schedule.event_mask[:, :, None]

        extras = {}
        if self.drop_prob > 0.0:
            rng = np.random.default_rng(
                np.random.SeedSequence([int(seed), _CHANNEL_TAG, 0]))
            partners = partners.copy()
            u = rng.uniform(size=(R, K, n))
            rr, kk, ii = np.nonzero(pair_anchor(partners)
                                    & (u < self.drop_prob))
            jj = partners[rr, kk, ii]
            partners[rr, kk, ii] = ii
            partners[rr, kk, jj.astype(np.intp)] = jj
            # telemetry marker at BOTH erased endpoints (see DROP_KEY)
            dropped = np.zeros((R, K, n), np.int32)
            dropped[rr, kk, ii] = 1
            dropped[rr, kk, jj.astype(np.intp)] = 1
            extras[DROP_KEY] = dropped

        involved = (partners != idx) & schedule.event_mask[:, :, None]
        if self.delay is not None and not self.delay.is_trivial:
            rng = np.random.default_rng(
                np.random.SeedSequence([int(seed), _CHANNEL_TAG, 1]))
            offs = self.delay.sample_offsets((R, K, n), rng)
            # round r has only r past snapshots; the ring holds horizon
            cap = np.minimum(np.arange(R), self.delay.horizon)
            offs = np.minimum(offs, cap[:, None, None])
            extras[STALE_KEY] = np.where(involved, offs, 0).astype(np.int32)
        if self.adversary is not None:
            byz = self.adversary.lookup(n)
            hit = involved & byz[np.broadcast_to(idx, (R, K, n)), partners]
            if self.adversary.prob < 1.0:
                # intermittent fault: one duty-cycle draw per EXCHANGE (the
                # fault sits on the link, so both directions share it)
                rng = np.random.default_rng(
                    np.random.SeedSequence([int(seed), _CHANNEL_TAG, 2]))
                u = rng.uniform(size=(R, K, n))
                rr, kk, ii = np.nonzero(hit & pair_anchor(partners)
                                        & (u >= self.adversary.prob))
                jj = partners[rr, kk, ii]
                hit[rr, kk, ii] = False
                hit[rr, kk, jj.astype(np.intp)] = False
            extras[CORRUPT_KEY] = np.where(
                hit, np.float32(self.adversary.corrupt_offset()),
                np.float32(0.0))

        out = schedule
        if partners is not schedule.partners:
            out = dataclasses.replace(out, partners=partners)
        return out.with_extras(**extras) if extras else out

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"delay": None if self.delay is None else self.delay.to_dict(),
                "adversary": None if self.adversary is None
                else self.adversary.to_dict(),
                "drop_prob": self.drop_prob}

    @staticmethod
    def from_dict(d: dict) -> "ChannelModel":
        delay = d.get("delay")
        adversary = d.get("adversary")
        return ChannelModel(
            delay=None if delay is None else DelayProcess.from_dict(delay),
            adversary=None if adversary is None
            else ByzantineEdges.from_dict(adversary),
            drop_prob=d.get("drop_prob", 0.0))


def has_channel_extras(schedule) -> bool:
    """True iff a schedule (or coalesced schedule / event stream) carries
    channel extras the replay engines must honor."""
    extras = schedule.extras or {}
    return STALE_KEY in extras or CORRUPT_KEY in extras


def degradation_profile(schedule) -> np.ndarray:
    """(R,) per-round channel-degradation score: the fraction of involved
    partner reads that are degraded — stale (served from the snapshot
    ring) or corrupted (a Byzantine multiplier on the received value).
    Rounds with no involved reads (or no channel extras at all) score 0.
    The defense's host-side comm controller derates its keep-fraction by
    this profile (``AdaptiveDefense.comm_degrade``)."""
    R, K, n = schedule.partners.shape
    idx = np.arange(n)
    involved = (schedule.partners != idx) & schedule.event_mask[:, :, None]
    extras = schedule.extras_dict()
    bad = np.zeros((R, K, n), bool)
    stale = extras.get(STALE_KEY)
    if stale is not None:
        bad |= np.asarray(stale) > 0
    corrupt = extras.get(CORRUPT_KEY)
    if corrupt is not None:
        bad |= np.asarray(corrupt) != 0
    num = (bad & involved).reshape(R, -1).sum(axis=1)
    den = np.maximum(involved.reshape(R, -1).sum(axis=1), 1)
    return (num / den).astype(np.float32)
