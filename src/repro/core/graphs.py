"""Communication graphs for decentralized gossip (paper Sec 3.1, App E.1).

The paper models the network as a set of edges ``E`` with per-edge Poisson
communication rates ``lambda_ij``.  The *instantaneous expected Laplacian*

    Lambda = sum_{(i,j) in E} lambda_ij (e_i - e_j)(e_i - e_j)^T          (Def 3.1)

defines the two quantities controlling convergence:

    chi_1 = sup_{||x||=1, x ⟂ 1} 1 / (x^T Lambda x)        (Eq 2, = 1/lambda_2)
    chi_2 = 1/2 max_{(i,j) in E} (e_i-e_j)^T Lambda^+ (e_i-e_j)   (Eq 3)

with chi_2 <= chi_1.  A2CiD2 accelerates the communication complexity from
chi_1 to sqrt(chi_1 * chi_2).

Everything here is plain numpy (host-side graph bookkeeping) — the training
step only consumes small static artifacts (edge list, matchings, chi values).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

Edge = tuple[int, int]


@dataclasses.dataclass(frozen=True)
class Graph:
    """A communication topology with per-edge expected rates."""

    n: int
    edges: tuple[Edge, ...]
    # expected number of averaging events per unit time on each edge
    rates: tuple[float, ...]
    name: str = "custom"

    def __post_init__(self):
        for (i, j) in self.edges:
            if not (0 <= i < self.n and 0 <= j < self.n and i != j):
                raise ValueError(f"invalid edge ({i},{j}) for n={self.n}")
        if len(self.rates) != len(self.edges):
            raise ValueError("rates must align with edges")
        seen = set()
        for (i, j) in self.edges:
            key = (min(i, j), max(i, j))
            if key in seen:
                raise ValueError(f"duplicate edge {key}")
            seen.add(key)

    # ---------------------------------------------------------------- basic
    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def neighbors(self, i: int) -> list[int]:
        out = []
        for (a, b) in self.edges:
            if a == i:
                out.append(b)
            elif b == i:
                out.append(a)
        return sorted(out)

    def adjacency(self) -> np.ndarray:
        A = np.zeros((self.n, self.n))
        for (i, j), r in zip(self.edges, self.rates):
            A[i, j] += r
            A[j, i] += r
        return A

    # ------------------------------------------------------------ laplacian
    def laplacian(self) -> np.ndarray:
        """Instantaneous expected Laplacian (Def 3.1)."""
        L = np.zeros((self.n, self.n))
        for (i, j), r in zip(self.edges, self.rates):
            L[i, i] += r
            L[j, j] += r
            L[i, j] -= r
            L[j, i] -= r
        return L

    def total_rate(self) -> float:
        """Expected #p2p communications per unit time = Tr(Lambda)/2 (Prop 3.6)."""
        return float(np.trace(self.laplacian())) / 2.0

    def chi1(self) -> float:
        """Algebraic-connectivity term (Eq 2): 1 / (second-smallest eigenvalue)."""
        lam = np.linalg.eigvalsh(self.laplacian())
        lam2 = lam[1]  # smallest is ~0 (connected graph)
        if lam2 <= 1e-12:
            return float("inf")
        return float(1.0 / lam2)

    def chi2(self) -> float:
        """Max effective-resistance term (Eq 3)."""
        Lp = np.linalg.pinv(self.laplacian())
        best = 0.0
        for (i, j) in self.edges:
            e = np.zeros(self.n)
            e[i], e[j] = 1.0, -1.0
            best = max(best, float(e @ Lp @ e))
        return 0.5 * best

    def is_connected(self) -> bool:
        lam = np.linalg.eigvalsh(self.laplacian())
        return bool(lam[1] > 1e-9)

    # ------------------------------------------------------------ matchings
    def edge_index(self) -> dict[Edge, int]:
        return {(min(i, j), max(i, j)): k for k, ((i, j)) in enumerate(self.edges)}

    def sample_matching(self, rng: np.random.Generator) -> list[Edge]:
        """Sample a maximal matching by scanning edges in random order.

        This emulates the paper's FIFO availability-queue pairing: every worker
        participates in at most one simultaneous p2p averaging, and edges are
        picked uniformly (App E.2 verifies uniformity holds in their runs).
        Edges with higher rate are proportionally more likely to be scanned
        first (weighted order), matching the expected Laplacian.
        """
        if not self.edges:  # e.g. a fully-churned phase
            return []
        order = rng.permutation(self.num_edges)
        w = np.asarray(self.rates, dtype=np.float64)
        if not np.allclose(w, w[0]):
            # weighted random order: Gumbel trick on log-rates
            keys = np.log(w) + rng.gumbel(size=self.num_edges)
            order = np.argsort(-keys)
        used = np.zeros(self.n, dtype=bool)
        matching: list[Edge] = []
        for k in order:
            i, j = self.edges[int(k)]
            if not (used[i] or used[j]):
                used[i] = used[j] = True
                matching.append((i, j))
        return matching

    def matching_to_partner(self, matching: Sequence[Edge]) -> np.ndarray:
        """partner[i] = j if (i,j) matched else i (self-loop = idle)."""
        p = np.arange(self.n)
        for (i, j) in matching:
            p[i], p[j] = j, i
        return p

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        """JSON-ready description (the World spec embeds this; see
        ``world.World.to_json``)."""
        return {"n": self.n, "edges": [list(e) for e in self.edges],
                "rates": list(self.rates), "name": self.name}

    @staticmethod
    def from_dict(d: dict) -> "Graph":
        return Graph(int(d["n"]),
                     tuple((int(i), int(j)) for i, j in d["edges"]),
                     tuple(float(r) for r in d["rates"]),
                     name=d.get("name", "custom"))

    # ---------------------------------------------------------- derivations
    def with_rates(self, rates) -> "Graph":
        """Same topology with per-edge rates replaced (heterogeneous worlds:
        hot links, degraded links).  Rates align with ``self.edges``."""
        rates = tuple(float(r) for r in np.asarray(rates, dtype=np.float64))
        return Graph(self.n, self.edges, rates, name=self.name)

    def subgraph(self, active, relabel: bool = False) -> "Graph":
        """Induced subgraph on the ``active`` worker mask.

        relabel=False keeps all n worker slots (detached workers become
        isolated nodes — partner arrays stay n-wide, the scenario-engine
        form); relabel=True compacts to the active workers only (the form
        on which chi1/chi2 of a churned phase are well defined).
        """
        active = np.asarray(active, dtype=bool)
        if active.shape != (self.n,):
            raise ValueError(f"active mask must be ({self.n},)")
        keep = [(e, r) for e, r in zip(self.edges, self.rates)
                if active[e[0]] and active[e[1]]]
        edges = tuple(e for e, _ in keep)
        rates = tuple(r for _, r in keep)
        if not relabel:
            return Graph(self.n, edges, rates, name=f"{self.name}|churn")
        idx = np.cumsum(active) - 1  # old -> new labels
        edges = tuple((int(idx[i]), int(idx[j])) for (i, j) in edges)
        return Graph(int(active.sum()), edges, rates,
                     name=f"{self.name}|churn")


# ------------------------------------------------------------------ builders

def complete_graph(n: int, rate_per_worker: float = 1.0) -> Graph:
    """Complete graph; each worker communicates `rate_per_worker` times per unit
    time in expectation => each edge has rate rate_per_worker / (n-1)."""
    edges = tuple((i, j) for i in range(n) for j in range(i + 1, n))
    r = rate_per_worker / (n - 1)
    return Graph(n, edges, tuple(r for _ in edges), name="complete")


def ring_graph(n: int, rate_per_worker: float = 1.0) -> Graph:
    """Cycle graph; each worker has 2 neighbors => edge rate = rate/2."""
    edges = tuple((i, (i + 1) % n) for i in range(n)) if n > 2 else ((0, 1),)
    r = rate_per_worker / 2.0 if n > 2 else rate_per_worker
    return Graph(n, tuple((min(i, j), max(i, j)) for (i, j) in edges),
                 tuple(r for _ in edges), name="ring")


def exponential_graph(n: int, rate_per_worker: float = 1.0) -> Graph:
    """Exponential graph of [28, 2]: i connects to i +/- 2^k mod n."""
    edges = set()
    k = 0
    while (1 << k) < n:
        for i in range(n):
            j = (i + (1 << k)) % n
            if i != j:
                edges.add((min(i, j), max(i, j)))
        k += 1
    edges = tuple(sorted(edges))
    deg = np.zeros(n)
    for (i, j) in edges:
        deg[i] += 1
        deg[j] += 1
    # uniform edge rate chosen so the *average* worker rate matches
    r = rate_per_worker * n / (2 * len(edges))
    return Graph(n, edges, tuple(r for _ in edges), name="exponential")


def star_graph(n: int, rate_per_worker: float = 1.0) -> Graph:
    edges = tuple((0, i) for i in range(1, n))
    # center participates in every event; normalize so mean worker rate matches
    r = rate_per_worker * n / (2 * len(edges))
    return Graph(n, edges, tuple(r for _ in edges), name="star")


def torus_graph(side: int, rate_per_worker: float = 1.0) -> Graph:
    """2D torus (side x side) — the natural TPU-ICI-like topology (beyond paper)."""
    n = side * side
    edges = set()
    for r_ in range(side):
        for c in range(side):
            i = r_ * side + c
            for (dr, dc) in ((0, 1), (1, 0)):
                j = ((r_ + dr) % side) * side + (c + dc) % side
                if i != j:
                    edges.add((min(i, j), max(i, j)))
    edges = tuple(sorted(edges))
    r = rate_per_worker * n / (2 * len(edges))
    return Graph(n, edges, tuple(r for _ in edges), name="torus")


def hypercube_graph(dim: int, rate_per_worker: float = 1.0) -> Graph:
    """d-dimensional hypercube on n = 2^d workers (paper's well-connected
    family at n=64 alongside ring/torus); each worker has ``dim`` neighbors
    => edge rate = rate/dim."""
    n = 1 << dim
    edges = tuple(sorted((i, i ^ (1 << k)) for i in range(n)
                         for k in range(dim) if i < i ^ (1 << k)))
    r = rate_per_worker / dim
    return Graph(n, edges, tuple(r for _ in edges), name="hypercube")


_BUILDERS = {
    "complete": complete_graph,
    "ring": ring_graph,
    "exponential": exponential_graph,
    "star": star_graph,
}


def build_graph(name: str, n: int, rate_per_worker: float = 1.0) -> Graph:
    if name == "torus":
        side = int(round(n ** 0.5))
        if side * side != n:
            raise ValueError("torus needs a square worker count")
        return torus_graph(side, rate_per_worker)
    if name == "hypercube":
        dim = int(round(np.log2(n)))
        if (1 << dim) != n:
            raise ValueError("hypercube needs a power-of-two worker count")
        return hypercube_graph(dim, rate_per_worker)
    if name not in _BUILDERS:
        raise ValueError(f"unknown graph '{name}', have {sorted(_BUILDERS)}"
                         " + torus + hypercube")
    return _BUILDERS[name](n, rate_per_worker)


# -------------------------------------------------------- topology schedules

@dataclasses.dataclass(frozen=True)
class TopologyPhase:
    """One phase of a time-varying topology: a graph held for ``rounds``
    units of simulated time, with an optional churn mask detaching workers.

    ``active[i] = False`` detaches worker i for the whole phase: it joins no
    matchings, takes no gradient ticks, and its event clock freezes (the
    lazy-mixing ODE integrates over the full outage at its first event after
    rejoin — see DESIGN.md §8)."""

    graph: Graph
    rounds: int
    active: tuple[bool, ...] | None = None

    def __post_init__(self):
        if self.rounds <= 0:
            raise ValueError("phase needs rounds >= 1")
        if self.active is not None and len(self.active) != self.graph.n:
            raise ValueError("active mask must have one entry per worker")

    def active_mask(self) -> np.ndarray:
        if self.active is None:
            return np.ones(self.graph.n, dtype=bool)
        return np.asarray(self.active, dtype=bool)

    def effective_graph(self) -> Graph:
        """The phase's communication graph with churned workers isolated
        (n-wide — what scheduling/matching banks consume)."""
        m = self.active_mask()
        return self.graph if m.all() else self.graph.subgraph(m)

    def chis(self) -> tuple[float, float]:
        """(chi1, chi2) of the phase, computed on the active workers only
        (isolated churned nodes would make the full-n chi1 infinite)."""
        g = self.graph.subgraph(self.active_mask(), relabel=True)
        return g.chi1(), g.chi2()

    def to_dict(self) -> dict:
        # bool() strips np.bool_ entries (tuple(np_mask) keeps them), which
        # the json encoder rejects
        return {"graph": self.graph.to_dict(), "rounds": int(self.rounds),
                "active": None if self.active is None
                else [bool(b) for b in self.active]}

    @staticmethod
    def from_dict(d: dict) -> "TopologyPhase":
        active = d.get("active")
        return TopologyPhase(Graph.from_dict(d["graph"]), int(d["rounds"]),
                             None if active is None
                             else tuple(bool(b) for b in active))


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A sequence of topology phases — ring->exponential switches, churn
    windows, degraded-link episodes.  ``events.make_topology_schedule``
    compiles it (plus rate heterogeneity) into one concatenated event
    schedule that both simulator replay paths consume unchanged."""

    phases: tuple[TopologyPhase, ...]

    def __post_init__(self):
        if not self.phases:
            raise ValueError("need at least one phase")
        ns = {p.graph.n for p in self.phases}
        if len(ns) != 1:
            raise ValueError(f"all phases must share one worker count, got {ns}")

    @property
    def n(self) -> int:
        return self.phases[0].graph.n

    @property
    def total_rounds(self) -> int:
        return sum(p.rounds for p in self.phases)

    def phase_starts(self) -> np.ndarray:
        """Start round of each phase (cumulative durations, leading 0)."""
        return np.concatenate(
            [[0], np.cumsum([p.rounds for p in self.phases])[:-1]]).astype(int)

    def phase_at(self, rnd: int) -> int:
        """Index of the phase covering simulated round ``rnd``."""
        if not (0 <= rnd < self.total_rounds):
            raise ValueError(f"round {rnd} outside [0, {self.total_rounds})")
        return int(np.searchsorted(self.phase_starts(), rnd, side="right") - 1)

    def phase_chis(self) -> list[tuple[float, float]]:
        return [p.chis() for p in self.phases]

    def to_dict(self) -> dict:
        return {"phases": [p.to_dict() for p in self.phases]}

    @staticmethod
    def from_dict(d: dict) -> "TopologySchedule":
        return TopologySchedule(tuple(TopologyPhase.from_dict(p)
                                      for p in d["phases"]))
