"""Flat-buffer fused gossip-event engine — the one hot path all trainers
share (see DESIGN.md).

The engine owns three ingredients:

  1. a :class:`~repro.core.flatbuf.FlatLayout` packing the replica pytree
     into one contiguous buffer (stacked ``(W, D)`` or local ``(D,)``),
  2. the fused p2p-then-mix kernels from ``repro.kernels.a2cid2_mixing``
     (Pallas on TPU, jnp oracle on CPU),
  3. the *group* pass structure: the exact per-event sequence

         mix(d_0), S_0, mix(d_1), S_1, ..., S_{K-1}, mix(d_K)

     (S_i a fused comm batch or a gradient tick) regrouped as
     ``[mix(d_0)] [S_0, mix(d_1)] ... [S_{K-1}, mix(d_K)]`` — identical
     composition (the mixing flow is a semigroup and zero-dt segments are
     identities), but each bracketed group is ONE fused sweep reading 3
     state-sized buffers and writing 2.  events.coalesced_stream flattens a
     schedule into exactly these groups with every mixing segment
     precomputed host-side; masked schedule slots vanish entirely.

Traffic per coalesced batch: 3 reads + 2 writes of state, vs the per-event
path's 6 reads + 4 writes per event (2 unfused sweeps) — and the per-event
path also sweeps masked slots, which the coalesced stream drops entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.a2cid2_mixing.ops import (channel_event_local,
                                         channel_event_stacked,
                                         channel_event_worlds,
                                         gossip_event_stacked,
                                         gossip_event_worlds, p2p_mix_event)
from .a2cid2 import A2CiD2Params, apply_mixing
from .flatbuf import (FlatLayout, ring_init, ring_init_worlds, ring_push,
                      ring_push_worlds, ring_read, ring_read_worlds)

PyTree = Any


def mix_flat(bx: jax.Array, bxt: jax.Array, eta: float, dt: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Pure mixing pass on flat buffers; dt broadcasts ((W,) against (W, D)
    after the trailing-axis insert, or scalar against (D,)).  A flat buffer
    is a single-leaf pytree, so this is exactly ``a2cid2.apply_mixing``."""
    return apply_mixing(bx, bxt, eta, dt)


def mix_worlds(bx: jax.Array, bxt: jax.Array, eta: jax.Array,
               dt: jax.Array) -> tuple[jax.Array, jax.Array]:
    """World-batched mixing pass: (B, W, D) buffers, (B,) per-world eta,
    (B, W) dt.  The dynamic-eta twin of ``mix_flat`` — it cannot take the
    eta == 0 shortcut (eta is traced), so baseline worlds compute
    ``a + 0 * d`` explicitly; with d finite this is exact up to the sign
    of zero, the same contract as the fused kernels' mixing tail."""
    eta32 = jnp.asarray(eta, jnp.float32)[:, None]
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta32
                              * jnp.asarray(dt, jnp.float32)))
         ).astype(bx.dtype)[:, :, None]
    d = bxt - bx
    return bx + c * d, bxt - c * d


@dataclasses.dataclass(frozen=True)
class FlatGossipEngine:
    """Fused event engine bound to a layout, A2CiD2 params, and a backend.

    backend: 'auto' (Pallas on TPU, oracle elsewhere), 'ref',
    'pallas_interpret' (tests), or 'pallas'.

    robust_clip + robust_rule engage robust aggregation on the channel
    passes (DESIGN.md §10) — the defense knob against Byzantine partners.
    None = plain m-term.  Rules (tau = robust_clip):

      'trim'  — reject the whole delta when ||m||_2 > tau (m -> 0): the
                garbage-rejection defense; corrupted events become no-ops
                while honest deltas pass untouched.
      'clip'  — rescale to m * min(1, tau / ||m||_2) (ClippedGossip-style
                norm clipping).
      'coord' — clip each coordinate to [-tau, +tau] inside the kernel.

    The norm rules cost one extra fused reduce over (x, xp) to derive the
    per-worker scale; the kernel itself stays 3 reads + 2 writes.
    """

    layout: FlatLayout
    params: A2CiD2Params
    backend: str = "auto"
    robust_clip: float | None = None
    robust_rule: str = "trim"

    def __post_init__(self):
        if self.robust_rule not in ("trim", "clip", "coord"):
            raise ValueError("robust_rule must be 'trim', 'clip', or "
                             f"'coord', got {self.robust_rule!r}")

    @classmethod
    def for_pytree(cls, tree: PyTree, params: A2CiD2Params, *,
                   stacked: bool = True, worlds: bool = False,
                   backend: str = "auto",
                   robust_clip: float | None = None,
                   robust_rule: str = "trim") -> "FlatGossipEngine":
        return cls(FlatLayout.from_pytree(tree, stacked=stacked,
                                          worlds=worlds),
                   params, backend, robust_clip, robust_rule)

    # ------------------------------------------------------------- plumbing
    def pack(self, tree: PyTree) -> jax.Array:
        return self.layout.pack(tree)

    def unpack(self, buf: jax.Array) -> PyTree:
        return self.layout.unpack(buf)

    def pack_local(self, tree: PyTree) -> jax.Array:
        return self.layout.pack_local(tree)

    def unpack_local(self, vec: jax.Array) -> PyTree:
        return self.layout.unpack_local(vec)

    def pack_worlds(self, tree: PyTree) -> jax.Array:
        return self.layout.pack_worlds(tree)

    def unpack_worlds(self, buf: jax.Array) -> PyTree:
        return self.layout.unpack_worlds(buf)

    # -------------------------------------------------------------- passes
    def mix(self, bx: jax.Array, bxt: jax.Array, dt) -> tuple[jax.Array,
                                                              jax.Array]:
        """Standalone mixing sweep (engine prologue; 2 reads + 2 writes)."""
        return mix_flat(bx, bxt, self.params.eta, dt)

    def batch(self, bx: jax.Array, bxt: jax.Array, partner: jax.Array,
              dt_next: jax.Array) -> tuple[jax.Array, jax.Array]:
        """One fused group [p2p(partner), mix(dt_next)] on (W, D) buffers."""
        p = self.params
        return gossip_event_stacked(bx, bxt, partner, dt_next, eta=p.eta,
                                    alpha=p.alpha, alpha_t=p.alpha_tilde,
                                    backend=self.backend)

    def batch_local(self, bx: jax.Array, bxt: jax.Array, xp: jax.Array,
                    dt_next) -> tuple[jax.Array, jax.Array]:
        """One fused group on per-worker (D,) vectors (SPMD path); ``xp`` is
        the partner's current flat x (e.g. from a collective permute)."""
        p = self.params
        return p2p_mix_event(bx, bxt, xp, dt_next, eta=p.eta, alpha=p.alpha,
                             alpha_t=p.alpha_tilde, backend=self.backend)

    # ---------------------------------------------- world-batched passes
    # The many-worlds replay (DESIGN.md §11) runs B worlds on (B, W, D)
    # buffers; the A2CiD2 dynamics are PER-WORLD (B,) f32 arrays ``pw =
    # (eta, alpha, alpha_t)`` passed dynamically, so one trace serves a
    # whole sweep family (baseline + accelerated + every grid point).

    def mix_batch(self, bx: jax.Array, bxt: jax.Array, dt, eta: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
        """World-batched standalone mixing sweep (batched prologue)."""
        return mix_worlds(bx, bxt, eta, dt)

    def batch_worlds(self, bx: jax.Array, bxt: jax.Array,
                     partner: jax.Array, dt_next: jax.Array, pw
                     ) -> tuple[jax.Array, jax.Array]:
        """One fused group [p2p, mix] on (B, W, D) buffers; ``pw`` the
        per-world (eta, alpha, alpha_t) arrays."""
        eta, alpha, alpha_t = pw
        return gossip_event_worlds(bx, bxt, partner, dt_next, eta, alpha,
                                   alpha_t, backend=self.backend)

    def channel_batch_worlds(self, bx: jax.Array, bxt: jax.Array,
                             xp: jax.Array, corrupt: jax.Array,
                             dt_next: jax.Array, pw, taus=None
                             ) -> tuple[jax.Array, jax.Array]:
        """World-batched channel group: pre-gathered (B, W, D) partner
        values, (B, W) corrupt offsets, per-world dynamics; the engine's
        robust rule derives the (B, W) mscale in one fused reduce.  When
        ``taus`` (a traced (B,) threshold array) is given it replaces the
        static ``robust_clip`` per world — tau = inf arms degenerate
        bitwise to the plain m-term for finite deltas (DESIGN.md §11)."""
        eta, alpha, alpha_t = pw
        mscale = self._mscale(bx, xp, corrupt, axes=2, taus=taus)
        return channel_event_worlds(bx, bxt, xp, corrupt, mscale, dt_next,
                                    eta, alpha, alpha_t,
                                    clip=self._coord_clip(),
                                    backend=self.backend)

    def channel_batch_worlds_scaled(self, bx: jax.Array, bxt: jax.Array,
                                    xp: jax.Array, corrupt: jax.Array,
                                    mscale: jax.Array, dt_next: jax.Array,
                                    pw) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
        """World-batched channel group with an EXTERNAL (B, W) mscale (the
        self-healing defense derives it from adaptive tau + quarantine);
        also returns the kernel's (B, W) rejection mask for the trust
        loop."""
        eta, alpha, alpha_t = pw
        return channel_event_worlds(bx, bxt, xp, corrupt, mscale, dt_next,
                                    eta, alpha, alpha_t, clip=None,
                                    want_rej=True, backend=self.backend)

    def ring_init_worlds(self, bx: jax.Array, horizon: int) -> jax.Array:
        """(B, H, W, D) per-world snapshot rings seeded with ``bx``."""
        return ring_init_worlds(bx, horizon)

    def ring_push_worlds(self, ring: jax.Array, bx: jax.Array, pos
                         ) -> jax.Array:
        """Rotate every world's ring at the (shared) slot ``pos``."""
        return ring_push_worlds(ring, bx, pos)

    def partner_values_worlds(self, ring: jax.Array, bx: jax.Array,
                              partner: jax.Array, src_slot: jax.Array
                              ) -> jax.Array:
        """Per-world partner reads: fresh rows where src_slot == H, ring
        snapshots otherwise ((B, W) host-resolved indices)."""
        return ring_read_worlds(ring, bx, partner, src_slot)

    # ------------------------------------------- unreliable-channel passes
    def _coord_clip(self) -> float | None:
        return self.robust_clip if self.robust_rule == "coord" else None

    def _norm_scale(self, nrm: jax.Array, taus=None) -> jax.Array:
        """Robust scale from the delta norm (trim rejection or norm clip);
        honest/accepted deltas get exactly 1.0 (a bitwise no-op).  ``taus``
        (a traced per-world (B,) array) overrides the static threshold —
        tau = inf accepts every finite delta."""
        if taus is None:
            tau = self.robust_clip
        else:
            tau = jnp.asarray(taus, jnp.float32)
            tau = jnp.reshape(tau, tau.shape + (1,) * (nrm.ndim - tau.ndim))
        if self.robust_rule == "trim":
            return (nrm <= tau).astype(jnp.float32)
        return jnp.minimum(1.0, tau / jnp.maximum(nrm, 1e-30)
                           ).astype(jnp.float32)

    def delta_norms(self, bx: jax.Array, xp: jax.Array, corrupt: jax.Array,
                    axes) -> jax.Array:
        """f32 L2 norms of the corrupted channel deltas — one fused reduce
        (the same one ``_mscale`` runs; the defense path needs the raw
        norms for its quantile tracker)."""
        cadv = (1.0 + jnp.asarray(corrupt, jnp.float32)).astype(bx.dtype)
        cadv = jnp.reshape(cadv, cadv.shape + (1,) * (bx.ndim - cadv.ndim))
        m32 = (bx - cadv * xp).astype(jnp.float32)
        return jnp.sqrt(jnp.sum(m32 * m32, axis=axes))

    def _mscale(self, bx: jax.Array, xp: jax.Array, corrupt: jax.Array,
                axes, taus=None) -> jax.Array:
        """Per-worker robust scale — one fused reduce over the raw delta
        (the norm never materializes an extra state-sized buffer)."""
        if taus is None and (self.robust_clip is None
                             or self.robust_rule == "coord"):
            return jnp.ones(corrupt.shape, jnp.float32)
        if taus is not None and self.robust_rule == "coord":
            raise ValueError("per-world taus require a norm rule "
                             "('trim' or 'clip'), not 'coord'")
        return self._norm_scale(self.delta_norms(bx, xp, corrupt, axes),
                                taus=taus)

    def channel_batch(self, bx: jax.Array, bxt: jax.Array, xp: jax.Array,
                      corrupt: jax.Array, dt_next: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
        """One fused channel group on (W, D) buffers: ``xp`` is the
        PRE-GATHERED (W, D) partner-value buffer (fresh rows or ring-buffer
        stale snapshots — see ``partner_values``), ``corrupt`` the (W,)
        received-value multiplier offsets; the engine's
        ``robust_clip``/``robust_rule`` select the plain or robust
        m-term."""
        p = self.params
        mscale = self._mscale(bx, xp, corrupt, axes=1)
        return channel_event_stacked(bx, bxt, xp, corrupt, mscale, dt_next,
                                     eta=p.eta, alpha=p.alpha,
                                     alpha_t=p.alpha_tilde,
                                     clip=self._coord_clip(),
                                     backend=self.backend)

    def channel_batch_scaled(self, bx: jax.Array, bxt: jax.Array,
                             xp: jax.Array, corrupt: jax.Array,
                             mscale: jax.Array, dt_next: jax.Array
                             ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Serial channel group with an EXTERNAL (W,) mscale (the
        self-healing defense derives it from adaptive tau + quarantine);
        also returns the kernel's (W,) rejection mask for the trust
        loop."""
        p = self.params
        return channel_event_stacked(bx, bxt, xp, corrupt, mscale, dt_next,
                                     eta=p.eta, alpha=p.alpha,
                                     alpha_t=p.alpha_tilde, clip=None,
                                     want_rej=True, backend=self.backend)

    def channel_batch_local(self, bx: jax.Array, bxt: jax.Array,
                            xp: jax.Array, corrupt, dt_next
                            ) -> tuple[jax.Array, jax.Array]:
        """Channel group on per-worker (D,) vectors (SPMD path): scalar
        ``corrupt`` offset for this worker's received value."""
        p = self.params
        mscale = self._mscale(bx, xp, jnp.asarray(corrupt, jnp.float32),
                              axes=None)
        return channel_event_local(bx, bxt, xp, corrupt, mscale, dt_next,
                                   eta=p.eta, alpha=p.alpha,
                                   alpha_t=p.alpha_tilde,
                                   clip=self._coord_clip(),
                                   backend=self.backend)

    # --------------------------------------------------- snapshot ring API
    def ring_init(self, bx: jax.Array, horizon: int) -> jax.Array:
        """(H, W, D) snapshot ring seeded with the current buffer."""
        return ring_init(bx, horizon)

    def ring_push(self, ring: jax.Array, bx: jax.Array, pos) -> jax.Array:
        """Rotate: store the post-gradient state at slot ``pos`` (r mod H)."""
        return ring_push(ring, bx, pos)

    def partner_values(self, ring: jax.Array, bx: jax.Array,
                       partner: jax.Array, src_slot: jax.Array) -> jax.Array:
        """Resolve per-worker partner reads: fresh rows of ``bx`` where
        ``src_slot == H``, ring slots otherwise (host-resolved indices)."""
        return ring_read(ring, bx, partner, src_slot)


    # ------------------------------- sharded-replay passes (DESIGN.md §16)
    def publish_rows(self, ring, bx: jax.Array, rows: jax.Array,
                     slots: jax.Array) -> jax.Array:
        """Resolve the (B, nb) boundary rows a shard publishes into their
        (B, nb, D) channel values — fresh rows of ``bx`` at the sentinel
        slot, local snapshot-ring reads otherwise.  The PUBLISHER resolves
        staleness against its own (B, H, Ws, D) ring, so the value that
        crosses the permute ring is bitwise the one the single-device
        ``ring_read_worlds`` gather would have produced."""
        fresh = jnp.take_along_axis(bx, rows[:, :, None], axis=1)
        if ring is None:
            return fresh
        h = ring.shape[1]
        clamped = jnp.minimum(slots, h - 1)
        b_idx = jnp.arange(bx.shape[0])[:, None]
        stale = ring[b_idx, clamped, rows]
        return jnp.where((slots < h)[:, :, None], stale, fresh)

    def pool_partner_values(self, pool: jax.Array, hop: jax.Array,
                            pos: jax.Array, xp_local: jax.Array,
                            is_cross: jax.Array) -> jax.Array:
        """Merge permute-ring pool reads into the local partner-value
        buffer: cross rows read ``pool[hop, :, pos]`` (the block published
        by the source shard), intra/idle rows keep the shard-local gather
        ``xp_local``."""
        b_idx = jnp.arange(pool.shape[1])[:, None]
        xp_cross = pool[hop, b_idx, pos]
        return jnp.where(is_cross[:, :, None], xp_cross, xp_local)
