"""Flat-buffer fused gossip-event engine — the one hot path all trainers
share (see DESIGN.md).

The engine owns three ingredients:

  1. a :class:`~repro.core.flatbuf.FlatLayout` packing the replica pytree
     into one contiguous buffer (stacked ``(W, D)`` or local ``(D,)``),
  2. the fused p2p-then-mix kernels from ``repro.kernels.a2cid2_mixing``
     (Pallas on TPU, jnp oracle on CPU),
  3. the *group* pass structure: the exact per-event sequence

         mix(d_0), S_0, mix(d_1), S_1, ..., S_{K-1}, mix(d_K)

     (S_i a fused comm batch or a gradient tick) regrouped as
     ``[mix(d_0)] [S_0, mix(d_1)] ... [S_{K-1}, mix(d_K)]`` — identical
     composition (the mixing flow is a semigroup and zero-dt segments are
     identities), but each bracketed group is ONE fused sweep reading 3
     state-sized buffers and writing 2.  events.coalesced_stream flattens a
     schedule into exactly these groups with every mixing segment
     precomputed host-side; masked schedule slots vanish entirely.

Traffic per coalesced batch: 3 reads + 2 writes of state, vs the per-event
path's 6 reads + 4 writes per event (2 unfused sweeps) — and the per-event
path also sweeps masked slots, which the coalesced stream drops entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.a2cid2_mixing.ops import gossip_event_stacked, p2p_mix_event
from .a2cid2 import A2CiD2Params, apply_mixing
from .flatbuf import FlatLayout

PyTree = Any


def mix_flat(bx: jax.Array, bxt: jax.Array, eta: float, dt: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Pure mixing pass on flat buffers; dt broadcasts ((W,) against (W, D)
    after the trailing-axis insert, or scalar against (D,)).  A flat buffer
    is a single-leaf pytree, so this is exactly ``a2cid2.apply_mixing``."""
    return apply_mixing(bx, bxt, eta, dt)


@dataclasses.dataclass(frozen=True)
class FlatGossipEngine:
    """Fused event engine bound to a layout, A2CiD2 params, and a backend.

    backend: 'auto' (Pallas on TPU, oracle elsewhere), 'ref',
    'pallas_interpret' (tests), or 'pallas'.
    """

    layout: FlatLayout
    params: A2CiD2Params
    backend: str = "auto"

    @classmethod
    def for_pytree(cls, tree: PyTree, params: A2CiD2Params, *,
                   stacked: bool = True, backend: str = "auto"
                   ) -> "FlatGossipEngine":
        return cls(FlatLayout.from_pytree(tree, stacked=stacked),
                   params, backend)

    # ------------------------------------------------------------- plumbing
    def pack(self, tree: PyTree) -> jax.Array:
        return self.layout.pack(tree)

    def unpack(self, buf: jax.Array) -> PyTree:
        return self.layout.unpack(buf)

    def pack_local(self, tree: PyTree) -> jax.Array:
        return self.layout.pack_local(tree)

    def unpack_local(self, vec: jax.Array) -> PyTree:
        return self.layout.unpack_local(vec)

    # -------------------------------------------------------------- passes
    def mix(self, bx: jax.Array, bxt: jax.Array, dt) -> tuple[jax.Array,
                                                              jax.Array]:
        """Standalone mixing sweep (engine prologue; 2 reads + 2 writes)."""
        return mix_flat(bx, bxt, self.params.eta, dt)

    def batch(self, bx: jax.Array, bxt: jax.Array, partner: jax.Array,
              dt_next: jax.Array) -> tuple[jax.Array, jax.Array]:
        """One fused group [p2p(partner), mix(dt_next)] on (W, D) buffers."""
        p = self.params
        return gossip_event_stacked(bx, bxt, partner, dt_next, eta=p.eta,
                                    alpha=p.alpha, alpha_t=p.alpha_tilde,
                                    backend=self.backend)

    def batch_local(self, bx: jax.Array, bxt: jax.Array, xp: jax.Array,
                    dt_next) -> tuple[jax.Array, jax.Array]:
        """One fused group on per-worker (D,) vectors (SPMD path); ``xp`` is
        the partner's current flat x (e.g. from a collective permute)."""
        p = self.params
        return p2p_mix_event(bx, bxt, xp, dt_next, eta=p.eta, alpha=p.alpha,
                             alpha_t=p.alpha_tilde, backend=self.backend)

