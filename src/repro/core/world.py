"""Declarative World API — compile scenarios, don't kwarg them (DESIGN.md §9).

The paper's claims live in *worlds*: a topology, per-worker speeds, per-link
rates, failures.  A ``World`` is a declarative, serializable description of
one such scenario:

    World(topology=ring_graph(16),
          workers=WorkerModel(grad_rates=[1, .25, ...]),
          links=LinkModel(bandwidth_bytes_per_s=50e9, msg_bytes=4 * D),
          faults=(ChurnProcess(fail_rate=0.02, repair_rate=0.2),
                  PhaseSwitch(at_round=100, topology=hypercube_graph(4))),
          channel=ChannelModel(delay=DelayProcess(horizon=4),
                               adversary=ByzantineEdges(edges, "scale"),
                               drop_prob=0.02))

``world.compile(rounds, seed)`` lowers the description to the existing
``events.Schedule`` — plain numpy event data that both jit'd replay paths
(the per-event reference and the flat-buffer engine) consume unchanged.  The
legacy ``events.make_schedule`` / ``events.make_topology_schedule`` entry
points are thin wrappers that construct a ``World`` and compile it, and are
bit-for-bit identical to the pre-World sampler under the same seed
(``tests/test_world.py``).

Compilation model (all host-side numpy; no new jit'd control flow):

  1. topology + faults  ->  a list of *segments*, each a (graph, rounds,
     active-mask) triple.  ``PhaseSwitch`` faults cut the timeline at fixed
     rounds; ``ChurnProcess`` samples a per-worker failure/repair Markov
     chain (its own rng stream) and cuts at every aliveness change.
  2. each segment samples its own Poisson events (per-segment seed
     ``seed + p``, times offset by the segment start) via the same sampler
     the kwarg API always used.
  3. ``events.concat_schedules`` fuses the segments into ONE schedule.

``LinkModel`` is where communication physics lives: explicit per-edge
``rates``, or bandwidth-derived rates (``bandwidth_bytes_per_s`` /
``msg_bytes`` — faster links fire proportionally more often, normalized so
the mean worker communicates ``comms_per_grad`` times per round) plus the
wall-clock mapping ``round_seconds`` used by ``benchmarks/run.py`` to give
``BENCH_topology.json`` a seconds x-axis (default bandwidth/HBM constants
come from ``analysis/roofline.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
import json

import numpy as np

from .a2cid2 import Algorithm
from .channel import ChannelModel
from .defense import AdaptiveDefense
from .graphs import Graph, TopologyPhase, TopologySchedule
from .telemetry import Telemetry

# rng-stream tag for churn draws — independent of the schedule's main stream
# (events.py uses 0x48455 for straggler thinning)
_CHURN_TAG = 0xC50C4
# rng-stream tag for serving-load draws (arrival trace): independent of BOTH
# the schedule and churn streams, so every world sharing a ServeLoad spec +
# seed sees the identical request trace regardless of topology/channel/faults
_SERVE_TAG = 0x5E17E
# reserved extras key: per-round request-arrival counts at event slot 0
SERVE_ARRIVE_KEY = "arrive"


def _as_float_tuple(x, field: str) -> tuple[float, ...] | None:
    if x is None:
        return None
    arr = np.asarray(x, dtype=np.float64)
    if arr.ndim != 1:
        raise ValueError(
            f"{field} must be a 1-D sequence, got shape {arr.shape}")
    if not np.all(np.isfinite(arr)):
        raise ValueError(f"{field} must be finite, got {arr}")
    return tuple(float(v) for v in arr)


def _as_bool_tuple(x, field: str) -> tuple[bool, ...] | None:
    if x is None:
        return None
    arr = np.asarray(x)
    if arr.ndim != 1:
        raise ValueError(
            f"{field} must be a 1-D sequence, got shape {arr.shape}")
    if arr.dtype != bool and not np.all(np.isin(arr, (0, 1))):
        raise ValueError(f"{field} must be boolean, got dtype {arr.dtype}")
    return tuple(bool(v) for v in arr)


# ---------------------------------------------------------------- components

@dataclasses.dataclass(frozen=True)
class WorkerModel:
    """Per-worker physics.

    grad_rates — per-worker gradient-tick rates in [0, 1] relative to the
      unit tick process (straggler thinning; DESIGN.md §8).  None = all 1.
    active — static churn mask: ``active[i] = False`` detaches worker i for
      the whole world (no matchings, no gradients, frozen clock).
    """

    grad_rates: tuple[float, ...] | None = None
    active: tuple[bool, ...] | None = None

    def __post_init__(self):
        object.__setattr__(self, "grad_rates",
                           _as_float_tuple(self.grad_rates,
                                           "workers.grad_rates"))
        object.__setattr__(self, "active",
                           _as_bool_tuple(self.active, "workers.active"))
        if self.grad_rates is not None:
            bad = [r for r in self.grad_rates if not 0.0 <= r <= 1.0]
            if bad:
                raise ValueError(
                    "workers.grad_rates are thinning probabilities and must "
                    f"lie in [0, 1], got {bad}")

    def grad_rates_arr(self) -> np.ndarray | None:
        if self.grad_rates is None:
            return None
        return np.asarray(self.grad_rates, dtype=np.float64)

    def active_arr(self) -> np.ndarray | None:
        if self.active is None:
            return None
        return np.asarray(self.active, dtype=bool)

    def to_dict(self) -> dict:
        return {"grad_rates": None if self.grad_rates is None
                else list(self.grad_rates),
                "active": None if self.active is None else list(self.active)}

    @staticmethod
    def from_dict(d: dict) -> "WorkerModel":
        return WorkerModel(grad_rates=d.get("grad_rates"),
                           active=d.get("active"))


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-link physics: how often each edge fires, and what a firing costs.

    Exactly one of two descriptions (or neither, for topology-default rates):

    rates — explicit per-edge event rates overriding ``graph.rates``
      (aligned with the topology's edge list).
    bandwidth_bytes_per_s + msg_bytes — bandwidth-aware rates: a link of
      capacity ``bw`` moves one ``msg_bytes`` message every ``msg_bytes/bw``
      seconds, so edge event rates are proportional to bandwidth, normalized
      so the MEAN worker communicates once per unit simulated time (the
      ``comms_per_grad`` world knob scales from there).  ``bandwidth`` may
      be a scalar (uniform links) or per-edge.

    grad_seconds — wall-clock seconds of one gradient tick, used only by the
      wall-clock mapping ``round_seconds`` (couple it to the roofline terms
      of ``analysis/roofline.py`` for real models).
    per_edge — force the Def 3.1 single-pair point process on/off
      (None = auto: per-edge iff rates are non-uniform vs the topology).
    """

    rates: tuple[float, ...] | None = None
    bandwidth_bytes_per_s: float | tuple[float, ...] | None = None
    msg_bytes: float | None = None
    grad_seconds: float = 0.0
    per_edge: bool | None = None

    def __post_init__(self):
        object.__setattr__(self, "rates",
                           _as_float_tuple(self.rates, "links.rates"))
        bw = self.bandwidth_bytes_per_s
        if bw is not None and not np.isscalar(bw):
            bw = _as_float_tuple(bw, "links.bandwidth_bytes_per_s")
            object.__setattr__(self, "bandwidth_bytes_per_s", bw)
        elif bw is not None:
            object.__setattr__(self, "bandwidth_bytes_per_s", float(bw))
        if self.rates is not None and self.bandwidth_bytes_per_s is not None:
            raise ValueError("links: give either explicit rates OR "
                             "bandwidth_bytes_per_s, not both")
        if (self.bandwidth_bytes_per_s is None) != (self.msg_bytes is None):
            raise ValueError("links: bandwidth_bytes_per_s and msg_bytes "
                             "must be given together")
        if self.msg_bytes is not None and not self.msg_bytes > 0:
            raise ValueError(f"links.msg_bytes must be > 0, "
                             f"got {self.msg_bytes}")
        if self.rates is not None and any(r < 0 for r in self.rates):
            raise ValueError(f"links.rates must be >= 0, got {self.rates}")
        if self.bandwidth_bytes_per_s is not None:
            arr = np.atleast_1d(np.asarray(self.bandwidth_bytes_per_s))
            if not np.all(arr > 0):
                raise ValueError("links.bandwidth_bytes_per_s must be > 0, "
                                 f"got {self.bandwidth_bytes_per_s}")
        if self.grad_seconds < 0:
            raise ValueError(f"links.grad_seconds must be >= 0, "
                             f"got {self.grad_seconds}")

    @property
    def is_default(self) -> bool:
        return self.rates is None and self.bandwidth_bytes_per_s is None

    def _bandwidth_arr(self, graph: Graph) -> np.ndarray:
        bw = np.asarray(self.bandwidth_bytes_per_s, dtype=np.float64)
        if bw.ndim == 0:
            return np.full(graph.num_edges, float(bw))
        if bw.shape != (graph.num_edges,):
            raise ValueError(
                "links.bandwidth_bytes_per_s must be scalar or shape "
                f"({graph.num_edges},) = (num_edges,) for topology "
                f"'{graph.name}', got {bw.shape}")
        return bw

    def edge_rates(self, graph: Graph) -> np.ndarray | None:
        """Per-edge event rates this model induces on ``graph`` (None =
        keep the topology's own rates)."""
        if self.rates is not None:
            arr = np.asarray(self.rates, dtype=np.float64)
            if arr.shape != (graph.num_edges,):
                raise ValueError(
                    f"links.rates must have shape ({graph.num_edges},) = "
                    f"(num_edges,) for topology '{graph.name}', "
                    f"got {arr.shape}")
            return arr
        if self.bandwidth_bytes_per_s is not None:
            cap = self._bandwidth_arr(graph) / float(self.msg_bytes)
            # normalize so the mean worker rate is 1 (sum of worker rates =
            # 2 * sum of edge rates = n); comms_per_grad scales from there
            return cap * (graph.n / 2.0) / cap.sum()
        return None

    def seconds_per_event(self, graph: Graph) -> np.ndarray:
        """(E,) wall seconds one p2p message occupies each link."""
        if self.bandwidth_bytes_per_s is None:
            raise ValueError("seconds_per_event needs a bandwidth-aware "
                             "LinkModel (bandwidth_bytes_per_s + msg_bytes)")
        return float(self.msg_bytes) / self._bandwidth_arr(graph)

    def round_seconds(self, schedule, graph: Graph,
                      rounds: range | None = None) -> np.ndarray:
        """Wall seconds per simulated round under this link model.

        Links transfer in parallel; events on the SAME link serialize, so a
        round costs ``grad_seconds`` plus the busiest link's transfer time.
        This is the wall-clock x-axis of ``BENCH_topology.json``.  ``rounds``
        restricts to a slice of the schedule (``World.round_seconds`` uses
        it to apply each segment's own graph); default = all rounds.
        """
        spe = self.seconds_per_event(graph)
        eidx = graph.edge_index()
        rs = range(schedule.rounds) if rounds is None else rounds
        out = np.full(len(rs), float(self.grad_seconds))
        for o, r in enumerate(rs):
            busy = np.zeros(max(graph.num_edges, 1))
            for k in range(schedule.partners.shape[1]):
                if not schedule.event_mask[r, k]:
                    continue
                p = schedule.partners[r, k]
                for i in range(schedule.n):
                    j = int(p[i])
                    if j > i:
                        e = eidx.get((i, j))
                        if e is not None:
                            busy[e] += spe[e]
            out[o] += busy.max()
        return out

    def to_dict(self) -> dict:
        bw = self.bandwidth_bytes_per_s
        return {"rates": None if self.rates is None else list(self.rates),
                "bandwidth_bytes_per_s": list(bw) if isinstance(bw, tuple)
                else bw,
                "msg_bytes": self.msg_bytes,
                "grad_seconds": self.grad_seconds,
                "per_edge": self.per_edge}

    @staticmethod
    def from_dict(d: dict) -> "LinkModel":
        return LinkModel(rates=d.get("rates"),
                         bandwidth_bytes_per_s=d.get("bandwidth_bytes_per_s"),
                         msg_bytes=d.get("msg_bytes"),
                         grad_seconds=d.get("grad_seconds", 0.0),
                         per_edge=d.get("per_edge"))


# -------------------------------------------------------------------- faults

@dataclasses.dataclass(frozen=True)
class ChurnProcess:
    """Poisson failure/repair churn: each worker is a 2-state Markov chain
    (alive -> dead at rate ``fail_rate`` per round, dead -> alive at
    ``repair_rate``), sampled per round from a dedicated rng stream and
    compiled onto the schedule as segments of constant aliveness — detached
    rows keep the exact fixed-point/frozen-clock semantics of DESIGN.md §8.

    workers — optional subset of worker ids eligible to fail (None = all).
    """

    fail_rate: float
    repair_rate: float
    workers: tuple[int, ...] | None = None

    def __post_init__(self):
        if not (np.isfinite(self.fail_rate) and self.fail_rate >= 0):
            raise ValueError(
                f"ChurnProcess.fail_rate must be >= 0, got {self.fail_rate}")
        if not (np.isfinite(self.repair_rate) and self.repair_rate >= 0):
            raise ValueError(f"ChurnProcess.repair_rate must be >= 0, "
                             f"got {self.repair_rate}")
        if self.workers is not None:
            object.__setattr__(self, "workers",
                               tuple(int(w) for w in self.workers))

    def sample_alive(self, rounds: int, n: int, seed: int) -> np.ndarray:
        """(R, n) bool aliveness trajectory.  Round 0 starts all-alive; the
        chain then takes one transition per round.  Draws come from an rng
        stream independent of the schedule's — the aliveness PATTERN never
        depends on how events were sampled.  (The compiled events themselves
        DO change when churn cuts the timeline into differently-seeded
        segments; only a churn process that never fires leaves the event
        stream bit-for-bit intact.)"""
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), _CHURN_TAG]))
        p_fail = 1.0 - np.exp(-self.fail_rate)
        p_repair = 1.0 - np.exp(-self.repair_rate)
        eligible = np.zeros(n, dtype=bool)
        if self.workers is None:
            eligible[:] = True
        else:
            for w in self.workers:
                if not 0 <= w < n:
                    raise ValueError(f"ChurnProcess.workers entry {w} outside "
                                     f"[0, {n})")
                eligible[w] = True
        alive = np.ones((rounds, n), dtype=bool)
        state = np.ones(n, dtype=bool)
        u = rng.uniform(size=(rounds, n))
        for r in range(1, rounds):
            flip = np.where(state, u[r] < p_fail, u[r] < p_repair) & eligible
            state = np.where(flip, ~state, state)
            alive[r] = state
        return alive

    def to_dict(self) -> dict:
        return {"kind": "churn", "fail_rate": self.fail_rate,
                "repair_rate": self.repair_rate,
                "workers": None if self.workers is None
                else list(self.workers)}


@dataclasses.dataclass(frozen=True)
class PhaseSwitch:
    """Deterministic mid-run world change at a fixed round: a new topology
    (None = keep the current graph) and/or a new static active mask applying
    from this round on (None = revert to the worker model's base mask)."""

    at_round: int
    topology: Graph | None = None
    active: tuple[bool, ...] | None = None

    def __post_init__(self):
        if self.at_round <= 0:
            raise ValueError(
                f"PhaseSwitch.at_round must be >= 1, got {self.at_round}")
        object.__setattr__(self, "active",
                           _as_bool_tuple(self.active, "PhaseSwitch.active"))

    def to_dict(self) -> dict:
        return {"kind": "phase_switch", "at_round": self.at_round,
                "topology": None if self.topology is None
                else self.topology.to_dict(),
                "active": None if self.active is None else list(self.active)}


def _fault_from_dict(d: dict):
    kind = d.get("kind")
    if kind == "churn":
        return ChurnProcess(d["fail_rate"], d["repair_rate"],
                            workers=d.get("workers"))
    if kind == "phase_switch":
        topo = d.get("topology")
        return PhaseSwitch(d["at_round"],
                           topology=None if topo is None
                           else Graph.from_dict(topo),
                           active=d.get("active"))
    raise ValueError(f"unknown fault kind {kind!r} "
                     "(expected 'churn' or 'phase_switch')")


# --------------------------------------------------------------- serving load

@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """A materialized arrival trace: one row per request, sorted by arrival
    round.  Derived data (``ServeLoad.sample_trace``), not serialized — the
    (spec, rounds, seed) triple regenerates it bit-for-bit."""

    arrival_round: np.ndarray  # (N,) int32
    prompt_len: np.ndarray     # (N,) int32
    gen_len: np.ndarray        # (N,) int32

    @property
    def num_requests(self) -> int:
        return int(self.arrival_round.shape[0])

    def counts(self, rounds: int) -> np.ndarray:
        """(rounds,) arrivals per round."""
        return np.bincount(self.arrival_round,
                           minlength=rounds).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class ServeLoad:
    """The serving-workload axis of a World (DESIGN.md §14): a shared
    request arrival trace the gossip-serving fleet admits from while its
    replicas keep averaging.

    rate — mean fleet-wide request arrivals per round (Poisson), ignored
      when explicit ``arrivals`` are given.
    prompt_len / gen_len — inclusive (lo, hi) ranges sampled uniformly per
      request (heterogeneous work, the continuous-batching stressor).
    arrive_frac — arrivals land in rounds ``[0, ceil(arrive_frac * R))``;
      the remaining tail is drain headroom.
    arrivals — optional explicit per-round counts (a replayed trace);
      padded/truncated to the compiled horizon.

    Draws come from a dedicated rng stream (seed x ``_SERVE_TAG``), so two
    worlds differing in topology/channel/faults but sharing a ServeLoad and
    seed see the IDENTICAL trace — the "one request trace across fleets"
    contract ``BENCH_serve.json`` relies on.
    """

    rate: float = 1.0
    prompt_len: tuple[int, int] = (4, 8)
    gen_len: tuple[int, int] = (4, 16)
    arrive_frac: float = 0.6
    arrivals: tuple[int, ...] | None = None

    def __post_init__(self):
        if not (np.isfinite(self.rate) and self.rate >= 0):
            raise ValueError(f"ServeLoad.rate must be >= 0, got {self.rate}")
        for name in ("prompt_len", "gen_len"):
            rng_ = getattr(self, name)
            rng_ = tuple(int(v) for v in rng_)
            object.__setattr__(self, name, rng_)
            if len(rng_) != 2 or not 1 <= rng_[0] <= rng_[1]:
                raise ValueError(f"ServeLoad.{name} must be (lo, hi) with "
                                 f"1 <= lo <= hi, got {rng_}")
        if not 0.0 < self.arrive_frac <= 1.0:
            raise ValueError(f"ServeLoad.arrive_frac must lie in (0, 1], "
                             f"got {self.arrive_frac}")
        if self.arrivals is not None:
            arr = tuple(int(a) for a in self.arrivals)
            if any(a < 0 for a in arr):
                raise ValueError(f"ServeLoad.arrivals must be >= 0, got "
                                 f"{[a for a in arr if a < 0]}")
            object.__setattr__(self, "arrivals", arr)

    def _rng(self, seed: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([int(seed), _SERVE_TAG]))

    def sample_counts(self, rounds: int, seed: int = 0) -> np.ndarray:
        """(rounds,) arrivals per round — explicit trace or Poisson draws
        over the arrival window."""
        if self.arrivals is not None:
            out = np.zeros(rounds, np.int32)
            k = min(rounds, len(self.arrivals))
            out[:k] = self.arrivals[:k]
            return out
        window = int(np.ceil(self.arrive_frac * rounds))
        out = np.zeros(rounds, np.int32)
        out[:window] = self._rng(seed).poisson(self.rate, size=window)
        return out

    def sample_trace(self, rounds: int, seed: int = 0) -> RequestTrace:
        """The full per-request trace.  Length draws come AFTER the count
        draws from the same stream, so counts alone (``sample_counts``,
        what ``compile`` embeds in extras) are a prefix-consistent view."""
        counts = self.sample_counts(rounds, seed)
        n = int(counts.sum())
        rng = self._rng(seed)
        if self.arrivals is None:
            window = int(np.ceil(self.arrive_frac * rounds))
            rng.poisson(self.rate, size=window)  # replay the count draws
        plen = rng.integers(self.prompt_len[0], self.prompt_len[1] + 1,
                            size=n).astype(np.int32)
        glen = rng.integers(self.gen_len[0], self.gen_len[1] + 1,
                            size=n).astype(np.int32)
        return RequestTrace(
            arrival_round=np.repeat(np.arange(rounds, dtype=np.int32),
                                    counts),
            prompt_len=plen, gen_len=glen)

    def to_dict(self) -> dict:
        return {"rate": self.rate, "prompt_len": list(self.prompt_len),
                "gen_len": list(self.gen_len),
                "arrive_frac": self.arrive_frac,
                "arrivals": None if self.arrivals is None
                else list(self.arrivals)}

    @staticmethod
    def from_dict(d: dict) -> "ServeLoad":
        return ServeLoad(rate=d.get("rate", 1.0),
                         prompt_len=tuple(d.get("prompt_len", (4, 8))),
                         gen_len=tuple(d.get("gen_len", (4, 16))),
                         arrive_frac=d.get("arrive_frac", 0.6),
                         arrivals=None if d.get("arrivals") is None
                         else tuple(d["arrivals"]))


# ---------------------------------------------------- topology serialization

def _topology_to_dict(t: Graph | TopologySchedule) -> dict:
    if isinstance(t, TopologySchedule):
        return {"kind": "phases", **t.to_dict()}
    return {"kind": "graph", **t.to_dict()}


def _topology_from_dict(d: dict) -> Graph | TopologySchedule:
    if d.get("kind") == "phases":
        return TopologySchedule.from_dict(d)
    return Graph.from_dict(d)


# ------------------------------------------------------------------ segments

@dataclasses.dataclass(frozen=True)
class _Segment:
    """One compiled slice of the timeline: a graph held for ``rounds`` with
    a constant active mask, starting at absolute round ``start`` and sampled
    with seed offset ``seed_offset``."""

    graph: Graph
    rounds: int
    start: int
    active: np.ndarray | None  # (n,) bool or None = all alive
    seed_offset: int


# --------------------------------------------------------------------- world

@dataclasses.dataclass(frozen=True)
class World:
    """A declarative, serializable scenario: topology + worker model + link
    model + fault processes.  ``compile(rounds, seed)`` lowers it to one
    ``events.Schedule`` consumed unchanged by both replay paths."""

    topology: Graph | TopologySchedule
    workers: WorkerModel = WorkerModel()
    links: LinkModel = LinkModel()
    faults: tuple = ()
    channel: ChannelModel | None = None
    comms_per_grad: float = 1.0
    jitter_grad_times: bool = True
    t_offset: float = 0.0
    defense: AdaptiveDefense | None = None
    # algorithm zoo (DESIGN.md §13): None = the legacy default (bitwise
    # PR 6 compile; dynamics chosen by the caller), an Algorithm spec
    # otherwise — its clock structure lowers into the schedule here, its
    # dynamics column via ``algorithm_params()``
    algorithm: Algorithm | None = None
    # serving workload (DESIGN.md §14): None = training-only world (bitwise
    # PR 7 compile); a ServeLoad attaches per-round request-arrival counts
    # as ``extras[SERVE_ARRIVE_KEY]`` for the gossip-serving fleet driver
    serve: "ServeLoad | None" = None
    # flight recorder (DESIGN.md §15): None = no telemetry (bitwise PR 8
    # replay); a telemetry.Telemetry spec makes the replay emit per-round
    # metric columns as ``trace.telemetry`` without changing any number
    telemetry: "Telemetry | None" = None

    def __post_init__(self):
        if not isinstance(self.topology, (Graph, TopologySchedule)):
            raise ValueError("topology must be a Graph or TopologySchedule, "
                             f"got {type(self.topology).__name__}")
        if not isinstance(self.workers, WorkerModel):
            raise ValueError("workers must be a WorkerModel, "
                             f"got {type(self.workers).__name__}")
        if not isinstance(self.links, LinkModel):
            raise ValueError("links must be a LinkModel, "
                             f"got {type(self.links).__name__}")
        object.__setattr__(self, "faults", tuple(self.faults))
        for f in self.faults:
            if not isinstance(f, (ChurnProcess, PhaseSwitch)):
                raise ValueError("faults must be ChurnProcess/PhaseSwitch "
                                 f"instances, got {type(f).__name__}")
            if isinstance(f, ChurnProcess) and f.workers is not None:
                bad = [w for w in f.workers if not 0 <= w < self.topology.n]
                if bad:
                    raise ValueError(
                        f"ChurnProcess.workers entries {bad} outside "
                        f"[0, {self.topology.n}) for this topology")
        if not (np.isfinite(self.comms_per_grad)
                and self.comms_per_grad >= 0):
            raise ValueError(f"comms_per_grad must be >= 0, "
                             f"got {self.comms_per_grad}")
        n = self.n
        if self.workers.grad_rates is not None \
                and len(self.workers.grad_rates) != n:
            raise ValueError(
                f"workers.grad_rates must have shape ({n},) = (n_workers,) "
                f"for this topology, got ({len(self.workers.grad_rates)},)")
        if self.workers.active is not None \
                and len(self.workers.active) != n:
            raise ValueError(
                f"workers.active must have shape ({n},) = (n_workers,) "
                f"for this topology, got ({len(self.workers.active)},)")
        switches = [f for f in self.faults if isinstance(f, PhaseSwitch)]
        if switches and isinstance(self.topology, TopologySchedule):
            raise ValueError("PhaseSwitch faults require a static Graph "
                             "topology; a TopologySchedule already encodes "
                             "its own phases")
        ats = [s.at_round for s in switches]
        if ats != sorted(set(ats)):
            raise ValueError("PhaseSwitch.at_round values must be strictly "
                             f"increasing, got {ats}")
        for s in switches:
            if s.topology is not None and s.topology.n != n:
                raise ValueError(
                    f"PhaseSwitch topology must keep n={n} workers, "
                    f"got n={s.topology.n}")
            if s.active is not None and len(s.active) != n:
                raise ValueError(
                    f"PhaseSwitch.active must have shape ({n},) = "
                    f"(n_workers,), got ({len(s.active)},)")
        multi_graph = isinstance(self.topology, TopologySchedule) or any(
            s.topology is not None for s in switches)
        if multi_graph and (self.links.rates is not None or isinstance(
                self.links.bandwidth_bytes_per_s, tuple)):
            raise ValueError(
                "per-edge links.rates/bandwidth need a single static "
                "topology (edge lists differ across phases) — give each "
                "phase graph its own rates via Graph.with_rates, or use a "
                "scalar bandwidth")
        # eagerly validate per-edge alignment against the static topology
        if isinstance(self.topology, Graph):
            self.links.edge_rates(self.topology)
        if self.channel is not None:
            if not isinstance(self.channel, ChannelModel):
                raise ValueError("channel must be a ChannelModel, "
                                 f"got {type(self.channel).__name__}")
            # adversary edges must exist somewhere in the world's topology
            graphs = list(p.graph for p in self.topology.phases) \
                if isinstance(self.topology, TopologySchedule) \
                else [self.topology]
            graphs += [s.topology for s in switches
                       if s.topology is not None]
            self.channel.validate_for(
                n, [frozenset((min(i, j), max(i, j)) for i, j in g.edges)
                    for g in graphs])
        if self.defense is not None and not isinstance(self.defense,
                                                       AdaptiveDefense):
            raise ValueError("defense must be an AdaptiveDefense, "
                             f"got {type(self.defense).__name__}")
        if self.algorithm is not None and not isinstance(self.algorithm,
                                                         Algorithm):
            raise ValueError("algorithm must be an Algorithm, "
                             f"got {type(self.algorithm).__name__}")
        if self.serve is not None and not isinstance(self.serve, ServeLoad):
            raise ValueError("serve must be a ServeLoad, "
                             f"got {type(self.serve).__name__}")
        if self.telemetry is not None and not isinstance(self.telemetry,
                                                         Telemetry):
            raise ValueError("telemetry must be a telemetry.Telemetry, "
                             f"got {type(self.telemetry).__name__}")

    # ------------------------------------------------------------ structure
    @property
    def n(self) -> int:
        return self.topology.n

    def _base_phases(self, rounds: int | None
                     ) -> list[tuple[Graph, int, np.ndarray | None]]:
        """(graph, rounds, active) triples from topology + PhaseSwitch
        faults, before churn processes cut the timeline further."""
        base_active = self.workers.active_arr()

        def combine(a, b):
            if a is None:
                return None if b is None else b.copy()
            return a.copy() if b is None else (a & b)

        if isinstance(self.topology, TopologySchedule):
            if rounds is not None and rounds != self.topology.total_rounds:
                raise ValueError(
                    f"rounds={rounds} does not match the TopologySchedule's "
                    f"total of {self.topology.total_rounds}; pass rounds=None"
                    " to use the schedule's own duration")
            return [(p.graph, p.rounds,
                     combine(base_active,
                             None if p.active is None else p.active_mask()))
                    for p in self.topology.phases]
        if rounds is None:
            raise ValueError("a World with a static Graph topology needs "
                             "compile(rounds=...)")
        switches = sorted((f for f in self.faults
                           if isinstance(f, PhaseSwitch)),
                          key=lambda s: s.at_round)
        cuts = [0] + [s.at_round for s in switches if s.at_round < rounds] \
            + [rounds]
        out = []
        graph = self.topology
        active = base_active
        live = [s for s in switches if s.at_round < rounds]
        for i in range(len(cuts) - 1):
            if i > 0:
                sw = live[i - 1]
                if sw.topology is not None:
                    graph = sw.topology
                active = combine(base_active,
                                 None if sw.active is None
                                 else np.asarray(sw.active, bool))
            if cuts[i + 1] > cuts[i]:
                out.append((graph, cuts[i + 1] - cuts[i], active))
        return out

    def segments(self, rounds: int | None = None, seed: int = 0
                 ) -> list[_Segment]:
        """The fully-resolved compilation plan: phases cut at every
        ChurnProcess aliveness change, with per-segment seeds and starts."""
        phases = self._base_phases(rounds)
        total = sum(r for _, r, _ in phases)
        churns = [f for f in self.faults if isinstance(f, ChurnProcess)]
        churn_alive = None
        for i, c in enumerate(churns):
            a = c.sample_alive(total, self.n, seed + i)
            churn_alive = a if churn_alive is None else (churn_alive & a)

        segs: list[_Segment] = []
        start = 0
        for graph, ph_rounds, ph_active in phases:
            if churn_alive is None:
                segs.append(_Segment(graph, ph_rounds, start, ph_active,
                                     len(segs)))
            else:
                rows = churn_alive[start:start + ph_rounds]
                if ph_active is not None:
                    rows = rows & ph_active[None, :]
                r0 = 0
                for r in range(1, ph_rounds + 1):
                    if r == ph_rounds or not np.array_equal(rows[r],
                                                            rows[r0]):
                        act = None if rows[r0].all() else rows[r0]
                        segs.append(_Segment(graph, r - r0, start + r0,
                                             act, len(segs)))
                        r0 = r
            start += ph_rounds
        return segs

    def phase_plan(self, rounds: int | None = None, seed: int = 0
                   ) -> TopologySchedule:
        """The compiled segment structure as a TopologySchedule (for chi
        inspection, per-phase matching banks, reporting)."""
        return TopologySchedule(tuple(
            TopologyPhase(s.graph, s.rounds,
                          None if s.active is None else tuple(s.active))
            for s in self.segments(rounds, seed)))

    def segment_graphs(self, rounds: int | None = None, seed: int = 0
                       ) -> list[Graph]:
        """Per-segment *effective* communication graphs: link-model rates
        applied, detached workers isolated (what matching banks consume)."""
        out = []
        for s in self.segments(rounds, seed):
            g = s.graph
            er = self.links.edge_rates(g)
            if er is not None:
                g = g.with_rates(er)
            if s.active is not None and not s.active.all():
                g = g.subgraph(s.active)
            out.append(g)
        return out

    def static_graph(self) -> Graph:
        """The single effective graph of a static (fault-free, fully-attached
        Graph) world — what the mesh trainers derive A²CiD² parameters and
        matching banks from.  Raises for phased/churned worlds: a detached
        worker would sit as an isolated node, making chi1 infinite and the
        derived mixing parameters degenerate (DESIGN.md §8)."""
        a = self.workers.active_arr()
        if not isinstance(self.topology, Graph) or self.faults \
                or (a is not None and not a.all()):
            raise ValueError(
                "static_graph needs a fault-free Graph-topology world with "
                "all workers attached (chi of a world with detached workers "
                "is only defined per phase) — use segment_graphs()/"
                "phase_plan() and gossip.phase_banks/world_banks")
        g = self.topology
        er = self.links.edge_rates(g)
        if er is not None:
            g = g.with_rates(er)
        return g

    def algorithm_params(self, accelerated: bool | None = None):
        """The world's scalar dynamics column — what rides the batched
        replay's per-world (B,) arrays (``Simulator.world_params``).

        Resolves ``algorithm`` (default ``Algorithm()`` = canonical A²CiD²)
        against ``static_graph()``'s chi values; ``accelerated`` overrides
        the arm (the benchmarks' base/accelerated sweep axis).  Needs a
        static world — chi of a phased/churned world is only defined per
        phase (see ``static_graph``).
        """
        algo = self.algorithm if self.algorithm is not None else Algorithm()
        if accelerated is not None:
            algo = dataclasses.replace(algo, accelerated=bool(accelerated))
        return algo.params_for(self.static_graph())

    # -------------------------------------------------------------- compile
    def compile(self, rounds: int | None = None, seed: int = 0):
        """Lower the world to ONE ``events.Schedule``.

        Bit-for-bit contract: a World mirroring ``make_schedule`` /
        ``make_topology_schedule`` kwargs produces the identical schedule
        under the same seed (those entry points are now wrappers over this).
        """
        from .events import _sample_schedule, concat_schedules

        grad_rates = self.workers.grad_rates_arr()
        comm_ctrl = self.defense is not None \
            and self.defense.has_comm_control
        # the algorithm's independent gossip clock (DADAO) replaces
        # comms_per_grad as the comm-event intensity; coupled algorithms
        # pass it through unchanged, keeping the compile bitwise-identical
        cpg = self.comms_per_grad if self.algorithm is None \
            else self.algorithm.comm_rate(self.comms_per_grad)
        # with the comm controller on, sample at the controller's CEILING
        # rate; the controller thins each round down to its keep-fraction
        rate = cpg * (self.defense.comm_hi if comm_ctrl else 1.0)
        scheds = []
        for s in self.segments(rounds, seed):
            scheds.append(_sample_schedule(
                s.graph, s.rounds, rate,
                seed=seed + s.seed_offset,
                jitter_grad_times=self.jitter_grad_times,
                grad_rates=grad_rates,
                edge_rates=self.links.edge_rates(s.graph),
                per_edge=self.links.per_edge,
                t_offset=self.t_offset + float(s.start),
                active=s.active))
        sched = concat_schedules(scheds)
        if self.algorithm is not None:
            # decoupled gradient clock (DADAO): Bernoulli tick thinning on
            # the final concatenated schedule, drawn from the algorithm's
            # own rng stream — a coupled (unit-rate) algorithm returns the
            # schedule bitwise unchanged
            sched = self.algorithm.apply_grad_clock(sched, seed=seed)
        if self.channel is not None:
            # the channel rides on the FINAL concatenated schedule (its
            # staleness caps need absolute round indices), drawing from its
            # own rng stream — a trivial channel is an exact no-op
            sched = self.channel.apply(sched, seed=seed)
        if comm_ctrl:
            # the controller thins AFTER the channel: its degradation
            # score reads the channel extras, and gated slots zero them
            sched = self.defense.apply_comm_control(sched)
        if self.serve is not None:
            # arrivals ride LAST so comm-control thinning (which zeroes
            # gated slots' extras) can't erase workload data; counts sit
            # at event slot 0 (kmax >= 1 always) of every round
            counts = self.serve.sample_counts(sched.rounds, seed)
            arrive = np.zeros(sched.partners.shape[:2], np.float32)
            arrive[:, 0] = counts
            sched = sched.with_extras(**{SERVE_ARRIVE_KEY: arrive})
        return sched

    def round_seconds(self, schedule) -> np.ndarray:
        """(R,) wall seconds per round of a schedule this world compiled,
        applying each phase's own graph to the link model (phase switches
        change the edge set mid-run; churn cuts don't — detached workers
        simply have no events, so only the graph-per-phase structure
        matters and the result is seed-independent)."""
        rounds = None if isinstance(self.topology, TopologySchedule) \
            else schedule.rounds
        out = np.zeros(schedule.rounds)
        start = 0
        for graph, ph_rounds, _ in self._base_phases(rounds):
            out[start:start + ph_rounds] = self.links.round_seconds(
                schedule, graph, range(start, start + ph_rounds))
            start += ph_rounds
        return out

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"topology": _topology_to_dict(self.topology),
                "workers": self.workers.to_dict(),
                "links": self.links.to_dict(),
                "faults": [f.to_dict() for f in self.faults],
                "channel": None if self.channel is None
                else self.channel.to_dict(),
                "comms_per_grad": self.comms_per_grad,
                "jitter_grad_times": self.jitter_grad_times,
                "t_offset": self.t_offset,
                "defense": None if self.defense is None
                else self.defense.to_dict(),
                "algorithm": None if self.algorithm is None
                else self.algorithm.to_dict(),
                "serve": None if self.serve is None
                else self.serve.to_dict(),
                "telemetry": None if self.telemetry is None
                else self.telemetry.to_dict()}

    @staticmethod
    def from_dict(d: dict) -> "World":
        return World(topology=_topology_from_dict(d["topology"]),
                     workers=WorkerModel.from_dict(d.get("workers", {})),
                     links=LinkModel.from_dict(d.get("links", {})),
                     faults=tuple(_fault_from_dict(f)
                                  for f in d.get("faults", ())),
                     channel=None if d.get("channel") is None
                     else ChannelModel.from_dict(d["channel"]),
                     comms_per_grad=d.get("comms_per_grad", 1.0),
                     jitter_grad_times=d.get("jitter_grad_times", True),
                     t_offset=d.get("t_offset", 0.0),
                     defense=None if d.get("defense") is None
                     else AdaptiveDefense.from_dict(d["defense"]),
                     algorithm=None if d.get("algorithm") is None
                     else Algorithm.from_dict(d["algorithm"]),
                     serve=None if d.get("serve") is None
                     else ServeLoad.from_dict(d["serve"]),
                     telemetry=None if d.get("telemetry") is None
                     else Telemetry.from_dict(d["telemetry"]))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "World":
        return World.from_dict(json.loads(s))


# --------------------------------------------------------------------- sweeps

@dataclasses.dataclass(frozen=True)
class WorldSweep:
    """A declarative grid of worlds — the unit the batched replay consumes.

    The paper's claims are sweep-shaped (gain vs. topology, vs. Byzantine
    fraction, vs. staleness horizon); a ``WorldSweep`` names one such grid:
    explicit ``worlds`` (or ``WorldSweep.over(base, field=[...], ...)`` for
    a cartesian product of ``World`` field overrides) crossed with
    ``seeds``.  ``compile(rounds)`` lowers the whole grid host-side to one
    schedule per point — seed-major within each world, so
    ``points()[i]`` names what ``compile()[i]`` replays — ready for
    ``Simulator.run_worlds`` to replay in ONE compiled scan (DESIGN.md
    §11).  All worlds must share one worker count; ragged event shapes
    across the grid are the batcher's problem (identity padding), not the
    sweep's.
    """

    worlds: tuple[World, ...]
    seeds: tuple[int, ...] = (0,)

    def __post_init__(self):
        object.__setattr__(self, "worlds", tuple(self.worlds))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        if not self.worlds:
            raise ValueError("WorldSweep needs at least one world")
        if not self.seeds:
            raise ValueError("WorldSweep needs at least one seed")
        for i, w in enumerate(self.worlds):
            if not isinstance(w, World):
                raise ValueError(f"worlds[{i}] must be a World, "
                                 f"got {type(w).__name__}")
        n = self.worlds[0].n
        bad = [i for i, w in enumerate(self.worlds) if w.n != n]
        if bad:
            raise ValueError(f"all worlds must share one worker count "
                             f"(worlds[0].n = {n}); worlds {bad} differ")

    @staticmethod
    def over(base: World, seeds=(0,), **axes) -> "WorldSweep":
        """Cartesian product of ``World`` field overrides on ``base``.

        Each keyword names a ``World`` dataclass field (``topology``,
        ``channel``, ``comms_per_grad``, ...) with a sequence of values;
        the grid is built with ``dataclasses.replace`` in the keyword
        order given (last axis fastest), re-validating every point.
        """
        fields = {f.name for f in dataclasses.fields(World)}
        bad = sorted(set(axes) - fields)
        if bad:
            raise ValueError(f"unknown World field(s) {bad}; sweep axes "
                             f"must name one of {sorted(fields)}")
        if not axes:
            return WorldSweep((base,), seeds=tuple(seeds))
        names = list(axes)
        worlds = tuple(
            dataclasses.replace(base, **dict(zip(names, values)))
            for values in itertools.product(*[list(axes[k])
                                              for k in names]))
        return WorldSweep(worlds, seeds=tuple(seeds))

    @property
    def n(self) -> int:
        return self.worlds[0].n

    @property
    def size(self) -> int:
        return len(self.worlds) * len(self.seeds)

    def points(self) -> list[tuple[World, int]]:
        """The flattened (world, seed) grid, seed-major within a world."""
        return [(w, s) for w in self.worlds for s in self.seeds]

    def compile(self, rounds: int | None = None) -> list:
        """One ``events.Schedule`` per grid point (host-side; the whole
        grid is plain numpy event data before any jit runs)."""
        return [w.compile(rounds, seed=s) for w, s in self.points()]

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"worlds": [w.to_dict() for w in self.worlds],
                "seeds": list(self.seeds)}

    @staticmethod
    def from_dict(d: dict) -> "WorldSweep":
        return WorldSweep(tuple(World.from_dict(w) for w in d["worlds"]),
                          seeds=tuple(d.get("seeds", (0,))))

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_json(s: str) -> "WorldSweep":
        return WorldSweep.from_dict(json.loads(s))


# --------------------------------------------------------------------------
# Shard-aware schedule compilation, schedule-level half (DESIGN.md §16).
# The stream-level partition lives in events.shard_partition; these two
# operate on compiled Schedules — the form tests and telemetry consume.
# --------------------------------------------------------------------------

def shard_cross_reads(sched, n_shards: int) -> np.ndarray:
    """(R,) per-round cross-shard boundary-read counts of a compiled
    schedule under an ``n_shards``-way equal split of the worker axis —
    the host-side exact column behind the telemetry ``bytes_cross``
    split (boundary rows x flat-row width).  Returns zeros when the
    worker axis does not divide evenly (the replay falls back to one
    device, so nothing crosses a boundary)."""
    from .telemetry import cross_shard_reads

    return cross_shard_reads(sched.partners, sched.event_mask, n_shards)


def shard_lag_schedule(sched, n_shards: int, lag: int):
    """The per-event delay REFERENCE of a lag-``lag`` sharded replay: the
    same schedule with every cross-shard read's staleness floored at
    ``lag`` (clamped to rounds elapsed, the ``ChannelModel`` guarantee).

    ``Simulator.run_worlds(mesh=MeshReplay(mesh, lag=L))`` on ``sched``
    is pinned bitwise against the SINGLE-DEVICE replay of
    ``shard_lag_schedule(sched, NS, L)`` — the permute ring is exactly a
    ``DelayProcess`` whose floor is the ring lag on boundary edges
    (tests/test_sharded_replay.py).
    """
    from .channel import STALE_KEY

    partners = np.asarray(sched.partners)
    R, K, n = partners.shape
    if lag <= 0 or n_shards <= 1:
        return sched
    if n % n_shards != 0:
        raise ValueError(f"worker axis {n} is not divisible by "
                         f"{n_shards} shards")
    ws = n // n_shards
    rdr = np.arange(n, dtype=np.int64)
    cross = ((partners != rdr)
             & (partners.astype(np.int64) // ws != rdr // ws)
             & np.asarray(sched.event_mask)[..., None])
    extras = sched.extras_dict()
    stale = np.asarray(extras.get(STALE_KEY,
                                  np.zeros((R, K, n), np.int32)), np.int64)
    rounds_elapsed = np.arange(R, dtype=np.int64)[:, None, None]
    eff = np.minimum(np.maximum(stale, int(lag)), rounds_elapsed)
    extras[STALE_KEY] = np.where(cross, eff, stale).astype(np.int32)
    return dataclasses.replace(sched, extras=extras)
