"""SPMD gossip: the paper's p2p averaging mapped onto a TPU mesh axis.

A decentralized *worker* is one slice of the mesh along a dedicated "worker"
axis (a pod or pod-slice; inside, the replica is FSDP/TP sharded over the
remaining axes).  A pairwise averaging event between workers i and j is a
`jax.lax.ppermute` along the worker axis: every chip exchanges only its own
parameter *shard* with the homologous chip of the partner worker, so one
gossip event moves P/(chips-per-worker) bytes per link — and it is a single
collective-permute XLA can overlap with compute, unlike a blocking multi-stage
all-reduce.

`ppermute` requires a *static* permutation, while the algorithm samples random
matchings.  We therefore decompose the edge set into a static *matching bank*
via greedy edge coloring (every color class is a matching; by Vizing's theorem
at most max_degree+1 classes) and `lax.switch` over the bank with a traced
matching index.  Sampling bank entries uniformly realizes uniform edge
frequencies — the same assumption under which chi1/chi2 are computed (paper
App E.2).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .a2cid2 import A2CiD2Params, apply_mixing
from .channel import ChannelModel
from .engine import FlatGossipEngine
from .graphs import Graph, TopologySchedule

PyTree = Any


def bank_corruption(bank: np.ndarray, adversary) -> np.ndarray:
    """Per-matching received-value corruption offsets for a static bank.

    Returns (M, n) float32: entry [k, i] is the multiplier offset worker i
    applies to the value it receives under matching k (0 = honest) — the
    Byzantine edge set is STATIC, so mesh trainers resolve the channel's
    ``corrupt`` axis to one constant vector per bank entry, exactly like
    the matchings themselves (no traced adversary state).
    """
    M, n = bank.shape
    out = np.zeros((M, n), np.float32)
    if adversary is None:
        return out
    byz = adversary.lookup(n)
    off = np.float32(adversary.corrupt_offset())
    for k in range(M):
        for i in range(n):
            j = int(bank[k, i])
            if j != i and byz[i, j]:
                out[k, i] = off
    return out


def check_mesh_channel(channel: ChannelModel | None,
                       permute_ring: bool = False) -> None:
    """Mesh trainers model the statically-resolvable channel axes
    (always-on adversary, drops) plus — when the trainer carries the
    bounded-staleness permute ring (``permute_ring=True``, DESIGN.md
    §16) — message delay: each worker keeps a ring of its OWN past flat
    states and resolves a read's staleness before the collective permute
    ships it, so no worker ever needs its peers' history.  Only the
    ``DelayProcess`` kinds the ring can sample ("uniform", "fixed") are
    routed; an unknown kind — or any delay on a ring-less trainer — is
    rejected loudly rather than silently mis-modeled.  A duty-cycled
    adversary (prob < 1) stays rejected either way: it needs
    pair-correlated corruption draws the per-worker SPMD event loop
    cannot share."""
    if channel is None:
        return
    if not isinstance(channel, ChannelModel):
        raise ValueError("channel must be a ChannelModel, "
                         f"got {type(channel).__name__}")
    if channel.horizon > 0:
        if not permute_ring:
            raise ValueError(
                "this mesh trainer does not emulate message delay (stale "
                "partner reads need a ring buffer of past states) — "
                "replay delayed worlds with Simulator.run_world, use a "
                "permute-ring trainer, or drop the DelayProcess from the "
                "trainer's channel")
        if channel.delay.kind not in ("uniform", "fixed"):
            raise ValueError(
                "the bounded-staleness permute ring samples 'uniform' "
                "and 'fixed' DelayProcess kinds only, got "
                f"{channel.delay.kind!r} — replay this delay law with "
                "Simulator.run_world")
    if channel.adversary is not None and channel.adversary.prob < 1.0:
        raise ValueError(
            "mesh trainers model always-on Byzantine edges only (a "
            "prob < 1 duty cycle needs per-exchange corruption draws "
            "shared across the pair) — replay duty-cycled adversaries "
            "with Simulator.run_world, or set ByzantineEdges.prob = 1")


def matching_bank(graph: Graph) -> np.ndarray:
    """Decompose edges into matchings via greedy edge coloring.

    Returns (M, n) int32: bank[k, i] = partner of worker i in matching k
    (i itself if idle).  Union over k covers every edge exactly once.
    An edgeless graph (e.g. a fully-churned phase) yields one identity row.
    """
    import networkx as nx

    if not graph.edges:
        return np.arange(graph.n, dtype=np.int32)[None, :]

    G = nx.Graph()
    G.add_nodes_from(range(graph.n))
    G.add_edges_from(graph.edges)
    coloring = nx.coloring.greedy_color(nx.line_graph(G), strategy="largest_first")
    n_colors = max(coloring.values()) + 1
    bank = np.tile(np.arange(graph.n, dtype=np.int32), (n_colors, 1))
    for edge, color in coloring.items():
        i, j = edge
        bank[color, i] = j
        bank[color, j] = i
    return bank


def bank_edge_rates(graph: Graph, bank: np.ndarray) -> np.ndarray:
    """Per-matching sampling weights reproducing the graph's edge rates.

    For uniform-rate graphs this is uniform over the bank. For non-uniform
    rates we weight each matching by the mean rate of its edges (approximate;
    exact per-edge rates would need non-maximal matchings).
    """
    rates = {tuple(sorted(e)): r for e, r in zip(graph.edges, graph.rates)}
    w = np.zeros(bank.shape[0])
    for k in range(bank.shape[0]):
        edge_rs = [rates[(i, int(j))] for i, j in enumerate(bank[k]) if int(j) > i]
        w[k] = float(np.mean(edge_rs)) if edge_rs else 0.0
    s = w.sum()
    return w / s if s > 0 else np.full(bank.shape[0], 1.0 / bank.shape[0])


def phase_banks(tsched: TopologySchedule
                ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-phase (matching bank, sampling probs) for a time-varying topology.

    Each phase's bank is rebuilt from its *effective* graph (churned workers
    isolated — their rows are identity in every matching, so a detached
    worker's flat-buffer row is a fixed point of the gossip loop).  Clock
    continuity is the trainers' concern: the bank switch itself carries no
    state, so phases swap by swapping static branch tables between steps.
    """
    out = []
    for ph in tsched.phases:
        g = ph.effective_graph()
        bank = matching_bank(g)
        out.append((bank, bank_edge_rates(g, bank)))
    return out


def world_banks(world, rounds: int | None = None, seed: int = 0
                ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-segment (matching bank, sampling probs) for a declarative World
    (``core/world.py``) — the mesh-trainer counterpart of ``World.compile``.

    Segments come from ``World.segment_graphs`` (link-model rates applied,
    churned workers isolated), so the banks line up one-to-one with the
    compiled schedule's phase structure under the same (rounds, seed).
    """
    out = []
    for g in world.segment_graphs(rounds, seed):
        bank = matching_bank(g)
        out.append((bank, bank_edge_rates(g, bank)))
    return out


class DelayRing(NamedTuple):
    """One worker's bounded-staleness ring: ``buf`` holds its own last H
    flat snapshots (one push per super-step), ``round`` the index of the
    last pushed round (-1 before the first push).  The SENDER resolves a
    read's staleness against this ring before the collective permute
    ships the value — distribution-equal to the simulator's per-reader
    draws (each directed read has exactly one sender), with no peer
    history held anywhere."""

    buf: jax.Array    # (H, D) own past flat states, slot = round % H
    round: jax.Array  # () int32 — last pushed round index


class GossipMixer:
    """Applies A2CiD2 events across the worker mesh axis (use inside shard_map
    or under a mesh with explicit out-of-shard_map collectives via pjit —
    here we target shard_map)."""

    def __init__(self, graph: Graph, params: A2CiD2Params,
                 axis_name: str = "worker", backend: str = "auto",
                 channel: ChannelModel | None = None,
                 robust_clip: float | None = None,
                 robust_rule: str = "trim"):
        check_mesh_channel(channel, permute_ring=True)
        self.graph = graph
        self.params = params
        self.axis_name = axis_name
        self.backend = backend  # fused-kernel backend for the event loop
        self.bank = matching_bank(graph)
        self.bank_probs = bank_edge_rates(graph, self.bank)
        # unreliable-channel axes a mesh can model (DESIGN.md §10): static
        # Byzantine edges become per-matching corruption vectors, message
        # drops thin the sampled event stream, robust_clip/robust_rule
        # engage the trimmed/clipped m-term in the fused channel kernel
        self.channel = channel
        self.robust_clip = robust_clip
        self.robust_rule = robust_rule
        self.drop_prob = 0.0 if channel is None else channel.drop_prob
        self.bank_corrupt = bank_corruption(
            self.bank, None if channel is None else channel.adversary)
        # message delay rides the bounded-staleness permute ring
        # (``DelayRing``): trivial delay lowers to None so the ring-free
        # event loop stays bitwise
        d = None if channel is None else channel.delay
        self.delay = None if d is None or d.is_trivial else d

    def _engine(self, x: PyTree) -> FlatGossipEngine:
        return FlatGossipEngine.for_pytree(x, self.params, stacked=False,
                                           backend=self.backend,
                                           robust_clip=self.robust_clip,
                                           robust_rule=self.robust_rule)

    # ------------------------------------------------- delay (permute ring)
    def init_ring(self, x: PyTree) -> DelayRing | None:
        """Fresh ring for this worker's replica (None without delay)."""
        if self.delay is None:
            return None
        bx = self._engine(x).pack_local(x)
        return DelayRing(jnp.tile(bx[None], (self.delay.horizon, 1)),
                         jnp.asarray(-1, jnp.int32))

    def push_ring(self, ring: DelayRing | None, x: PyTree
                  ) -> DelayRing | None:
        """Snapshot this worker's replica at its gradient tick — the same
        cadence the simulator's channel ring rotates on."""
        if ring is None:
            return None
        bx = self._engine(x).pack_local(x)
        r = ring.round + 1
        return DelayRing(ring.buf.at[r % self.delay.horizon].set(bx), r)

    def sample_stale(self, key: jax.Array, num_events: int) -> jax.Array:
        """(E,) raw staleness draws from the channel's DelayProcess law
        (0 = fresh); ``gossip_events`` clamps them to the rounds actually
        pushed, exactly like the schedule compiler."""
        d = self.delay
        k1, k2 = jax.random.split(key)
        hit = jax.random.bernoulli(k1, d.prob, (num_events,))
        if d.kind == "fixed":
            offs = jnp.full((num_events,), d.horizon, jnp.int32)
        else:
            offs = jax.random.randint(k2, (num_events,), 1, d.horizon + 1,
                                      dtype=jnp.int32)
        return jnp.where(hit, offs, 0).astype(jnp.int32)

    # ------------------------------------------------------------ primitives
    def _perm(self, k: int) -> list[tuple[int, int]]:
        return [(i, int(j)) for i, j in enumerate(self.bank[k])]

    def mix(self, x: PyTree, x_tilde: PyTree, dt: jax.Array
            ) -> tuple[PyTree, PyTree]:
        """Lazy continuous mixing exp(dt*A) — dt is this worker's local scalar."""
        return apply_mixing(x, x_tilde, self.params.eta, dt)

    def gossip_events(self, x: PyTree, x_tilde: PyTree,
                      matching_idxs: jax.Array, dts: jax.Array, *,
                      ring: DelayRing | None = None,
                      stale: jax.Array | None = None
                      ) -> tuple[PyTree, PyTree]:
        """Apply a fixed-length sequence of (mix, p2p) events via lax.scan.

        matching_idxs (E,) int32 — bank index per event (negative = skip),
        dts (E,) — elapsed worker-local time before each event.
        ring/stale — bounded-staleness delay emulation: ``stale`` (E,)
        int32 staleness draws (``sample_stale``); each event's outgoing
        value is resolved against this worker's own ``ring`` before the
        collective permute, so a stale read costs the same one permute.

        The event loop runs on the flat-buffer engine: the replica pytree is
        packed ONCE into a (D,) vector, each event is one collective permute
        plus one fused [p2p, mix-to-next-event] sweep (see DESIGN.md), and
        the pytree is rebuilt once at the end — no per-leaf kernel dispatch
        or flatten/unflatten inside the hot loop.  The regrouping

            mix(dt_0), P_0, mix(dt_1), P_1, ... =
            [mix(dt_0)] [P_0, mix(dt_1)] ... [P_{E-1}, mix(0)]

        is exact (semigroup property), so the dynamic is unchanged.
        """
        if matching_idxs.shape[0] == 0:
            return x, x_tilde
        engine = self._engine(x)
        bx = engine.pack_local(x)
        bxt = engine.pack_local(x_tilde)
        bx, bxt = engine.mix(bx, bxt, dts[0])
        dt_next = jnp.concatenate([dts[1:], jnp.zeros((1,), dts.dtype)])

        def make_branch(k: int):
            perm = self._perm(k)
            return lambda v: jax.lax.ppermute(v, self.axis_name, perm)

        branches = [make_branch(k) for k in range(self.bank.shape[0])]
        channel_on = (self.robust_clip is not None
                      or bool(self.bank_corrupt.any()))
        corrupt_tab = jnp.asarray(self.bank_corrupt)
        delayed = self.delay is not None and ring is not None \
            and stale is not None
        xs = (matching_idxs, dt_next, stale) if delayed \
            else (matching_idxs, dt_next)
        # per-matching involvement: an idle worker (bank[k, i] == i)
        # receives its own payload back, which must be its FRESH state —
        # an idle event is an exact no-op even when it drew a stale offset
        involved_tab = jnp.asarray(
            self.bank != np.arange(self.bank.shape[1], dtype=np.int32))

        def body(carry, ev):
            bx, bxt = carry
            payload = bx
            if delayed:
                idx, dtn, s = ev
                inv = involved_tab[jnp.maximum(idx, 0),
                                   jax.lax.axis_index(self.axis_name)]
                # clamp to the rounds actually pushed, resolve against
                # this worker's OWN ring, ship the resolved value
                s = jnp.where(inv,
                              jnp.minimum(s, jnp.maximum(ring.round, 0)),
                              0)
                slot = jnp.where(s > 0,
                                 (ring.round - s) % self.delay.horizon, 0)
                payload = jnp.where(s > 0, ring.buf[slot], bx)
            else:
                idx, dtn = ev
            xp = jax.lax.switch(jnp.maximum(idx, 0), branches, payload)
            # skipped/dropped events keep the pure-mix segment: xp = x => m=0
            xp = jnp.where(idx < 0, bx, xp)
            if channel_on:
                wid = jax.lax.axis_index(self.axis_name)
                c = jnp.where(idx < 0, 0.0,
                              corrupt_tab[jnp.maximum(idx, 0), wid])
                bx, bxt = engine.channel_batch_local(bx, bxt, xp, c, dtn)
            else:
                bx, bxt = engine.batch_local(bx, bxt, xp, dtn)
            return (bx, bxt), None

        (bx, bxt), _ = jax.lax.scan(body, (bx, bxt), xs)
        return engine.unpack_local(bx), engine.unpack_local(bxt)

    # ------------------------------------------------------------ schedules
    def sample_event_batch(self, key: jax.Array, num_events: int
                           ) -> tuple[jax.Array, jax.Array]:
        """Traced sampling of (matching_idxs, dts) for one super-step.

        Poisson thinning: we draw `num_events` slots; each is active with
        probability rate/num_events is approximated by always-active slots at
        the expected rate (slot count chosen by the host from the Poisson law,
        like the paper's implementation).  dts are Exp(1/num_events) gaps.
        """
        k3 = None
        if self.drop_prob > 0.0:
            # extra split only when drops can occur — a drop-free mixer
            # keeps the pre-channel seeded event stream bit-for-bit
            key, k3 = jax.random.split(key)
        k1, k2 = jax.random.split(key)
        logits = jnp.log(jnp.asarray(self.bank_probs, dtype=jnp.float32))
        idxs = jax.random.categorical(k1, logits, shape=(num_events,))
        gaps = jax.random.exponential(k2, (num_events,)) / max(num_events, 1)
        if k3 is not None:
            # channel drops: the matching never happens (idx < 0 = skip),
            # but simulated time still elapses — the mix segment survives
            dropped = jax.random.bernoulli(k3, self.drop_prob,
                                           (num_events,))
            idxs = jnp.where(dropped, -1, idxs)
        return idxs.astype(jnp.int32), gaps


def consensus_distance_spmd(x: PyTree, axis_name: str = "worker") -> jax.Array:
    """||pi x||^2 / n across the worker axis (per-chip shard contribution;
    callers psum over the remaining mesh axes if the replica is sharded)."""
    def leaf(a):
        mean = jax.lax.pmean(a, axis_name)
        return jax.lax.psum(jnp.sum((a - mean) ** 2), axis_name) / jax.lax.psum(
            jnp.ones(()), axis_name)
    return sum(leaf(a) for a in jax.tree.leaves(x))
