"""SPMD gossip: the paper's p2p averaging mapped onto a TPU mesh axis.

A decentralized *worker* is one slice of the mesh along a dedicated "worker"
axis (a pod or pod-slice; inside, the replica is FSDP/TP sharded over the
remaining axes).  A pairwise averaging event between workers i and j is a
`jax.lax.ppermute` along the worker axis: every chip exchanges only its own
parameter *shard* with the homologous chip of the partner worker, so one
gossip event moves P/(chips-per-worker) bytes per link — and it is a single
collective-permute XLA can overlap with compute, unlike a blocking multi-stage
all-reduce.

`ppermute` requires a *static* permutation, while the algorithm samples random
matchings.  We therefore decompose the edge set into a static *matching bank*
via greedy edge coloring (every color class is a matching; by Vizing's theorem
at most max_degree+1 classes) and `lax.switch` over the bank with a traced
matching index.  Sampling bank entries uniformly realizes uniform edge
frequencies — the same assumption under which chi1/chi2 are computed (paper
App E.2).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .a2cid2 import A2CiD2Params, apply_mixing
from .graphs import Graph

PyTree = Any


def matching_bank(graph: Graph) -> np.ndarray:
    """Decompose edges into matchings via greedy edge coloring.

    Returns (M, n) int32: bank[k, i] = partner of worker i in matching k
    (i itself if idle).  Union over k covers every edge exactly once.
    """
    import networkx as nx

    G = nx.Graph()
    G.add_nodes_from(range(graph.n))
    G.add_edges_from(graph.edges)
    coloring = nx.coloring.greedy_color(nx.line_graph(G), strategy="largest_first")
    n_colors = max(coloring.values()) + 1
    bank = np.tile(np.arange(graph.n, dtype=np.int32), (n_colors, 1))
    for edge, color in coloring.items():
        i, j = edge
        bank[color, i] = j
        bank[color, j] = i
    return bank


def bank_edge_rates(graph: Graph, bank: np.ndarray) -> np.ndarray:
    """Per-matching sampling weights reproducing the graph's edge rates.

    For uniform-rate graphs this is uniform over the bank. For non-uniform
    rates we weight each matching by the mean rate of its edges (approximate;
    exact per-edge rates would need non-maximal matchings).
    """
    rates = {tuple(sorted(e)): r for e, r in zip(graph.edges, graph.rates)}
    w = np.zeros(bank.shape[0])
    for k in range(bank.shape[0]):
        edge_rs = [rates[(i, int(j))] for i, j in enumerate(bank[k]) if int(j) > i]
        w[k] = float(np.mean(edge_rs)) if edge_rs else 0.0
    s = w.sum()
    return w / s if s > 0 else np.full(bank.shape[0], 1.0 / bank.shape[0])


class GossipMixer:
    """Applies A2CiD2 events across the worker mesh axis (use inside shard_map
    or under a mesh with explicit out-of-shard_map collectives via pjit —
    here we target shard_map)."""

    def __init__(self, graph: Graph, params: A2CiD2Params,
                 axis_name: str = "worker"):
        self.graph = graph
        self.params = params
        self.axis_name = axis_name
        self.bank = matching_bank(graph)
        self.bank_probs = bank_edge_rates(graph, self.bank)

    # ------------------------------------------------------------ primitives
    def _perm(self, k: int) -> list[tuple[int, int]]:
        return [(i, int(j)) for i, j in enumerate(self.bank[k])]

    def p2p_round(self, x: PyTree, x_tilde: PyTree, matching_idx: jax.Array
                  ) -> tuple[PyTree, PyTree]:
        """One pairwise-averaging event, selected from the static bank."""

        def make_branch(k: int):
            perm = self._perm(k)

            def branch(operand):
                x, x_tilde = operand
                xp = jax.tree.map(
                    lambda a: jax.lax.ppermute(a, self.axis_name, perm), x)
                new_x = jax.tree.map(
                    lambda a, b: a - self.params.alpha * (a - b), x, xp)
                new_t = jax.tree.map(
                    lambda at, a, b: at - self.params.alpha_tilde * (a - b),
                    x_tilde, x, xp)
                return new_x, new_t

            return branch

        branches = [make_branch(k) for k in range(self.bank.shape[0])]
        return jax.lax.switch(matching_idx, branches, (x, x_tilde))

    def mix(self, x: PyTree, x_tilde: PyTree, dt: jax.Array
            ) -> tuple[PyTree, PyTree]:
        """Lazy continuous mixing exp(dt*A) — dt is this worker's local scalar."""
        return apply_mixing(x, x_tilde, self.params.eta, dt)

    def gossip_events(self, x: PyTree, x_tilde: PyTree,
                      matching_idxs: jax.Array, dts: jax.Array
                      ) -> tuple[PyTree, PyTree]:
        """Apply a fixed-length sequence of (mix, p2p) events via lax.scan.

        matching_idxs (E,) int32 — bank index per event (negative = skip),
        dts (E,) — elapsed worker-local time before each event.
        """

        def body(carry, ev):
            x, x_tilde = carry
            idx, dt = ev
            x, x_tilde = self.mix(x, x_tilde, dt)
            skip = idx < 0
            x2, t2 = self.p2p_round(x, x_tilde, jnp.maximum(idx, 0))
            x = jax.tree.map(lambda a, b: jnp.where(skip, a, b), x, x2)
            x_tilde = jax.tree.map(lambda a, b: jnp.where(skip, a, b), x_tilde, t2)
            return (x, x_tilde), None

        (x, x_tilde), _ = jax.lax.scan(body, (x, x_tilde),
                                       (matching_idxs, dts))
        return x, x_tilde

    # ------------------------------------------------------------ schedules
    def sample_event_batch(self, key: jax.Array, num_events: int
                           ) -> tuple[jax.Array, jax.Array]:
        """Traced sampling of (matching_idxs, dts) for one super-step.

        Poisson thinning: we draw `num_events` slots; each is active with
        probability rate/num_events is approximated by always-active slots at
        the expected rate (slot count chosen by the host from the Poisson law,
        like the paper's implementation).  dts are Exp(1/num_events) gaps.
        """
        k1, k2 = jax.random.split(key)
        logits = jnp.log(jnp.asarray(self.bank_probs, dtype=jnp.float32))
        idxs = jax.random.categorical(k1, logits, shape=(num_events,))
        gaps = jax.random.exponential(k2, (num_events,)) / max(num_events, 1)
        return idxs.astype(jnp.int32), gaps


def consensus_distance_spmd(x: PyTree, axis_name: str = "worker") -> jax.Array:
    """||pi x||^2 / n across the worker axis (per-chip shard contribution;
    callers psum over the remaining mesh axes if the replica is sharded)."""
    def leaf(a):
        mean = jax.lax.pmean(a, axis_name)
        return jax.lax.psum(jnp.sum((a - mean) ** 2), axis_name) / jax.lax.psum(
            jnp.ones(()), axis_name)
    return sum(leaf(a) for a in jax.tree.leaves(x))
