"""Compiled per-round telemetry: the flight recorder's in-scan side
(DESIGN.md §15).

A ``Telemetry(...)`` spec on ``World``/``Simulator.run_schedule`` lowers to
per-round metric COLUMNS riding the scan carry exactly the way
``DefenseTrace`` does — metrics are data on the carry, never host
callbacks, so a telemetry-enabled replay stays one ``lax.scan`` / one
dispatch and a ``WorldSweep`` grid keeps its one-trace invariant (the
spec is a static jit argument shared by every world of a batch).

Two kinds of columns, split by where the information lives:

  * **runtime columns** (only knowable inside the scan — they depend on
    the evolving state): per-round counts of APPLIED vs REJECTED directed
    reads and the first two moments of the admitted channel-delta norms.
    These accumulate across a round's comm steps in a tiny f32 carry
    tuple (scalars serially, (B,) world-batched) and are emitted + reset
    at each gradient tick, exactly like the defense counters.
  * **schedule columns** (pure schedule data — recomputing them in-scan
    would waste carry width): scheduled/dropped read counts, the
    staleness-bucket histogram, per-worker participation.  These are
    derived host-side by :func:`schedule_columns` from the SAME arrays the
    scan consumes, so they are exact, and cost nothing on device.

Bytes moved are runtime x layout: each applied directed read transfers
one flat row — ``row_bytes`` from the ``FlatLayout`` dtype widths — so
``bytes_moved = applied * row_bytes`` (attached host-side after the
replay returns).

``telemetry=None`` is a BITWISE no-op: the spec is a static argument, so
the ``None`` trace contains exactly the pre-telemetry jaxpr — pinned in
tests/test_telemetry.py against both backends and both replay flavors.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, NamedTuple

import numpy as np

from .channel import CORRUPT_KEY, DROP_KEY, STALE_KEY


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Declarative, serializable per-round telemetry spec.

    staleness_buckets — upper edges (inclusive) of the staleness
      histogram; reads bucket as [fresh, <=b1, <=b2, ..., overflow].
    norm_moments — record sum and sum-of-squares of admitted delta
      norms per round (the closed-loop controller's input signal).
    participation — per-worker directed-read counts per round.
    bytes_moved — applied reads x flat-row bytes per round.

    Hashable (tuple fields only): the spec doubles as a static jit
    argument, so every distinct spec — not every world — costs a trace.
    """

    staleness_buckets: tuple[int, ...] = (1, 2, 4, 8)
    norm_moments: bool = True
    participation: bool = True
    bytes_moved: bool = True
    # worker-shard count of the replay the spec instruments (DESIGN.md
    # §16): > 1 splits the bytes column into intra-shard vs cross-shard
    # moved bytes (cross = permute-ring boundary rows x flat-row width).
    # 0 (the default) keeps the pre-sharding trace shape exactly.
    shards: int = 0

    def __post_init__(self):
        try:
            edges = tuple(int(b) for b in self.staleness_buckets)
        except (TypeError, ValueError):
            raise ValueError("Telemetry.staleness_buckets must be ints, "
                             f"got {self.staleness_buckets!r}") from None
        if any(b <= 0 for b in edges) or list(edges) != sorted(set(edges)):
            raise ValueError("Telemetry.staleness_buckets must be strictly "
                             f"increasing positive ints, got {edges}")
        object.__setattr__(self, "staleness_buckets", edges)
        if int(self.shards) < 0:
            raise ValueError(f"Telemetry.shards must be >= 0, "
                             f"got {self.shards}")
        object.__setattr__(self, "shards", int(self.shards))

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"staleness_buckets": list(self.staleness_buckets),
                "norm_moments": self.norm_moments,
                "participation": self.participation,
                "bytes_moved": self.bytes_moved,
                "shards": self.shards}

    @staticmethod
    def from_dict(d: dict) -> "Telemetry":
        return Telemetry(
            staleness_buckets=tuple(d.get("staleness_buckets", (1, 2, 4, 8))),
            norm_moments=d.get("norm_moments", True),
            participation=d.get("participation", True),
            bytes_moved=d.get("bytes_moved", True),
            shards=d.get("shards", 0))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "Telemetry":
        return Telemetry.from_dict(json.loads(s))


class TelemetryTrace(NamedTuple):
    """Per-round telemetry columns of one replay.

    Runtime columns (jax arrays, (R,) serial / (B, R) world-batched):
    ``applied``, ``rejected``, ``norm_sum``, ``norm_sq_sum``,
    ``bytes_moved``.  Schedule columns (numpy, exact): ``scheduled``,
    ``dropped`` (same shapes) and ``stale_hist`` ((R, nb) /
    (B, R, nb)), ``participation`` ((R, n) / (B, R, n)).  ``row_bytes``
    is the flat-row transfer size the bytes column used.
    """

    applied: Any            # admitted directed reads per round
    rejected: Any           # robust/defense-rejected directed reads
    norm_sum: Any           # sum of admitted delta norms (None if off)
    norm_sq_sum: Any        # sum of squared admitted delta norms
    scheduled: Any          # directed reads the schedule asked for
    dropped: Any            # reads erased by channel drops
    stale_hist: Any         # staleness histogram (None if no buckets)
    participation: Any      # (.., n) per-worker read counts (None if off)
    bytes_moved: Any        # applied * row_bytes (None if off)
    row_bytes: int = 0
    # sharded-replay wire split (None unless ``Telemetry.shards`` set):
    # each SURVIVING scheduled read moves one flat row over exactly one
    # path — an intra-shard gather or a permute-ring boundary hop —
    # before any robust/defense rejection, so the split is exact
    # schedule-side accounting (cross = boundary rows x row_bytes)
    cross_reads: Any = None  # permute-ring boundary reads per round
    bytes_intra: Any = None  # (scheduled - dropped - cross) * row_bytes
    bytes_cross: Any = None  # cross_reads * row_bytes


def row_bytes_of(layout=None, tree=None) -> int:
    """Bytes one directed partner read moves: the REAL (unpadded) flat
    row width times the buffer dtype — from a ``FlatLayout`` when the
    engine path built one, else summed over the pytree's leaves."""
    if layout is not None:
        return int(layout.d_real) * int(np.dtype(layout.buf_dtype).itemsize)
    if tree is not None:
        import jax

        total = 0
        for leaf in jax.tree.leaves(tree):
            # leaves are (n, ...) worker-stacked: one row is the per-worker
            # slice
            per_row = int(np.prod(leaf.shape[1:])) if leaf.ndim > 1 else 1
            total += per_row * int(np.dtype(leaf.dtype).itemsize)
        return total
    return 0


def stale_bucket_edges(tel: Telemetry) -> np.ndarray:
    return np.asarray(tel.staleness_buckets, np.int64)


def _involved(partners: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """(R, K, n) directed-read involvement from schedule arrays."""
    n = partners.shape[-1]
    return (partners != np.arange(n)) & mask[..., None]


def cross_shard_reads(partners: np.ndarray, mask: np.ndarray,
                      n_shards: int) -> np.ndarray:
    """(R,) cross-shard boundary-read counts of schedule arrays under an
    equal ``n_shards``-way worker split; zeros when the split is trivial
    or ragged (a ragged worker axis falls back to one device, so nothing
    crosses a boundary)."""
    partners = np.asarray(partners)
    R, K, n = partners.shape
    if n_shards <= 1 or n % n_shards != 0:
        return np.zeros(R, np.int64)
    ws = n // n_shards
    rdr = np.arange(n, dtype=np.int64)
    cross = ((partners != rdr)
             & (partners.astype(np.int64) // ws != rdr // ws)
             & np.asarray(mask)[..., None])
    return cross.reshape(R, -1).sum(axis=1).astype(np.int64)


def schedule_columns(tel: Telemetry, sched) -> dict:
    """Host-side exact columns from one compiled ``events.Schedule``.

    Returns numpy arrays keyed ``scheduled`` (R,), ``dropped`` (R,),
    ``stale_hist`` (R, len(buckets)+2), ``participation`` (R, n) —
    the latter two ``None`` when the spec turns them off."""
    partners = np.asarray(sched.partners)
    mask = np.asarray(sched.event_mask)
    R, K, n = partners.shape
    inv = _involved(partners, mask)
    extras = sched.extras_dict()

    drop = extras.get(DROP_KEY)
    dropped = (np.asarray(drop).astype(bool) & mask[..., None]) \
        .reshape(R, -1).sum(axis=1).astype(np.int64) \
        if drop is not None else np.zeros(R, np.int64)
    # drops rewrite the partner involution to identity at compile time
    # (channel.py), so ``inv`` counts only SURVIVING reads — add the
    # erased endpoints back so ``scheduled`` means "asked for" and the
    # budget applied + rejected + dropped == scheduled balances
    scheduled = inv.reshape(R, -1).sum(axis=1).astype(np.int64) + dropped

    stale_hist = None
    if tel.staleness_buckets:
        stale = extras.get(STALE_KEY)
        s = np.asarray(stale, np.int64) if stale is not None \
            else np.zeros((R, K, n), np.int64)
        edges = stale_bucket_edges(tel)
        nb = len(edges) + 2
        # bucket 0 = fresh reads, buckets 1..k = s <= edge_k, last = beyond
        bucket = np.searchsorted(edges, np.where(s > 0, s, 0),
                                 side="left") + 1
        bucket = np.where(s > 0, bucket, 0)
        stale_hist = np.zeros((R, nb), np.int64)
        for b in range(nb):
            stale_hist[:, b] = (inv & (bucket == b)).reshape(R, -1) \
                .sum(axis=1)

    participation = inv.sum(axis=1).astype(np.int64) \
        if tel.participation else None
    cross = cross_shard_reads(partners, mask, tel.shards) \
        if tel.shards > 1 else None
    return {"scheduled": scheduled, "dropped": dropped,
            "stale_hist": stale_hist, "participation": participation,
            "cross_reads": cross}


def batch_schedule_columns(tel: Telemetry, scheds) -> dict:
    """Stack :func:`schedule_columns` over B worlds -> (B, R, ...)."""
    cols = [schedule_columns(tel, s) for s in scheds]

    def stack(key):
        vals = [c[key] for c in cols]
        return None if vals[0] is None else np.stack(vals)

    return {k: stack(k) for k in ("scheduled", "dropped", "stale_hist",
                                  "participation", "cross_reads")}


def finalize_trace(tel: Telemetry, runtime, sched_cols: dict,
                   row_bytes: int) -> TelemetryTrace:
    """Assemble the public :class:`TelemetryTrace` from the scan's raw
    runtime tuple ``(applied, rejected, norm_sum, norm_sq_sum)`` and the
    host-side schedule columns."""
    applied, rejected, norm_sum, norm_sq = runtime
    if not tel.norm_moments:
        norm_sum = norm_sq = None
    bytes_moved = applied * float(row_bytes) if tel.bytes_moved else None
    cross = sched_cols.get("cross_reads")
    bytes_intra = bytes_cross = None
    if tel.bytes_moved and cross is not None:
        survived = sched_cols["scheduled"] - sched_cols["dropped"]
        bytes_cross = cross * float(row_bytes)
        bytes_intra = (survived - cross) * float(row_bytes)
    return TelemetryTrace(
        applied=applied, rejected=rejected,
        norm_sum=norm_sum, norm_sq_sum=norm_sq,
        scheduled=sched_cols["scheduled"], dropped=sched_cols["dropped"],
        stale_hist=sched_cols["stale_hist"],
        participation=sched_cols["participation"],
        bytes_moved=bytes_moved,
        row_bytes=int(row_bytes) if tel.bytes_moved else 0,
        cross_reads=cross, bytes_intra=bytes_intra,
        bytes_cross=bytes_cross)


def trace_summary(tt: TelemetryTrace) -> dict:
    """JSON-able digest of a telemetry trace (benchmark artifacts)."""
    def tot(a):
        return None if a is None else float(np.asarray(a).sum())

    applied = np.asarray(tt.applied, np.float64)
    out = {
        "applied_total": float(applied.sum()),
        "rejected_total": tot(tt.rejected),
        "scheduled_total": tot(tt.scheduled),
        "dropped_total": tot(tt.dropped),
        "row_bytes": tt.row_bytes,
        "bytes_moved_total": tot(tt.bytes_moved),
    }
    if tt.cross_reads is not None:
        out["cross_reads_total"] = tot(tt.cross_reads)
        out["bytes_intra_total"] = tot(tt.bytes_intra)
        out["bytes_cross_total"] = tot(tt.bytes_cross)
    if tt.norm_sum is not None:
        # a diverged world (e.g. a scale-attack arm) pushes its delta
        # norms to inf/nan; digest over the finite rounds only so one
        # blown-up arm doesn't null the whole grid's moment
        ns = np.asarray(tt.norm_sum, np.float64)
        fin = np.isfinite(ns)
        napp = float(applied[fin].sum())
        out["admitted_norm_mean"] = float(ns[fin].sum()) / max(napp, 1.0)
        if not fin.all():
            out["norm_finite_frac"] = float(fin.mean())
    if tt.stale_hist is not None:
        h = np.asarray(tt.stale_hist)
        out["stale_hist_total"] = [int(v) for v in
                                   h.reshape(-1, h.shape[-1]).sum(axis=0)]
    return out
