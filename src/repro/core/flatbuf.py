"""Flat-buffer layout for worker-stacked pytree state (see DESIGN.md).

The gossip-event loop is the repro's unit of cost: every event touches the
whole replica.  Sweeping a pytree leaf-by-leaf pays one kernel dispatch (and
one HBM round trip boundary) per leaf per event.  `FlatLayout` packs the
replica into ONE contiguous buffer with a static layout spec so an event is a
single fused sweep:

  * stacked form  — leaves (W, *shape) -> one (W, D) buffer, worker-major;
  * local form    — leaves (*shape)    -> one (D,) vector (the shard_map /
    per-worker SPMD path);
  * worlds form   — leaves (B, W, *shape) -> one (B, W, D) buffer: B
    independent worlds' replicas stacked on a leading batch axis (the
    many-worlds batched replay, DESIGN.md §11).  The layout spec is
    identical to the stacked form — the batch axis rides above it.

D is the sum of leaf sizes rounded up to a multiple of ``lane`` (128, the TPU
lane width) so the buffer tiles cleanly into Pallas blocks; padding columns
are zeros and stay zero under mixing/p2p/gradient updates (all updates are
linear with 0 fixed point), so reductions over the buffer need no masking.

Leaves are stored as ``buf_dtype``.  By default the dtype is inferred: a
uniform-dtype pytree packs at its own precision (a bf16 model's gossip
event moves bf16 bytes, not f32), mixed floating dtypes pack at the
narrowest dtype that embeds every leaf losslessly (f32, else f64).
Round-tripping is bit-exact for every floating dtype that embeds in
``buf_dtype``; anything else is rejected loudly rather than silently
truncated.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128  # TPU lane width; last-dim tiles are multiples of this

# floating dtypes whose values embed losslessly in each buffer dtype
_EXACT_EMBED = {
    jnp.dtype(jnp.float16): {jnp.dtype(jnp.float16)},
    jnp.dtype(jnp.bfloat16): {jnp.dtype(jnp.bfloat16)},
    jnp.dtype(jnp.float32): {jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16),
                             jnp.dtype(jnp.float16)},
    jnp.dtype(jnp.float64): {jnp.dtype(jnp.float64), jnp.dtype(jnp.float32),
                             jnp.dtype(jnp.bfloat16),
                             jnp.dtype(jnp.float16)},
}


def _infer_buf_dtype(dtypes: set) -> Any:
    """Narrowest buffer dtype that round-trips every leaf dtype exactly."""
    if len(dtypes) == 1:
        (d,) = dtypes
        if d in _EXACT_EMBED:
            return d
        raise TypeError(f"leaf dtype {d} is not a supported buffer dtype")
    for buf in (jnp.dtype(jnp.float32), jnp.dtype(jnp.float64)):
        if dtypes <= _EXACT_EMBED[buf]:
            return buf
    raise TypeError(f"no buffer dtype embeds leaf dtypes {sorted(map(str, dtypes))} exactly")


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static placement of one pytree leaf inside the flat buffer."""

    offset: int              # start column in the flat axis
    size: int                # number of elements (= prod(shape))
    shape: tuple[int, ...]   # per-worker shape (no leading worker axis)
    dtype: Any               # original leaf dtype, restored on unpack


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Static pack/unpack spec between a replica pytree and a flat buffer."""

    treedef: Any
    specs: tuple[LeafSpec, ...]
    d: int                   # padded flat width (multiple of ``lane``)
    d_real: int              # sum of leaf sizes (<= d)
    buf_dtype: Any

    # ------------------------------------------------------------ builders
    @classmethod
    def from_pytree(cls, tree: PyTree, *, stacked: bool = False,
                    worlds: bool = False, buf_dtype=None,
                    lane: int = LANE) -> "FlatLayout":
        """Build a layout from a template pytree (shapes/dtypes only — works
        on concrete arrays, ShapeDtypeStructs, and tracers alike).

        stacked=True strips a leading worker axis from every leaf;
        worlds=True strips a leading (batch, worker) axis pair (implies
        stacked — the per-replica layout is the same either way).
        buf_dtype=None infers the narrowest exact buffer dtype (see module
        docstring); passing one explicitly still validates exactness.
        """
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if buf_dtype is None:
            buf_dtype = _infer_buf_dtype({jnp.dtype(a.dtype) for a in leaves})
        buf_dtype = jnp.dtype(buf_dtype)
        lead = 2 if worlds else (1 if stacked else 0)
        specs = []
        off = 0
        for leaf in leaves:
            shape = tuple(leaf.shape[lead:])
            dtype = jnp.dtype(leaf.dtype)
            if dtype not in _EXACT_EMBED.get(buf_dtype, ()):
                raise TypeError(
                    f"leaf dtype {dtype} does not round-trip exactly "
                    f"through buffer dtype {buf_dtype}")
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            specs.append(LeafSpec(off, size, shape, dtype))
            off += size
        d = ((off + lane - 1) // lane) * lane if off else lane
        return cls(treedef=treedef, specs=tuple(specs), d=d, d_real=off,
                   buf_dtype=buf_dtype)

    # ---------------------------------------------------------------- pack
    def pack(self, tree: PyTree) -> jax.Array:
        """Stacked pytree (leaves (W, *shape)) -> (W, D) buffer."""
        leaves = self.treedef.flatten_up_to(tree)
        w = leaves[0].shape[0]
        cols = [leaf.reshape(w, spec.size).astype(self.buf_dtype)
                for leaf, spec in zip(leaves, self.specs)]
        if self.d > self.d_real:
            cols.append(jnp.zeros((w, self.d - self.d_real), self.buf_dtype))
        return jnp.concatenate(cols, axis=1)

    def unpack(self, buf: jax.Array) -> PyTree:
        """(W, D) buffer -> stacked pytree with original shapes/dtypes."""
        w = buf.shape[0]
        leaves = [
            buf[:, s.offset:s.offset + s.size]
            .astype(s.dtype).reshape((w,) + s.shape)
            for s in self.specs
        ]
        return self.treedef.unflatten(leaves)

    def pack_local(self, tree: PyTree) -> jax.Array:
        """Replica pytree (leaves (*shape)) -> (D,) vector."""
        leaves = self.treedef.flatten_up_to(tree)
        cols = [leaf.reshape(spec.size).astype(self.buf_dtype)
                for leaf, spec in zip(leaves, self.specs)]
        if self.d > self.d_real:
            cols.append(jnp.zeros((self.d - self.d_real,), self.buf_dtype))
        return jnp.concatenate(cols, axis=0)

    def unpack_local(self, vec: jax.Array) -> PyTree:
        """(D,) vector -> replica pytree with original shapes/dtypes."""
        leaves = [
            vec[s.offset:s.offset + s.size].astype(s.dtype).reshape(s.shape)
            for s in self.specs
        ]
        return self.treedef.unflatten(leaves)

    def pack_worlds(self, tree: PyTree) -> jax.Array:
        """World-batched pytree (leaves (B, W, *shape)) -> (B, W, D)."""
        leaves = self.treedef.flatten_up_to(tree)
        b, w = leaves[0].shape[:2]
        cols = [leaf.reshape(b, w, spec.size).astype(self.buf_dtype)
                for leaf, spec in zip(leaves, self.specs)]
        if self.d > self.d_real:
            cols.append(jnp.zeros((b, w, self.d - self.d_real),
                                  self.buf_dtype))
        return jnp.concatenate(cols, axis=2)

    def unpack_worlds(self, buf: jax.Array) -> PyTree:
        """(B, W, D) buffer -> world-batched pytree."""
        b, w = buf.shape[:2]
        leaves = [
            buf[:, :, s.offset:s.offset + s.size]
            .astype(s.dtype).reshape((b, w) + s.shape)
            for s in self.specs
        ]
        return self.treedef.unflatten(leaves)


# ---------------------------------------------------------------------------
# snapshot ring buffer (unreliable-channel stale reads; DESIGN.md §10)
# ---------------------------------------------------------------------------
# The delay axis of the channel subsystem reads partner values from past
# flat states.  The replay engines thread an (H, W, D) ring of the last H
# snapshots through the scan carry, rotated at each gradient tick (one
# snapshot per round — "the state at the end of round r").  Slot indices
# are schedule data resolved host-side ((r - staleness) mod H); the jit'd
# loop only gathers and scatters.

def ring_init(buf: jax.Array, horizon: int) -> jax.Array:
    """(H, W, D) ring seeded with the start state (pre-history snapshots
    equal the initial buffer; staleness clamping guarantees no slot is
    read before round r >= 1 has written it anyway)."""
    if horizon <= 0:
        raise ValueError(f"ring_init needs horizon >= 1, got {horizon}")
    return jnp.broadcast_to(buf, (horizon,) + buf.shape)


def ring_push(ring: jax.Array, buf: jax.Array, pos) -> jax.Array:
    """Overwrite slot ``pos`` (= round mod H, host-resolved) with ``buf``."""
    return ring.at[pos].set(buf)


def ring_read(ring: jax.Array, buf: jax.Array, partner: jax.Array,
              src_slot: jax.Array) -> jax.Array:
    """(W, D) partner values under staleness.

    ``src_slot[w]`` selects where worker w's read is served from: the
    sentinel ``H`` (= ring depth) means a fresh read of the partner's
    current row in ``buf``; ``0..H-1`` name a ring slot.  Two row gathers
    plus a select — no (H, W, D)-sized temporaries.
    """
    h = ring.shape[0]
    fresh = jnp.take(buf, partner, axis=0)
    stale = ring[jnp.minimum(src_slot, h - 1), partner]
    return jnp.where((src_slot < h)[:, None], stale, fresh)


# -- world-batched ring (B, H, W, D): one snapshot ring per world in the
# batched replay.  Slot/round alignment is shared across the batch (the
# batched stream aligns gradient ticks), so push positions are one scalar.

def ring_init_worlds(buf: jax.Array, horizon: int) -> jax.Array:
    """(B, H, W, D) ring seeded with each world's start buffer."""
    if horizon <= 0:
        raise ValueError(f"ring_init_worlds needs horizon >= 1, "
                         f"got {horizon}")
    return jnp.broadcast_to(buf[:, None],
                            (buf.shape[0], horizon) + buf.shape[1:])


def ring_push_worlds(ring: jax.Array, buf: jax.Array, pos) -> jax.Array:
    """Overwrite slot ``pos`` (shared scalar, = round mod H) in every
    world's ring with that world's (W, D) buffer."""
    return ring.at[:, pos].set(buf)


def ring_read_worlds(ring: jax.Array, buf: jax.Array, partner: jax.Array,
                     src_slot: jax.Array) -> jax.Array:
    """(B, W, D) partner values under staleness, per world — the batched
    twin of ``ring_read`` (vmapped over the leading world axis; ``partner``
    and ``src_slot`` are (B, W))."""
    return jax.vmap(ring_read)(ring, buf, partner, src_slot)


# -- bounded-staleness permute ring (DESIGN.md §16): the cross-shard half
# of the sharded worlds replay.  Each shard publishes the (B, nb, D) block
# of boundary rows its peers read this step; n_shards - 1 static ring hops
# of lax.ppermute stack every shard's block into an (NS, B, nb, D) pool,
# which readers index by (hop, pool_pos) — hop h holds the block published
# by shard (self - h) mod NS, matching events.ShardPlan.hop.

def ring_pool_exchange(vals: jax.Array, axis_name: str,
                       n_shards: int) -> jax.Array:
    """All-to-all the published boundary blocks along ``axis_name``.

    The pool is HOP-ordered — ``pool[h]`` is the block published by shard
    ``(self - h) mod NS``, the block an ``h``-step ring walk (shard i ->
    i+1 mod NS) would deliver — because the host shard plan
    (``events.shard_partition``) addresses cross reads by hop count, which
    is lag-friendly: a lag-L ring simply serves deeper hops from older
    snapshots.  The exchange itself is ONE fused ``all_gather`` (then a
    local hop-reindex) rather than NS-1 chained ``ppermute`` rounds: the
    values are identical exact copies either way, but a single collective
    per comm step keeps the sharding overhead flat where the chained ring
    cost grew with the mesh (measured 16ms -> 3ms per tiny-world replay at
    8 forced host shards).  The collective schedule stays compile-time
    static — nothing about it depends on which pairs cross a boundary at
    which step — so the whole scan stays ONE trace.  With one shard there
    is no collective and the pool is the local block alone.
    """
    if n_shards == 1:
        return vals[None]
    pool = jax.lax.all_gather(vals, axis_name)    # (NS, ...) by source
    me = jax.lax.axis_index(axis_name)
    hops = (me - jnp.arange(n_shards, dtype=jnp.int32)) % n_shards
    return pool[hops]
