"""Discrete-event simulator of Algorithm 1 — the faithful reproduction.

Simulates n asynchronous workers on one host: every leaf of the worker state
carries a leading worker axis ``(n, ...)``; gradient computations are vmapped
and the Poisson event schedule (events.Schedule) is replayed exactly:

  for each comm event e (time u_e, matching P_e):
      involved workers apply the lazy mixing exp((u_e - t_last) A)   [Algo 1 l.17]
      then the p2p update  x -= alpha*m, x~ -= alpha_t*m             [l.18-19]
  at each worker's gradient time t_g:
      lazy mixing exp((t_g - t_last) A)                              [l.9]
      gradient step on BOTH buffers                                  [Eq 4]

With eta = 0, alpha = alpha_t = 1/2 this is exactly the asynchronous baseline
(Eq 6, ~AD-PSGD).  The simulator is jit'd end-to-end with lax.scan.

Two replay paths exist:

  * ``run`` — the per-event reference: one unfused (mix, p2p) pytree sweep
    per schedule slot, masked slots included.  Kept as the equivalence
    oracle and the benchmark baseline.
  * ``run_coalesced`` — the flat-buffer event engine (default in
    ``run_schedule``): the schedule is compiled to coalesced batches
    (events.coalesce_schedule) and each batch is ONE fused sweep of a
    packed (n, D) state buffer (engine.FlatGossipEngine; Pallas on TPU).
    Same dynamic, ~kmax/E_active fewer sweeps and 2x less traffic per sweep.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .a2cid2 import (A2CiD2Params, apply_mixing, consensus_distance,
                     matched_p2p_update, worker_mean)
from .engine import FlatGossipEngine
from .events import Schedule, coalesce_schedule
from .flatbuf import FlatLayout

PyTree = Any
# grad_fn(params_i, key, worker_id) -> (loss_i, grads_i) for ONE worker;
# vmapped inside.  worker_id lets each worker sample its own data stream
# (paper Sec 4.1: every worker sees the whole dataset with its own shuffle).
GradFn = Callable[[PyTree, jax.Array, jax.Array], tuple[jax.Array, PyTree]]


class SimState(NamedTuple):
    x: PyTree          # leaves (n, ...)
    x_tilde: PyTree    # leaves (n, ...)
    t_last: jax.Array  # (n,) last per-worker event time (for lazy mixing)
    key: jax.Array


class SimTrace(NamedTuple):
    loss: jax.Array               # (rounds,) mean worker loss
    consensus: jax.Array          # (rounds,) ||pi x||^2 / n
    mean_param_norm: jax.Array    # (rounds,)


@dataclasses.dataclass(frozen=True)
class Simulator:
    grad_fn: GradFn
    params: A2CiD2Params
    gamma: float
    backend: str = "auto"  # engine kernel backend: auto | ref | pallas[_interpret]

    def init(self, x0: PyTree, n: int, key: jax.Array) -> SimState:
        """All workers start at consensus (paper: one all-reduce before training)."""
        stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)
        return SimState(x=stack, x_tilde=stack, t_last=jnp.zeros((n,)), key=key)

    # ------------------------------------------------------------- one round
    def _comm_event(self, carry, event):
        x, x_tilde, t_last = carry
        partner, time, mask = event
        involved = (partner != jnp.arange(partner.shape[0])) & mask
        # lazy mixing for involved workers only (their clocks advance)
        dt = jnp.where(involved, time - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        t_last = jnp.where(involved, time, t_last)
        # p2p update; idle workers have partner=i => m=0 no-op. Masked events
        # have partner=identity by construction.
        x, x_tilde = matched_p2p_update(x, x_tilde, partner, self.params)
        return (x, x_tilde, t_last), None

    def _round(self, state: SimState, round_sched) -> tuple[SimState, dict]:
        partners, times, mask, grad_times, grad_scale, alive = round_sched
        carry = (state.x, state.x_tilde, state.t_last)
        carry, _ = jax.lax.scan(self._comm_event, carry, (partners, times, mask))
        x, x_tilde, t_last = carry

        # gradient event per worker at its own clock; detached (not-alive)
        # workers neither advance their clock nor mix, stragglers (alive but
        # grad_scale 0) advance and mix but skip the gradient
        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)

        new_state = SimState(x, x_tilde,
                             jnp.where(alive, grad_times, t_last), key)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        return new_state, metrics

    # ------------------------------------------ coalesced flat-buffer steps
    def _engine_step(self, engine: FlatGossipEngine, n: int, carry, xs):
        """One event-stream step: a fused comm batch OR a gradient tick,
        each followed by the precomputed mixing segment to the next step."""
        partner, dt_nxt, is_grad, gscale = xs

        def comm(args):
            bx, bxt, key = args
            bx, bxt = engine.batch(bx, bxt, partner, dt_nxt)
            z = jnp.zeros((), jnp.float32)
            return (bx, bxt, key), (z, z, z)

        def grad(args):
            bx, bxt, key = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            # grad_scale masks straggler/churned ticks (1.0 elsewhere)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            # padding columns are zero across workers: they add 0 to both
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            return (bx, bxt, key), (loss, consensus, mean_norm)

        return jax.lax.cond(is_grad, grad, comm, carry)

    # ------------------------------------------------------------------ run
    @partial(jax.jit, static_argnums=0)
    def run(self, state: SimState, schedule_arrays) -> tuple[SimState, SimTrace]:
        """Per-event reference replay (unfused, sweeps masked slots too)."""
        final, metrics = jax.lax.scan(self._round, state, schedule_arrays)
        return final, SimTrace(metrics["loss"], metrics["consensus"],
                               metrics["mean_param_norm"])

    @partial(jax.jit, static_argnums=0)
    def _run_coalesced_jit(self, state: SimState, stream_arrays
                           ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        (bx, bxt, key), ys = jax.lax.scan(
            partial(self._engine_step, engine, n), (bx, bxt, state.key),
            (partners, dt_next, is_grad, grad_scale))
        loss, consensus, mean_norm = ys
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        # compact per-step metrics back to per-round (gradient-tick rows)
        return final, SimTrace(loss[grad_pos], consensus[grad_pos],
                               mean_norm[grad_pos])

    def coalesced_arrays(self, state: SimState, sched: Schedule, *, cs=None):
        """Compile a schedule + start clocks into the engine's scan inputs.

        ``cs`` reuses an already-coalesced schedule (else coalesced here).
        """
        from .events import coalesced_stream
        stream = coalesced_stream(cs or coalesce_schedule(sched),
                                  np.asarray(state.t_last))
        return (jnp.asarray(stream.prologue), jnp.asarray(stream.partners),
                jnp.asarray(stream.dt_next), jnp.asarray(stream.is_grad),
                jnp.asarray(stream.grad_scale),
                jnp.asarray(stream.grad_pos),
                jnp.asarray(stream.t_final))

    def reference_arrays(self, sched: Schedule):
        """Schedule arrays for the per-event reference replay (``run``)."""
        return (jnp.asarray(sched.partners), jnp.asarray(sched.event_times),
                jnp.asarray(sched.event_mask), jnp.asarray(sched.grad_times),
                jnp.asarray(sched.grad_scale()),
                jnp.asarray(sched.alive_arr()))

    def run_coalesced(self, state: SimState, stream_arrays
                      ) -> tuple[SimState, SimTrace]:
        """Flat-buffer engine replay of a coalesced event stream (hot path)."""
        return self._run_coalesced_jit(state, stream_arrays)

    def run_world(self, state: SimState, world, rounds: int | None = None, *,
                  seed: int = 0, engine: bool = True):
        """Compile a declarative ``world.World`` and replay it.

        Sugar for ``run_schedule(state, world.compile(rounds, seed))`` —
        the scenario description stays first-class up to the replay call.
        """
        return self.run_schedule(state, world.compile(rounds, seed=seed),
                                 engine=engine)

    def run_schedule(self, state: SimState, sched: Schedule, *,
                     engine: bool = True):
        if engine:
            try:
                # layout build validates an exact buffer dtype exists
                FlatLayout.from_pytree(state.x, stacked=True)
            except TypeError:
                engine = False  # e.g. int leaves: per-event path handles
        if engine:
            return self.run_coalesced(state, self.coalesced_arrays(state,
                                                                   sched))
        return self.run(state, self.reference_arrays(sched))


# --------------------------------------------------------------- AR-SGD ref

def allreduce_sgd(grad_fn: GradFn, gamma: float, x0: PyTree, n: int,
                  rounds: int, key: jax.Array) -> tuple[PyTree, jax.Array]:
    """Synchronous All-Reduce SGD baseline (the paper's AR-SGD)."""

    stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)

    def step(carry, _):
        x, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(grad_fn)(x, keys, jnp.arange(n))
        mean_g = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True), grads)
        x = jax.tree.map(lambda p, g: p - gamma * jnp.broadcast_to(g, p.shape),
                         x, mean_g)
        return (x, key), jnp.mean(losses)

    (x, _), losses = jax.lax.scan(step, (stack, key), None, length=rounds)
    return jax.tree.map(lambda a: a[0], x), losses
