"""Discrete-event simulator of Algorithm 1 — the faithful reproduction.

Simulates n asynchronous workers on one host: every leaf of the worker state
carries a leading worker axis ``(n, ...)``; gradient computations are vmapped
and the Poisson event schedule (events.Schedule) is replayed exactly:

  for each comm event e (time u_e, matching P_e):
      involved workers apply the lazy mixing exp((u_e - t_last) A)   [Algo 1 l.17]
      then the p2p update  x -= alpha*m, x~ -= alpha_t*m             [l.18-19]
  at each worker's gradient time t_g:
      lazy mixing exp((t_g - t_last) A)                              [l.9]
      gradient step on BOTH buffers                                  [Eq 4]

With eta = 0, alpha = alpha_t = 1/2 this is exactly the asynchronous baseline
(Eq 6, ~AD-PSGD).  The simulator is jit'd end-to-end with lax.scan.

Two replay paths exist:

  * ``run`` — the per-event reference: one unfused (mix, p2p) pytree sweep
    per schedule slot, masked slots included.  Kept as the equivalence
    oracle and the benchmark baseline.
  * ``run_coalesced`` — the flat-buffer event engine (default in
    ``run_schedule``): the schedule is compiled to coalesced batches
    (events.coalesce_schedule) and each batch is ONE fused sweep of a
    packed (n, D) state buffer (engine.FlatGossipEngine; Pallas on TPU).
    Same dynamic, ~kmax/E_active fewer sweeps and 2x less traffic per sweep.

Both paths have unreliable-channel twins (DESIGN.md §10) that
``run_schedule`` dispatches to when the schedule carries ``stale``/
``corrupt`` extras or robust aggregation is on: they thread a ring buffer
of the last H flat states through the scan (stale partner reads), apply
per-event corruption multipliers, and optionally trim/clip the p2p delta
(``robust_clip``/``robust_rule``).  Channel-free schedules run the
original paths bit-for-bit.

All three flavors (plain reference, coalesced engine, channel) also exist
WORLD-BATCHED (DESIGN.md §11): ``run_worlds`` replays B independent
worlds in ONE compiled ``lax.scan`` over (B, W, D) buffers / (B, H, W, D)
snapshot rings, with per-world A2CiD2 dynamics as (B,) arrays so an
entire sweep family — baseline and accelerated, every grid point, every
seed — is one trace and one device dispatch.  Batched replay is pinned
equal to the serial per-world replay (tests/test_batched_replay.py).

``Simulator(donate=True)`` opts the scan jits into buffer donation
(``donate_argnums`` on the state), letting XLA reuse the input state's
memory for the scan carries instead of round-tripping through fresh
allocations.  Donation consumes the passed state — callers must thread
the returned one — so it is opt-in; the default keeps states reusable
(the equivalence suites replay one state down several paths).
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .a2cid2 import (A2CiD2Params, apply_mixing, consensus_distance,
                     matched_p2p_update, worker_mean)
from .channel import CORRUPT_KEY, STALE_KEY
from .defense import (DefenseTrace, defense_absorb, defense_comm,
                      defense_grad, defense_init, knobs_single, knobs_worlds)
from .engine import FlatGossipEngine
from .events import Schedule, coalesce_schedule
from .flatbuf import FlatLayout
from .telemetry import (Telemetry, batch_schedule_columns, finalize_trace,
                        row_bytes_of, schedule_columns)


def _jit_pair(impl, *, static=(0,), donate=(1,)):
    """(plain, donating) jit twins of one scan impl: the donating variant
    hands the state argument's buffers to XLA (``donate_argnums``) so the
    scan carries alias them in place; the plain one leaves inputs alive."""
    return (partial(jax.jit, static_argnums=static)(impl),
            partial(jax.jit, static_argnums=static,
                    donate_argnums=donate)(impl))

PyTree = Any
# grad_fn(params_i, key, worker_id) -> (loss_i, grads_i) for ONE worker;
# vmapped inside.  worker_id lets each worker sample its own data stream
# (paper Sec 4.1: every worker sees the whole dataset with its own shuffle).
GradFn = Callable[[PyTree, jax.Array, jax.Array], tuple[jax.Array, PyTree]]


class SimState(NamedTuple):
    x: PyTree          # leaves (n, ...)
    x_tilde: PyTree    # leaves (n, ...)
    t_last: jax.Array  # (n,) last per-worker event time (for lazy mixing)
    key: jax.Array


class SimTrace(NamedTuple):
    loss: jax.Array               # (rounds,) mean worker loss
    consensus: jax.Array          # (rounds,) ||pi x||^2 / n
    mean_param_norm: jax.Array    # (rounds,)
    # control-loop trace (defense.DefenseTrace) on the self-healing
    # replays, None elsewhere — a defaulted tail field so every existing
    # 3-tuple construction/unpacking site stays valid
    defense: Any = None
    # flight-recorder columns (telemetry.TelemetryTrace) when a Telemetry
    # spec was passed, None elsewhere — same defaulted-tail mechanism.
    # Inside the jitted impls this briefly holds the raw in-scan runtime
    # tuple; the public entry points replace it with the finalized trace.
    telemetry: Any = None


@dataclasses.dataclass(frozen=True)
class Simulator:
    grad_fn: GradFn
    params: A2CiD2Params
    gamma: float
    backend: str = "auto"  # engine kernel backend: auto | ref | pallas[_interpret]
    # robust aggregation (DESIGN.md §10): the replay-side defense knob
    # against Byzantine channel worlds.  None = plain m-term; with a
    # threshold tau = robust_clip, robust_rule selects 'trim' (reject the
    # delta when ||m|| > tau — garbage rejection), 'clip' (rescale to
    # norm tau, ClippedGossip-style), or 'coord' (per-coordinate clip).
    robust_clip: float | None = None
    robust_rule: str = "trim"
    # opt-in buffer donation for every scan jit (see module docstring):
    # the replay consumes the passed state, so callers must thread the
    # returned one instead of reusing the input
    donate: bool = False

    def __post_init__(self):
        if self.robust_rule not in ("trim", "clip", "coord"):
            raise ValueError("robust_rule must be 'trim', 'clip', or "
                             f"'coord', got {self.robust_rule!r}")

    def init(self, x0: PyTree, n: int, key: jax.Array) -> SimState:
        """All workers start at consensus (paper: one all-reduce before training)."""
        stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)
        # donation hands each argument buffer to XLA exactly once, so the
        # two state buffers must not alias (f(donate(a), donate(a)) is an
        # error); without donation they can share until first divergence
        x_tilde = jax.tree.map(jnp.copy, stack) if self.donate else stack
        return SimState(x=stack, x_tilde=x_tilde, t_last=jnp.zeros((n,)),
                        key=key)

    # ----------------------------------------------- telemetry accumulation
    # (DESIGN.md §15) When a Telemetry spec is active, the channel/defense
    # flavors thread a tiny f32 accumulator — (applied, rejected,
    # norm_sum, norm_sq_sum), scalars serially / (B,) world-batched —
    # through their comm steps and emit + reset it at every gradient
    # tick, exactly the DefenseTrace mechanism.  The spec is a STATIC jit
    # argument, so ``tel=None`` traces contain none of this machinery:
    # the None jaxpr is the pre-telemetry jaxpr, bit for bit.

    @staticmethod
    def _tel_zeros(shape=()):
        z = jnp.zeros(shape, jnp.float32)
        return (z, z, z, z)

    def _tel_rej(self, nrm, tau=None):
        """Rejected-read mask under the replay's robust rule.  Only the
        trim rule REJECTS a read; 'clip'/'coord' attenuate but still
        apply it.  ``tau`` (traced scalar or (B,) array) overrides the
        static threshold — the lifted ``robust_clips`` axis; tau = inf
        rejects nothing, matching its bitwise-plain degeneration."""
        tval = tau if tau is not None else self.robust_clip
        if tval is None or self.robust_rule != "trim":
            return jnp.zeros_like(nrm)
        t = jnp.asarray(tval, jnp.float32)
        t = jnp.reshape(t, t.shape + (1,) * (nrm.ndim - t.ndim))
        return (nrm > t).astype(jnp.float32)

    @staticmethod
    def _tel_step(acc, involved, rej, nrm, batched: bool = False):
        """Fold one comm step into the accumulator.  ``involved`` is the
        directed-read mask ((n,) or (B, n)), ``rej`` the rejected subset,
        ``nrm`` the per-read channel-delta norms (the moments are taken
        over ADMITTED reads only — rejected garbage would swamp them)."""
        a_cnt, r_cnt, s1, s2 = acc
        inv = involved.astype(jnp.float32)
        rj = jnp.asarray(rej, jnp.float32) * inv
        adm = inv - rj
        ax = 1 if batched else 0
        a_cnt = a_cnt + adm.sum(axis=ax)
        r_cnt = r_cnt + rj.sum(axis=ax)
        nf = nrm.astype(jnp.float32)
        s1 = s1 + (nf * adm).sum(axis=ax)
        s2 = s2 + (nf * nf * adm).sum(axis=ax)
        return (a_cnt, r_cnt, s1, s2)

    def _row_bytes(self, state: SimState, worlds: bool = False) -> int:
        """Flat-row transfer size for the bytes-moved column.  Falls back
        to summing leaf widths when no exact buffer dtype exists (the
        same pytrees that reject the engine path)."""
        try:
            return row_bytes_of(FlatLayout.from_pytree(
                state.x, stacked=True, worlds=worlds))
        except TypeError:
            lead = 2 if worlds else 1
            return sum(int(np.prod(leaf.shape[lead:], dtype=np.int64))
                       * int(np.dtype(leaf.dtype).itemsize)
                       for leaf in jax.tree.leaves(state.x))

    # ------------------------------------------------------------- one round
    def _comm_event(self, carry, event):
        x, x_tilde, t_last = carry
        partner, time, mask = event
        involved = (partner != jnp.arange(partner.shape[0])) & mask
        # lazy mixing for involved workers only (their clocks advance)
        dt = jnp.where(involved, time - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        t_last = jnp.where(involved, time, t_last)
        # p2p update; idle workers have partner=i => m=0 no-op. Masked events
        # have partner=identity by construction.
        x, x_tilde = matched_p2p_update(x, x_tilde, partner, self.params)
        return (x, x_tilde, t_last), None

    def _round(self, state: SimState, round_sched) -> tuple[SimState, dict]:
        partners, times, mask, grad_times, grad_scale, alive = round_sched
        carry = (state.x, state.x_tilde, state.t_last)
        carry, _ = jax.lax.scan(self._comm_event, carry, (partners, times, mask))
        x, x_tilde, t_last = carry

        # gradient event per worker at its own clock; detached (not-alive)
        # workers neither advance their clock nor mix, stragglers (alive but
        # grad_scale 0) advance and mix but skip the gradient
        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)

        new_state = SimState(x, x_tilde,
                             jnp.where(alive, grad_times, t_last), key)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        return new_state, metrics

    # ------------------------------------------ coalesced flat-buffer steps
    def _engine_step(self, engine: FlatGossipEngine, n: int, carry, xs):
        """One event-stream step: a fused comm batch OR a gradient tick,
        each followed by the precomputed mixing segment to the next step."""
        partner, dt_nxt, is_grad, gscale = xs

        def comm(args):
            bx, bxt, key = args
            bx, bxt = engine.batch(bx, bxt, partner, dt_nxt)
            z = jnp.zeros((), jnp.float32)
            return (bx, bxt, key), (z, z, z)

        def grad(args):
            bx, bxt, key = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            # grad_scale masks straggler/churned ticks (1.0 elsewhere)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            # padding columns are zero across workers: they add 0 to both
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            return (bx, bxt, key), (loss, consensus, mean_norm)

        return jax.lax.cond(is_grad, grad, comm, carry)

    # ----------------------------------------- unreliable-channel replays
    # (DESIGN.md §10) Channel worlds attach per-event ``stale``/``corrupt``
    # extras; both replay paths thread a ring buffer of the last H flat
    # states (one snapshot per round, taken right after the gradient tick)
    # and serve stale partner reads from it.  Slot indices are resolved
    # host-side — the jit'd loops gather/scatter with schedule data only.

    def _partner_leaf(self, a, ring_a, partner, src_slot, horizon: int):
        """Per-leaf partner read: fresh rows of ``a`` where src_slot == H,
        ring snapshots otherwise.  a: (n, *s); ring_a: (H, n, *s)."""
        fresh = jnp.take(a, partner, axis=0)
        if not horizon:
            return fresh
        stale = ring_a[jnp.minimum(src_slot, horizon - 1), partner]
        sel = jnp.reshape(src_slot < horizon,
                          (a.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(sel, stale, fresh)

    def _channel_p2p(self, x, x_tilde, xp, corrupt):
        """p2p update from (possibly corrupted/stale) received values, with
        the optional robust rule on the m-term (norm trim/clip across the
        whole replica, matching the engine's flat-row norm; or the
        per-coordinate clip).  Delegates to the dynamic-params twin with
        the static alphas lifted to traced constants — ``jnp.asarray`` of
        a Python float lands on the same bits a weak scalar would (full
        precision under x64, f32 otherwise)."""
        return self._channel_p2p_dyn(x, x_tilde, xp, corrupt,
                                     jnp.asarray(self.params.alpha),
                                     jnp.asarray(self.params.alpha_tilde))

    def _comm_event_channel(self, horizon: int, ring, carry, event,
                            tel=None):
        if tel is None:
            x, x_tilde, t_last = carry
        else:
            x, x_tilde, t_last, acc = carry
        partner, time, mask, src_slot, corrupt = event
        involved = (partner != jnp.arange(partner.shape[0])) & mask
        dt = jnp.where(involved, time - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        t_last = jnp.where(involved, time, t_last)
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        ring_leaves = treedef.flatten_up_to(ring) if horizon \
            else [None] * len(flat_x)
        xp = treedef.unflatten([
            self._partner_leaf(a, ra, partner, src_slot, horizon)
            for a, ra in zip(flat_x, ring_leaves)])
        if tel is not None:
            nrm = self._delta_norms_tree(x, xp, corrupt)
            acc = self._tel_step(acc, involved, self._tel_rej(nrm), nrm)
        # idle/masked rows read themselves fresh with corrupt 0 => m = 0
        x, x_tilde = self._channel_p2p(x, x_tilde, xp, corrupt)
        if tel is None:
            return (x, x_tilde, t_last), None
        return (x, x_tilde, t_last, acc), None

    def _round_channel(self, horizon: int, carry, round_sched, tel=None):
        x, x_tilde, t_last, ring, key = carry
        (partners, times, mask, src_slots, corrupts, grad_times, grad_scale,
         alive, ring_pos) = round_sched
        inner = partial(self._comm_event_channel, horizon, ring, tel=tel)
        # the telemetry accumulator is LOCAL to the round's event scan —
        # zeroed here, emitted through the metrics dict below — so the
        # round-level carry keeps its public shape (the fleet jits this
        # round body directly)
        inner_carry = (x, x_tilde, t_last) if tel is None else \
            (x, x_tilde, t_last, self._tel_zeros())
        inner_carry, _ = jax.lax.scan(
            inner, inner_carry,
            (partners, times, mask, src_slots, corrupts))
        if tel is None:
            x, x_tilde, t_last = inner_carry
        else:
            x, x_tilde, t_last, acc = inner_carry

        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)
        if horizon:
            # end-of-round snapshot: post-gradient, pre-trailing-mixing —
            # exactly what the engine path's ring_push captures
            ring = jax.tree.map(lambda ra, a: ra.at[ring_pos].set(a),
                                ring, x)
        t_last = jnp.where(alive, grad_times, t_last)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        if tel is not None:
            metrics.update(tel_applied=acc[0], tel_rejected=acc[1],
                           tel_norm_sum=acc[2], tel_norm_sq=acc[3])
        return (x, x_tilde, t_last, ring, key), metrics

    def _run_channel_reference_impl(self, state: SimState, schedule_arrays,
                                    horizon: int, tel=None
                                    ) -> tuple[SimState, SimTrace]:
        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (horizon,) + a.shape), state.x) \
            if horizon else None
        carry = (state.x, state.x_tilde, state.t_last, ring, state.key)
        carry, metrics = jax.lax.scan(
            partial(self._round_channel, horizon, tel=tel), carry,
            schedule_arrays)
        x, x_tilde, t_last, _, key = carry
        return SimState(x, x_tilde, t_last, key), \
            SimTrace(metrics["loss"], metrics["consensus"],
                     metrics["mean_param_norm"],
                     telemetry=None if tel is None else
                     (metrics["tel_applied"], metrics["tel_rejected"],
                      metrics["tel_norm_sum"], metrics["tel_norm_sq"]))

    _run_channel_reference_jit, _run_channel_reference_dnt = _jit_pair(
        _run_channel_reference_impl, static=(0, 3, 4))

    def _round_defense(self, horizon: int, dk, carry, round_sched,
                       tel=None):
        """Defense twin of ``_round_channel``: defense_comm runs per EVENT
        here where the engine path runs it per fused batch — equivalent
        because a batch merges only disjoint matchings (each reader row
        and its trust entry sees at most one event per batch, so the row
        updates commute; DESIGN.md §12)."""
        x, x_tilde, t_last, ring, key, ds = carry
        (partners, times, mask, src_slots, corrupts, grad_times, grad_scale,
         alive, ring_pos) = round_sched
        alpha = jnp.asarray(self.params.alpha)
        alpha_t = jnp.asarray(self.params.alpha_tilde)
        idx = jnp.arange(t_last.shape[0])

        def comm_event(carry, event):
            if tel is None:
                x, xt, tl, ds = carry
            else:
                x, xt, tl, ds, acc = carry
            partner, time, msk, src_slot, corrupt = event
            involved = (partner != idx) & msk
            dt = jnp.where(involved, time - tl, 0.0)
            x, xt = apply_mixing(x, xt, self.params.eta, dt)
            tl = jnp.where(involved, time, tl)
            flat_x, treedef = jax.tree_util.tree_flatten(x)
            ring_leaves = treedef.flatten_up_to(ring) if horizon \
                else [None] * len(flat_x)
            xp = treedef.unflatten([
                self._partner_leaf(a, ra, partner, src_slot, horizon)
                for a, ra in zip(flat_x, ring_leaves)])
            nrm = self._delta_norms_tree(x, xp, corrupt)
            mscale, quar, ds = defense_comm(dk, ds, partner, involved, nrm)
            x, xt = self._channel_p2p_scaled(x, xt, xp, corrupt, mscale,
                                             alpha, alpha_t)
            # the kernel's rejection output IS (mscale == 0) — provably,
            # so the reference folds the same mask into the counters
            rej = (mscale == 0.0).astype(jnp.float32)
            ds = defense_absorb(ds, rej, quar, involved)
            if tel is None:
                return (x, xt, tl, ds), None
            acc = self._tel_step(acc, involved, rej, nrm)
            return (x, xt, tl, ds, acc), None

        inner_carry = (x, x_tilde, t_last, ds) if tel is None else \
            (x, x_tilde, t_last, ds, self._tel_zeros())
        inner_carry, _ = jax.lax.scan(
            comm_event, inner_carry,
            (partners, times, mask, src_slots, corrupts))
        if tel is None:
            x, x_tilde, t_last, ds = inner_carry
        else:
            x, x_tilde, t_last, ds, acc = inner_carry

        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)
        ds, (tau, rejn, quarn) = defense_grad(dk, ds)
        if horizon:
            ring = jax.tree.map(lambda ra, a: ra.at[ring_pos].set(a),
                                ring, x)
        t_last = jnp.where(alive, grad_times, t_last)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
            "tau": tau, "rejections": rejn, "quarantined": quarn,
        }
        if tel is not None:
            metrics.update(tel_applied=acc[0], tel_rejected=acc[1],
                           tel_norm_sum=acc[2], tel_norm_sq=acc[3])
        return (x, x_tilde, t_last, ring, key, ds), metrics

    def _run_defense_reference_impl(self, state: SimState, dk,
                                    schedule_arrays, horizon: int, tel=None
                                    ) -> tuple[SimState, SimTrace]:
        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (horizon,) + a.shape), state.x) \
            if horizon else None
        n = jnp.asarray(state.t_last).shape[0]
        carry = (state.x, state.x_tilde, state.t_last, ring, state.key,
                 defense_init(n))
        carry, metrics = jax.lax.scan(
            partial(self._round_defense, horizon, dk, tel=tel), carry,
            schedule_arrays)
        x, x_tilde, t_last, _, key, _ = carry
        return SimState(x, x_tilde, t_last, key), \
            SimTrace(metrics["loss"], metrics["consensus"],
                     metrics["mean_param_norm"],
                     DefenseTrace(metrics["tau"], metrics["rejections"],
                                  metrics["quarantined"]),
                     telemetry=None if tel is None else
                     (metrics["tel_applied"], metrics["tel_rejected"],
                      metrics["tel_norm_sum"], metrics["tel_norm_sq"]))

    _run_defense_reference_jit, _run_defense_reference_dnt = _jit_pair(
        _run_defense_reference_impl, static=(0, 4, 5))

    def _channel_step(self, engine: FlatGossipEngine, n: int, horizon: int,
                      carry, xs, tel=None):
        """Channel twin of ``_engine_step``: fused channel batches with
        ring-buffer stale reads, ring rotation at gradient ticks.  With a
        telemetry spec the carry tail holds the round accumulator —
        emitted + reset at each gradient tick, DefenseTrace-style."""
        partner, dt_nxt, is_grad, gscale, corrupt, src_slot, ring_pos = xs

        def comm(args):
            if tel is None:
                bx, bxt, ring, key = args
            else:
                bx, bxt, ring, key, acc = args
            if horizon:
                xp = engine.partner_values(ring, bx, partner, src_slot)
            else:
                xp = jnp.take(bx, partner, axis=0)
            if tel is not None:
                nrm = engine.delta_norms(bx, xp, corrupt, axes=1)
                involved = partner != jnp.arange(n)
                acc = self._tel_step(acc, involved, self._tel_rej(nrm),
                                     nrm)
            bx, bxt = engine.channel_batch(bx, bxt, xp, corrupt, dt_nxt)
            z = jnp.zeros((), jnp.float32)
            if tel is None:
                return (bx, bxt, ring, key), (z, z, z)
            return (bx, bxt, ring, key, acc), (z,) * 7

        def grad(args):
            if tel is None:
                bx, bxt, ring, key = args
            else:
                bx, bxt, ring, key, acc = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            if horizon:
                ring = engine.ring_push(ring, bx, ring_pos)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            if tel is None:
                return (bx, bxt, ring, key), (loss, consensus, mean_norm)
            return (bx, bxt, ring, key, self._tel_zeros()), \
                (loss, consensus, mean_norm) + acc

        return jax.lax.cond(is_grad, grad, comm, carry)

    def _run_channel_impl(self, state: SimState, stream_arrays, horizon: int,
                          tel=None) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final, corrupt, src_slot, ring_pos) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend,
                                             robust_clip=self.robust_clip,
                                             robust_rule=self.robust_rule)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        ring = engine.ring_init(bx, horizon) if horizon else None
        init = (bx, bxt, ring, state.key) if tel is None else \
            (bx, bxt, ring, state.key, self._tel_zeros())
        carry, ys = jax.lax.scan(
            partial(self._channel_step, engine, n, horizon, tel=tel),
            init,
            (partners, dt_next, is_grad, grad_scale, corrupt, src_slot,
             ring_pos))
        bx, bxt, _, key = carry[:4]
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        if tel is None:
            loss, consensus, mean_norm = ys
            tcols = None
        else:
            loss, consensus, mean_norm = ys[:3]
            tcols = tuple(c[grad_pos] for c in ys[3:])
        return final, SimTrace(loss[grad_pos], consensus[grad_pos],
                               mean_norm[grad_pos], telemetry=tcols)

    _run_channel_jit, _run_channel_dnt = _jit_pair(
        _run_channel_impl, static=(0, 3, 4))

    # ------------------------------------------- self-healing replays
    # (DESIGN.md §12) The defense flavors are the channel flavors with the
    # control loop threaded through the scan carry: per comm step the
    # delta norms feed defense_comm (adaptive tau + trust/quarantine ->
    # the external mscale), the fused kernel emits its rejection mask back
    # into the trust counters, and each gradient tick runs defense_grad
    # (quantile EMA update + trace row).  NEUTRAL knobs reproduce the
    # static trim arithmetic bitwise, so one trace serves the whole
    # none-vs-static-vs-adaptive grid.

    def _defense_step(self, engine: FlatGossipEngine, n: int, horizon: int,
                      dk, carry, xs, tel=None):
        """Defense twin of ``_channel_step``: the control loop rides the
        carry as a ``defense.DefenseState``."""
        partner, dt_nxt, is_grad, gscale, corrupt, src_slot, ring_pos = xs

        def comm(args):
            if tel is None:
                bx, bxt, ring, key, ds = args
            else:
                bx, bxt, ring, key, ds, acc = args
            if horizon:
                xp = engine.partner_values(ring, bx, partner, src_slot)
            else:
                xp = jnp.take(bx, partner, axis=0)
            nrm = engine.delta_norms(bx, xp, corrupt, axes=1)
            involved = partner != jnp.arange(n)
            mscale, quar, ds = defense_comm(dk, ds, partner, involved, nrm)
            bx, bxt, rej = engine.channel_batch_scaled(bx, bxt, xp, corrupt,
                                                       mscale, dt_nxt)
            ds = defense_absorb(ds, rej, quar, involved)
            z = jnp.zeros((), jnp.float32)
            if tel is None:
                return (bx, bxt, ring, key, ds), (z, z, z, z, z, z)
            acc = self._tel_step(acc, involved, rej, nrm)
            return (bx, bxt, ring, key, ds, acc), (z,) * 10

        def grad(args):
            if tel is None:
                bx, bxt, ring, key, ds = args
            else:
                bx, bxt, ring, key, ds, acc = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            ds, (tau, rejn, quarn) = defense_grad(dk, ds)
            if horizon:
                ring = engine.ring_push(ring, bx, ring_pos)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            if tel is None:
                return (bx, bxt, ring, key, ds), (loss, consensus,
                                                  mean_norm, tau, rejn,
                                                  quarn)
            return (bx, bxt, ring, key, ds, self._tel_zeros()), \
                (loss, consensus, mean_norm, tau, rejn, quarn) + acc

        return jax.lax.cond(is_grad, grad, comm, carry)

    def _run_defense_impl(self, state: SimState, dk, stream_arrays,
                          horizon: int, tel=None
                          ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final, corrupt, src_slot, ring_pos) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend,
                                             robust_clip=self.robust_clip,
                                             robust_rule=self.robust_rule)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        ring = engine.ring_init(bx, horizon) if horizon else None
        init = (bx, bxt, ring, state.key, defense_init(n))
        if tel is not None:
            init = init + (self._tel_zeros(),)
        carry, ys = jax.lax.scan(
            partial(self._defense_step, engine, n, horizon, dk, tel=tel),
            init,
            (partners, dt_next, is_grad, grad_scale, corrupt, src_slot,
             ring_pos))
        bx, bxt, _, key = carry[:4]
        loss, consensus, mean_norm, tau, rejn, quarn = ys[:6]
        tcols = None if tel is None else tuple(c[grad_pos] for c in ys[6:])
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        return final, SimTrace(
            loss[grad_pos], consensus[grad_pos], mean_norm[grad_pos],
            DefenseTrace(tau[grad_pos], rejn[grad_pos], quarn[grad_pos]),
            telemetry=tcols)

    _run_defense_jit, _run_defense_dnt = _jit_pair(
        _run_defense_impl, static=(0, 4, 5))

    @staticmethod
    def _channel_extras(extras: dict, shape, horizon_from: str = STALE_KEY):
        """(stale, corrupt, horizon) materialized at ``shape`` (zeros where
        a key is absent); the ring depth is the max staleness the schedule
        actually demands, so replays are self-contained."""
        stale = extras.get(STALE_KEY)
        stale = np.zeros(shape, np.int32) if stale is None \
            else np.asarray(stale, np.int32)
        corrupt = extras.get(CORRUPT_KEY)
        corrupt = np.zeros(shape, np.float32) if corrupt is None \
            else np.asarray(corrupt, np.float32)
        horizon = int(stale.max()) if stale.size else 0
        return stale, corrupt, horizon

    def channel_coalesced_arrays(self, state: SimState, sched: Schedule, *,
                                 cs=None):
        """Engine scan inputs for a channel schedule + the ring depth H.

        Staleness offsets are resolved to absolute ring slots host-side:
        an event in round r reading s rounds back is served from slot
        ``(r - s) mod H``; the sentinel H means a fresh read.
        """
        from .events import coalesced_stream
        stream = coalesced_stream(cs or coalesce_schedule(sched),
                                  np.asarray(state.t_last))
        S, n = stream.partners.shape
        stale, corrupt, horizon = self._channel_extras(
            stream.extras or {}, (S, n))
        h = max(horizon, 1)
        # round index per step: a round closes at its gradient tick
        step_round = np.searchsorted(np.asarray(stream.grad_pos),
                                     np.arange(S), side="left")
        src_slot = np.where(stale > 0, (step_round[:, None] - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (step_round % h).astype(np.int32)
        return (jnp.asarray(stream.prologue), jnp.asarray(stream.partners),
                jnp.asarray(stream.dt_next), jnp.asarray(stream.is_grad),
                jnp.asarray(stream.grad_scale),
                jnp.asarray(stream.grad_pos),
                jnp.asarray(stream.t_final),
                jnp.asarray(corrupt), jnp.asarray(src_slot),
                jnp.asarray(ring_pos)), horizon

    def channel_reference_arrays(self, sched: Schedule):
        """Per-event channel replay inputs + ring depth H (slot resolution
        as in ``channel_coalesced_arrays``, at (R, K, n))."""
        R, K, n = sched.partners.shape
        stale, corrupt, horizon = self._channel_extras(
            sched.extras_dict(), (R, K, n))
        h = max(horizon, 1)
        rr = np.arange(R)[:, None, None]
        src_slot = np.where(stale > 0, (rr - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (np.arange(R) % h).astype(np.int32)
        return (jnp.asarray(sched.partners), jnp.asarray(sched.event_times),
                jnp.asarray(sched.event_mask), jnp.asarray(src_slot),
                jnp.asarray(corrupt), jnp.asarray(sched.grad_times),
                jnp.asarray(sched.grad_scale()),
                jnp.asarray(sched.alive_arr()),
                jnp.asarray(ring_pos)), horizon

    # ------------------------------------------------------------------ run
    def _run_reference_impl(self, state: SimState, schedule_arrays
                            ) -> tuple[SimState, SimTrace]:
        final, metrics = jax.lax.scan(self._round, state, schedule_arrays)
        return final, SimTrace(metrics["loss"], metrics["consensus"],
                               metrics["mean_param_norm"])

    _run_reference_jit, _run_reference_dnt = _jit_pair(_run_reference_impl)

    def run(self, state: SimState, schedule_arrays) -> tuple[SimState, SimTrace]:
        """Per-event reference replay (unfused, sweeps masked slots too)."""
        fn = self._run_reference_dnt if self.donate \
            else self._run_reference_jit
        return fn(state, schedule_arrays)

    def _run_coalesced_impl(self, state: SimState, stream_arrays
                            ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        (bx, bxt, key), ys = jax.lax.scan(
            partial(self._engine_step, engine, n), (bx, bxt, state.key),
            (partners, dt_next, is_grad, grad_scale))
        loss, consensus, mean_norm = ys
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        # compact per-step metrics back to per-round (gradient-tick rows)
        return final, SimTrace(loss[grad_pos], consensus[grad_pos],
                               mean_norm[grad_pos])

    _run_coalesced_jit, _run_coalesced_dnt = _jit_pair(_run_coalesced_impl)

    def coalesced_arrays(self, state: SimState, sched: Schedule, *, cs=None):
        """Compile a schedule + start clocks into the engine's scan inputs.

        ``cs`` reuses an already-coalesced schedule (else coalesced here).
        """
        from .events import coalesced_stream
        stream = coalesced_stream(cs or coalesce_schedule(sched),
                                  np.asarray(state.t_last))
        return (jnp.asarray(stream.prologue), jnp.asarray(stream.partners),
                jnp.asarray(stream.dt_next), jnp.asarray(stream.is_grad),
                jnp.asarray(stream.grad_scale),
                jnp.asarray(stream.grad_pos),
                jnp.asarray(stream.t_final))

    def reference_arrays(self, sched: Schedule):
        """Schedule arrays for the per-event reference replay (``run``)."""
        return (jnp.asarray(sched.partners), jnp.asarray(sched.event_times),
                jnp.asarray(sched.event_mask), jnp.asarray(sched.grad_times),
                jnp.asarray(sched.grad_scale()),
                jnp.asarray(sched.alive_arr()))

    def run_coalesced(self, state: SimState, stream_arrays
                      ) -> tuple[SimState, SimTrace]:
        """Flat-buffer engine replay of a coalesced event stream (hot path)."""
        fn = self._run_coalesced_dnt if self.donate \
            else self._run_coalesced_jit
        return fn(state, stream_arrays)

    def run_world(self, state: SimState, world, rounds: int | None = None, *,
                  seed: int = 0, engine: bool = True):
        """Compile a declarative ``world.World`` and replay it.

        Sugar for ``run_schedule(state, world.compile(rounds, seed))`` —
        the scenario description stays first-class up to the replay call.
        A ``world.defense`` rides along: its comm controller was already
        applied by ``compile``, its in-scan loop engages here.
        """
        return self.run_schedule(state, world.compile(rounds, seed=seed),
                                 engine=engine,
                                 defense=getattr(world, "defense", None),
                                 telemetry=getattr(world, "telemetry", None))

    def run_schedule(self, state: SimState, sched: Schedule, *,
                     engine: bool = True, defense=None, telemetry=None,
                     mesh=None):
        if mesh is not None:
            # lift to a B=1 worlds replay (the sharded flavors are
            # world-batched only) and squeeze the world axis back off —
            # the pinned batched-equals-serial precedent
            finalw, trw = self.run_worlds(
                [state], [sched],
                defenses=None if defense is None else [defense],
                engine=engine, telemetry=telemetry, mesh=mesh)

            def _sq(v):
                return v[0] if getattr(v, "ndim", 0) >= 1 else v

            def _sqt(t):
                return None if t is None else type(t)(*[_sq(v) for v in t])

            final = SimState(jax.tree.map(lambda a: a[0], finalw.x),
                             jax.tree.map(lambda a: a[0], finalw.x_tilde),
                             finalw.t_last[0], finalw.key[0])
            return final, SimTrace(trw.loss[0], trw.consensus[0],
                                   trw.mean_param_norm[0],
                                   _sqt(trw.defense), _sqt(trw.telemetry))
        tel = telemetry
        active = defense is not None and defense.is_active
        if active and self.robust_rule != "trim":
            raise ValueError("the self-healing defense needs "
                             "robust_rule='trim' (its accept/reject loop "
                             f"is binary), got {self.robust_rule!r}")
        if engine:
            try:
                # layout build validates an exact buffer dtype exists
                FlatLayout.from_pytree(state.x, stacked=True)
            except TypeError:
                engine = False  # e.g. int leaves: per-event path handles
        # channel worlds (stale/corrupt extras) and robust aggregation run
        # on the channel twins of both paths; an active defense selects
        # the self-healing twins; everything else stays on the original
        # replays bit-for-bit
        extras = sched.extras_dict()
        # a telemetry spec forces the channel flavor too: plain schedules
        # degenerate on it bitwise (horizon 0 / corrupt 0 — the pinned
        # channel-equals-plain precedent), and the flavor carries the
        # accumulator machinery
        channel = (STALE_KEY in extras or CORRUPT_KEY in extras
                   or self.robust_clip is not None or tel is not None)
        # schedule columns + row bytes BEFORE dispatch: under donation the
        # replay consumes ``state``, and only shapes survive it
        rb = self._row_bytes(state) if tel is not None and tel.bytes_moved \
            else 0
        cols = schedule_columns(tel, sched) if tel is not None else None
        if engine:
            if active:
                arrays, horizon = self.channel_coalesced_arrays(state, sched)
                dk = knobs_single(defense, self.robust_clip)
                fn = self._run_defense_dnt if self.donate \
                    else self._run_defense_jit
                out = fn(state, dk, arrays, horizon, tel)
            elif channel:
                arrays, horizon = self.channel_coalesced_arrays(state, sched)
                fn = self._run_channel_dnt if self.donate \
                    else self._run_channel_jit
                out = fn(state, arrays, horizon, tel)
            else:
                return self.run_coalesced(state,
                                          self.coalesced_arrays(state,
                                                                sched))
        elif active:
            arrays, horizon = self.channel_reference_arrays(sched)
            dk = knobs_single(defense, self.robust_clip)
            fn = self._run_defense_reference_dnt if self.donate \
                else self._run_defense_reference_jit
            out = fn(state, dk, arrays, horizon, tel)
        elif channel:
            arrays, horizon = self.channel_reference_arrays(sched)
            fn = self._run_channel_reference_dnt if self.donate \
                else self._run_channel_reference_jit
            out = fn(state, arrays, horizon, tel)
        else:
            return self.run(state, self.reference_arrays(sched))
        if tel is None:
            return out
        final, tr = out
        return final, tr._replace(
            telemetry=finalize_trace(tel, tr.telemetry, cols, rb))

    # ---------------------------------------- batched many-worlds replay
    # (DESIGN.md §11) B independent worlds in ONE compiled scan: (B, W, D)
    # buffers, (B, H, W, D) snapshot rings, per-world A2CiD2 dynamics as
    # (B,) arrays.  The batched stream aligns every world's gradient ticks
    # on shared step indices (events.stack_streams), so the scan keeps the
    # serial replay's single lax.cond — the batch axis never enters
    # control flow, and per world the replay is the serial one bit-for-bit
    # (signed zeros aside; pinned in tests/test_batched_replay.py).

    @staticmethod
    def world_params(params_list) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Per-world (eta, alpha, alpha_tilde) as (B,) arrays — the
        dynamic twins of the static Python-float scalars the serial
        replays bind.  Built at the DEFAULT float precision (f64 under
        JAX_ENABLE_X64, f32 otherwise) so every consumer can reproduce
        the serial arithmetic bitwise: the p2p multiplies cast to the
        buffer dtype (full precision under x64, exactly like a weak
        Python scalar), while the kernels' mixing-coefficient pipeline
        downcasts eta to f32 — the precision the serial fused kernels
        compute c in regardless of x64 (their dt operand is f32 and weak
        scalars don't promote).  Rounding to f32 once commutes with the
        power-of-two multiplies (rn(2x) = 2 rn(x)), so both routes land
        on the serial bits."""
        return (jnp.asarray([p.eta for p in params_list]),
                jnp.asarray([p.alpha for p in params_list]),
                jnp.asarray([p.alpha_tilde for p in params_list]))

    @staticmethod
    def batch_states(states) -> SimState:
        """Stack per-world SimStates onto a leading world axis (leaves
        (n, ...) -> (B, n, ...); keys (B, 2) — each world keeps its own
        stream)."""
        states = list(states)
        if not states:
            raise ValueError("need at least one state")
        return SimState(
            x=jax.tree.map(lambda *a: jnp.stack(a),
                           *[s.x for s in states]),
            x_tilde=jax.tree.map(lambda *a: jnp.stack(a),
                                 *[s.x_tilde for s in states]),
            t_last=jnp.stack([s.t_last for s in states]),
            key=jnp.stack([s.key for s in states]))

    def _grad_worlds(self, engine: FlatGossipEngine, n: int, bx, bxt, key,
                     gscale, gammas):
        """Shared gradient tick of the batched engine flavors: per-world
        key streams (identical to each serial replay's), doubly-vmapped
        grad_fn, per-world metrics.  ``gammas`` is the (B,) per-world
        step-size array (built at default precision, so the cast to the
        buffer dtype reproduces the serial weak-scalar multiply
        bitwise)."""
        ks = jax.vmap(jax.random.split)(key)
        key, sub = ks[:, 0], ks[:, 1]
        wkeys = jax.vmap(lambda k: jax.random.split(k, n))(sub)
        losses, grads = jax.vmap(jax.vmap(self.grad_fn),
                                 in_axes=(0, 0, None))(
            engine.unpack_worlds(bx), wkeys, jnp.arange(n))
        g = engine.pack_worlds(grads)
        g = gscale[:, :, None].astype(g.dtype) * g
        gs = jnp.asarray(gammas).astype(g.dtype)[:, None, None]
        bx = bx - gs * g
        bxt = bxt - gs * g
        mean = jnp.mean(bx, axis=1, keepdims=True)
        loss = jnp.mean(losses, axis=1).astype(jnp.float32)
        consensus = (jnp.sum((bx - mean) ** 2, axis=(1, 2)) / n
                     ).astype(jnp.float32)
        mean_norm = jnp.sum(mean ** 2, axis=(1, 2)).astype(jnp.float32)
        return bx, bxt, key, (loss, consensus, mean_norm)

    def _worlds_step(self, engine: FlatGossipEngine, n: int, pw, gammas,
                     carry, xs):
        """Batched twin of ``_engine_step``; ``is_grad`` is shared across
        the batch (stream alignment), so the step keeps one lax.cond."""
        partner, dt_nxt, is_grad, gscale = xs

        def comm(args):
            bx, bxt, key = args
            bx, bxt = engine.batch_worlds(bx, bxt, partner, dt_nxt, pw)
            z = jnp.zeros((partner.shape[0],), jnp.float32)
            return (bx, bxt, key), (z, z, z)

        def grad(args):
            bx, bxt, key = args
            bx, bxt, key, metrics = self._grad_worlds(engine, n, bx, bxt,
                                                      key, gscale, gammas)
            bx, bxt = engine.mix_batch(bx, bxt, dt_nxt, pw[0])
            return (bx, bxt, key), metrics

        return jax.lax.cond(is_grad, grad, comm, carry)

    def _run_worlds_impl(self, state: SimState, pw, gammas, stream_arrays
                         ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True, worlds=True,
                                             backend=self.backend)
        bx = engine.pack_worlds(state.x)
        bxt = engine.pack_worlds(state.x_tilde)
        bx, bxt = engine.mix_batch(bx, bxt, prologue, pw[0])
        n = prologue.shape[1]
        (bx, bxt, key), ys = jax.lax.scan(
            partial(self._worlds_step, engine, n, pw, gammas),
            (bx, bxt, state.key),
            (partners, dt_next, is_grad, grad_scale))
        loss, consensus, mean_norm = ys
        final = SimState(engine.unpack_worlds(bx), engine.unpack_worlds(bxt),
                         t_final, key)
        # per-step (S, B) metrics -> per-world (B, R) traces
        return final, SimTrace(loss[grad_pos].T, consensus[grad_pos].T,
                               mean_norm[grad_pos].T)

    _run_worlds_jit, _run_worlds_dnt = _jit_pair(_run_worlds_impl)

    def _worlds_channel_step(self, engine: FlatGossipEngine, n: int,
                             horizon: int, pw, gammas, taus, carry, xs,
                             tel=None):
        """Batched twin of ``_channel_step``: per-world ring reads, one
        shared ring rotation slot per gradient tick.  ``taus`` (None or a
        traced (B,) array) is the lifted per-world robust threshold."""
        (partner, dt_nxt, is_grad, gscale, corrupt, src_slot,
         ring_pos) = xs

        def comm(args):
            if tel is None:
                bx, bxt, ring, key = args
            else:
                bx, bxt, ring, key, acc = args
            if horizon:
                xp = engine.partner_values_worlds(ring, bx, partner,
                                                  src_slot)
            else:
                xp = jnp.take_along_axis(bx, partner[:, :, None], axis=1)
            if tel is not None:
                nrm = engine.delta_norms(bx, xp, corrupt, axes=2)
                involved = partner != jnp.arange(n)[None, :]
                acc = self._tel_step(acc, involved,
                                     self._tel_rej(nrm, taus), nrm,
                                     batched=True)
            bx, bxt = engine.channel_batch_worlds(bx, bxt, xp, corrupt,
                                                  dt_nxt, pw, taus)
            z = jnp.zeros((partner.shape[0],), jnp.float32)
            if tel is None:
                return (bx, bxt, ring, key), (z, z, z)
            return (bx, bxt, ring, key, acc), (z,) * 7

        def grad(args):
            if tel is None:
                bx, bxt, ring, key = args
            else:
                bx, bxt, ring, key, acc = args
            bx, bxt, key, metrics = self._grad_worlds(engine, n, bx, bxt,
                                                      key, gscale, gammas)
            if horizon:
                ring = engine.ring_push_worlds(ring, bx, ring_pos)
            bx, bxt = engine.mix_batch(bx, bxt, dt_nxt, pw[0])
            if tel is None:
                return (bx, bxt, ring, key), metrics
            B = partner.shape[0]
            return (bx, bxt, ring, key, self._tel_zeros((B,))), \
                metrics + acc

        return jax.lax.cond(is_grad, grad, comm, carry)

    def _run_worlds_channel_impl(self, state: SimState, pw, gammas, taus,
                                 stream_arrays, horizon: int, tel=None
                                 ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final, corrupt, src_slot, ring_pos) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True, worlds=True,
                                             backend=self.backend,
                                             robust_clip=self.robust_clip,
                                             robust_rule=self.robust_rule)
        bx = engine.pack_worlds(state.x)
        bxt = engine.pack_worlds(state.x_tilde)
        bx, bxt = engine.mix_batch(bx, bxt, prologue, pw[0])
        B, n = prologue.shape
        ring = engine.ring_init_worlds(bx, horizon) if horizon else None
        init = (bx, bxt, ring, state.key) if tel is None else \
            (bx, bxt, ring, state.key, self._tel_zeros((B,)))
        carry, ys = jax.lax.scan(
            partial(self._worlds_channel_step, engine, n, horizon, pw,
                    gammas, taus, tel=tel),
            init,
            (partners, dt_next, is_grad, grad_scale, corrupt, src_slot,
             ring_pos))
        bx, bxt, _, key = carry[:4]
        final = SimState(engine.unpack_worlds(bx), engine.unpack_worlds(bxt),
                         t_final, key)
        loss, consensus, mean_norm = ys[:3]
        tcols = None if tel is None else \
            tuple(c[grad_pos].T for c in ys[3:])
        return final, SimTrace(loss[grad_pos].T, consensus[grad_pos].T,
                               mean_norm[grad_pos].T, telemetry=tcols)

    _run_worlds_channel_jit, _run_worlds_channel_dnt = _jit_pair(
        _run_worlds_channel_impl, static=(0, 6, 7))

    def _worlds_defense_step(self, engine: FlatGossipEngine, n: int,
                             horizon: int, pw, gammas, dk, carry, xs,
                             tel=None):
        """Batched twin of ``_defense_step``: the control loop vmaps over
        the world axis (``dk`` a DefenseKnobs of (B,) arrays — every arm,
        including 'no defense' lowered to the neutral knobs, shares this
        one trace)."""
        (partner, dt_nxt, is_grad, gscale, corrupt, src_slot,
         ring_pos) = xs

        def comm(args):
            if tel is None:
                bx, bxt, ring, key, ds = args
            else:
                bx, bxt, ring, key, ds, acc = args
            if horizon:
                xp = engine.partner_values_worlds(ring, bx, partner,
                                                  src_slot)
            else:
                xp = jnp.take_along_axis(bx, partner[:, :, None], axis=1)
            nrm = engine.delta_norms(bx, xp, corrupt, axes=2)
            involved = partner != jnp.arange(n)[None, :]
            mscale, quar, ds = jax.vmap(defense_comm)(dk, ds, partner,
                                                      involved, nrm)
            bx, bxt, rej = engine.channel_batch_worlds_scaled(
                bx, bxt, xp, corrupt, mscale, dt_nxt, pw)
            ds = jax.vmap(defense_absorb)(ds, rej, quar, involved)
            z = jnp.zeros((partner.shape[0],), jnp.float32)
            if tel is None:
                return (bx, bxt, ring, key, ds), (z, z, z, z, z, z)
            acc = self._tel_step(acc, involved, rej, nrm, batched=True)
            return (bx, bxt, ring, key, ds, acc), (z,) * 10

        def grad(args):
            if tel is None:
                bx, bxt, ring, key, ds = args
            else:
                bx, bxt, ring, key, ds, acc = args
            bx, bxt, key, metrics = self._grad_worlds(engine, n, bx, bxt,
                                                      key, gscale, gammas)
            ds, (tau, rejn, quarn) = jax.vmap(defense_grad)(dk, ds)
            if horizon:
                ring = engine.ring_push_worlds(ring, bx, ring_pos)
            bx, bxt = engine.mix_batch(bx, bxt, dt_nxt, pw[0])
            if tel is None:
                return (bx, bxt, ring, key, ds), metrics + (tau, rejn,
                                                            quarn)
            B = partner.shape[0]
            return (bx, bxt, ring, key, ds, self._tel_zeros((B,))), \
                metrics + (tau, rejn, quarn) + acc

        return jax.lax.cond(is_grad, grad, comm, carry)

    def _run_worlds_defense_impl(self, state: SimState, pw, gammas, dk,
                                 stream_arrays, horizon: int, tel=None
                                 ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final, corrupt, src_slot, ring_pos) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True, worlds=True,
                                             backend=self.backend,
                                             robust_clip=self.robust_clip,
                                             robust_rule=self.robust_rule)
        bx = engine.pack_worlds(state.x)
        bxt = engine.pack_worlds(state.x_tilde)
        bx, bxt = engine.mix_batch(bx, bxt, prologue, pw[0])
        B, n = prologue.shape
        ring = engine.ring_init_worlds(bx, horizon) if horizon else None
        init = (bx, bxt, ring, state.key, defense_init(n, batch=B))
        if tel is not None:
            init = init + (self._tel_zeros((B,)),)
        carry, ys = jax.lax.scan(
            partial(self._worlds_defense_step, engine, n, horizon, pw,
                    gammas, dk, tel=tel),
            init,
            (partners, dt_next, is_grad, grad_scale, corrupt, src_slot,
             ring_pos))
        bx, bxt, _, key = carry[:4]
        loss, consensus, mean_norm, tau, rejn, quarn = ys[:6]
        tcols = None if tel is None else \
            tuple(c[grad_pos].T for c in ys[6:])
        final = SimState(engine.unpack_worlds(bx), engine.unpack_worlds(bxt),
                         t_final, key)
        return final, SimTrace(
            loss[grad_pos].T, consensus[grad_pos].T, mean_norm[grad_pos].T,
            DefenseTrace(tau[grad_pos].T, rejn[grad_pos].T,
                         quarn[grad_pos].T),
            telemetry=tcols)

    _run_worlds_defense_jit, _run_worlds_defense_dnt = _jit_pair(
        _run_worlds_defense_impl, static=(0, 6, 7))

    # --- batched per-event reference flavor: the serial round body with
    # dynamic per-world params, vmapped over the world axis inside the
    # round scan (the equivalence oracle at batch scale)

    @staticmethod
    def _mix_dyn(x, x_tilde, eta, dt):
        """``apply_mixing`` with a traced per-world eta (no eta == 0
        shortcut: baseline worlds compute the exact-zero coefficient).
        ``dt`` keeps its incoming dtype exactly like the serial path —
        under x64 the reference round promotes it to f64, and the
        coefficient must be computed there at full precision to match."""
        dt = jnp.asarray(dt)

        def mix(a, b):
            c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))).astype(a.dtype)
            c = jnp.reshape(c, c.shape + (1,) * (a.ndim - c.ndim))
            d = b - a
            return a + c * d, b - c * d

        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_t = treedef.flatten_up_to(x_tilde)
        mixed = [mix(a, b) for a, b in zip(flat_x, flat_t)]
        return (treedef.unflatten([m[0] for m in mixed]),
                treedef.unflatten([m[1] for m in mixed]))

    @staticmethod
    def _p2p_dyn(x, x_tilde, partner, alpha, alpha_t):
        """``matched_p2p_update`` with traced per-world alphas."""
        def upd(a, at):
            b = jnp.take(a, partner, axis=0)
            m = a - b
            return (a - alpha.astype(a.dtype) * m,
                    at - alpha_t.astype(a.dtype) * m)

        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_t = treedef.flatten_up_to(x_tilde)
        out = [upd(a, at) for a, at in zip(flat_x, flat_t)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    def _channel_p2p_dyn(self, x, x_tilde, xp, corrupt, alpha, alpha_t,
                         tau=None):
        """``_channel_p2p`` with traced per-world alphas (robust rule and
        clip stay static — they are replay knobs, not world data).  A
        traced per-world ``tau`` overrides the static threshold (the
        lifted ``robust_clips`` axis; norm rules only): tau = inf arms
        degenerate bitwise to the plain m-term for finite deltas."""
        clip = self.robust_clip
        rule = self.robust_rule
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_t = treedef.flatten_up_to(x_tilde)
        flat_p = treedef.flatten_up_to(xp)

        def cadv_for(a):
            c = (1.0 + corrupt).astype(a.dtype)
            return jnp.reshape(c, c.shape + (1,) * (a.ndim - 1))

        mscale = None
        if tau is not None or (clip is not None and rule != "coord"):
            nrm2 = sum(
                jnp.sum(((a - cadv_for(a) * b).astype(jnp.float32)) ** 2,
                        axis=tuple(range(1, a.ndim)))
                for a, b in zip(flat_x, flat_p))
            nrm = jnp.sqrt(nrm2)
            tval = tau if tau is not None else clip
            if rule == "trim":
                mscale = (nrm <= tval).astype(jnp.float32)
            else:
                mscale = jnp.minimum(1.0, tval / jnp.maximum(nrm, 1e-30))

        def upd(a, at, b):
            m = a - cadv_for(a) * b
            if mscale is not None:
                s = mscale.astype(a.dtype)
                m = m * jnp.reshape(s, s.shape + (1,) * (a.ndim - 1))
            elif clip is not None:
                m = jnp.clip(m, -clip, clip)
            return (a - alpha.astype(a.dtype) * m,
                    at - alpha_t.astype(a.dtype) * m)

        out = [upd(a, at, b) for a, at, b in zip(flat_x, flat_t, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    @staticmethod
    def _delta_norms_tree(x, xp, corrupt):
        """Pytree twin of ``engine.delta_norms``: (n,) f32 L2 norms of the
        corrupted channel deltas (per-leaf f32 square-sums, the same
        arithmetic ``_channel_p2p_dyn`` runs for its norm rules)."""
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_p = treedef.flatten_up_to(xp)

        def cadv_for(a):
            c = (1.0 + corrupt).astype(a.dtype)
            return jnp.reshape(c, c.shape + (1,) * (a.ndim - 1))

        nrm2 = sum(
            jnp.sum(((a - cadv_for(a) * b).astype(jnp.float32)) ** 2,
                    axis=tuple(range(1, a.ndim)))
            for a, b in zip(flat_x, flat_p))
        return jnp.sqrt(nrm2)

    @staticmethod
    def _channel_p2p_scaled(x, x_tilde, xp, corrupt, mscale, alpha,
                            alpha_t):
        """Channel p2p with an EXTERNAL (n,) mscale (the defense loop's
        adaptive-tau + quarantine decision) — the reference twin of
        ``engine.channel_batch_scaled``'s m-term."""
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_t = treedef.flatten_up_to(x_tilde)
        flat_p = treedef.flatten_up_to(xp)

        def upd(a, at, b):
            c = (1.0 + corrupt).astype(a.dtype)
            c = jnp.reshape(c, c.shape + (1,) * (a.ndim - 1))
            m = a - c * b
            s = mscale.astype(a.dtype)
            m = m * jnp.reshape(s, s.shape + (1,) * (a.ndim - 1))
            return (a - alpha.astype(a.dtype) * m,
                    at - alpha_t.astype(a.dtype) * m)

        out = [upd(a, at, b) for a, at, b in zip(flat_x, flat_t, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    def _grad_world_ref(self, x, x_tilde, t_last, key, eta, gamma,
                        grad_times, grad_scale, alive):
        """Shared gradient tail of the per-world reference round;
        ``gamma`` is the traced per-world step size (cast to the leaf
        dtype — the same bits the serial weak-scalar multiply lands
        on)."""
        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = self._mix_dyn(x, x_tilde, eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - gamma.astype(g.dtype) * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        return x, x_tilde, key, metrics

    def _run_worlds_reference_impl(self, state: SimState, pw, gammas,
                                   sched_arrays
                                   ) -> tuple[SimState, SimTrace]:
        def per_world(x, xt, tl, key, eta, alpha, alphat, gamma, partners,
                      times, mask, grad_times, grad_scale, alive):
            idx = jnp.arange(tl.shape[0])

            def comm_event(carry, event):
                x, xt, tl = carry
                partner, time, msk = event
                involved = (partner != idx) & msk
                dt = jnp.where(involved, time - tl, 0.0)
                x, xt = self._mix_dyn(x, xt, eta, dt)
                tl = jnp.where(involved, time, tl)
                x, xt = self._p2p_dyn(x, xt, partner, alpha, alphat)
                return (x, xt, tl), None

            (x, xt, tl), _ = jax.lax.scan(comm_event, (x, xt, tl),
                                          (partners, times, mask))
            x, xt, key, metrics = self._grad_world_ref(
                x, xt, tl, key, eta, gamma, grad_times, grad_scale, alive)
            tl = jnp.where(alive, grad_times, tl)
            return (x, xt, tl, key), metrics

        def round_fn(carry, xs):
            x, xt, tl, key = carry
            partners, times, mask, grad_times, grad_scale, alive = xs
            (x, xt, tl, key), metrics = jax.vmap(per_world)(
                x, xt, tl, key, *pw, gammas, partners, times, mask,
                grad_times, grad_scale, alive)
            return (x, xt, tl, key), metrics

        carry = (state.x, state.x_tilde, state.t_last, state.key)
        (x, xt, tl, key), metrics = jax.lax.scan(round_fn, carry,
                                                 sched_arrays)
        return SimState(x, xt, tl, key), \
            SimTrace(metrics["loss"].T, metrics["consensus"].T,
                     metrics["mean_param_norm"].T)

    _run_worlds_reference_jit, _run_worlds_reference_dnt = _jit_pair(
        _run_worlds_reference_impl)

    def _run_worlds_channel_reference_impl(self, state: SimState, pw,
                                           gammas, taus, sched_arrays,
                                           horizon: int, tel=None
                                           ) -> tuple[SimState, SimTrace]:
        def per_world(x, xt, tl, ring, key, eta, alpha, alphat, gamma, tau,
                      partners, times, mask, src_slots, corrupts,
                      grad_times, grad_scale, alive, ring_pos):
            idx = jnp.arange(tl.shape[0])

            def comm_event(carry, event):
                if tel is None:
                    x, xt, tl = carry
                else:
                    x, xt, tl, acc = carry
                partner, time, msk, src_slot, corrupt = event
                involved = (partner != idx) & msk
                dt = jnp.where(involved, time - tl, 0.0)
                x, xt = self._mix_dyn(x, xt, eta, dt)
                tl = jnp.where(involved, time, tl)
                flat_x, treedef = jax.tree_util.tree_flatten(x)
                ring_leaves = treedef.flatten_up_to(ring) if horizon \
                    else [None] * len(flat_x)
                xp = treedef.unflatten([
                    self._partner_leaf(a, ra, partner, src_slot, horizon)
                    for a, ra in zip(flat_x, ring_leaves)])
                if tel is not None:
                    nrm = self._delta_norms_tree(x, xp, corrupt)
                    acc = self._tel_step(acc, involved,
                                         self._tel_rej(nrm, tau), nrm)
                x, xt = self._channel_p2p_dyn(x, xt, xp, corrupt, alpha,
                                              alphat, tau)
                if tel is None:
                    return (x, xt, tl), None
                return (x, xt, tl, acc), None

            inner = (x, xt, tl) if tel is None else \
                (x, xt, tl, self._tel_zeros())
            inner, _ = jax.lax.scan(
                comm_event, inner,
                (partners, times, mask, src_slots, corrupts))
            if tel is None:
                x, xt, tl = inner
            else:
                x, xt, tl, acc = inner
            x, xt, key, metrics = self._grad_world_ref(
                x, xt, tl, key, eta, gamma, grad_times, grad_scale, alive)
            if tel is not None:
                metrics = {**metrics, "tel_applied": acc[0],
                           "tel_rejected": acc[1], "tel_norm_sum": acc[2],
                           "tel_norm_sq": acc[3]}
            if horizon:
                ring = jax.tree.map(lambda ra, a: ra.at[ring_pos].set(a),
                                    ring, x)
            tl = jnp.where(alive, grad_times, tl)
            return (x, xt, tl, ring, key), metrics

        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[:, None], (a.shape[0], horizon) + a.shape[1:]),
            state.x) if horizon else None

        def round_fn(carry, xs):
            x, xt, tl, ring, key = carry
            (partners, times, mask, src_slots, corrupts, grad_times,
             grad_scale, alive, ring_pos) = xs
            out, metrics = jax.vmap(
                per_world,
                in_axes=(0,) * 18 + (None,))(
                x, xt, tl, ring, key, *pw, gammas, taus, partners, times,
                mask, src_slots, corrupts, grad_times, grad_scale, alive,
                ring_pos)
            return out, metrics

        carry = (state.x, state.x_tilde, state.t_last, ring, state.key)
        (x, xt, tl, _, key), metrics = jax.lax.scan(round_fn, carry,
                                                    sched_arrays)
        return SimState(x, xt, tl, key), \
            SimTrace(metrics["loss"].T, metrics["consensus"].T,
                     metrics["mean_param_norm"].T,
                     telemetry=None if tel is None else
                     (metrics["tel_applied"].T, metrics["tel_rejected"].T,
                      metrics["tel_norm_sum"].T, metrics["tel_norm_sq"].T))

    _run_worlds_channel_reference_jit, _run_worlds_channel_reference_dnt = \
        _jit_pair(_run_worlds_channel_reference_impl, static=(0, 6, 7))

    def _run_worlds_defense_reference_impl(self, state: SimState, pw,
                                           gammas, dk, sched_arrays,
                                           horizon: int, tel=None
                                           ) -> tuple[SimState, SimTrace]:
        def per_world(x, xt, tl, ring, key, ds, eta, alpha, alphat, gamma,
                      dkr, partners, times, mask, src_slots, corrupts,
                      grad_times, grad_scale, alive, ring_pos):
            idx = jnp.arange(tl.shape[0])

            def comm_event(carry, event):
                if tel is None:
                    x, xt, tl, ds = carry
                else:
                    x, xt, tl, ds, acc = carry
                partner, time, msk, src_slot, corrupt = event
                involved = (partner != idx) & msk
                dt = jnp.where(involved, time - tl, 0.0)
                x, xt = self._mix_dyn(x, xt, eta, dt)
                tl = jnp.where(involved, time, tl)
                flat_x, treedef = jax.tree_util.tree_flatten(x)
                ring_leaves = treedef.flatten_up_to(ring) if horizon \
                    else [None] * len(flat_x)
                xp = treedef.unflatten([
                    self._partner_leaf(a, ra, partner, src_slot, horizon)
                    for a, ra in zip(flat_x, ring_leaves)])
                nrm = self._delta_norms_tree(x, xp, corrupt)
                mscale, quar, ds = defense_comm(dkr, ds, partner, involved,
                                                nrm)
                x, xt = self._channel_p2p_scaled(x, xt, xp, corrupt,
                                                 mscale, alpha, alphat)
                rej = (mscale == 0.0).astype(jnp.float32)
                ds = defense_absorb(ds, rej, quar, involved)
                if tel is None:
                    return (x, xt, tl, ds), None
                acc = self._tel_step(acc, involved, rej, nrm)
                return (x, xt, tl, ds, acc), None

            inner = (x, xt, tl, ds) if tel is None else \
                (x, xt, tl, ds, self._tel_zeros())
            inner, _ = jax.lax.scan(
                comm_event, inner,
                (partners, times, mask, src_slots, corrupts))
            if tel is None:
                x, xt, tl, ds = inner
            else:
                x, xt, tl, ds, acc = inner
            x, xt, key, metrics = self._grad_world_ref(
                x, xt, tl, key, eta, gamma, grad_times, grad_scale, alive)
            ds, (tau, rejn, quarn) = defense_grad(dkr, ds)
            if horizon:
                ring = jax.tree.map(lambda ra, a: ra.at[ring_pos].set(a),
                                    ring, x)
            tl = jnp.where(alive, grad_times, tl)
            metrics = {**metrics, "tau": tau, "rejections": rejn,
                       "quarantined": quarn}
            if tel is not None:
                metrics = {**metrics, "tel_applied": acc[0],
                           "tel_rejected": acc[1], "tel_norm_sum": acc[2],
                           "tel_norm_sq": acc[3]}
            return (x, xt, tl, ring, key, ds), metrics

        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a[:, None], (a.shape[0], horizon) + a.shape[1:]),
            state.x) if horizon else None
        B, n = jnp.asarray(state.t_last).shape

        def round_fn(carry, xs):
            x, xt, tl, ring, key, ds = carry
            (partners, times, mask, src_slots, corrupts, grad_times,
             grad_scale, alive, ring_pos) = xs
            out, metrics = jax.vmap(
                per_world,
                in_axes=(0,) * 19 + (None,))(
                x, xt, tl, ring, key, ds, *pw, gammas, dk, partners,
                times, mask, src_slots, corrupts, grad_times, grad_scale,
                alive, ring_pos)
            return out, metrics

        carry = (state.x, state.x_tilde, state.t_last, ring, state.key,
                 defense_init(n, batch=B))
        (x, xt, tl, _, key, _), metrics = jax.lax.scan(round_fn, carry,
                                                       sched_arrays)
        return SimState(x, xt, tl, key), \
            SimTrace(metrics["loss"].T, metrics["consensus"].T,
                     metrics["mean_param_norm"].T,
                     DefenseTrace(metrics["tau"].T,
                                  metrics["rejections"].T,
                                  metrics["quarantined"].T),
                     telemetry=None if tel is None else
                     (metrics["tel_applied"].T, metrics["tel_rejected"].T,
                      metrics["tel_norm_sum"].T, metrics["tel_norm_sq"].T))

    _run_worlds_defense_reference_jit, _run_worlds_defense_reference_dnt = \
        _jit_pair(_run_worlds_defense_reference_impl, static=(0, 6, 7))

    # --- host-side batch compilation + the public entry point

    @staticmethod
    def _coalesce_batch(scheds):
        """Coalesce each schedule once per unique OBJECT — a sweep grid
        legitimately repeats one schedule across arms (baseline vs
        accelerated replay the identical world), and coalescing is the
        expensive host-side pass."""
        cache = {}
        for s in scheds:
            if id(s) not in cache:
                cache[id(s)] = coalesce_schedule(s)
        return [cache[id(s)] for s in scheds]

    def worlds_coalesced_arrays(self, states: SimState, scheds, *,
                                css=None):
        """Engine scan inputs for B schedules: coalesce each world, align
        the streams (events.stack_streams), lift to device arrays."""
        from .events import stack_streams
        css = css if css is not None else self._coalesce_batch(scheds)
        bs = stack_streams(css, np.asarray(states.t_last))
        return (jnp.asarray(bs.prologue), jnp.asarray(bs.partners),
                jnp.asarray(bs.dt_next), jnp.asarray(bs.is_grad),
                jnp.asarray(bs.grad_scale), jnp.asarray(bs.grad_pos),
                jnp.asarray(bs.t_final))

    def worlds_channel_arrays(self, states: SimState, scheds, *, css=None):
        """Channel twin of ``worlds_coalesced_arrays`` + shared ring depth
        H = the max staleness ANY world demands (worlds with a shallower —
        or no — delay read the same snapshots they would serially: a
        deeper ring holds a superset of their window, and fresh reads use
        the sentinel H)."""
        from .events import stack_streams
        css = css if css is not None else self._coalesce_batch(scheds)
        bs = stack_streams(css, np.asarray(states.t_last))
        S, B, n = bs.partners.shape
        stale, corrupt, horizon = self._channel_extras(bs.extras_dict(),
                                                       (S, B, n))
        h = max(horizon, 1)
        step_round = np.searchsorted(np.asarray(bs.grad_pos), np.arange(S),
                                     side="left")
        src_slot = np.where(stale > 0,
                            (step_round[:, None, None] - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (step_round % h).astype(np.int32)
        return (jnp.asarray(bs.prologue), jnp.asarray(bs.partners),
                jnp.asarray(bs.dt_next), jnp.asarray(bs.is_grad),
                jnp.asarray(bs.grad_scale), jnp.asarray(bs.grad_pos),
                jnp.asarray(bs.t_final), jnp.asarray(corrupt),
                jnp.asarray(src_slot), jnp.asarray(ring_pos)), horizon

    def worlds_reference_arrays(self, scheds):
        """Batched per-event reference inputs (events.stack_schedules)."""
        from .events import stack_schedules
        b = stack_schedules(list(scheds))
        return (jnp.asarray(b.partners), jnp.asarray(b.event_times),
                jnp.asarray(b.event_mask), jnp.asarray(b.grad_times),
                jnp.asarray(b.grad_scale), jnp.asarray(b.alive))

    def worlds_channel_reference_arrays(self, scheds):
        """Batched per-event channel reference inputs + shared ring depth
        (slot resolution as in ``worlds_channel_arrays``)."""
        from .events import stack_schedules
        b = stack_schedules(list(scheds))
        R, B, K, n = b.partners.shape
        stale, corrupt, horizon = self._channel_extras(b.extras_dict(),
                                                       (R, B, K, n))
        h = max(horizon, 1)
        rr = np.arange(R)[:, None, None, None]
        src_slot = np.where(stale > 0, (rr - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (np.arange(R) % h).astype(np.int32)
        return (jnp.asarray(b.partners), jnp.asarray(b.event_times),
                jnp.asarray(b.event_mask), jnp.asarray(src_slot),
                jnp.asarray(corrupt), jnp.asarray(b.grad_times),
                jnp.asarray(b.grad_scale), jnp.asarray(b.alive),
                jnp.asarray(ring_pos)), horizon

    def run_worlds(self, states, scheds, *, params=None, gammas=None,
                   robust_clips=None, defenses=None, worlds=None,
                   engine: bool = True, telemetry=None, mesh=None
                   ) -> tuple[SimState, SimTrace]:
        """Replay B independent worlds in ONE compiled scan.

        states — a list of per-world SimStates (stacked here via
          ``batch_states``) or an already world-batched SimState (leaves
          (B, n, ...)).
        scheds — B compiled ``events.Schedule``s sharing (rounds, n) —
          e.g. ``WorldSweep(...).compile(rounds)``.  Ragged event counts
          are padded with identity groups (exact no-ops), never branches.
        params — optional per-world ``A2CiD2Params`` (one per schedule),
          letting baseline and accelerated worlds — and any parameter
          grid — share the ONE trace; default replicates ``self.params``.
        worlds — optional B ``World`` specs (one per schedule): derives
          what the spec declares and the call didn't pass explicitly —
          ``params`` from each world's algorithm zoo arm
          (``World.algorithm_params()``; worlds with ``algorithm=None``
          keep ``self.params``, so scenario grids without a declared
          algorithm stay bitwise PR 6) and ``defenses`` from each world's
          ``defense`` field.  Explicit kwargs always win.
        gammas — optional per-world step sizes (floats; default
          ``self.gamma``), lifted to a traced (B,) array so a step-size
          grid shares the trace too.
        robust_clips — optional per-world robust-trim/clip thresholds
          (None entries fall back to ``self.robust_clip``, or +inf = the
          non-robust m-term bitwise), lifted to a traced (B,) array so
          robust-vs-plain ablations stop forcing a second trace.
        defenses — optional per-world ``AdaptiveDefense | None`` arms.
          Any ACTIVE arm routes the whole batch onto the self-healing
          flavor; inactive arms lower to the neutral knobs, which
          reproduce their static trim (or plain-channel) arithmetic
          bitwise — none-vs-static-vs-adaptive is still ONE trace.
        telemetry — optional ``telemetry.Telemetry`` spec (or declared on
          the ``worlds``; all declaring worlds must share ONE spec — it
          is a static jit argument).  Adds per-round flight-recorder
          columns as ``trace.telemetry`` ((B, rounds) arrays) without
          changing any replayed number; ``None`` is a bitwise no-op.
        mesh — optional ``jax.sharding.Mesh`` (a 1-D worker mesh from
          ``launch.mesh.make_replay_mesh``) or ``launch.mesh_replay.
          MeshReplay``: shard the worker axis of the flat banks over the
          mesh's devices and serve cross-shard partner reads through the
          bounded-staleness permute ring (DESIGN.md §16).  At lag 0 the
          final state is bitwise the single-device replay; a ragged
          worker axis (n % n_shards != 0) warns and falls back to the
          single-device flavors.

        Returns the world-batched final state and a SimTrace whose arrays
        are (B, rounds) — row b equals the serial replay of world b.
        Dispatch mirrors ``run_schedule``: channel extras or robust
        aggregation select the channel flavor; ``engine=False`` (or a
        layout-rejected pytree) the per-event reference flavor.
        """
        twin, args, tel, cols, rb = self._worlds_plan(
            states, scheds, params=params, gammas=gammas,
            robust_clips=robust_clips, defenses=defenses, worlds=worlds,
            engine=engine, telemetry=telemetry, mesh=mesh)
        fn = self._twin_fn(twin, self.donate)
        out = fn(*args)
        if tel is None:
            return out
        final, tr = out
        return final, tr._replace(
            telemetry=finalize_trace(tel, tr.telemetry, cols, rb))

    def worlds_executable(self, states, scheds, **kw):
        """The exact (jitted twin, argument tuple) a ``run_worlds`` call
        would dispatch — plain (non-donating) flavor, host-side batching
        already done.  Callers AOT-lower the grid's ONE executable
        (``fn.lower(*args).compile()``) for cost/roofline analysis
        without paying a replay, and without tracing through the host
        prep (``jax.jit(lambda: sim.run_worlds(...))`` would trip on
        ``batch_states``'s host numpy).  ``kw`` mirrors ``run_worlds``'s
        keywords."""
        twin, args, _, _, _ = self._worlds_plan(states, scheds, **kw)
        return self._twin_fn(twin, False), args

    def _twin_fn(self, twin: str, donate: bool):
        """Resolve a ``_worlds_plan`` twin name to its jitted callable.
        Class-level twins hang on ``type(self)``; the sharded-replay
        twins (``"@sharded_*"``) live in ``launch.mesh_replay`` —
        imported lazily so core never depends on launch at import."""
        if twin.startswith("@sharded_"):
            from ..launch.mesh_replay import sharded_twin
            return sharded_twin(twin[len("@sharded_"):], donate)
        return getattr(type(self), twin + ("_dnt" if donate else "_jit"))

    def worlds_sharded_arrays(self, states: SimState, scheds, mr, *,
                              css=None):
        """Sharded twin of ``worlds_channel_arrays``: the channel stream
        arrays plus the host-compiled shard plan
        (``events.shard_partition``) for ``mr``'s worker mesh.  A
        positive ``mr.lag`` first floors the staleness of every
        cross-shard read (``events.shard_lag_stale``) and deepens the
        shared ring to hold the lagged window."""
        from .events import shard_lag_stale, shard_partition, stack_streams
        css = css if css is not None else self._coalesce_batch(scheds)
        bs = stack_streams(css, np.asarray(states.t_last))
        S, B, n = bs.partners.shape
        stale, corrupt, horizon = self._channel_extras(bs.extras_dict(),
                                                       (S, B, n))
        step_round = np.searchsorted(np.asarray(bs.grad_pos), np.arange(S),
                                     side="left")
        if mr.lag > 0 and mr.n_shards > 1:
            stale = shard_lag_stale(bs.partners, stale, step_round,
                                    mr.n_shards, mr.lag)
            horizon = max(horizon, int(stale.max()))
        h = max(horizon, 1)
        src_slot = np.where(stale > 0,
                            (step_round[:, None, None] - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (step_round % h).astype(np.int32)
        plan = shard_partition(bs.partners, src_slot, mr.n_shards, horizon)
        return (jnp.asarray(bs.prologue), jnp.asarray(bs.partners),
                jnp.asarray(bs.dt_next), jnp.asarray(bs.is_grad),
                jnp.asarray(bs.grad_scale), jnp.asarray(bs.grad_pos),
                jnp.asarray(bs.t_final), jnp.asarray(corrupt),
                jnp.asarray(src_slot), jnp.asarray(ring_pos),
                jnp.asarray(plan.local_partner),
                jnp.asarray(plan.is_cross), jnp.asarray(plan.hop),
                jnp.asarray(plan.pool_pos), jnp.asarray(plan.pub_row),
                jnp.asarray(plan.pub_slot)), horizon

    def _worlds_plan(self, states, scheds, *, params=None, gammas=None,
                     robust_clips=None, defenses=None, worlds=None,
                     engine: bool = True, telemetry=None, mesh=None):
        """Shared host-side prep of a worlds replay: validate, derive
        per-world knobs, build the batched device arrays, pick the scan
        flavor.  Returns ``(twin_name, args, tel, cols, rb)`` where
        ``twin_name + '_jit'/'_dnt'`` names the class-level jit twin and
        ``args`` is its FULL argument tuple (``self`` included — the
        twins hang unbound on the class with ``self`` static)."""
        scheds = list(scheds)
        if not isinstance(states, SimState):
            states = self.batch_states(states)
        B = len(scheds)
        lead = jax.tree.leaves(states.x)[0].shape[0]
        if lead != B:
            raise ValueError(f"states are batched for {lead} worlds but "
                             f"{B} schedules were given")
        if worlds is not None:
            wlist = list(worlds)
            if len(wlist) != B:
                raise ValueError(f"worlds must have one entry per schedule "
                                 f"({B}), got {len(wlist)}")
            if params is None:
                params = [self.params if w.algorithm is None
                          else w.algorithm_params() for w in wlist]
            if defenses is None and any(w.defense is not None
                                        for w in wlist):
                defenses = [w.defense for w in wlist]
            if telemetry is None:
                tspecs = {w.telemetry for w in wlist
                          if getattr(w, "telemetry", None) is not None}
                if len(tspecs) > 1:
                    raise ValueError(
                        "worlds declare multiple distinct Telemetry specs; "
                        "a batch shares ONE static spec (it is a jit "
                        "static argument)")
                if tspecs:
                    telemetry = next(iter(tspecs))
        plist = list(params) if params is not None else [self.params] * B
        if len(plist) != B:
            raise ValueError(f"params must have one entry per world "
                             f"({B}), got {len(plist)}")
        pw = self.world_params(plist)
        glist = list(gammas) if gammas is not None else [self.gamma] * B
        if len(glist) != B:
            raise ValueError(f"gammas must have one entry per world "
                             f"({B}), got {len(glist)}")
        gw = jnp.asarray([float(g) for g in glist])
        clist = list(robust_clips) if robust_clips is not None \
            else [None] * B
        if len(clist) != B:
            raise ValueError(f"robust_clips must have one entry per world "
                             f"({B}), got {len(clist)}")
        taus_list = [self.robust_clip if c is None else float(c)
                     for c in clist]
        any_clip = robust_clips is not None
        dlist = list(defenses) if defenses is not None else [None] * B
        if len(dlist) != B:
            raise ValueError(f"defenses must have one entry per world "
                             f"({B}), got {len(dlist)}")
        active = any(d is not None and d.is_active for d in dlist)
        if (active or any_clip) and self.robust_rule == "coord":
            raise ValueError("per-world thresholds and the self-healing "
                             "defense need a norm rule ('trim' or "
                             "'clip'), not 'coord'")
        if active and self.robust_rule != "trim":
            raise ValueError("the self-healing defense needs "
                             "robust_rule='trim' (its accept/reject loop "
                             f"is binary), got {self.robust_rule!r}")
        tel = telemetry
        if engine:
            try:
                FlatLayout.from_pytree(states.x, stacked=True, worlds=True)
            except TypeError:
                engine = False
        mr = None
        if mesh is not None:
            from ..launch.mesh_replay import MeshReplay
            mr = mesh if isinstance(mesh, MeshReplay) else MeshReplay(mesh)
            if not engine:
                raise ValueError(
                    "the sharded replay (mesh=) runs on the flat-buffer "
                    "engine; engine=False (or a layout-rejected pytree) "
                    "has no worker banks to shard")
            n = jax.tree.leaves(states.x)[0].shape[1]
            if n % mr.n_shards != 0:
                warnings.warn(
                    f"worker axis {n} is not divisible by {mr.n_shards} "
                    f"shards; falling back to the single-device replay",
                    RuntimeWarning, stacklevel=3)
                mr = None
            elif tel is not None and tel.shards == 0:
                tel = dataclasses.replace(tel, shards=mr.n_shards)
        channel = (active or any_clip or self.robust_clip is not None
                   or tel is not None
                   or any(STALE_KEY in s.extras_dict()
                          or CORRUPT_KEY in s.extras_dict()
                          for s in scheds))
        taus = None
        if any_clip and not active:
            taus = jnp.asarray([float("inf") if t is None else t
                                for t in taus_list], jnp.float32)
        # exact schedule columns + row bytes before dispatch (donation
        # consumes the state buffers)
        rb = self._row_bytes(states, worlds=True) \
            if tel is not None and tel.bytes_moved else 0
        cols = batch_schedule_columns(tel, scheds) if tel is not None \
            else None
        if engine:
            if mr is not None:
                # the sharded twins are channel-based; plain worlds
                # degenerate on them bitwise (the pinned channel-equals-
                # plain precedent)
                arrays, horizon = self.worlds_sharded_arrays(states,
                                                             scheds, mr)
                if active:
                    dk = knobs_worlds(dlist, taus_list)
                    return ("@sharded_defense",
                            (self, states, pw, gw, dk, arrays, horizon,
                             tel, mr), tel, cols, rb)
                return ("@sharded_channel",
                        (self, states, pw, gw, taus, arrays, horizon,
                         tel, mr), tel, cols, rb)
            if active:
                arrays, horizon = self.worlds_channel_arrays(states, scheds)
                dk = knobs_worlds(dlist, taus_list)
                return ("_run_worlds_defense",
                        (self, states, pw, gw, dk, arrays, horizon, tel),
                        tel, cols, rb)
            if channel:
                arrays, horizon = self.worlds_channel_arrays(states, scheds)
                return ("_run_worlds_channel",
                        (self, states, pw, gw, taus, arrays, horizon, tel),
                        tel, cols, rb)
            return ("_run_worlds",
                    (self, states, pw, gw,
                     self.worlds_coalesced_arrays(states, scheds)),
                    None, None, 0)
        if active:
            arrays, horizon = self.worlds_channel_reference_arrays(scheds)
            dk = knobs_worlds(dlist, taus_list)
            return ("_run_worlds_defense_reference",
                    (self, states, pw, gw, dk, arrays, horizon, tel),
                    tel, cols, rb)
        if channel:
            arrays, horizon = self.worlds_channel_reference_arrays(scheds)
            return ("_run_worlds_channel_reference",
                    (self, states, pw, gw, taus, arrays, horizon, tel),
                    tel, cols, rb)
        return ("_run_worlds_reference",
                (self, states, pw, gw, self.worlds_reference_arrays(scheds)),
                None, None, 0)


# --------------------------------------------------------------- AR-SGD ref

def allreduce_sgd(grad_fn: GradFn, gamma: float, x0: PyTree, n: int,
                  rounds: int, key: jax.Array) -> tuple[PyTree, jax.Array]:
    """Synchronous All-Reduce SGD baseline (the paper's AR-SGD)."""

    stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)

    def step(carry, _):
        x, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(grad_fn)(x, keys, jnp.arange(n))
        mean_g = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True), grads)
        x = jax.tree.map(lambda p, g: p - gamma * jnp.broadcast_to(g, p.shape),
                         x, mean_g)
        return (x, key), jnp.mean(losses)

    (x, _), losses = jax.lax.scan(step, (stack, key), None, length=rounds)
    return jax.tree.map(lambda a: a[0], x), losses
