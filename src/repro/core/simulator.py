"""Discrete-event simulator of Algorithm 1 — the faithful reproduction.

Simulates n asynchronous workers on one host: every leaf of the worker state
carries a leading worker axis ``(n, ...)``; gradient computations are vmapped
and the Poisson event schedule (events.Schedule) is replayed exactly:

  for each comm event e (time u_e, matching P_e):
      involved workers apply the lazy mixing exp((u_e - t_last) A)   [Algo 1 l.17]
      then the p2p update  x -= alpha*m, x~ -= alpha_t*m             [l.18-19]
  at each worker's gradient time t_g:
      lazy mixing exp((t_g - t_last) A)                              [l.9]
      gradient step on BOTH buffers                                  [Eq 4]

With eta = 0, alpha = alpha_t = 1/2 this is exactly the asynchronous baseline
(Eq 6, ~AD-PSGD).  The simulator is jit'd end-to-end with lax.scan.

Two replay paths exist:

  * ``run`` — the per-event reference: one unfused (mix, p2p) pytree sweep
    per schedule slot, masked slots included.  Kept as the equivalence
    oracle and the benchmark baseline.
  * ``run_coalesced`` — the flat-buffer event engine (default in
    ``run_schedule``): the schedule is compiled to coalesced batches
    (events.coalesce_schedule) and each batch is ONE fused sweep of a
    packed (n, D) state buffer (engine.FlatGossipEngine; Pallas on TPU).
    Same dynamic, ~kmax/E_active fewer sweeps and 2x less traffic per sweep.

Both paths have unreliable-channel twins (DESIGN.md §10) that
``run_schedule`` dispatches to when the schedule carries ``stale``/
``corrupt`` extras or robust aggregation is on: they thread a ring buffer
of the last H flat states through the scan (stale partner reads), apply
per-event corruption multipliers, and optionally trim/clip the p2p delta
(``robust_clip``/``robust_rule``).  Channel-free schedules run the
original paths bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .a2cid2 import (A2CiD2Params, apply_mixing, consensus_distance,
                     matched_p2p_update, worker_mean)
from .channel import CORRUPT_KEY, STALE_KEY
from .engine import FlatGossipEngine
from .events import Schedule, coalesce_schedule
from .flatbuf import FlatLayout

PyTree = Any
# grad_fn(params_i, key, worker_id) -> (loss_i, grads_i) for ONE worker;
# vmapped inside.  worker_id lets each worker sample its own data stream
# (paper Sec 4.1: every worker sees the whole dataset with its own shuffle).
GradFn = Callable[[PyTree, jax.Array, jax.Array], tuple[jax.Array, PyTree]]


class SimState(NamedTuple):
    x: PyTree          # leaves (n, ...)
    x_tilde: PyTree    # leaves (n, ...)
    t_last: jax.Array  # (n,) last per-worker event time (for lazy mixing)
    key: jax.Array


class SimTrace(NamedTuple):
    loss: jax.Array               # (rounds,) mean worker loss
    consensus: jax.Array          # (rounds,) ||pi x||^2 / n
    mean_param_norm: jax.Array    # (rounds,)


@dataclasses.dataclass(frozen=True)
class Simulator:
    grad_fn: GradFn
    params: A2CiD2Params
    gamma: float
    backend: str = "auto"  # engine kernel backend: auto | ref | pallas[_interpret]
    # robust aggregation (DESIGN.md §10): the replay-side defense knob
    # against Byzantine channel worlds.  None = plain m-term; with a
    # threshold tau = robust_clip, robust_rule selects 'trim' (reject the
    # delta when ||m|| > tau — garbage rejection), 'clip' (rescale to
    # norm tau, ClippedGossip-style), or 'coord' (per-coordinate clip).
    robust_clip: float | None = None
    robust_rule: str = "trim"

    def __post_init__(self):
        if self.robust_rule not in ("trim", "clip", "coord"):
            raise ValueError("robust_rule must be 'trim', 'clip', or "
                             f"'coord', got {self.robust_rule!r}")

    def init(self, x0: PyTree, n: int, key: jax.Array) -> SimState:
        """All workers start at consensus (paper: one all-reduce before training)."""
        stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)
        return SimState(x=stack, x_tilde=stack, t_last=jnp.zeros((n,)), key=key)

    # ------------------------------------------------------------- one round
    def _comm_event(self, carry, event):
        x, x_tilde, t_last = carry
        partner, time, mask = event
        involved = (partner != jnp.arange(partner.shape[0])) & mask
        # lazy mixing for involved workers only (their clocks advance)
        dt = jnp.where(involved, time - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        t_last = jnp.where(involved, time, t_last)
        # p2p update; idle workers have partner=i => m=0 no-op. Masked events
        # have partner=identity by construction.
        x, x_tilde = matched_p2p_update(x, x_tilde, partner, self.params)
        return (x, x_tilde, t_last), None

    def _round(self, state: SimState, round_sched) -> tuple[SimState, dict]:
        partners, times, mask, grad_times, grad_scale, alive = round_sched
        carry = (state.x, state.x_tilde, state.t_last)
        carry, _ = jax.lax.scan(self._comm_event, carry, (partners, times, mask))
        x, x_tilde, t_last = carry

        # gradient event per worker at its own clock; detached (not-alive)
        # workers neither advance their clock nor mix, stragglers (alive but
        # grad_scale 0) advance and mix but skip the gradient
        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)

        new_state = SimState(x, x_tilde,
                             jnp.where(alive, grad_times, t_last), key)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        return new_state, metrics

    # ------------------------------------------ coalesced flat-buffer steps
    def _engine_step(self, engine: FlatGossipEngine, n: int, carry, xs):
        """One event-stream step: a fused comm batch OR a gradient tick,
        each followed by the precomputed mixing segment to the next step."""
        partner, dt_nxt, is_grad, gscale = xs

        def comm(args):
            bx, bxt, key = args
            bx, bxt = engine.batch(bx, bxt, partner, dt_nxt)
            z = jnp.zeros((), jnp.float32)
            return (bx, bxt, key), (z, z, z)

        def grad(args):
            bx, bxt, key = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            # grad_scale masks straggler/churned ticks (1.0 elsewhere)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            # padding columns are zero across workers: they add 0 to both
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            return (bx, bxt, key), (loss, consensus, mean_norm)

        return jax.lax.cond(is_grad, grad, comm, carry)

    # ----------------------------------------- unreliable-channel replays
    # (DESIGN.md §10) Channel worlds attach per-event ``stale``/``corrupt``
    # extras; both replay paths thread a ring buffer of the last H flat
    # states (one snapshot per round, taken right after the gradient tick)
    # and serve stale partner reads from it.  Slot indices are resolved
    # host-side — the jit'd loops gather/scatter with schedule data only.

    def _partner_leaf(self, a, ring_a, partner, src_slot, horizon: int):
        """Per-leaf partner read: fresh rows of ``a`` where src_slot == H,
        ring snapshots otherwise.  a: (n, *s); ring_a: (H, n, *s)."""
        fresh = jnp.take(a, partner, axis=0)
        if not horizon:
            return fresh
        stale = ring_a[jnp.minimum(src_slot, horizon - 1), partner]
        sel = jnp.reshape(src_slot < horizon,
                          (a.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(sel, stale, fresh)

    def _channel_p2p(self, x, x_tilde, xp, corrupt):
        """p2p update from (possibly corrupted/stale) received values, with
        the optional robust rule on the m-term (norm trim/clip across the
        whole replica, matching the engine's flat-row norm; or the
        per-coordinate clip)."""
        clip = self.robust_clip
        rule = self.robust_rule
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        flat_t = treedef.flatten_up_to(x_tilde)
        flat_p = treedef.flatten_up_to(xp)

        def cadv_for(a):
            c = (1.0 + corrupt).astype(a.dtype)
            return jnp.reshape(c, c.shape + (1,) * (a.ndim - 1))

        mscale = None
        if clip is not None and rule != "coord":
            nrm2 = sum(
                jnp.sum(((a - cadv_for(a) * b).astype(jnp.float32)) ** 2,
                        axis=tuple(range(1, a.ndim)))
                for a, b in zip(flat_x, flat_p))
            nrm = jnp.sqrt(nrm2)
            if rule == "trim":
                mscale = (nrm <= clip).astype(jnp.float32)
            else:
                mscale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-30))

        def upd(a, at, b):
            m = a - cadv_for(a) * b
            if mscale is not None:
                s = mscale.astype(a.dtype)
                m = m * jnp.reshape(s, s.shape + (1,) * (a.ndim - 1))
            elif clip is not None:
                m = jnp.clip(m, -clip, clip)
            return a - self.params.alpha * m, at - self.params.alpha_tilde * m

        out = [upd(a, at, b) for a, at, b in zip(flat_x, flat_t, flat_p)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    def _comm_event_channel(self, horizon: int, ring, carry, event):
        x, x_tilde, t_last = carry
        partner, time, mask, src_slot, corrupt = event
        involved = (partner != jnp.arange(partner.shape[0])) & mask
        dt = jnp.where(involved, time - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        t_last = jnp.where(involved, time, t_last)
        flat_x, treedef = jax.tree_util.tree_flatten(x)
        ring_leaves = treedef.flatten_up_to(ring) if horizon \
            else [None] * len(flat_x)
        xp = treedef.unflatten([
            self._partner_leaf(a, ra, partner, src_slot, horizon)
            for a, ra in zip(flat_x, ring_leaves)])
        # idle/masked rows read themselves fresh with corrupt 0 => m = 0
        x, x_tilde = self._channel_p2p(x, x_tilde, xp, corrupt)
        return (x, x_tilde, t_last), None

    def _round_channel(self, horizon: int, carry, round_sched):
        x, x_tilde, t_last, ring, key = carry
        (partners, times, mask, src_slots, corrupts, grad_times, grad_scale,
         alive, ring_pos) = round_sched
        inner = partial(self._comm_event_channel, horizon, ring)
        (x, x_tilde, t_last), _ = jax.lax.scan(
            inner, (x, x_tilde, t_last),
            (partners, times, mask, src_slots, corrupts))

        dt = jnp.where(alive, grad_times - t_last, 0.0)
        x, x_tilde = apply_mixing(x, x_tilde, self.params.eta, dt)
        n = grad_times.shape[0]
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(self.grad_fn)(x, keys, jnp.arange(n))

        def upd(p, g):
            s = jnp.reshape(grad_scale, grad_scale.shape
                            + (1,) * (g.ndim - 1)).astype(g.dtype)
            return p - self.gamma * (s * g)

        x = jax.tree.map(upd, x, grads)
        x_tilde = jax.tree.map(upd, x_tilde, grads)
        if horizon:
            # end-of-round snapshot: post-gradient, pre-trailing-mixing —
            # exactly what the engine path's ring_push captures
            ring = jax.tree.map(lambda ra, a: ra.at[ring_pos].set(a),
                                ring, x)
        t_last = jnp.where(alive, grad_times, t_last)
        metrics = {
            "loss": jnp.mean(losses),
            "consensus": consensus_distance(x),
            "mean_param_norm": sum(jnp.sum(m ** 2) for m in
                                   jax.tree.leaves(worker_mean(x))),
        }
        return (x, x_tilde, t_last, ring, key), metrics

    @partial(jax.jit, static_argnums=(0, 3))
    def _run_channel_reference_jit(self, state: SimState, schedule_arrays,
                                   horizon: int
                                   ) -> tuple[SimState, SimTrace]:
        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (horizon,) + a.shape), state.x) \
            if horizon else None
        carry = (state.x, state.x_tilde, state.t_last, ring, state.key)
        carry, metrics = jax.lax.scan(
            partial(self._round_channel, horizon), carry, schedule_arrays)
        x, x_tilde, t_last, _, key = carry
        return SimState(x, x_tilde, t_last, key), \
            SimTrace(metrics["loss"], metrics["consensus"],
                     metrics["mean_param_norm"])

    def _channel_step(self, engine: FlatGossipEngine, n: int, horizon: int,
                      carry, xs):
        """Channel twin of ``_engine_step``: fused channel batches with
        ring-buffer stale reads, ring rotation at gradient ticks."""
        partner, dt_nxt, is_grad, gscale, corrupt, src_slot, ring_pos = xs

        def comm(args):
            bx, bxt, ring, key = args
            if horizon:
                xp = engine.partner_values(ring, bx, partner, src_slot)
            else:
                xp = jnp.take(bx, partner, axis=0)
            bx, bxt = engine.channel_batch(bx, bxt, xp, corrupt, dt_nxt)
            z = jnp.zeros((), jnp.float32)
            return (bx, bxt, ring, key), (z, z, z)

        def grad(args):
            bx, bxt, ring, key = args
            key, sub = jax.random.split(key)
            keys = jax.random.split(sub, n)
            losses, grads = jax.vmap(self.grad_fn)(engine.unpack(bx), keys,
                                                   jnp.arange(n))
            g = engine.pack(grads)
            g = gscale[:, None].astype(g.dtype) * g
            bx = bx - self.gamma * g
            bxt = bxt - self.gamma * g
            mean = jnp.mean(bx, axis=0, keepdims=True)
            loss = jnp.mean(losses).astype(jnp.float32)
            consensus = (jnp.sum((bx - mean) ** 2) / n).astype(jnp.float32)
            mean_norm = jnp.sum(mean ** 2).astype(jnp.float32)
            if horizon:
                ring = engine.ring_push(ring, bx, ring_pos)
            bx, bxt = engine.mix(bx, bxt, dt_nxt)
            return (bx, bxt, ring, key), (loss, consensus, mean_norm)

        return jax.lax.cond(is_grad, grad, comm, carry)

    @partial(jax.jit, static_argnums=(0, 3))
    def _run_channel_jit(self, state: SimState, stream_arrays, horizon: int
                         ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final, corrupt, src_slot, ring_pos) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend,
                                             robust_clip=self.robust_clip,
                                             robust_rule=self.robust_rule)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        ring = engine.ring_init(bx, horizon) if horizon else None
        (bx, bxt, ring, key), ys = jax.lax.scan(
            partial(self._channel_step, engine, n, horizon),
            (bx, bxt, ring, state.key),
            (partners, dt_next, is_grad, grad_scale, corrupt, src_slot,
             ring_pos))
        loss, consensus, mean_norm = ys
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        return final, SimTrace(loss[grad_pos], consensus[grad_pos],
                               mean_norm[grad_pos])

    @staticmethod
    def _channel_extras(extras: dict, shape, horizon_from: str = STALE_KEY):
        """(stale, corrupt, horizon) materialized at ``shape`` (zeros where
        a key is absent); the ring depth is the max staleness the schedule
        actually demands, so replays are self-contained."""
        stale = extras.get(STALE_KEY)
        stale = np.zeros(shape, np.int32) if stale is None \
            else np.asarray(stale, np.int32)
        corrupt = extras.get(CORRUPT_KEY)
        corrupt = np.zeros(shape, np.float32) if corrupt is None \
            else np.asarray(corrupt, np.float32)
        horizon = int(stale.max()) if stale.size else 0
        return stale, corrupt, horizon

    def channel_coalesced_arrays(self, state: SimState, sched: Schedule, *,
                                 cs=None):
        """Engine scan inputs for a channel schedule + the ring depth H.

        Staleness offsets are resolved to absolute ring slots host-side:
        an event in round r reading s rounds back is served from slot
        ``(r - s) mod H``; the sentinel H means a fresh read.
        """
        from .events import coalesced_stream
        stream = coalesced_stream(cs or coalesce_schedule(sched),
                                  np.asarray(state.t_last))
        S, n = stream.partners.shape
        stale, corrupt, horizon = self._channel_extras(
            stream.extras or {}, (S, n))
        h = max(horizon, 1)
        # round index per step: a round closes at its gradient tick
        step_round = np.searchsorted(np.asarray(stream.grad_pos),
                                     np.arange(S), side="left")
        src_slot = np.where(stale > 0, (step_round[:, None] - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (step_round % h).astype(np.int32)
        return (jnp.asarray(stream.prologue), jnp.asarray(stream.partners),
                jnp.asarray(stream.dt_next), jnp.asarray(stream.is_grad),
                jnp.asarray(stream.grad_scale),
                jnp.asarray(stream.grad_pos),
                jnp.asarray(stream.t_final),
                jnp.asarray(corrupt), jnp.asarray(src_slot),
                jnp.asarray(ring_pos)), horizon

    def channel_reference_arrays(self, sched: Schedule):
        """Per-event channel replay inputs + ring depth H (slot resolution
        as in ``channel_coalesced_arrays``, at (R, K, n))."""
        R, K, n = sched.partners.shape
        stale, corrupt, horizon = self._channel_extras(
            sched.extras_dict(), (R, K, n))
        h = max(horizon, 1)
        rr = np.arange(R)[:, None, None]
        src_slot = np.where(stale > 0, (rr - stale) % h,
                            horizon).astype(np.int32)
        ring_pos = (np.arange(R) % h).astype(np.int32)
        return (jnp.asarray(sched.partners), jnp.asarray(sched.event_times),
                jnp.asarray(sched.event_mask), jnp.asarray(src_slot),
                jnp.asarray(corrupt), jnp.asarray(sched.grad_times),
                jnp.asarray(sched.grad_scale()),
                jnp.asarray(sched.alive_arr()),
                jnp.asarray(ring_pos)), horizon

    # ------------------------------------------------------------------ run
    @partial(jax.jit, static_argnums=0)
    def run(self, state: SimState, schedule_arrays) -> tuple[SimState, SimTrace]:
        """Per-event reference replay (unfused, sweeps masked slots too)."""
        final, metrics = jax.lax.scan(self._round, state, schedule_arrays)
        return final, SimTrace(metrics["loss"], metrics["consensus"],
                               metrics["mean_param_norm"])

    @partial(jax.jit, static_argnums=0)
    def _run_coalesced_jit(self, state: SimState, stream_arrays
                           ) -> tuple[SimState, SimTrace]:
        (prologue, partners, dt_next, is_grad, grad_scale, grad_pos,
         t_final) = stream_arrays
        engine = FlatGossipEngine.for_pytree(state.x, self.params,
                                             stacked=True,
                                             backend=self.backend)
        bx = engine.pack(state.x)
        bxt = engine.pack(state.x_tilde)
        bx, bxt = engine.mix(bx, bxt, prologue)
        n = prologue.shape[0]
        (bx, bxt, key), ys = jax.lax.scan(
            partial(self._engine_step, engine, n), (bx, bxt, state.key),
            (partners, dt_next, is_grad, grad_scale))
        loss, consensus, mean_norm = ys
        final = SimState(engine.unpack(bx), engine.unpack(bxt), t_final, key)
        # compact per-step metrics back to per-round (gradient-tick rows)
        return final, SimTrace(loss[grad_pos], consensus[grad_pos],
                               mean_norm[grad_pos])

    def coalesced_arrays(self, state: SimState, sched: Schedule, *, cs=None):
        """Compile a schedule + start clocks into the engine's scan inputs.

        ``cs`` reuses an already-coalesced schedule (else coalesced here).
        """
        from .events import coalesced_stream
        stream = coalesced_stream(cs or coalesce_schedule(sched),
                                  np.asarray(state.t_last))
        return (jnp.asarray(stream.prologue), jnp.asarray(stream.partners),
                jnp.asarray(stream.dt_next), jnp.asarray(stream.is_grad),
                jnp.asarray(stream.grad_scale),
                jnp.asarray(stream.grad_pos),
                jnp.asarray(stream.t_final))

    def reference_arrays(self, sched: Schedule):
        """Schedule arrays for the per-event reference replay (``run``)."""
        return (jnp.asarray(sched.partners), jnp.asarray(sched.event_times),
                jnp.asarray(sched.event_mask), jnp.asarray(sched.grad_times),
                jnp.asarray(sched.grad_scale()),
                jnp.asarray(sched.alive_arr()))

    def run_coalesced(self, state: SimState, stream_arrays
                      ) -> tuple[SimState, SimTrace]:
        """Flat-buffer engine replay of a coalesced event stream (hot path)."""
        return self._run_coalesced_jit(state, stream_arrays)

    def run_world(self, state: SimState, world, rounds: int | None = None, *,
                  seed: int = 0, engine: bool = True):
        """Compile a declarative ``world.World`` and replay it.

        Sugar for ``run_schedule(state, world.compile(rounds, seed))`` —
        the scenario description stays first-class up to the replay call.
        """
        return self.run_schedule(state, world.compile(rounds, seed=seed),
                                 engine=engine)

    def run_schedule(self, state: SimState, sched: Schedule, *,
                     engine: bool = True):
        if engine:
            try:
                # layout build validates an exact buffer dtype exists
                FlatLayout.from_pytree(state.x, stacked=True)
            except TypeError:
                engine = False  # e.g. int leaves: per-event path handles
        # channel worlds (stale/corrupt extras) and robust aggregation run
        # on the channel twins of both paths; everything else stays on the
        # original replays bit-for-bit
        extras = sched.extras_dict()
        channel = (STALE_KEY in extras or CORRUPT_KEY in extras
                   or self.robust_clip is not None)
        if engine:
            if channel:
                arrays, horizon = self.channel_coalesced_arrays(state, sched)
                return self._run_channel_jit(state, arrays, horizon)
            return self.run_coalesced(state, self.coalesced_arrays(state,
                                                                   sched))
        if channel:
            arrays, horizon = self.channel_reference_arrays(sched)
            return self._run_channel_reference_jit(state, arrays, horizon)
        return self.run(state, self.reference_arrays(sched))


# --------------------------------------------------------------- AR-SGD ref

def allreduce_sgd(grad_fn: GradFn, gamma: float, x0: PyTree, n: int,
                  rounds: int, key: jax.Array) -> tuple[PyTree, jax.Array]:
    """Synchronous All-Reduce SGD baseline (the paper's AR-SGD)."""

    stack = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), x0)

    def step(carry, _):
        x, key = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        losses, grads = jax.vmap(grad_fn)(x, keys, jnp.arange(n))
        mean_g = jax.tree.map(lambda g: jnp.mean(g, axis=0, keepdims=True), grads)
        x = jax.tree.map(lambda p, g: p - gamma * jnp.broadcast_to(g, p.shape),
                         x, mean_g)
        return (x, key), jnp.mean(losses)

    (x, _), losses = jax.lax.scan(step, (stack, key), None, length=rounds)
    return jax.tree.map(lambda a: a[0], x), losses
