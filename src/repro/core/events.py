"""Poisson event schedules for the asynchronous dynamic (Assumption 3.2).

The paper's implementation emulates the point processes: "each worker samples
a random number of p2p averagings to perform between each gradient
computation, following a Poisson law using the communication rate as mean",
and pairs available workers through a FIFO queue (~ uniform matchings,
App E.2).  We reproduce exactly that emulation:

  * a *round* covers one unit of simulated time; every worker takes one
    gradient step per round at a jittered time (rate-1 process, time
    renormalized exactly like the paper's running-average normalizer),
  * the number of matching events in a round is Poisson(comm_rate) — a
    matching event pairs (at most) all workers simultaneously, so it models
    "one p2p averaging per worker",
  * matchings are maximal matchings sampled from random edge orders — the
    matching marginals define the empirical Laplacian we verify against
    Def 3.1 (the paper's Fig 7 check).

Schedules are built host-side with numpy (they are data, not compute) and
consumed by `lax.scan` inside the jit'd simulator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graphs import Graph


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Precomputed event schedule for `rounds` units of simulated time.

    Shapes (R = rounds, K = max events/round, n = workers):
      partners    (R, K, n) int32 — partner[e, i] = j or i (idle / masked)
      event_times (R, K) float32  — sorted within each round, masked events
                                    repeat the previous valid time
      event_mask  (R, K) bool
      grad_times  (R, n) float32  — time of each worker's gradient event
    """

    partners: np.ndarray
    event_times: np.ndarray
    event_mask: np.ndarray
    grad_times: np.ndarray

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def n(self) -> int:
        return self.partners.shape[2]

    def num_comm_events(self) -> int:
        """Total pairwise communications in the schedule (counted per pair)."""
        total = 0
        for r in range(self.rounds):
            for k in range(self.partners.shape[1]):
                if self.event_mask[r, k]:
                    p = self.partners[r, k]
                    total += int(np.sum(p != np.arange(self.n))) // 2
        return total


def make_schedule(
    graph: Graph,
    rounds: int,
    comms_per_grad: float = 1.0,
    seed: int = 0,
    jitter_grad_times: bool = True,
) -> Schedule:
    """Build a Poisson event schedule.

    comms_per_grad — expected number of p2p averagings per worker between two
    of its gradient steps (the paper's "#com/#grad" knob, Tab 5).
    """
    rng = np.random.default_rng(seed)
    n = graph.n

    counts = rng.poisson(lam=comms_per_grad, size=rounds)
    kmax = max(1, int(counts.max()))

    partners = np.tile(np.arange(n, dtype=np.int32), (rounds, kmax, 1))
    event_times = np.zeros((rounds, kmax), dtype=np.float32)
    event_mask = np.zeros((rounds, kmax), dtype=bool)
    grad_times = np.zeros((rounds, n), dtype=np.float32)

    for r in range(rounds):
        k = int(counts[r])
        times = np.sort(rng.uniform(r, r + 1, size=k)).astype(np.float32)
        last = np.float32(r)
        for e in range(kmax):
            if e < k:
                matching = graph.sample_matching(rng)
                partners[r, e] = graph.matching_to_partner(matching).astype(np.int32)
                event_times[r, e] = times[e]
                event_mask[r, e] = True
                last = times[e]
            else:
                event_times[r, e] = last  # masked: dt contribution handled by mask
        if jitter_grad_times:
            # each worker's gradient lands at a jittered point in the second
            # half of the round (unit-rate process, staggered workers)
            grad_times[r] = (r + 0.5 + 0.5 * rng.uniform(size=n)).astype(np.float32)
        else:
            grad_times[r] = np.float32(r + 1.0)
        # gradient events must come after the last comm event of the round for
        # the per-round scan ordering to be exact
        grad_times[r] = np.maximum(grad_times[r], event_times[r].max() + 1e-4)

    return Schedule(partners, event_times, event_mask, grad_times)


# ---------------------------------------------------------------------------
# Event coalescing (flat-buffer event engine, see DESIGN.md)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoalescedSchedule:
    """Schedule compiled to fused event *batches* (B = max batches/round).

    A batch is a set of events whose matchings are worker-disjoint, so their
    updates commute and apply in ONE sweep of the state with a combined
    partner involution and per-worker event times.  Masked slots of the raw
    schedule vanish entirely (they were full-buffer no-op sweeps in the
    per-event path), and runs of matchings on disjoint pairs merge.

    Shapes (R = rounds, B = max batches/round, n = workers):
      partners     (R, B, n) int32 — combined involution; i for idle workers
      wtimes       (R, B, n) f32   — per-worker event time (valid where the
                                     worker is involved, i.e. partner != i)
      batch_active (R, B) bool     — False = padding, skip the sweep
      grad_times   (R, n) f32      — unchanged from the raw schedule
    """

    partners: np.ndarray
    wtimes: np.ndarray
    batch_active: np.ndarray
    grad_times: np.ndarray

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def n(self) -> int:
        return self.partners.shape[2]

    def num_batches(self) -> int:
        """Fused sweeps the engine performs (vs kmax*rounds in the raw path)."""
        return int(self.batch_active.sum())


def coalesce_schedule(schedule: Schedule) -> CoalescedSchedule:
    """Compile a raw per-event schedule into coalesced batches.

    Greedy in event order: event e merges into the current batch iff none of
    its involved workers already appears in the batch — disjoint matchings
    commute and exp(dt1 A) exp(dt2 A) = exp((dt1+dt2) A) lets each worker
    carry its own accumulated mixing horizon, so the merge is EXACT (the
    engine reproduces the per-event path bit-for-bit up to float reordering).
    Masked slots are dropped outright.
    """
    R, K, n = schedule.partners.shape
    idx = np.arange(n)
    per_round: list[list[tuple[np.ndarray, np.ndarray]]] = []
    for r in range(R):
        batches: list[tuple[np.ndarray, np.ndarray]] = []  # (partner, wtime)
        busy = np.zeros(n, dtype=bool)  # workers involved in current batch
        for e in range(K):
            if not schedule.event_mask[r, e]:
                continue
            p = schedule.partners[r, e]
            involved = p != idx
            if not involved.any():
                continue
            t = schedule.event_times[r, e]
            if batches and not (busy & involved).any():
                # disjoint from the open batch: merge
                partner, wtime = batches[-1]
                partner[involved] = p[involved]
                wtime[involved] = t
            else:
                partner = idx.astype(np.int32).copy()
                partner[involved] = p[involved]
                wtime = np.zeros(n, dtype=np.float32)
                wtime[involved] = t
                batches.append((partner, wtime))
                busy = np.zeros(n, dtype=bool)
            busy |= involved
        per_round.append(batches)

    B = max(1, max(len(b) for b in per_round))
    partners = np.tile(idx.astype(np.int32), (R, B, 1))
    wtimes = np.zeros((R, B, n), dtype=np.float32)
    batch_active = np.zeros((R, B), dtype=bool)
    for r, batches in enumerate(per_round):
        for b, (partner, wtime) in enumerate(batches):
            partners[r, b] = partner
            wtimes[r, b] = wtime
            batch_active[r, b] = True
    return CoalescedSchedule(partners, wtimes, batch_active,
                             schedule.grad_times.astype(np.float32))


@dataclasses.dataclass(frozen=True)
class EventStream:
    """A coalesced schedule flattened into ONE scan-ready step stream.

    The engine replays ``S = num_batches + rounds`` steps — one per fused
    comm batch plus one per gradient tick, nothing for masked slots — as a
    single ``lax.scan``.  Each step applies its own update then the mixing
    segment to the NEXT step ([P_i, mix(d_{i+1})] grouping, see DESIGN.md);
    ``prologue`` is the per-worker mixing from the start clocks ``t0`` to
    each worker's first event.  All segments are schedule data resolved
    host-side: the jit'd loop carries no clock arithmetic.

    Shapes (S = steps, n = workers, R = rounds):
      prologue  (n,) f32
      partners  (S, n) int32 — identity rows for gradient steps
      dt_next   (S, n) f32
      is_grad   (S,) bool
      grad_pos  (R,) int32   — step index of round r's gradient tick (for
                               compacting per-step metrics back to per-round)
    """

    prologue: np.ndarray
    partners: np.ndarray
    dt_next: np.ndarray
    is_grad: np.ndarray
    grad_pos: np.ndarray

    @property
    def steps(self) -> int:
        return self.partners.shape[0]


def coalesced_stream(cs: CoalescedSchedule, t0: np.ndarray) -> EventStream:
    """Flatten a coalesced schedule into an EventStream given start clocks."""
    R, B, n = cs.partners.shape
    idx = np.arange(n)
    partners, dt_next, is_grad, grad_pos = [], [], [], []
    prologue = None
    tl = np.array(t0, np.float32).copy()

    def emit(partner, delta, grad):
        nonlocal prologue
        if prologue is None:
            prologue = delta
        else:
            dt_next[-1] = delta
        partners.append(partner)
        dt_next.append(np.zeros(n, np.float32))
        is_grad.append(grad)

    for r in range(R):
        for b in range(B):
            if not cs.batch_active[r, b]:
                continue
            inv = cs.partners[r, b] != idx
            delta = np.zeros(n, np.float32)
            delta[inv] = cs.wtimes[r, b, inv] - tl[inv]
            tl[inv] = cs.wtimes[r, b, inv]
            emit(cs.partners[r, b].astype(np.int32), delta, False)
        delta = (cs.grad_times[r] - tl).astype(np.float32)
        tl = cs.grad_times[r].astype(np.float32).copy()
        emit(idx.astype(np.int32), delta, True)
        grad_pos.append(len(partners) - 1)

    return EventStream(
        prologue=prologue,
        partners=np.stack(partners),
        dt_next=np.stack(dt_next),
        is_grad=np.asarray(is_grad, bool),
        grad_pos=np.asarray(grad_pos, np.int32),
    )


def empirical_laplacian(schedule: Schedule, rounds: int | None = None) -> np.ndarray:
    """Empirical expected Laplacian from realized matchings (paper App E.2)."""
    R = rounds or schedule.rounds
    n = schedule.n
    L = np.zeros((n, n))
    for r in range(R):
        for k in range(schedule.partners.shape[1]):
            if not schedule.event_mask[r, k]:
                continue
            p = schedule.partners[r, k]
            for i in range(n):
                j = int(p[i])
                if j > i:
                    L[i, i] += 1
                    L[j, j] += 1
                    L[i, j] -= 1
                    L[j, i] -= 1
    return L / R
