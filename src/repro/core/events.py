"""Poisson event schedules for the asynchronous dynamic (Assumption 3.2).

The paper's implementation emulates the point processes: "each worker samples
a random number of p2p averagings to perform between each gradient
computation, following a Poisson law using the communication rate as mean",
and pairs available workers through a FIFO queue (~ uniform matchings,
App E.2).  We reproduce exactly that emulation:

  * a *round* covers one unit of simulated time; every worker takes one
    gradient step per round at a jittered time (rate-1 process, time
    renormalized exactly like the paper's running-average normalizer),
  * the number of matching events in a round is Poisson(comm_rate) — a
    matching event pairs (at most) all workers simultaneously, so it models
    "one p2p averaging per worker",
  * matchings are maximal matchings sampled from random edge orders — the
    matching marginals define the empirical Laplacian we verify against
    Def 3.1 (the paper's Fig 7 check).

Schedules are built host-side with numpy (they are data, not compute) and
consumed by `lax.scan` inside the jit'd simulator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graphs import Graph, TopologySchedule


def _alive_arr(rounds: int, n: int, alive: np.ndarray | None) -> np.ndarray:
    """(R, n) bool aliveness, materialized (None = all alive)."""
    if alive is None:
        return np.ones((rounds, n), dtype=bool)
    return np.asarray(alive, dtype=bool)


def _grad_scale(rounds: int, n: int, grad_mask: np.ndarray | None,
                alive: np.ndarray | None) -> np.ndarray:
    """(R, n) f32 gradient-application scale: 1.0 iff the worker both takes
    the tick (grad_mask) and is attached (alive)."""
    s = np.ones((rounds, n), dtype=bool)
    if grad_mask is not None:
        s &= np.asarray(grad_mask, dtype=bool)
    s &= _alive_arr(rounds, n, alive)
    return s.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Precomputed event schedule for `rounds` units of simulated time.

    Shapes (R = rounds, K = max events/round, n = workers):
      partners    (R, K, n) int32 — partner[e, i] = j or i (idle / masked)
      event_times (R, K) float32  — sorted within each round, masked events
                                    repeat the previous valid time
      event_mask  (R, K) bool
      grad_times  (R, n) float32  — time of each worker's gradient event

    Heterogeneous-world extensions (None = homogeneous, all-True):
      grad_mask   (R, n) bool — straggler thinning: a False tick means the
                  worker is ALIVE (clock advances, mixing applies) but skips
                  the gradient computation this round
      alive       (R, n) bool — churn: a False row entry means the worker is
                  DETACHED — no matchings (by schedule construction), no
                  gradient, and its event clock freezes for the round

    Extension channel:
      extras      dict of named per-event attribute arrays, each (R, K, n) —
                  the generic slot scenario axes ride in.  Extras are pure
                  schedule data: ``concat_schedules`` pads and concatenates
                  them, ``coalesce_schedule`` merges them alongside the
                  partner involution, and ``coalesced_stream`` flattens them
                  to one (S, n) row per scan step — so a new axis never adds
                  a scan branch, only a named array.  Attach with
                  ``with_extras``.  The unreliable-channel subsystem
                  (``core/channel.py``, DESIGN.md §10) populates the two
                  canonical keys the replay engines consume: ``"stale"``
                  (int32 ring-buffer staleness offsets per read) and
                  ``"corrupt"`` (float32 received-value multiplier offsets;
                  the zero padding produced here means "honest").
    """

    partners: np.ndarray
    event_times: np.ndarray
    event_mask: np.ndarray
    grad_times: np.ndarray
    grad_mask: np.ndarray | None = None
    alive: np.ndarray | None = None
    extras: dict[str, np.ndarray] | None = None

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def n(self) -> int:
        return self.partners.shape[2]

    def alive_arr(self) -> np.ndarray:
        return _alive_arr(self.rounds, self.n, self.alive)

    def grad_scale(self) -> np.ndarray:
        return _grad_scale(self.rounds, self.n, self.grad_mask, self.alive)

    def extras_dict(self) -> dict[str, np.ndarray]:
        return dict(self.extras) if self.extras else {}

    def with_extras(self, **arrays: np.ndarray) -> "Schedule":
        """Attach named per-event attribute arrays (merged with existing).

        Each array must be (R, K, n) — per event, per worker — or (R, K)
        (a per-event scalar, broadcast across workers here so downstream
        compilation stages handle one shape).
        """
        R, K, n = self.partners.shape
        out = self.extras_dict()
        for name, a in arrays.items():
            a = np.asarray(a)
            if a.shape == (R, K):
                a = np.broadcast_to(a[:, :, None], (R, K, n)).copy()
            if a.shape != (R, K, n):
                raise ValueError(
                    f"extras[{name!r}] must have shape ({R}, {K}, {n}) = "
                    f"(rounds, kmax, n) or ({R}, {K}), got {a.shape}")
            out[name] = a
        return dataclasses.replace(self, extras=out)

    def with_grad_gate(self, gate: np.ndarray) -> "Schedule":
        """AND a (R, n) boolean gate into ``grad_mask``.

        The decoupled-gradient-clock hook (``Algorithm`` kind "dadao",
        DESIGN.md §13): a False entry skips that worker's round-r gradient
        tick exactly like straggler thinning — the worker stays alive, its
        clock advances, mixing applies.  Like every heterogeneity axis the
        gate is schedule DATA (it lowers into the stream's ``grad_scale``
        column), never a scan branch.
        """
        gate = np.asarray(gate, dtype=bool)
        if gate.shape != (self.rounds, self.n):
            raise ValueError(
                f"grad gate must have shape ({self.rounds}, {self.n}) = "
                f"(rounds, n), got {gate.shape}")
        mask = gate if self.grad_mask is None else (self.grad_mask & gate)
        return dataclasses.replace(self, grad_mask=mask)

    def comm_events_per_round(self) -> np.ndarray:
        """(R,) pairwise communication count per round (benchmark x-axis)."""
        idx = np.arange(self.n)
        out = np.zeros(self.rounds, dtype=np.int64)
        for r in range(self.rounds):
            for k in range(self.partners.shape[1]):
                if self.event_mask[r, k]:
                    out[r] += int(np.sum(self.partners[r, k] != idx)) // 2
        return out

    def num_comm_events(self) -> int:
        """Total pairwise communications in the schedule (counted per pair)."""
        return int(self.comm_events_per_round().sum())


def make_schedule(
    graph: Graph,
    rounds: int,
    comms_per_grad: float = 1.0,
    seed: int = 0,
    jitter_grad_times: bool = True,
    grad_rates: np.ndarray | None = None,
    edge_rates: np.ndarray | None = None,
    per_edge: bool | None = None,
    t_offset: float = 0.0,
    active: np.ndarray | None = None,
) -> Schedule:
    """Build a Poisson event schedule, homogeneous or heterogeneous.

    Thin wrapper over the declarative World API (``core/world.py``): the
    kwargs are lowered onto ``World(topology, workers, links)`` and
    compiled — bit-for-bit identical to the pre-World sampler under the
    same seed (asserted in ``tests/test_world.py``).  World validates every
    field's shape/dtype/range with errors naming the offending input.

    comms_per_grad — expected number of p2p averagings per worker between two
    of its gradient steps (the paper's "#com/#grad" knob, Tab 5).

    Heterogeneous knobs (all default off; with them off — or set to their
    uniform values — the schedule is bit-for-bit the homogeneous one under
    the same seed, because heterogeneity draws come from a separate rng
    stream):

    grad_rates — (n,) per-worker gradient rates in [0, 1]: worker i takes
      its round-r gradient tick with probability grad_rates[i] (Bernoulli
      thinning of the unit-rate tick process — stragglers take fewer grad
      ticks but stay alive: clocks advance, mixing applies).
    edge_rates — (E,) per-edge communication rates overriding
      ``graph.rates``.  Non-uniform rates switch scheduling to the per-edge
      point process of Def 3.1: edge e fires Poisson(comms_per_grad *
      rate_e) times per round, each firing a single-pair event, so the
      empirical Laplacian converges to the rate-weighted Lambda exactly.
      ``edge_rates`` equal to ``graph.rates`` keeps the paper's
      maximal-matching emulation (the exact homogeneous reduction).
    per_edge — force the per-edge path on/off (None = auto as above).
    t_offset — shift all event/gradient times (phase concatenation).
    active — (n,) churn mask: detached workers are cut out of the graph
      (no matchings) and marked dead for every round of this schedule.
    """
    from .world import LinkModel, WorkerModel, World

    world = World(topology=graph,
                  workers=WorkerModel(grad_rates=grad_rates, active=active),
                  links=LinkModel(rates=edge_rates, per_edge=per_edge),
                  comms_per_grad=comms_per_grad,
                  jitter_grad_times=jitter_grad_times,
                  t_offset=t_offset)
    return world.compile(rounds, seed=seed)


def _sample_schedule(
    graph: Graph,
    rounds: int,
    comms_per_grad: float = 1.0,
    seed: int = 0,
    jitter_grad_times: bool = True,
    grad_rates: np.ndarray | None = None,
    edge_rates: np.ndarray | None = None,
    per_edge: bool | None = None,
    t_offset: float = 0.0,
    active: np.ndarray | None = None,
) -> Schedule:
    """The raw Poisson sampler one World segment compiles through.

    This is the pre-World ``make_schedule`` body, unchanged — the bit-for-bit
    compatibility contract of the wrapper rests on it staying byte-stable.
    """
    rng = np.random.default_rng(seed)
    # heterogeneity draws come from an independent stream so that uniform
    # rates leave the main stream — and hence the schedule — untouched
    het = np.random.default_rng(np.random.SeedSequence([int(seed), 0x48455]))
    n = graph.n

    # rate override first (edge_rates align with the FULL graph's edges),
    # churn subgraph second (it filters rates along with edges)
    if edge_rates is not None:
        edge_rates = np.asarray(edge_rates, dtype=np.float64)
        if per_edge is None:
            per_edge = not np.allclose(edge_rates, graph.rates)
        graph = graph.with_rates(edge_rates)
    elif per_edge is None:
        per_edge = False
    if active is not None:
        active = np.asarray(active, dtype=bool)
        if not active.all():
            graph = graph.subgraph(active)

    if per_edge:
        partners, event_times, event_mask = _per_edge_events(
            graph, rounds, comms_per_grad, rng, t_offset)
        kmax = partners.shape[1]
    else:
        counts = rng.poisson(lam=comms_per_grad, size=rounds)
        kmax = max(1, int(counts.max()))
        partners = np.tile(np.arange(n, dtype=np.int32), (rounds, kmax, 1))
        event_times = np.zeros((rounds, kmax), dtype=np.float32)
        event_mask = np.zeros((rounds, kmax), dtype=bool)
        for r in range(rounds):
            k = int(counts[r])
            times = np.sort(rng.uniform(r + t_offset, r + t_offset + 1,
                                        size=k)).astype(np.float32)
            last = np.float32(r + t_offset)
            for e in range(kmax):
                if e < k:
                    matching = graph.sample_matching(rng)
                    partners[r, e] = graph.matching_to_partner(
                        matching).astype(np.int32)
                    event_times[r, e] = times[e]
                    event_mask[r, e] = True
                    last = times[e]
                else:
                    # masked: dt contribution handled by mask
                    event_times[r, e] = last

    grad_times = np.zeros((rounds, n), dtype=np.float32)
    for r in range(rounds):
        if jitter_grad_times:
            # each worker's gradient lands at a jittered point in the second
            # half of the round (unit-rate process, staggered workers)
            grad_times[r] = (r + t_offset + 0.5
                             + 0.5 * rng.uniform(size=n)).astype(np.float32)
        else:
            grad_times[r] = np.float32(r + t_offset + 1.0)
        # gradient events must come after the last comm event of the round for
        # the per-round scan ordering to be exact
        grad_times[r] = np.maximum(grad_times[r],
                                   event_times[r].max() + 1e-4)

    grad_mask = None
    if grad_rates is not None:
        gr = np.clip(np.asarray(grad_rates, dtype=np.float64), 0.0, 1.0)
        if gr.shape != (n,):
            raise ValueError(f"grad_rates must be ({n},), got {gr.shape}")
        grad_mask = het.uniform(size=(rounds, n)) < gr
    alive = None
    if active is not None and not active.all():
        alive = np.broadcast_to(active, (rounds, n)).copy()

    return Schedule(partners, event_times, event_mask, grad_times,
                    grad_mask=grad_mask, alive=alive)


def _per_edge_events(graph: Graph, rounds: int, comms_per_grad: float,
                     rng: np.random.Generator, t_offset: float):
    """Per-edge Poisson firing (Def 3.1): edge e fires Poisson(c * rate_e)
    times per round; each firing is a single-pair event."""
    n, E = graph.n, graph.num_edges
    lam = comms_per_grad * np.asarray(graph.rates, dtype=np.float64)
    counts = rng.poisson(lam=lam, size=(rounds, max(E, 1))) if E else \
        np.zeros((rounds, 1), dtype=np.int64)
    kmax = max(1, int(counts.sum(axis=1).max()))
    partners = np.tile(np.arange(n, dtype=np.int32), (rounds, kmax, 1))
    event_times = np.zeros((rounds, kmax), dtype=np.float32)
    event_mask = np.zeros((rounds, kmax), dtype=bool)
    for r in range(rounds):
        fired = np.repeat(np.arange(counts.shape[1]), counts[r]) if E else \
            np.zeros(0, np.int64)
        k = len(fired)
        rng.shuffle(fired)  # decorrelate edge identity from the sorted times
        times = np.sort(rng.uniform(r + t_offset, r + t_offset + 1,
                                    size=k)).astype(np.float32)
        last = np.float32(r + t_offset)
        for e in range(kmax):
            if e < k:
                i, j = graph.edges[int(fired[e])]
                partners[r, e, i] = j
                partners[r, e, j] = i
                event_times[r, e] = times[e]
                event_mask[r, e] = True
                last = times[e]
            else:
                event_times[r, e] = last
    return partners, event_times, event_mask


def concat_schedules(schedules: list[Schedule]) -> Schedule:
    """Concatenate per-phase schedules (absolute times) into one Schedule.

    Rounds are padded to the widest per-phase kmax with masked
    identity-partner slots, so both replay paths consume the result exactly
    like a single-phase schedule.
    """
    if not schedules:
        raise ValueError("need at least one schedule")
    if len(schedules) == 1:
        return schedules[0]
    n = schedules[0].n
    if any(s.n != n for s in schedules):
        raise ValueError("schedules must share one worker count")
    kmax = max(s.partners.shape[1] for s in schedules)
    parts, times, masks = [], [], []
    for s in schedules:
        R, K, _ = s.partners.shape
        if K < kmax:
            pad_p = np.tile(np.arange(n, dtype=np.int32), (R, kmax - K, 1))
            # masked pads repeat the row's last time (dt handled by mask)
            pad_t = np.repeat(s.event_times[:, -1:], kmax - K, axis=1)
            parts.append(np.concatenate([s.partners, pad_p], axis=1))
            times.append(np.concatenate([s.event_times, pad_t], axis=1))
            masks.append(np.concatenate(
                [s.event_mask, np.zeros((R, kmax - K), bool)], axis=1))
        else:
            parts.append(s.partners)
            times.append(s.event_times)
            masks.append(s.event_mask)
    any_gmask = any(s.grad_mask is not None for s in schedules)
    any_alive = any(s.alive is not None for s in schedules)
    gmask = np.concatenate(
        [s.grad_mask if s.grad_mask is not None
         else np.ones((s.rounds, n), bool) for s in schedules]) \
        if any_gmask else None
    alive = np.concatenate([s.alive_arr() for s in schedules]) \
        if any_alive else None
    # extension channel: union of keys; schedules without a key contribute
    # zero rows, the K axis pads with zeros like masked slots
    keys: list[str] = []
    for s in schedules:
        keys += [k for k in s.extras_dict() if k not in keys]
    extras = None
    if keys:
        extras = {}
        for k in keys:
            dtype = next(s.extras[k].dtype for s in schedules
                         if s.extras_dict().get(k) is not None)
            chunks = []
            for s in schedules:
                a = s.extras_dict().get(k)
                if a is None:
                    a = np.zeros((s.rounds, kmax, n), dtype)
                elif a.shape[1] < kmax:
                    a = np.concatenate(
                        [a, np.zeros((s.rounds, kmax - a.shape[1], n),
                                     a.dtype)], axis=1)
                chunks.append(a)
            extras[k] = np.concatenate(chunks)
    return Schedule(
        np.concatenate(parts), np.concatenate(times).astype(np.float32),
        np.concatenate(masks),
        np.concatenate([s.grad_times for s in schedules]).astype(np.float32),
        grad_mask=gmask, alive=alive, extras=extras)


def make_topology_schedule(
    tsched: TopologySchedule,
    comms_per_grad: float = 1.0,
    seed: int = 0,
    jitter_grad_times: bool = True,
    grad_rates: np.ndarray | None = None,
    per_edge: bool | None = None,
) -> Schedule:
    """Compile a time-varying topology into one concatenated event schedule.

    Thin wrapper over the declarative World API (``core/world.py``).
    Phase p covers rounds [start_p, start_p + rounds_p) with its own graph
    and churn mask; per-phase seeds are ``seed + p`` so a single-phase
    topology schedule reproduces ``make_schedule(graph, ..., seed)``
    bit-for-bit.  Per-edge rate heterogeneity is expressed through each
    phase graph's own ``rates`` (``Graph.with_rates``); ``per_edge`` forces
    the Def 3.1 single-pair point process for every phase.
    """
    from .world import LinkModel, WorkerModel, World

    world = World(topology=tsched,
                  workers=WorkerModel(grad_rates=grad_rates),
                  links=LinkModel(per_edge=per_edge),
                  comms_per_grad=comms_per_grad,
                  jitter_grad_times=jitter_grad_times)
    return world.compile(seed=seed)


# ---------------------------------------------------------------------------
# Event coalescing (flat-buffer event engine, see DESIGN.md)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoalescedSchedule:
    """Schedule compiled to fused event *batches* (B = max batches/round).

    A batch is a set of events whose matchings are worker-disjoint, so their
    updates commute and apply in ONE sweep of the state with a combined
    partner involution and per-worker event times.  Masked slots of the raw
    schedule vanish entirely (they were full-buffer no-op sweeps in the
    per-event path), and runs of matchings on disjoint pairs merge.

    Shapes (R = rounds, B = max batches/round, n = workers):
      partners     (R, B, n) int32 — combined involution; i for idle workers
      wtimes       (R, B, n) f32   — per-worker event time (valid where the
                                     worker is involved, i.e. partner != i)
      batch_active (R, B) bool     — False = padding, skip the sweep
      grad_times   (R, n) f32      — unchanged from the raw schedule
      grad_mask / alive — heterogeneous-world masks carried through from the
                          raw schedule (see Schedule)
      extras       dict of named (R, B, n) attribute arrays — the raw
                   schedule's extension channel, merged exactly like the
                   partner involution (each involved worker carries its own
                   event's attribute; idle workers read 0)
    """

    partners: np.ndarray
    wtimes: np.ndarray
    batch_active: np.ndarray
    grad_times: np.ndarray
    grad_mask: np.ndarray | None = None
    alive: np.ndarray | None = None
    extras: dict[str, np.ndarray] | None = None

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def n(self) -> int:
        return self.partners.shape[2]

    def alive_arr(self) -> np.ndarray:
        return _alive_arr(self.rounds, self.n, self.alive)

    def grad_scale(self) -> np.ndarray:
        return _grad_scale(self.rounds, self.n, self.grad_mask, self.alive)

    def extras_dict(self) -> dict[str, np.ndarray]:
        return dict(self.extras) if self.extras else {}

    def num_batches(self) -> int:
        """Fused sweeps the engine performs (vs kmax*rounds in the raw path)."""
        return int(self.batch_active.sum())


def coalesce_schedule(schedule: Schedule) -> CoalescedSchedule:
    """Compile a raw per-event schedule into coalesced batches.

    Greedy in event order: event e merges into the current batch iff none of
    its involved workers already appears in the batch — disjoint matchings
    commute and exp(dt1 A) exp(dt2 A) = exp((dt1+dt2) A) lets each worker
    carry its own accumulated mixing horizon, so the merge is EXACT (the
    engine reproduces the per-event path bit-for-bit up to float reordering).
    Masked slots are dropped outright.
    """
    R, K, n = schedule.partners.shape
    idx = np.arange(n)
    raw_ext = schedule.extras_dict()
    per_round: list[list[tuple]] = []
    for r in range(R):
        batches: list[tuple] = []  # (partner, wtime, {name: (n,) attr})
        busy = np.zeros(n, dtype=bool)  # workers involved in current batch
        for e in range(K):
            if not schedule.event_mask[r, e]:
                continue
            p = schedule.partners[r, e]
            involved = p != idx
            if not involved.any():
                continue
            t = schedule.event_times[r, e]
            if batches and not (busy & involved).any():
                # disjoint from the open batch: merge
                partner, wtime, ext = batches[-1]
                partner[involved] = p[involved]
                wtime[involved] = t
            else:
                partner = idx.astype(np.int32).copy()
                partner[involved] = p[involved]
                wtime = np.zeros(n, dtype=np.float32)
                wtime[involved] = t
                ext = {k: np.zeros(n, a.dtype) for k, a in raw_ext.items()}
                batches.append((partner, wtime, ext))
                busy = np.zeros(n, dtype=bool)
            for k, a in raw_ext.items():
                ext[k][involved] = a[r, e, involved]
            busy |= involved
        per_round.append(batches)

    B = max(1, max(len(b) for b in per_round))
    partners = np.tile(idx.astype(np.int32), (R, B, 1))
    wtimes = np.zeros((R, B, n), dtype=np.float32)
    batch_active = np.zeros((R, B), dtype=bool)
    extras = {k: np.zeros((R, B, n), a.dtype) for k, a in raw_ext.items()} \
        if raw_ext else None
    for r, batches in enumerate(per_round):
        for b, (partner, wtime, ext) in enumerate(batches):
            partners[r, b] = partner
            wtimes[r, b] = wtime
            batch_active[r, b] = True
            if extras is not None:
                for k in extras:
                    extras[k][r, b] = ext[k]
    return CoalescedSchedule(partners, wtimes, batch_active,
                             schedule.grad_times.astype(np.float32),
                             grad_mask=schedule.grad_mask,
                             alive=schedule.alive, extras=extras)


@dataclasses.dataclass(frozen=True)
class EventStream:
    """A coalesced schedule flattened into ONE scan-ready step stream.

    The engine replays ``S = num_batches + rounds`` steps — one per fused
    comm batch plus one per gradient tick, nothing for masked slots — as a
    single ``lax.scan``.  Each step applies its own update then the mixing
    segment to the NEXT step ([P_i, mix(d_{i+1})] grouping, see DESIGN.md);
    ``prologue`` is the per-worker mixing from the start clocks ``t0`` to
    each worker's first event.  All segments are schedule data resolved
    host-side: the jit'd loop carries no clock arithmetic.

    Shapes (S = steps, n = workers, R = rounds):
      prologue   (n,) f32
      partners   (S, n) int32 — identity rows for gradient steps
      dt_next    (S, n) f32
      is_grad    (S,) bool
      grad_scale (S, n) f32  — gradient-application scale at gradient steps
                               (straggler thinning x churn); 1.0 elsewhere
      grad_pos   (R,) int32  — step index of round r's gradient tick (for
                               compacting per-step metrics back to per-round)
      t_final    (n,) f32    — per-worker clock after the last step (frozen
                               at detach time for churned workers)
      extras     dict of named (S, n) attribute arrays — the schedule's
                 extension channel flattened to one row per step (zero rows
                 at gradient ticks), ready for a future engine's scan xs
    """

    prologue: np.ndarray
    partners: np.ndarray
    dt_next: np.ndarray
    is_grad: np.ndarray
    grad_scale: np.ndarray
    grad_pos: np.ndarray
    t_final: np.ndarray
    extras: dict[str, np.ndarray] | None = None

    @property
    def steps(self) -> int:
        return self.partners.shape[0]


def coalesced_stream(cs: CoalescedSchedule, t0: np.ndarray,
                     round_batches: np.ndarray | None = None) -> EventStream:
    """Flatten a coalesced schedule into an EventStream given start clocks.

    Heterogeneous worlds ride along as schedule data: a detached worker's
    clock never advances (zero dt segments — its row is a fixed point of the
    replay), a straggler's masked gradient tick still advances its clock and
    mixing horizon but contributes grad_scale 0.

    ``round_batches`` (R,) pads round r to that many comm steps with
    *identity groups* — self-partner p2p, zero-dt mixing, zero extras — an
    exact no-op of the replay.  ``stack_streams`` uses it to align the
    per-round step structure of B ragged worlds so their gradient ticks land
    on the SAME scan step (the batched replay's one shared ``lax.cond``).
    """
    R, B, n = cs.partners.shape
    idx = np.arange(n)
    alive = cs.alive_arr()
    gscale = cs.grad_scale()
    cs_ext = cs.extras_dict()
    partners, dt_next, is_grad, grad_scale, grad_pos = [], [], [], [], []
    ext_rows: dict[str, list[np.ndarray]] = {k: [] for k in cs_ext}
    ext_zero = {k: np.zeros(n, a.dtype) for k, a in cs_ext.items()}
    prologue = None
    tl = np.array(t0, np.float32).copy()

    def emit(partner, delta, grad, gs, ext):
        nonlocal prologue
        if prologue is None:
            prologue = delta
        else:
            dt_next[-1] = delta
        partners.append(partner)
        dt_next.append(np.zeros(n, np.float32))
        is_grad.append(grad)
        grad_scale.append(gs)
        for k in ext_rows:
            ext_rows[k].append(ext[k])

    ones = np.ones(n, np.float32)
    idt = idx.astype(np.int32)
    for r in range(R):
        emitted = 0
        for b in range(B):
            if not cs.batch_active[r, b]:
                continue
            inv = cs.partners[r, b] != idx
            delta = np.zeros(n, np.float32)
            delta[inv] = cs.wtimes[r, b, inv] - tl[inv]
            tl[inv] = cs.wtimes[r, b, inv]
            emit(cs.partners[r, b].astype(np.int32), delta, False, ones,
                 {k: a[r, b] for k, a in cs_ext.items()})
            emitted += 1
        if round_batches is not None:
            target = int(round_batches[r])
            if target < emitted:
                raise ValueError(
                    f"round_batches[{r}] = {target} is below this "
                    f"schedule's {emitted} active batches")
            for _ in range(target - emitted):
                emit(idt, np.zeros(n, np.float32), False, ones, ext_zero)
        adv = alive[r]
        delta = np.where(adv, cs.grad_times[r] - tl, 0.0).astype(np.float32)
        tl = np.where(adv, cs.grad_times[r], tl).astype(np.float32)
        emit(idx.astype(np.int32), delta, True, gscale[r], ext_zero)
        grad_pos.append(len(partners) - 1)

    return EventStream(
        prologue=prologue,
        partners=np.stack(partners),
        dt_next=np.stack(dt_next),
        is_grad=np.asarray(is_grad, bool),
        grad_scale=np.stack(grad_scale).astype(np.float32),
        grad_pos=np.asarray(grad_pos, np.int32),
        t_final=tl.copy(),
        extras={k: np.stack(v) for k, v in ext_rows.items()}
        if ext_rows else None,
    )


# ---------------------------------------------------------------------------
# Many-worlds batching (batched replay subsystem, see DESIGN.md §11)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedSchedule:
    """B per-event schedules padded to one (R, B, K, n) block.

    The batch axis sits directly after the scan (round) axis so a
    ``lax.scan`` over rounds hands each step a (B, ...) slice that a
    ``jax.vmap`` over worlds consumes.  Ragged per-round event counts cost
    masked identity padding (exactly the K-padding ``concat_schedules``
    uses), never a branch; ``grad_scale``/``alive``/``extras`` are
    materialized so the batched reference replay is branch-free.
    """

    partners: np.ndarray     # (R, B, K, n) int32
    event_times: np.ndarray  # (R, B, K) f32
    event_mask: np.ndarray   # (R, B, K) bool
    grad_times: np.ndarray   # (R, B, n) f32
    grad_scale: np.ndarray   # (R, B, n) f32
    alive: np.ndarray        # (R, B, n) bool
    extras: dict[str, np.ndarray] | None = None  # each (R, B, K, n)

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def batch(self) -> int:
        return self.partners.shape[1]

    @property
    def n(self) -> int:
        return self.partners.shape[3]

    def extras_dict(self) -> dict[str, np.ndarray]:
        return dict(self.extras) if self.extras else {}


def _pad_events_k(partners, event_times, event_mask, kmax: int):
    """Pad the K axis with masked identity slots (times repeat the row's
    last value so dt segments stay mask-resolved) — concat_schedules'
    padding, shared by the batch stacker.  A K = 0 schedule (unreachable
    via the samplers, which floor kmax at 1, but legal as hand-built
    data) pads with zero times: every slot is masked, so the values are
    never read."""
    R, K, n = partners.shape
    if K == kmax:
        return partners, event_times, event_mask
    pad_p = np.tile(np.arange(n, dtype=np.int32), (R, kmax - K, 1))
    pad_t = np.repeat(event_times[:, -1:], kmax - K, axis=1) if K else \
        np.zeros((R, kmax), event_times.dtype)
    return (np.concatenate([partners, pad_p], axis=1),
            np.concatenate([event_times, pad_t], axis=1),
            np.concatenate([event_mask, np.zeros((R, kmax - K), bool)],
                           axis=1))


def _union_keys(extra_dicts: list[dict]) -> list[str]:
    keys: list[str] = []
    for d in extra_dicts:
        keys += [k for k in d if k not in keys]
    return keys


def stack_schedules(schedules: list[Schedule]) -> BatchedSchedule:
    """Stack B independent worlds' schedules into one BatchedSchedule.

    All schedules must share (rounds, n) — the sweep grid's common frame;
    ragged event counts (K) are padded to the widest world with masked
    identity slots.  Extras are unioned across worlds: a world without a
    key contributes zeros, which every consumer reads as "no channel
    effect" (fresh, honest).
    """
    if not schedules:
        raise ValueError("need at least one schedule")
    R, n = schedules[0].rounds, schedules[0].n
    for i, s in enumerate(schedules):
        if s.rounds != R or s.n != n:
            raise ValueError(
                f"schedules[{i}] has (rounds, n) = ({s.rounds}, {s.n}); a "
                f"batch must share one frame, expected ({R}, {n})")
    kmax = max(s.partners.shape[1] for s in schedules)
    parts, times, masks = [], [], []
    for s in schedules:
        p, t, m = _pad_events_k(s.partners, s.event_times, s.event_mask,
                                kmax)
        parts.append(p)
        times.append(t)
        masks.append(m)
    ex_dicts = [s.extras_dict() for s in schedules]
    keys = _union_keys(ex_dicts)
    extras = None
    if keys:
        extras = {}
        for k in keys:
            dtype = next(d[k].dtype for d in ex_dicts if k in d)
            chunks = []
            for d in ex_dicts:
                a = d.get(k)
                if a is None:
                    a = np.zeros((R, kmax, n), dtype)
                elif a.shape[1] < kmax:
                    a = np.concatenate(
                        [a, np.zeros((R, kmax - a.shape[1], n), a.dtype)],
                        axis=1)
                chunks.append(a)
            extras[k] = np.stack(chunks, axis=1)
    return BatchedSchedule(
        partners=np.stack(parts, axis=1),
        event_times=np.stack(times, axis=1).astype(np.float32),
        event_mask=np.stack(masks, axis=1),
        grad_times=np.stack([s.grad_times for s in schedules],
                            axis=1).astype(np.float32),
        grad_scale=np.stack([s.grad_scale() for s in schedules], axis=1),
        alive=np.stack([s.alive_arr() for s in schedules], axis=1),
        extras=extras)


@dataclasses.dataclass(frozen=True)
class BatchedStream:
    """B event streams aligned to ONE shared scan skeleton.

    ``stack_streams`` pads every round of every world to the per-round max
    batch count across the batch (identity groups), so each world's round-r
    gradient tick lands on the SAME step index: ``is_grad`` and
    ``grad_pos`` are shared (S,)/(R,) vectors and the batched engine scan
    keeps the single ``lax.cond`` step structure of the serial replay —
    the batch axis never enters control flow.

    Shapes (S = shared steps, B = worlds, n = workers, R = rounds):
      prologue   (B, n) f32
      partners   (S, B, n) int32
      dt_next    (S, B, n) f32
      is_grad    (S,) bool   — shared across the batch by construction
      grad_scale (S, B, n) f32
      grad_pos   (R,) int32  — shared
      t_final    (B, n) f32
      extras     dict of named (S, B, n) arrays (union over worlds;
                 missing keys are zero = fresh/honest)
    """

    prologue: np.ndarray
    partners: np.ndarray
    dt_next: np.ndarray
    is_grad: np.ndarray
    grad_scale: np.ndarray
    grad_pos: np.ndarray
    t_final: np.ndarray
    extras: dict[str, np.ndarray] | None = None

    @property
    def steps(self) -> int:
        return self.partners.shape[0]

    @property
    def batch(self) -> int:
        return self.partners.shape[1]

    def extras_dict(self) -> dict[str, np.ndarray]:
        return dict(self.extras) if self.extras else {}


def stack_streams(cs_list: list[CoalescedSchedule],
                  t0: np.ndarray) -> BatchedStream:
    """Compile B coalesced schedules + start clocks into one BatchedStream.

    Alignment: round r contributes ``max_b active_batches_b(r)`` comm steps
    for EVERY world — worlds with fewer real batches that round replay
    identity groups (self-partner p2p, zero-dt mix, zero extras), which
    both kernel backends reduce to exact no-ops.  Padding therefore costs
    per-round raggedness, not the global max, and the gradient ticks of all
    worlds coincide step-for-step.
    """
    if not cs_list:
        raise ValueError("need at least one coalesced schedule")
    R, n = cs_list[0].rounds, cs_list[0].n
    for i, cs in enumerate(cs_list):
        if cs.rounds != R or cs.n != n:
            raise ValueError(
                f"coalesced schedules[{i}] has (rounds, n) = "
                f"({cs.rounds}, {cs.n}); a batch must share one frame, "
                f"expected ({R}, {n})")
    t0 = np.asarray(t0, np.float32)
    if t0.shape != (len(cs_list), n):
        raise ValueError(f"t0 must be (B, n) = ({len(cs_list)}, {n}) start "
                         f"clocks, got {t0.shape}")
    round_batches = np.stack(
        [cs.batch_active.sum(axis=1) for cs in cs_list]).max(axis=0)
    streams = [coalesced_stream(cs, t0[i], round_batches=round_batches)
               for i, cs in enumerate(cs_list)]
    s0 = streams[0]
    for st in streams[1:]:
        # same rounds + same per-round batch counts => identical skeleton
        assert st.steps == s0.steps
        assert np.array_equal(st.is_grad, s0.is_grad)
        assert np.array_equal(st.grad_pos, s0.grad_pos)
    ex_dicts = [st.extras or {} for st in streams]
    keys = _union_keys(ex_dicts)
    extras = None
    if keys:
        extras = {}
        for k in keys:
            dtype = next(d[k].dtype for d in ex_dicts if k in d)
            extras[k] = np.stack(
                [d.get(k, np.zeros((s0.steps, n), dtype))
                 for d in ex_dicts], axis=1)
    return BatchedStream(
        prologue=np.stack([st.prologue for st in streams]),
        partners=np.stack([st.partners for st in streams], axis=1),
        dt_next=np.stack([st.dt_next for st in streams], axis=1),
        is_grad=s0.is_grad,
        grad_scale=np.stack([st.grad_scale for st in streams], axis=1),
        grad_pos=s0.grad_pos,
        t_final=np.stack([st.t_final for st in streams]),
        extras=extras)


def empirical_laplacian(schedule: Schedule, rounds: int | None = None) -> np.ndarray:
    """Empirical expected Laplacian from realized matchings (paper App E.2)."""
    R = rounds or schedule.rounds
    n = schedule.n
    L = np.zeros((n, n))
    for r in range(R):
        for k in range(schedule.partners.shape[1]):
            if not schedule.event_mask[r, k]:
                continue
            p = schedule.partners[r, k]
            for i in range(n):
                j = int(p[i])
                if j > i:
                    L[i, i] += 1
                    L[j, j] += 1
                    L[i, j] -= 1
                    L[j, i] -= 1
    return L / R


# --------------------------------------------------------------------------
# Shard-aware schedule compilation (DESIGN.md §16): partition a batched
# stream's matchings over a worker-sharded device mesh.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Host-compiled partition of a :class:`BatchedStream` over ``n_shards``
    equal worker shards.

    Every comm step's matching splits into INTRA-shard pairs (both
    endpoints on one shard — the partner involution restricted to a shard
    is still an involution, because a pair is either wholly intra or both
    of its directed reads are cross) and CROSS-shard boundary reads.  The
    intra reads keep the fused per-shard gather (``local_partner`` indexes
    the shard's own (Ws, D) rows); the cross reads are served by the
    bounded-staleness permute ring: each shard publishes the boundary rows
    its peers will read this step (``pub_row``/``pub_slot``, staleness
    resolved by the PUBLISHER against its own snapshot ring — an exact
    copy of what the single-device ``ring_read`` would have produced),
    ``n_shards - 1`` static ``lax.ppermute`` ring hops stack the published
    blocks into an (NS, B, nb, D) pool, and readers index the pool by
    ``(hop, pool_pos)``.

    Shapes (S = steps, B = worlds, n = workers, NS = shards,
    Ws = n // NS, nb = max boundary rows one shard serves in one step):
      local_partner (S, B, n) int32 — partner % Ws for intra reads; the
                    reader's own local row for cross/idle reads (a valid
                    self-gather whose value the cross select discards)
      is_cross      (S, B, n) bool
      hop           (S, B, n) int32 — (reader_shard - source_shard) % NS,
                    the pool index the read is served from (0 if intra)
      pool_pos      (S, B, n) int32 — position inside the source shard's
                    published block (0 if intra)
      pub_row       (S, NS, B, nb) int32 — for each DESTINATION-facing
                    source shard u: the local rows u publishes at this
                    step, ordered by reader index (padding = row 0)
      pub_slot      (S, NS, B, nb) int32 — ring slot each published row is
                    resolved at (the sentinel ``horizon`` = fresh)
      cross_reads   (S, B) int64 — boundary-read counts (telemetry)
    """

    n_shards: int
    shard_size: int
    pool_width: int
    local_partner: np.ndarray
    is_cross: np.ndarray
    hop: np.ndarray
    pool_pos: np.ndarray
    pub_row: np.ndarray
    pub_slot: np.ndarray
    cross_reads: np.ndarray


def shard_partition(partners: np.ndarray, src_slot: np.ndarray,
                    n_shards: int, horizon: int) -> ShardPlan:
    """Partition batched-stream matchings into intra-shard groups and
    cross-shard boundary exchanges.

    ``partners`` is the stream's (S, B, n) global partner involution,
    ``src_slot`` the host-resolved (S, B, n) ring slots (sentinel =
    ``horizon`` = fresh) the reads are served at — the SAME array the
    single-device channel scan consumes, so the publisher-side resolution
    is bitwise the single-device ``ring_read``.
    """
    partners = np.asarray(partners)
    S, B, n = partners.shape
    if n % n_shards != 0:
        raise ValueError(f"worker axis {n} is not divisible by "
                         f"{n_shards} shards")
    ws = n // n_shards
    rdr = np.arange(n, dtype=np.int64)
    rdr_shard = rdr // ws                       # (n,)
    p_shard = partners.astype(np.int64) // ws   # (S, B, n)
    involved = partners != rdr
    is_cross = involved & (p_shard != rdr_shard)
    hop = np.where(is_cross, (rdr_shard - p_shard) % n_shards, 0
                   ).astype(np.int32)
    local_partner = np.where(is_cross | ~involved, rdr % ws,
                             partners.astype(np.int64) % ws
                             ).astype(np.int32)
    # rank each cross read among same-(step, world, source-shard) reads,
    # reader-index ascending — the order the source shard publishes in
    pool_pos = np.zeros((S, B, n), np.int32)
    counts = np.zeros((S, B, n_shards), np.int64)
    for u in range(n_shards):
        m = is_cross & (p_shard == u)
        pool_pos = np.where(m, np.cumsum(m, axis=-1) - 1, pool_pos
                            ).astype(np.int32)
        counts[:, :, u] = m.sum(axis=-1)
    nb = max(int(counts.max()), 1)
    pub_row = np.zeros((S, n_shards, B, nb), np.int32)
    pub_slot = np.full((S, n_shards, B, nb), horizon, np.int32)
    s_i, b_i, r_i = np.nonzero(is_cross)
    u_i = p_shard[s_i, b_i, r_i]
    k_i = pool_pos[s_i, b_i, r_i]
    pub_row[s_i, u_i, b_i, k_i] = (partners[s_i, b_i, r_i] % ws)
    pub_slot[s_i, u_i, b_i, k_i] = np.asarray(src_slot)[s_i, b_i, r_i]
    return ShardPlan(n_shards=n_shards, shard_size=ws, pool_width=nb,
                     local_partner=local_partner, is_cross=is_cross,
                     hop=hop, pool_pos=pool_pos,
                     pub_row=pub_row, pub_slot=pub_slot,
                     cross_reads=is_cross.sum(axis=-1).astype(np.int64))


def shard_lag_stale(partners: np.ndarray, stale: np.ndarray,
                    step_round: np.ndarray, n_shards: int, lag: int
                    ) -> np.ndarray:
    """Impose the permute ring's staleness floor ``lag`` on cross-shard
    reads of a batched stream.

    A lag-L ring serves boundary reads from snapshots at least L rounds
    old: ``stale' = min(max(stale, L), rounds_elapsed)`` on cross reads
    (the clamp to elapsed rounds is the same guarantee ``ChannelModel``
    compiles — no slot is read before it was written).  Intra-shard reads
    keep their scheduled staleness untouched, so lag > 0 is EXACTLY a
    single-device replay of the same schedule with rewritten ``stale``
    extras — the per-event ``DelayProcess`` reference the tests pin
    against.
    """
    partners = np.asarray(partners)
    S, B, n = partners.shape
    ws = n // n_shards
    rdr = np.arange(n, dtype=np.int64)
    is_cross = (partners != rdr) & \
        (partners.astype(np.int64) // ws != rdr // ws)
    eff = np.minimum(np.maximum(np.asarray(stale, np.int64), int(lag)),
                     step_round[:, None, None])
    return np.where(is_cross, eff, stale).astype(np.int32)
