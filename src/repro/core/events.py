"""Poisson event schedules for the asynchronous dynamic (Assumption 3.2).

The paper's implementation emulates the point processes: "each worker samples
a random number of p2p averagings to perform between each gradient
computation, following a Poisson law using the communication rate as mean",
and pairs available workers through a FIFO queue (~ uniform matchings,
App E.2).  We reproduce exactly that emulation:

  * a *round* covers one unit of simulated time; every worker takes one
    gradient step per round at a jittered time (rate-1 process, time
    renormalized exactly like the paper's running-average normalizer),
  * the number of matching events in a round is Poisson(comm_rate) — a
    matching event pairs (at most) all workers simultaneously, so it models
    "one p2p averaging per worker",
  * matchings are maximal matchings sampled from random edge orders — the
    matching marginals define the empirical Laplacian we verify against
    Def 3.1 (the paper's Fig 7 check).

Schedules are built host-side with numpy (they are data, not compute) and
consumed by `lax.scan` inside the jit'd simulator.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .graphs import Graph


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Precomputed event schedule for `rounds` units of simulated time.

    Shapes (R = rounds, K = max events/round, n = workers):
      partners    (R, K, n) int32 — partner[e, i] = j or i (idle / masked)
      event_times (R, K) float32  — sorted within each round, masked events
                                    repeat the previous valid time
      event_mask  (R, K) bool
      grad_times  (R, n) float32  — time of each worker's gradient event
    """

    partners: np.ndarray
    event_times: np.ndarray
    event_mask: np.ndarray
    grad_times: np.ndarray

    @property
    def rounds(self) -> int:
        return self.partners.shape[0]

    @property
    def n(self) -> int:
        return self.partners.shape[2]

    def num_comm_events(self) -> int:
        """Total pairwise communications in the schedule (counted per pair)."""
        total = 0
        for r in range(self.rounds):
            for k in range(self.partners.shape[1]):
                if self.event_mask[r, k]:
                    p = self.partners[r, k]
                    total += int(np.sum(p != np.arange(self.n))) // 2
        return total


def make_schedule(
    graph: Graph,
    rounds: int,
    comms_per_grad: float = 1.0,
    seed: int = 0,
    jitter_grad_times: bool = True,
) -> Schedule:
    """Build a Poisson event schedule.

    comms_per_grad — expected number of p2p averagings per worker between two
    of its gradient steps (the paper's "#com/#grad" knob, Tab 5).
    """
    rng = np.random.default_rng(seed)
    n = graph.n

    counts = rng.poisson(lam=comms_per_grad, size=rounds)
    kmax = max(1, int(counts.max()))

    partners = np.tile(np.arange(n, dtype=np.int32), (rounds, kmax, 1))
    event_times = np.zeros((rounds, kmax), dtype=np.float32)
    event_mask = np.zeros((rounds, kmax), dtype=bool)
    grad_times = np.zeros((rounds, n), dtype=np.float32)

    for r in range(rounds):
        k = int(counts[r])
        times = np.sort(rng.uniform(r, r + 1, size=k)).astype(np.float32)
        last = np.float32(r)
        for e in range(kmax):
            if e < k:
                matching = graph.sample_matching(rng)
                partners[r, e] = graph.matching_to_partner(matching).astype(np.int32)
                event_times[r, e] = times[e]
                event_mask[r, e] = True
                last = times[e]
            else:
                event_times[r, e] = last  # masked: dt contribution handled by mask
        if jitter_grad_times:
            # each worker's gradient lands at a jittered point in the second
            # half of the round (unit-rate process, staggered workers)
            grad_times[r] = (r + 0.5 + 0.5 * rng.uniform(size=n)).astype(np.float32)
        else:
            grad_times[r] = np.float32(r + 1.0)
        # gradient events must come after the last comm event of the round for
        # the per-round scan ordering to be exact
        grad_times[r] = np.maximum(grad_times[r], event_times[r].max() + 1e-4)

    return Schedule(partners, event_times, event_mask, grad_times)


def empirical_laplacian(schedule: Schedule, rounds: int | None = None) -> np.ndarray:
    """Empirical expected Laplacian from realized matchings (paper App E.2)."""
    R = rounds or schedule.rounds
    n = schedule.n
    L = np.zeros((n, n))
    for r in range(R):
        for k in range(schedule.partners.shape[1]):
            if not schedule.event_mask[r, k]:
                continue
            p = schedule.partners[r, k]
            for i in range(n):
                j = int(p[i])
                if j > i:
                    L[i, i] += 1
                    L[j, j] += 1
                    L[i, j] -= 1
                    L[j, i] -= 1
    return L / R
