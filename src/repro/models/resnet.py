"""ResNet for the paper's own experiments (ResNet18-CIFAR10, Sec 4).

Pure-JAX pre-activation ResNet with lax.conv; BatchNorm is replaced by
GroupNorm — the standard substitution for decentralized/small-local-batch
training where BN statistics differ per worker (noted in DESIGN.md).  A
ResNet-8 variant makes the paper's CIFAR experiment CPU-tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet18"
    stage_sizes: Sequence[int] = (2, 2, 2, 2)
    width: int = 64
    num_classes: int = 10
    groups: int = 8  # groupnorm groups


def resnet18_cifar() -> ResNetConfig:
    return ResNetConfig("resnet18", (2, 2, 2, 2), 64, 10)


def resnet8_cifar() -> ResNetConfig:
    """CPU-scale stand-in with the same family (3 stages x 1 block)."""
    return ResNetConfig("resnet8", (1, 1, 1), 16, 10, groups=4)


def _conv_init(key, shape):
    fan_in = int(np.prod(shape[:-1]))
    return jax.random.normal(key, shape) * np.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, scale, bias, groups, eps=1e-5):
    B, H, W, C = x.shape
    g = x.reshape(B, H, W, groups, C // groups)
    mean = jnp.mean(g, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(g, axis=(1, 2, 4), keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    return g.reshape(B, H, W, C) * scale + bias


def init_resnet(key, cfg: ResNetConfig) -> dict:
    keys = iter(jax.random.split(key, 256))
    p: dict = {"stem": _conv_init(next(keys), (3, 3, 3, cfg.width)),
               "stem_gn": (jnp.ones((cfg.width,)), jnp.zeros((cfg.width,)))}
    c_in = cfg.width
    p["stages"] = []
    for si, n_blocks in enumerate(cfg.stage_sizes):
        c_out = cfg.width * (2 ** si)
        stage = []
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {
                "conv1": _conv_init(next(keys), (3, 3, c_in, c_out)),
                "gn1": (jnp.ones((c_in,)), jnp.zeros((c_in,))),
                "conv2": _conv_init(next(keys), (3, 3, c_out, c_out)),
                "gn2": (jnp.ones((c_out,)), jnp.zeros((c_out,))),
            }
            # stride-2 blocks are exactly the projected ones in these configs,
            # so `stride` stays out of the param pytree (grad-friendly)
            if stride != 1 or c_in != c_out:
                blk["proj"] = _conv_init(next(keys), (1, 1, c_in, c_out))
            stage.append(blk)
            c_in = c_out
        p["stages"].append(stage)
    p["head"] = (jax.random.normal(next(keys), (c_in, cfg.num_classes))
                 / np.sqrt(c_in), jnp.zeros((cfg.num_classes,)))
    return p


def apply_resnet(p, cfg: ResNetConfig, x: jax.Array) -> jax.Array:
    """x: (B, 32, 32, 3) -> logits (B, num_classes)."""
    h = _conv(x, p["stem"])
    for stage in p["stages"]:
        for blk in stage:
            g = cfg.groups
            stride = 2 if "proj" in blk else 1
            y = _gn(h, *blk["gn1"], g)
            y = jax.nn.relu(y)
            shortcut = _conv(y, blk["proj"], stride) if "proj" in blk else h
            y = _conv(y, blk["conv1"], stride)
            y = jax.nn.relu(_gn(y, *blk["gn2"], g))
            y = _conv(y, blk["conv2"])
            h = shortcut + y
    h = jnp.mean(jax.nn.relu(h), axis=(1, 2))
    w, b = p["head"]
    return h @ w + b


def resnet_loss(p, cfg: ResNetConfig, batch: dict) -> tuple[jax.Array, dict]:
    logits = apply_resnet(p, cfg, batch["images"])
    lp = jax.nn.log_softmax(logits)
    ce = -jnp.take_along_axis(lp, batch["labels"][:, None], axis=-1).mean()
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
    return ce, {"acc": acc}
