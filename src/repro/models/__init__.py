"""Pure-JAX model zoo."""
from .config import Block, MLAConfig, MoEConfig, ModelConfig, RGLRUConfig, SSMConfig
from .transformer import Model

__all__ = ["Block", "MLAConfig", "MoEConfig", "ModelConfig", "RGLRUConfig",
           "SSMConfig", "Model"]
