"""Model configuration: a composable block-pattern description.

A model is a stack of *layer groups*; each group is a (Block, repeat) pair and
its parameters are stacked along a leading axis so the forward pass is a
`lax.scan` over the group (small HLO, fast SPMD-partitioner compiles even at
61+ layers / 512 devices).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mla", "ssd", "rglru"]
Mlp = Literal["dense", "moe", "moe+dense", "none"]


@dataclasses.dataclass(frozen=True)
class Block:
    """One residual block: token mixer + channel mlp."""

    mixer: Mixer = "attn"
    mlp: Mlp = "dense"
    window: int | None = None  # sliding-window size for local attention


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 0            # expert hidden dim (0 => use d_ff)
    shared_expert: bool = False  # one always-on shared expert (DeepSeek-V3)
    d_shared: int = 0            # shared expert hidden (0 => d_expert)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance auxiliary loss
    dense_d_ff: int = 0          # parallel dense residual MLP (Arctic) hidden


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block (RecurrentGemma / Griffin)."""

    d_rnn: int = 0       # recurrent width (0 => d_model)
    conv_width: int = 4
    c: float = 8.0       # power constant a_t = a^(c * r_t)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    # layer groups: ((unit_of_blocks, repeat), ...).  Each group's params are
    # stacked over `repeat` and the forward pass lax.scans the unit — e.g.
    # RecurrentGemma is (((rglru, rglru, local_attn), 12), ((rglru, rglru), 1)).
    blocks: tuple[tuple[tuple[Block, ...], int], ...]
    # attention
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0          # 0 => d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # fraction of head dims rotated (GLM-4: 0.5)
    d_ff: int = 0
    mlp_act: str = "silu"      # silu (swiglu) | gelu
    # sub-configs (None when unused)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    # I/O
    input_mode: str = "tokens"     # tokens | embeddings (stubbed frontend)
    num_codebooks: int = 1         # musicgen: parallel codebook heads
    tie_embeddings: bool = False
    # long-context decode: window applied to *all* attention blocks when set
    # by the shape adapter (sub-quadratic carve-out for long_500k)
    long_context_window: int = 4096
    # residual-stream (scan carry) sharding: "embed" shards d_model over the
    # model axis (min memory, gathers x per block), "seq" shards the sequence
    # (gathers only k/v per attention — cheaper with GQA), "none" replicates
    carry_shard: str = "embed"
    # multi-token prediction (DeepSeek-V3): extra depth-1 MTP head
    mtp: bool = False
    # attention implementation: "xla" (einsum path, shardable — used by the
    # dry-run) or "pallas" (the flash kernel; interpret mode on CPU)
    attention_impl: str = "xla"
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------ api
    @property
    def num_layers(self) -> int:
        return sum(len(unit) * r for unit, r in self.blocks)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab axis shards
        evenly under tensor parallelism (e.g. mamba2's 50280 -> 50432).
        Logits/embeddings use the padded size; token ids never reach the pad."""
        return (self.vocab_size + 255) // 256 * 256

    def with_updates(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def windowed(self, window: int | None = None) -> "ModelConfig":
        """Return a variant where every attention block is sliding-window —
        used for the long_500k decode shape (sub-quadratic carve-out)."""
        w = window or self.long_context_window
        blocks = tuple(
            (tuple(dataclasses.replace(
                b, window=(min(b.window, w) if b.window else w))
                if b.mixer in ("attn", "mla") else b for b in unit), r)
            for unit, r in self.blocks)
        return dataclasses.replace(self, blocks=blocks)

    def all_blocks(self) -> list[Block]:
        out: list[Block] = []
        for unit, r in self.blocks:
            out.extend(list(unit) * r)
        return out

    def validate(self) -> None:
        assert self.num_layers > 0
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        for b in self.all_blocks():
            if b.mixer == "mla":
                assert self.mla is not None
            if b.mixer == "ssd":
                assert self.ssm is not None
            if b.mixer == "rglru":
                assert self.rglru is not None
            if b.mlp in ("moe", "moe+dense"):
                assert self.moe is not None


def uniform_blocks(block: Block, n: int) -> tuple[tuple[tuple[Block, ...], int], ...]:
    return (((block,), n),)
