"""The composable decoder stack: scan-over-layer-groups, train + decode paths.

Parameters are nested dicts; every layer group's params are stacked along a
leading `repeat` axis and the forward pass is a single `lax.scan` per group —
the HLO stays small regardless of depth, which keeps 512-device SPMD
partitioning tractable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .. import sharding
from . import attention, rglru, ssm
from .config import Block, ModelConfig
from .layers import (apply_lm_head, apply_mlp, apply_moe, dtype_of,
                     embed_inputs, init_embedding, init_lm_head, init_mlp,
                     init_moe, init_rmsnorm, rmsnorm)

PyTree = Any


# ----------------------------------------------------------------- per block

def init_block(key, cfg: ModelConfig, block: Block) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, dtype)}
    if block.mixer == "attn":
        p["mixer"] = attention.init_attention(k1, cfg, dtype)
    elif block.mixer == "mla":
        p["mixer"] = attention.init_mla(k1, cfg, dtype)
    elif block.mixer == "ssd":
        p["mixer"] = ssm.init_ssd(k1, cfg, dtype)
    elif block.mixer == "rglru":
        p["mixer"] = rglru.init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(block.mixer)
    if block.mlp != "none":
        p["norm2"] = init_rmsnorm(cfg.d_model, dtype)
        if block.mlp == "dense":
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_act)
        else:  # moe / moe+dense
            p["mlp"] = init_moe(k2, cfg.d_model, cfg.moe, dtype, cfg.mlp_act)
    return p


def apply_block(p, cfg: ModelConfig, block: Block, x, positions
                ) -> tuple[jax.Array, jax.Array]:
    """Residual block; returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if block.mixer == "attn":
        h = attention.apply_attention(p["mixer"], cfg, h, positions,
                                      block.window)
    elif block.mixer == "mla":
        h = attention.apply_mla(p["mixer"], cfg, h, positions, block.window)
    elif block.mixer == "ssd":
        h = ssm.apply_ssd(p["mixer"], cfg, h)
    elif block.mixer == "rglru":
        h = rglru.apply_rglru(p["mixer"], cfg, h)
    x = x + h
    if block.mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if block.mlp == "dense":
            h = apply_mlp(p["mlp"], h, cfg.mlp_act)
        else:
            h, aux = apply_moe(p["mlp"], h, cfg.moe, cfg.mlp_act)
        x = x + h
    return x, aux


def init_block_cache(cfg: ModelConfig, block: Block, batch: int, length: int,
                     dtype) -> dict:
    if block.mixer == "attn":
        return attention.init_attn_cache(cfg, batch, length, block.window,
                                         dtype)
    if block.mixer == "mla":
        return attention.init_mla_cache(cfg, batch, length, block.window,
                                        dtype)
    if block.mixer == "ssd":
        return ssm.init_ssd_cache(cfg, batch, dtype)
    if block.mixer == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(block.mixer)


def decode_block(p, cfg: ModelConfig, block: Block, x, pos, cache
                 ) -> tuple[jax.Array, dict]:
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if block.mixer == "attn":
        h, cache = attention.decode_attention(p["mixer"], cfg, h, pos, cache,
                                              block.window)
    elif block.mixer == "mla":
        h, cache = attention.decode_mla(p["mixer"], cfg, h, pos, cache,
                                        block.window)
    elif block.mixer == "ssd":
        h, cache = ssm.decode_ssd(p["mixer"], cfg, h, pos, cache)
    elif block.mixer == "rglru":
        h, cache = rglru.decode_rglru(p["mixer"], cfg, h, pos, cache)
    x = x + h
    if block.mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if block.mlp == "dense":
            h = apply_mlp(p["mlp"], h, cfg.mlp_act)
        else:
            h, _ = apply_moe(p["mlp"], h, cfg.moe, cfg.mlp_act)
        x = x + h
    return x, cache


# --------------------------------------------------------------------- model

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        cfg.validate()
        dtype = dtype_of(cfg.param_dtype)
        k_embed, k_head, k_mtp, *k_groups = jax.random.split(
            key, 3 + len(cfg.blocks))
        params: dict = {
            "embed": init_embedding(k_embed, cfg, dtype),
            "final_norm": init_rmsnorm(cfg.d_model, dtype),
            "head": init_lm_head(k_head, cfg, dtype),
            "groups": [],
        }
        for (unit, repeat), kg in zip(cfg.blocks, k_groups):
            keys = jax.random.split(kg, repeat)

            def init_unit(k):
                uks = jax.random.split(k, len(unit))
                return {f"b{i}": init_block(uk, cfg, b)
                        for i, (uk, b) in enumerate(zip(uks, unit))}

            params["groups"].append(jax.vmap(init_unit)(keys))
        if cfg.mtp:
            from .layers import dense_init
            km1, km2 = jax.random.split(k_mtp)
            params["mtp"] = {
                "proj": dense_init(km1, 2 * cfg.d_model,
                                   (2 * cfg.d_model, cfg.d_model), dtype),
                "norm": init_rmsnorm(2 * cfg.d_model, dtype),
                "block": init_block(km2, cfg,
                                    Block(mixer="attn", mlp="dense")
                                    if cfg.d_ff else cfg.all_blocks()[0]),
            }
        return params

    # --------------------------------------------------------------- forward
    def forward(self, params: dict, inputs: jax.Array, *, remat: bool = False
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """inputs: tokens (B,S) int32 or embeddings (B,S,D).

        Returns (logits, aux_loss, final_hidden)."""
        cfg = self.cfg
        x = embed_inputs(params["embed"], cfg, inputs)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        aux_total = jnp.zeros((), jnp.float32)
        # the scan carry (residual stream) is what backward saves per layer —
        # sharding it makes the saved stack 1/TP of the naive size; "embed"
        # (Megatron-SP-style) gathers x per block, "seq" gathers only k/v at
        # attention (see EXPERIMENTS.md §Perf for the measured trade-off)
        carry_axes = {"embed": ("batch", None, "act_embed"),
                      "seq": ("batch", "seq", None),
                      "none": ("batch", None, None)}[cfg.carry_shard]
        x = sharding.hint(x, *carry_axes)

        for (unit, repeat), group_p in zip(cfg.blocks, params["groups"]):

            def unit_fn(x, layer_p, unit=unit):
                aux = jnp.zeros((), jnp.float32)
                for i, b in enumerate(unit):
                    x, a = apply_block(layer_p[f"b{i}"], cfg, b, x, positions)
                    aux = aux + a
                x = sharding.hint(x, *carry_axes)
                return x, aux

            if remat:
                unit_fn = jax.checkpoint(
                    unit_fn, policy=jax.checkpoint_policies.nothing_saveable)
            x, auxs = jax.lax.scan(lambda c, p_: unit_fn(c, p_), x, group_p)
            aux_total = aux_total + jnp.sum(auxs)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = apply_lm_head(params["head"], params["embed"], cfg, x)
        return logits, aux_total, x

    # ------------------------------------------------------------------ loss
    def loss(self, params: dict, batch: dict, *, remat: bool = False
             ) -> tuple[jax.Array, dict]:
        """batch: {"inputs": tokens/embeddings, "labels": (B,S) or (B,S,C)}."""
        cfg = self.cfg
        logits, aux, h = self.forward(params, batch["inputs"], remat=remat)
        labels = batch["labels"]
        B, S = labels.shape[:2]
        C = cfg.num_codebooks
        logits = logits.reshape(B, S, C, cfg.padded_vocab)
        if labels.ndim == 2:
            labels = labels[..., None]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(ce)
        metrics = {"ce": loss, "aux": aux}
        if cfg.mtp and cfg.input_mode == "tokens":
            mtp_loss = self._mtp_loss(params, batch, h)
            metrics["mtp"] = mtp_loss
            loss = loss + 0.3 * mtp_loss
        return loss + aux, metrics

    def _mtp_loss(self, params, batch, h):
        """DeepSeek-V3 multi-token prediction: depth-1 extra block predicting
        token t+2 from [h_t ; emb(tok_{t+1})]."""
        cfg = self.cfg
        tok = batch["inputs"]
        B, S = tok.shape
        emb_next = jnp.take(params["embed"]["tok"], tok[:, 1:], axis=0)
        hh = jnp.concatenate([h[:, :-1], emb_next.astype(h.dtype)], axis=-1)
        hh = rmsnorm(hh, params["mtp"]["norm"], cfg.norm_eps)
        hh = hh @ params["mtp"]["proj"]
        positions = jnp.broadcast_to(jnp.arange(S - 1, dtype=jnp.int32),
                                     (B, S - 1))
        block = (Block(mixer="attn", mlp="dense") if cfg.d_ff
                 else cfg.all_blocks()[0])
        hh, _ = apply_block(params["mtp"]["block"], cfg, block, hh, positions)
        logits = apply_lm_head(params["head"], params["embed"], cfg, hh)
        logits = logits.reshape(B, S - 1, cfg.num_codebooks, cfg.padded_vocab)
        labels = batch["labels"]
        if labels.ndim == 2:
            labels = labels[..., None]
        # labels are already inputs shifted by 1 => use labels shifted by 1
        tgt = labels[:, 1:]
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(ce)

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, length: int, dtype=None) -> list:
        cfg = self.cfg
        dtype = dtype or dtype_of(cfg.compute_dtype)
        caches = []
        for (unit, repeat) in cfg.blocks:
            def one(_):
                return {f"b{i}": init_block_cache(cfg, b, batch, length, dtype)
                        for i, b in enumerate(unit)}
            stacked = jax.vmap(one)(jnp.arange(repeat))
            caches.append(stacked)
        return caches

    def decode_step(self, params: dict, inputs: jax.Array, pos: jax.Array,
                    caches: list) -> tuple[jax.Array, list]:
        """inputs: tokens (B,1) or embeddings (B,1,D); pos scalar int32 or
        (B,) per-sequence positions (continuous batching: each slot decodes
        at its own offset — RoPE, cache index, and visibility mask are all
        per-sequence).

        Returns (logits (B,1,V*C), new caches)."""
        cfg = self.cfg
        x = embed_inputs(params["embed"], cfg, inputs)
        new_caches = []
        for (unit, repeat), group_p, cache in zip(cfg.blocks, params["groups"],
                                                  caches):

            def unit_fn(x, pc):
                layer_p, c = pc
                new_c = {}
                for i, b in enumerate(unit):
                    x, nc = decode_block(layer_p[f"b{i}"], cfg, b, x, pos,
                                         c[f"b{i}"])
                    new_c[f"b{i}"] = nc
                return x, new_c

            x, nc = jax.lax.scan(unit_fn, x, (group_p, cache))
            new_caches.append(nc)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = apply_lm_head(params["head"], params["embed"], cfg, x)
        return logits, new_caches

    def prefill(self, params: dict, inputs: jax.Array, caches: list,
                pos0: jax.Array = 0) -> tuple[jax.Array, list]:
        """Chunked prefill: feed a whole (B, P) prompt through the decode
        path in ONE dispatch — a ``lax.scan`` over ``decode_step`` instead
        of P separate device round-trips.  Scanning the decode path (rather
        than running ``forward`` and scattering K/V) keeps prefill exact for
        every mixer family: ssd/rglru carry recurrent caches whose decode
        recurrence IS the definition the full-sequence kernels re-derive.

        inputs: tokens (B, P) or embeddings (B, P, D); positions are
        ``pos0 .. pos0 + P - 1``.  Returns (logits (B,1,V*C) at the LAST
        position, filled caches) — exactly what step ``P - 1`` of the
        token-by-token loop returned.
        """
        P = inputs.shape[1]

        def body(c, t):
            tok = jax.lax.dynamic_slice_in_dim(inputs, t, 1, axis=1)
            _, c = self.decode_step(params, tok, pos0 + t, c)
            return c, None

        if P > 1:
            caches, _ = jax.lax.scan(body, caches,
                                     jnp.arange(P - 1, dtype=jnp.int32))
        return self.decode_step(params, inputs[:, P - 1:P],
                                pos0 + jnp.int32(P - 1), caches)

    # ------------------------------------------------------------------ misc
    def param_count(self, params: dict) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))
