"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (quadratic within chunks of
length Q, linear across chunks); decode is the O(1)-per-token state update.
Attention-free — supports long_500k natively with a constant-size state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding
from .config import ModelConfig, SSMConfig
from .layers import dense_init, rmsnorm


def _dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return s, d_inner, n_heads


def init_ssd(key, cfg: ModelConfig, dtype):
    s, d_inner, H = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    # dt bias: softplus^-1 of dt ~ U[1e-3, 1e-1]
    dt = np.exp(np.random.RandomState(0).uniform(
        np.log(1e-3), np.log(1e-1), size=H)).astype(np.float32)
    dt_bias = dt + np.log(-np.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              (cfg.d_model,
                               2 * d_inner + 2 * s.n_groups * s.d_state + H),
                              dtype),
        "conv_w": dense_init(ks[1], s.conv_width,
                             (s.conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.asarray(
            np.log(np.random.RandomState(1).uniform(1, 16, size=H)), dtype),
        "dt_bias": jnp.asarray(dt_bias, dtype),
        "D": jnp.ones((H,), dtype),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, (d_inner, cfg.d_model), dtype),
    }


def _split_proj(p, cfg: ModelConfig, x):
    s, d_inner, H = _dims(cfg)
    gn = s.n_groups * s.d_state
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: d_inner + d_inner + 2 * gn]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv1d; xbc (B,S,C), w (W,C).  state (B,W-1,C) for
    decode.  Returns (out, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (W - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state
    full = jnp.concatenate([pad, xbc], axis=1)                  # (B, S+W-1, C)
    out = sum(full[:, k: k + xbc.shape[1]] * w[k] for k in range(W)) + b
    return jax.nn.silu(out), full[:, -(W - 1):]


def _segsum(a):
    """a (..., Q) -> (..., Q, Q) lower-tri cumulative sums a_i+..+a_{j+1}."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, B_, C_, chunk: int):
    """SSD scan. x (B,S,H,P), a (B,S,H) = dt*A (<0), B_/C_ (B,S,H,N).

    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = chunk
    nc = S // Q
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    r = lambda t: t.reshape(Bb, nc, Q, *t.shape[2:])
    x, a, B_, C_ = r(x), r(a), r(B_), r(C_)
    a = a.astype(jnp.float32)

    a_cum = jnp.cumsum(a, axis=2)                               # (B,nc,Q,H)
    # 1) diagonal (within-chunk) term — quadratic in Q
    L = jnp.exp(_segsum(jnp.moveaxis(a, -1, -2)))               # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        C_, B_, L.astype(C_.dtype), x)
    # 2) per-chunk input states
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)        # (B,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        B_, decay_states.astype(B_.dtype), x)   # (B,nc,H,P,N)
    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                   # (B,nc,H)

    def step(h, inp):
        st, dec = inp                                           # (B,H,P,N),(B,H)
        h = h * dec[..., None, None].astype(h.dtype) + st
        return h, h

    h0 = jnp.zeros((Bb, H, P, N), x.dtype)
    h_last, h_all = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.concatenate([h0[None], h_all[:-1]], axis=0)    # states entering
    h_prev = jnp.moveaxis(h_prev, 0, 1)                         # (B,nc,H,P,N)
    # 4) off-diagonal (cross-chunk) output
    out_decay = jnp.exp(a_cum)                                  # (B,nc,Q,H)
    y_off = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                       C_, out_decay.astype(C_.dtype), h_prev)
    y = (y_diag + y_off).reshape(Bb, S, H, P)
    return y, h_last


def apply_ssd(p, cfg: ModelConfig, x: jax.Array, positions=None) -> jax.Array:
    s, d_inner, H = _dims(cfg)
    B, S, _ = x.shape
    gn = s.n_groups * s.d_state
    z, xbc, dt = _split_proj(p, cfg, x)
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner].reshape(B, S, H, s.head_dim)
    B_ = xbc[..., d_inner: d_inner + gn].reshape(B, S, s.n_groups, s.d_state)
    C_ = xbc[..., d_inner + gn:].reshape(B, S, s.n_groups, s.d_state)
    heads_per_group = H // s.n_groups
    B_ = jnp.repeat(B_, heads_per_group, axis=2)
    C_ = jnp.repeat(C_, heads_per_group, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xs = sharding.hint(xs, "batch", None, "heads", None)
    y, _ = ssd_chunked(xs * dt[..., None].astype(xs.dtype),
                       dt * A, B_, C_, s.chunk)
    y = y + p["D"].astype(y.dtype)[:, None] * xs
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return sharding.hint(y @ p["out_proj"], "batch", None, None)


# ------------------------------------------------------------------- decode

def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, d_inner, H = _dims(cfg)
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def decode_ssd(p, cfg: ModelConfig, x: jax.Array, pos, cache: dict
               ) -> tuple[jax.Array, dict]:
    """x (B,1,D) — O(1) state update."""
    s, d_inner, H = _dims(cfg)
    B = x.shape[0]
    gn = s.n_groups * s.d_state
    z, xbc, dt = _split_proj(p, cfg, x)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=cache["conv"])
    xs = xbc[..., :d_inner].reshape(B, H, s.head_dim)
    B_ = xbc[..., d_inner: d_inner + gn].reshape(B, s.n_groups, s.d_state)
    C_ = xbc[..., d_inner + gn:].reshape(B, s.n_groups, s.d_state)
    hpg = H // s.n_groups
    B_ = jnp.repeat(B_, hpg, axis=1)                            # (B,H,N)
    C_ = jnp.repeat(C_, hpg, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)[..., None, None].astype(cache["h"].dtype)
    update = jnp.einsum("bhp,bhn->bhpn", xs * dt[..., None].astype(xs.dtype), B_)
    h = cache["h"] * decay + update
    y = jnp.einsum("bhpn,bhn->bhp", h, C_)
    y = y + p["D"].astype(y.dtype)[:, None] * xs
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = sharding.hint(y @ p["out_proj"], "batch", None, None)
    return out, {"h": h, "conv": conv_state}
