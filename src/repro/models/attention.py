"""Attention mixers: GQA (RoPE, qk-norm, sliding window) and MLA (DeepSeek-V3).

Two entry points per mixer:
  * ``apply_*``        — full-sequence training/prefill forward
  * ``decode_*``       — single-token decode against a KV cache
Caches for windowed attention are ring buffers of size ``window`` (the
long_500k sub-quadratic carve-out: memory O(window), compute O(window)/token).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding
from .config import MLAConfig, ModelConfig
from .layers import dense_init, init_rmsnorm, rmsnorm

NEG_INF = -1e30


# --------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return rot, jnp.asarray(inv)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               fraction: float = 1.0) -> jax.Array:
    """x: (..., S, n_heads, head_dim) or (..., S, head_dim); positions (..., S)."""
    hd = x.shape[-1]
    rot, inv = rope_freqs(hd, theta, fraction)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, rot/2)
    # angles/trig in f32 (positions up to 512k), rotation in the input dtype:
    # upcasting x here makes XLA rewrite convert(x@W) into f32 dots and push
    # an f32 convert onto the sharded residual carry, which then all-gathers
    # at 2x bytes throughout the backward pass (EXPERIMENTS.md §Perf B)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    if x.ndim == cos.ndim + 1:                                 # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    xr = x[..., :rot]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.reshape(xr.shape)
    return jnp.concatenate([out, x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------- GQA

def init_attention(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], D, (D, H * hd), dtype),
        "wk": dense_init(ks[1], D, (D, KV * hd), dtype),
        "wv": dense_init(ks[2], D, (D, KV * hd), dtype),
        "wo": dense_init(ks[3], H * hd, (H * hd, D), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    B, S, D = x.shape
    hd, H, KV = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = sharding.hint(q, "batch", None, "heads", None)
    k = sharding.hint(k, "batch", None, "heads", None)
    v = sharding.hint(v, "batch", None, "heads", None)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q (B,S,H,hd), k/v (B,T,KV,hd), boolean mask (S,T) or (B,S,T).

    k/v are broadcast to H heads so every tensor keeps a plain H axis —
    splitting the sharded H axis into (KV, G) makes the SPMD partitioner
    fall back to full rematerialization (replicating S x T logits).  XLA
    fuses the broadcast into the dots, so no extra HBM traffic materializes.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H and S == 1:
        # decode: grouped-query einsum — materializing the G-fold broadcast
        # of the KV cache would multiply decode HBM traffic by G (no sharded
        # axis is reshaped here, so the train-time partitioner hazard that
        # motivates the broadcast below does not apply at S == 1)
        G = H // KV
        qg = q.reshape(B, 1, KV, G, hd)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k)
        logits = sharding.hint_any(
            logits, ("batch", None, None, None, "seq"))
        logits = logits.astype(jnp.float32) / np.sqrt(hd)
        if mask.ndim == 2:
            mask = mask[None]
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        probs = sharding.hint_any(
            probs, ("batch", None, None, None, "seq"))
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        return out.reshape(B, 1, H * v.shape[-1])
    if KV != H:
        G = H // KV
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # dot in the activation dtype, softmax in f32: an f32-output qk dot
    # makes its backward upcast k (and transitively the sharded residual
    # carry) to f32, doubling every activation all-gather in the backward
    # pass (EXPERIMENTS.md §Perf B)
    logits = jnp.einsum("bshd,bthd->bhst", q, k)
    # training/prefill: prefer head-sharding; archs whose head count does not
    # divide the model axis (yi 56H, qwen3-14b 40H, musicgen 24H) shard the
    # query sequence instead.  decode (S==1): keep the CACHE-resident layout
    # (kv/T sharded over "model") — otherwise the partitioner reshards the
    # whole KV cache to head-sharded every token (~86 GB/device of all-gather
    # on qwen3-14b decode_32k; see EXPERIMENTS.md §Perf).
    if S == 1:
        cands = (("batch", None, None, "seq"),
                 ("batch", "heads", None, None))
        probs_cands = cands
    elif sharding.is_forward_only():
        # prefill: head-sharding preferred, q-seq fallback for head counts
        # that don't divide the model axis (musicgen 24H, yi 56H) — halves
        # the replicated S x T score footprint
        cands = (("batch", "heads", None, None),
                 ("batch", None, "seq", None))
        probs_cands = cands
    else:
        # training: constrain only when heads divide; a forced q-seq
        # sharding fights the partitioner's partial head sharding in the
        # backward dots and triggers f32 full-remat gathers (yi-34b:
        # +3.5 TB/device/step — EXPERIMENTS.md §Perf B)
        cands = (("batch", "heads", None, None),)
        probs_cands = cands
    logits = sharding.hint_any(logits, *cands)
    logits = logits.astype(jnp.float32) / np.sqrt(hd)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    probs = sharding.hint_any(probs, *probs_cands)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out.reshape(B, S, H * v.shape[-1])  # v dim may differ (MLA)


def causal_mask(S: int, window: int | None = None) -> jax.Array:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    return m


def apply_attention(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                    window: int | None = None) -> jax.Array:
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.attention_impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention
        B, S, H, hd = q.shape
        out = flash_attention(q, k, v, causal=True, window=window,
                              force_pallas=True,
                              interpret=jax.default_backend() != "tpu")
        out = out.reshape(B, S, H * hd)
    else:
        mask = causal_mask(x.shape[1], window)
        out = _sdpa(q, k, v, mask, cfg)
    return sharding.hint(out @ p["wo"], "batch", None, None)


# ------------------------------------------------------------- GQA decoding

def decode_positions(pos: jax.Array, batch: int) -> jax.Array:
    """(B,) per-slot positions from a scalar or already-(B,) ``pos``."""
    return jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (batch,))


def _cache_slots(pos_vec: jax.Array, size: int, window: int | None
                 ) -> jax.Array:
    """(B,) ring/dense cache slot per sequence.  The dense slot is clamped
    to the last entry past capacity — the same semantics the old scalar
    ``dynamic_update_slice`` start-index clamping gave."""
    return pos_vec % size if window else jnp.minimum(pos_vec, size - 1)


def _update_slot(cache: jax.Array, update: jax.Array, slot: jax.Array
                 ) -> jax.Array:
    """Write ``update[b]`` at row ``slot[b]`` of every sequence's cache:
    cache (B, size, ...), update (B, 1, ...), slot (B,)."""
    def one(c, u, s):
        return jax.lax.dynamic_update_slice(c, u, (s,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, update, slot)


def _slot_mask(spos: jax.Array, pos_vec: jax.Array, window: int | None
               ) -> jax.Array:
    """(B, 1, size) visibility mask from per-sequence slot positions."""
    mask = (spos >= 0) & (spos <= pos_vec[:, None])
    if window:
        mask &= spos > pos_vec[:, None] - window
    return mask[:, None, :]


def init_attn_cache(cfg: ModelConfig, batch: int, length: int,
                    window: int | None, dtype) -> dict:
    """length = full context for dense cache; ring of size window if windowed."""
    size = min(length, window) if window else length
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, KV, hd), dtype),
        "v": jnp.zeros((batch, size, KV, hd), dtype),
        # absolute position held by each sequence's slots (-1 = empty);
        # per-sequence so continuous-batching slots decode at their own pos
        "slot_pos": jnp.full((batch, size), -1, jnp.int32),
    }


def decode_attention(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                     cache: dict, window: int | None = None
                     ) -> tuple[jax.Array, dict]:
    """x (B, 1, D), pos scalar int32 or (B,) per-slot positions —
    returns (out (B,1,D), new cache)."""
    B = x.shape[0]
    pos_vec = decode_positions(pos, B)
    q, k, v = _qkv(p, cfg, x, pos_vec[:, None])     # k rope'd at absolute pos
    size = cache["k"].shape[1]
    slot = _cache_slots(pos_vec, size, window)
    ck = _update_slot(cache["k"], k, slot)
    cv = _update_slot(cache["v"], v, slot)
    spos = _update_slot(cache["slot_pos"], pos_vec[:, None], slot)
    out = _sdpa(q, ck, cv, _slot_mask(spos, pos_vec, window), cfg)
    out = sharding.hint(out @ p["wo"], "batch", None, None)
    return out, {"k": ck, "v": cv, "slot_pos": spos}


# ---------------------------------------------------------------------- MLA

def init_mla(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], D, (D, m.q_lora_rank), dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], m.q_lora_rank, (m.q_lora_rank, H * qk), dtype),
        "w_dkv": dense_init(ks[2], D,
                            (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], m.kv_lora_rank,
                           (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[4], m.kv_lora_rank,
                           (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], H * m.v_head_dim,
                         (H * m.v_head_dim, D), dtype),
    }


def _mla_qkv(p, cfg: ModelConfig, x, positions):
    """Returns q (B,S,H,qk), latent c (B,S,rank), k_rope (B,S,rope)."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    dkv = x @ p["w_dkv"]
    c = rmsnorm(dkv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    q = sharding.hint(q, "batch", None, "heads", None)
    return q, c, k_rope


def _mla_expand_kv(p, cfg: ModelConfig, c, k_rope):
    """Up-project cached latents to per-head K, V."""
    m: MLAConfig = cfg.mla
    B, T, _ = c.shape
    H = cfg.num_heads
    k_nope = (c @ p["w_uk"]).reshape(B, T, H, m.qk_nope_head_dim)
    v = (c @ p["w_uv"]).reshape(B, T, H, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, T, H, m.qk_rope_head_dim))], axis=-1)
    k = sharding.hint(k, "batch", None, "heads", None)
    v = sharding.hint(v, "batch", None, "heads", None)
    return k, v


def apply_mla(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
              window: int | None = None) -> jax.Array:
    q, c, k_rope = _mla_qkv(p, cfg, x, positions)
    k, v = _mla_expand_kv(p, cfg, c, k_rope)
    mask = causal_mask(x.shape[1], window)
    out = _sdpa(q, k, v, mask, cfg)
    return sharding.hint(out @ p["wo"], "batch", None, None)


def init_mla_cache(cfg: ModelConfig, batch: int, length: int,
                   window: int | None, dtype) -> dict:
    m: MLAConfig = cfg.mla
    size = min(length, window) if window else length
    return {
        "c": jnp.zeros((batch, size, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, size, m.qk_rope_head_dim), dtype),
        "slot_pos": jnp.full((batch, size), -1, jnp.int32),
    }


def decode_mla(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
               cache: dict, window: int | None = None
               ) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    pos_vec = decode_positions(pos, B)
    q, c, k_rope = _mla_qkv(p, cfg, x, pos_vec[:, None])
    size = cache["c"].shape[1]
    slot = _cache_slots(pos_vec, size, window)
    cc = _update_slot(cache["c"], c, slot)
    cr = _update_slot(cache["k_rope"], k_rope, slot)
    spos = _update_slot(cache["slot_pos"], pos_vec[:, None], slot)
    k, v = _mla_expand_kv(p, cfg, cc, cr)
    out = _sdpa(q, k, v, _slot_mask(spos, pos_vec, window), cfg)
    out = sharding.hint(out @ p["wo"], "batch", None, None)
    return out, {"c": cc, "k_rope": cr, "slot_pos": spos}
