"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

The Griffin recurrent block: two parallel linear branches; one goes through a
causal conv1d + the Real-Gated LRU, the other is a GeLU gate; merged by
elementwise product and projected out.

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over the sequence (O(log S) depth);
decode is an O(1) state update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import sharding
from .config import ModelConfig, RGLRUConfig
from .layers import dense_init


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.d_rnn or cfg.d_model


def init_rglru(key, cfg: ModelConfig, dtype):
    r: RGLRUConfig = cfg.rglru
    d, w = cfg.d_model, _width(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in_rnn": dense_init(ks[0], d, (d, w), dtype),
        "w_in_gate": dense_init(ks[1], d, (d, w), dtype),
        "conv_w": dense_init(ks[2], r.conv_width, (r.conv_width, w), dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], w, (w, w), dtype),
        "b_a": jnp.zeros((w,), dtype),
        "w_x": dense_init(ks[4], w, (w, w), dtype),
        "b_x": jnp.zeros((w,), dtype),
        # Lambda init so a ~ U[0.9, 0.999]^(1/c) at r=1 (paper's init range)
        "lam": jnp.full((w,), 0.65, dtype),
        "w_out": dense_init(ks[5], w, (w, d), dtype),
    }


def _conv(x, w, b, state=None):
    W = w.shape[0]
    pad = (jnp.zeros(x.shape[:1] + (W - 1,) + x.shape[2:], x.dtype)
           if state is None else state)
    full = jnp.concatenate([pad, x], axis=1)
    out = sum(full[:, k: k + x.shape[1]] * w[k] for k in range(W)) + b
    return out, full[:, -(W - 1):]


def _gates(p, cfg: ModelConfig, u):
    """u: conv'd rnn-branch activations (B,S,W). Returns (log_a, beta*gated_in)."""
    c = cfg.rglru.c
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_x"] + p["b_x"])
    log_a = -c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    return log_a, (beta.astype(u.dtype) * (i * u))


def apply_rglru(p, cfg: ModelConfig, x: jax.Array, positions=None) -> jax.Array:
    B, S, D = x.shape
    u = x @ p["w_in_rnn"]
    u = sharding.hint(u, "batch", None, "ffn")
    gate = jax.nn.gelu(x @ p["w_in_gate"])
    u, _ = _conv(u, p["conv_w"], p["conv_b"])
    log_a, b = _gates(p, cfg, u)
    a = jnp.exp(log_a).astype(u.dtype)                        # (B,S,W)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate) @ p["w_out"]
    return sharding.hint(y, "batch", None, None)


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    r, w = cfg.rglru, _width(cfg)
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, r.conv_width - 1, w), dtype),
    }


def decode_rglru(p, cfg: ModelConfig, x: jax.Array, pos, cache: dict
                 ) -> tuple[jax.Array, dict]:
    u = x @ p["w_in_rnn"]
    gate = jax.nn.gelu(x @ p["w_in_gate"])
    u, conv_state = _conv(u, p["conv_w"], p["conv_b"], state=cache["conv"])
    log_a, b = _gates(p, cfg, u)
    a = jnp.exp(log_a).astype(u.dtype)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (h[:, None] * gate) @ p["w_out"]
    return sharding.hint(y, "batch", None, None), {"h": h, "conv": conv_state}
