"""Common layers: norms, MLPs, embeddings, MoE — pure JAX (no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .. import sharding
from .config import ModelConfig, MoEConfig


def dtype_of(name: str):
    return jnp.dtype(name)


# ------------------------------------------------------------------- inits

def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# -------------------------------------------------------------------- norms

@jax.custom_vjp
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with a hand-written VJP.

    Forward: f32 accumulation of x.x as a dot (preferred_element_type) — an
    explicit x.astype(f32) gets hoisted by XLA out of the backward layer scan
    into a full f32 copy of the saved residual stack.
    Backward: custom VJP keeping every (B,S,D) cotangent in the input dtype —
    the autodiff rule of the f32-output variance dot produces f32 cotangents
    for x, which the partitioner then all-gathers at 2x bytes throughout the
    backward pass (measured on yi-34b; EXPERIMENTS.md §Perf B)."""
    out, _ = _rmsnorm_fwd(x, scale, eps)
    return out


def _rms_inv(x, eps):
    sq = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = sq[..., None] / x.shape[-1]
    return jax.lax.rsqrt(var + eps)


def _rmsnorm_fwd(x, scale, eps):
    dt = x.dtype
    inv = _rms_inv(x, eps).astype(dt)
    out = (x * inv) * (1.0 + scale.astype(dt))
    return out, (x, inv, scale, eps)


def _rmsnorm_bwd(res, g):
    x, inv, scale, eps = res
    dt = x.dtype
    sp = (1.0 + scale.astype(dt))
    gs = g * sp                                           # (..., D)
    # row scalar sum(g*s'*x) in f32 via a dot — no f32 (B,S,D) materializes
    dot = jnp.einsum("...d,...d->...", gs, x,
                     preferred_element_type=jnp.float32)
    coef = (dot[..., None] / x.shape[-1]).astype(dt) * (inv * inv * inv)
    gx = gs * inv - x * coef
    gscale = jnp.sum((g * x * inv).astype(jnp.float32),
                     axis=tuple(range(g.ndim - 1)))
    return gx, gscale.astype(scale.dtype), None


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def init_rmsnorm(dim: int, dtype) -> jax.Array:
    # stored as deviation from 1 (gemma-style) for clean wd behaviour
    return jnp.zeros((dim,), dtype)


# --------------------------------------------------------------------- MLPs

def init_mlp(key, d_model: int, d_ff: int, dtype, act: str = "silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k1, d_model, (d_model, d_ff), dtype),
        "w_down": dense_init(k2, d_ff, (d_ff, d_model), dtype),
    }
    if act == "silu":  # gated (swiglu)
        p["w_gate"] = dense_init(k3, d_model, (d_model, d_ff), dtype)
    return p


def apply_mlp(p, x: jax.Array, act: str = "silu") -> jax.Array:
    up = x @ p["w_up"]
    up = sharding.hint(up, "batch", None, "ffn")
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(act)
    out = h @ p["w_down"]
    return sharding.hint(out, "batch", None, None)


# ---------------------------------------------------------------------- MoE

def init_moe(key, d_model: int, cfg: MoEConfig, dtype, act: str = "silu"):
    d_e = cfg.d_expert or d_model * 4
    keys = jax.random.split(key, 8)
    p = {
        "router": dense_init(keys[0], d_model, (d_model, cfg.num_experts),
                             jnp.float32),  # router in fp32 for stable softmax
        "moe_up": dense_init(keys[1], d_model,
                             (cfg.num_experts, d_model, d_e), dtype),
        "moe_down": dense_init(keys[2], d_e,
                               (cfg.num_experts, d_e, d_model), dtype),
    }
    if act == "silu":
        p["moe_gate"] = dense_init(keys[3], d_model,
                                   (cfg.num_experts, d_model, d_e), dtype)
    if cfg.shared_expert:
        d_s = cfg.d_shared or d_e
        p["shared"] = init_mlp(keys[4], d_model, d_s, dtype, act)
    if cfg.dense_d_ff:
        p["dense"] = init_mlp(keys[5], d_model, cfg.dense_d_ff, dtype, act)
    return p


def _dispatch_group(xt, topi, topw, E: int, C: int, dtype):
    """Per-group dispatch: xt (T,D), topi/topw (T,K) -> buffer (E,C,D),
    dest (T,K), keep (T,K).  Pure local ops — vmapped over data-sharded
    groups so dispatch never crosses the data shards.

    Implemented as K unique scatter-SETs of (T, D): no (T*K, D) intermediate
    (whose repeat-transpose reduce promotes to f32), no accumulation (a bf16
    scatter-ADD promotes to f32 and XLA hoists the convert onto the saved
    residual stack of the backward layer scan)."""
    T, D = xt.shape
    K = topi.shape[1]
    flat_e = topi.reshape(-1)                                  # (T*K,)
    # rank of each (token, k) within its expert via stable sort
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(T * K) - starts[sorted_e]
    slot = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = (slot < C).reshape(T, K)                            # overflow drops
    dest = (flat_e * C + slot).reshape(T, K)
    buf = jnp.zeros((E * C + 1, D), dtype)
    for k in range(K):
        sdest = jnp.where(keep[:, k], dest[:, k], E * C + 1)   # OOB = dropped
        buf = buf.at[sdest].set(xt, mode="drop", unique_indices=True)
    return buf[: E * C].reshape(E, C, D), dest, keep


def _combine_group(out_e, dest, keep, topw, T: int, D: int, dtype):
    """K unique gathers of (T, D), weighted-summed.  Kept dests are unique;
    drops gather-fill 0 via out-of-bounds indices."""
    K = topw.shape[1]
    E_C = out_e.shape[0] * out_e.shape[1]
    flat_out = out_e.reshape(E_C, D)
    out = jnp.zeros((T, D), dtype)
    for k in range(K):
        sdest = jnp.where(keep[:, k], dest[:, k], E_C + 1)
        g = flat_out.at[sdest].get(mode="fill", fill_value=0,
                                   unique_indices=True)        # (T, D)
        out = out + g * (topw[:, k:k + 1] * keep[:, k:k + 1]).astype(dtype)
    return out


def apply_moe(p, x: jax.Array, cfg: MoEConfig, act: str = "silu"
              ) -> tuple[jax.Array, jax.Array]:
    """Group-wise capacity-based top-k MoE (GShard-style dispatch).

    x: (B, S, D).  Returns (out, aux_loss).

    Tokens are grouped by batch row (G = B); dispatch scatter/gather is
    vmapped over groups, so with the batch data-sharded every scatter is a
    *local* op — the only cross-shard traffic is the (G, E, C, D) buffer
    resharding from (G: data) to (E: model) at the expert einsum, which XLA
    lowers to an all-to-all: exactly the traffic a hand-written EP MoE does.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    # per-group capacity (statistical balance within each row of S tokens)
    C = max(1, int(np.ceil(S * K / E * cfg.capacity_factor)))

    xg = x  # (G=B, S, D)
    # router matmul fully in the activation dtype — any f32 operand/cotangent
    # on xg makes XLA hoist an f32 copy of the whole saved residual stack out
    # of the backward layer scan; softmax still runs in f32 on the (small)
    # logits tensor
    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                       # (B, S, K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)        # renormalize
    # combine weights participate in (T*K, D)-sized products — keep them in
    # the activation dtype so their cotangents don't promote those to f32
    topw = topw.astype(x.dtype)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e (global)
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    aux = cfg.router_aux_weight * E * jnp.sum(
        me * counts / (B * S * K))

    buf, dest, keep = jax.vmap(
        lambda xt, ti, tw: _dispatch_group(xt, ti, tw, E, C, x.dtype)
    )(xg, topi, topw)                                          # (B,E,C,D)...
    buf = sharding.hint(buf, "batch", "expert", None, None)

    up = jnp.einsum("gecd,edf->gecf", buf, p["moe_up"])
    if act == "silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["moe_gate"])) * up
    else:
        h = jax.nn.gelu(up)
    out_e = jnp.einsum("gecf,efd->gecd", h, p["moe_down"])     # (B,E,C,D)
    out_e = sharding.hint(out_e, "batch", "expert", None, None)

    out = jax.vmap(
        lambda oe, de, ke, tw: _combine_group(oe, de, ke, tw, S, D, x.dtype)
    )(out_e, dest, keep, topw)                                 # (B, S, D)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, act)
    if "dense" in p:
        out = out + apply_mlp(p["dense"], x, act)
    return out, aux


# --------------------------------------------------------------- embeddings

def init_embedding(key, cfg: ModelConfig, dtype):
    p = {}
    if cfg.input_mode == "tokens":
        p["tok"] = embed_init(key, (cfg.padded_vocab, cfg.d_model), dtype)
    else:  # stubbed frontend provides embeddings; learn an input projection
        p["in_proj"] = dense_init(key, cfg.d_model,
                                  (cfg.d_model, cfg.d_model), dtype)
    return p


def embed_inputs(p, cfg: ModelConfig, inputs: jax.Array) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = jnp.take(p["tok"], inputs, axis=0)
    else:
        x = inputs.astype(dtype_of(cfg.param_dtype)) @ p["in_proj"]
    return sharding.hint(x.astype(dtype_of(cfg.compute_dtype)),
                         "batch", None, None)


def init_lm_head(key, cfg: ModelConfig, dtype):
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        return {}
    out = cfg.padded_vocab * cfg.num_codebooks
    return {"w": dense_init(key, cfg.d_model, (cfg.d_model, out), dtype)}


def apply_lm_head(head_p, embed_p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x (..., D) -> logits (..., num_codebooks*vocab) [codebooks folded]."""
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = x @ embed_p["tok"].T.astype(x.dtype)
    else:
        logits = x @ head_p["w"]
    return sharding.hint(logits, "batch", None, "vocab")
