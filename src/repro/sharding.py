"""Logical-axis sharding hints.

Model code annotates intermediates with *logical* axis names; the launcher
installs a mapping from logical names to mesh axes.  With no mapping installed
(CPU tests, single device) every hint is a no-op, so model code stays pure.

Logical axes:
  batch   — data-parallel batch dim          -> ("pod","data") or ("data",)
  seq     — sequence (kept local by default) -> None
  embed   — d_model                           -> None (activations) / fsdp for params
  heads   — attention heads / kv heads        -> "model"
  ffn     — mlp hidden                        -> "model"
  vocab   — vocabulary                        -> "model"
  expert  — MoE expert axis                   -> "model"
  fsdp    — parameter FSDP shard axis         -> "data"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[dict[str, Any]]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def forward_only():
    """Mark the enclosed trace as having no backward pass (prefill/serve):
    attention score tensors may then take the q-seq sharding fallback, which
    under autodiff fights the partitioner's partial head sharding in the
    transposed dots (EXPERIMENTS.md §Perf B)."""
    old = getattr(_state, "forward_only", False)
    _state.forward_only = True
    try:
        yield
    finally:
        _state.forward_only = old


def is_forward_only() -> bool:
    return getattr(_state, "forward_only", False)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, Any]):
    """Install a mesh + logical->mesh-axis rules for sharding hints."""
    old_rules, old_mesh = _rules(), _mesh()
    _state.rules, _state.mesh = dict(rules), mesh
    try:
        with mesh:
            yield
    finally:
        _state.rules, _state.mesh = old_rules, old_mesh


def logical_to_spec(axes: tuple[Optional[str], ...],
                    rules: Optional[dict[str, Any]] = None) -> P:
    rules = rules if rules is not None else (_rules() or {})
    return P(*[rules.get(a) if a else None for a in axes])


def hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh.

    Axis assignments whose mesh size does not divide the dim are dropped
    (e.g. kv_heads=8 under model=16 stays unsharded rather than erroring)."""
    mesh, rules = _mesh(), _rules()
    if mesh is None or rules is None:
        return x
    parts = []
    for dim, logical in zip(x.shape, tuple(axes) + (None,) * (x.ndim - len(axes))):
        ax = rules.get(logical) if logical else None
        if ax is None:
            parts.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        parts.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def hint_any(x: jax.Array, *specs: tuple) -> jax.Array:
    """Apply the first spec (tuple of logical names) whose every non-None
    axis divides the corresponding dim.  Used where the preferred sharding
    (e.g. attention heads) may not divide for some architectures and an
    alternative axis (e.g. query sequence) should be sharded instead."""
    mesh, rules = _mesh(), _rules()
    if mesh is None or rules is None:
        return x
    for spec in specs:
        ok = True
        for dim, logical in zip(x.shape, spec):
            ax = rules.get(logical) if logical else None
            if ax is None:
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            if dim % size != 0:
                ok = False
                break
        if ok:
            return hint(x, *spec)
    return x


# Default rules for the production meshes (launch/mesh.py)
SINGLE_POD_RULES = {
    "batch": "data", "heads": "model", "ffn": "model", "vocab": "model",
    "expert": "model", "fsdp": "data", "tp": "model", "seq": "model", "act_embed": "model",
}
MULTI_POD_RULES = {
    "batch": ("pod", "data"), "heads": "model", "ffn": "model",
    "vocab": "model", "expert": "model", "fsdp": "data", "tp": "model", "seq": "model", "act_embed": "model",
}
GOSSIP_RULES = {  # worker axis never appears in model shardings
    "batch": "data", "heads": "model", "ffn": "model", "vocab": "model",
    "expert": "model", "fsdp": "data", "tp": "model", "seq": "model", "act_embed": "model",
}
REPLAY_RULES = {  # 1-D replay mesh (launch/mesh.make_replay_mesh): only
    # the flat gossip banks' worker axis is split; model-logical axes
    # have no mesh axis to land on and stay replicated
    "worker": "worker",
}
