"""Chameleon-34B: early-fusion VLM, VQ image tokens in-vocab, qk-norm
[arXiv:2405.09818].  Backbone only: the VQ image tokenizer frontend is
stubbed — input_specs() provides mixed text+image token ids directly."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="vlm", d_model=8192, vocab_size=65536,
        blocks=uniform_blocks(Block("attn", "dense"), 48),
        num_heads=64, num_kv_heads=8, head_dim=128, qk_norm=True,
        rope_theta=10_000.0, d_ff=22016, mlp_act="silu", carry_shard="seq",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-reduced", family="vlm", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=64, qk_norm=True,
        d_ff=512, mlp_act="silu",
    )
