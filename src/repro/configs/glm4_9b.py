"""GLM-4-9B: dense decoder, GQA kv=2, partial RoPE [hf:THUDM/glm-4-9b]."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense", d_model=4096, vocab_size=151552,
        blocks=uniform_blocks(Block("attn", "dense"), 40),
        num_heads=32, num_kv_heads=2, head_dim=128,
        rope_theta=10_000.0, rope_fraction=0.5, d_ff=13696, mlp_act="silu", carry_shard="seq",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-reduced", family="dense", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=64, rope_fraction=0.5,
        d_ff=512, mlp_act="silu",
    )
