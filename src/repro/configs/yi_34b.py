"""Yi-34B: llama-arch dense decoder, GQA kv=8 [arXiv:2403.04652]."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense", d_model=7168, vocab_size=64000,
        blocks=uniform_blocks(Block("attn", "dense"), 60),
        num_heads=56, num_kv_heads=8, head_dim=128,
        rope_theta=5_000_000.0, d_ff=20480, mlp_act="silu", carry_shard="seq",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-reduced", family="dense", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, mlp_act="silu",
    )
