"""Architecture registry: one module per assigned architecture.

Every module exposes ``config()`` (the exact assigned configuration) and
``reduced()`` (a smoke-test variant of the same family: <=2 layers,
d_model<=512, <=4 experts) plus cites its source in the module docstring.
"""
from __future__ import annotations

import importlib

ARCHITECTURES = (
    "musicgen-medium",
    "arctic-480b",
    "mamba2-780m",
    "chameleon-34b",
    "deepseek-v3-671b",
    "recurrentgemma-9b",
    "qwen3-14b",
    "glm4-9b",
    "yi-34b",
    "qwen3-0.6b",
)

EXTRA = ("nano-lm", "paper-resnet18")  # paper repro + example-scale configs


def _module(name: str):
    return importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get_config(name: str, reduced: bool = False):
    mod = _module(name)
    return mod.reduced() if reduced else mod.config()


def list_architectures() -> tuple[str, ...]:
    return ARCHITECTURES
