"""Mamba2-780m: attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import Block, ModelConfig, SSMConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", d_model=1536, vocab_size=50280,
        blocks=uniform_blocks(Block("ssd", "none"), 48),
        num_heads=1, num_kv_heads=1,  # unused (attention-free)
        d_ff=0,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk=128),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-reduced", family="ssm", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("ssd", "none"), 2),
        num_heads=1, num_kv_heads=1, d_ff=0,
        ssm=SSMConfig(d_state=32, head_dim=32, expand=2, n_groups=1,
                      conv_width=4, chunk=32),
        tie_embeddings=True,
    )
