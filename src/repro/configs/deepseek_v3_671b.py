"""DeepSeek-V3 (671B): MLA attention, 1 shared + 256 routed experts top-8,
multi-token prediction [arXiv:2412.19437].  First 3 layers dense (d_ff 18432
per the model card), remaining 58 MoE with 2048-dim experts."""
from repro.models.config import (Block, MLAConfig, MoEConfig, ModelConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", d_model=7168,
        vocab_size=129280,
        blocks=(((Block("mla", "dense"),), 3),
                ((Block("mla", "moe"),), 58)),
        num_heads=128, num_kv_heads=128,  # MLA: effectively MHA via latents
        rope_theta=10_000.0, d_ff=18432, mlp_act="silu",
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      shared_expert=True, d_shared=2048,
                      capacity_factor=1.25),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        mtp=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-reduced", family="moe", d_model=256,
        vocab_size=512,
        blocks=(((Block("mla", "dense"),), 1),
                ((Block("mla", "moe"),), 1)),
        num_heads=4, num_kv_heads=4,
        d_ff=512, mlp_act="silu",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=128,
                      shared_expert=True, d_shared=128),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        mtp=True,
    )
