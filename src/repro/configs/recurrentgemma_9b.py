"""RecurrentGemma-9B: RG-LRU + local attention in a 2:1 pattern
[arXiv:2402.19427].  38 layers = (rglru, rglru, local-attn) x 12 +
(rglru, rglru); local attention window 2048, MQA (kv=1)."""
from repro.models.config import Block, ModelConfig, RGLRUConfig


def config() -> ModelConfig:
    rec = Block("rglru", "dense")
    loc = Block("attn", "dense", window=2048)
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", d_model=4096,
        vocab_size=256000,
        blocks=(((rec, rec, loc), 12), ((rec, rec), 1)),
        num_heads=16, num_kv_heads=1, head_dim=256,
        rope_theta=10_000.0, d_ff=12288, mlp_act="silu",
        rglru=RGLRUConfig(d_rnn=4096, conv_width=4, c=8.0),
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    rec = Block("rglru", "dense")
    loc = Block("attn", "dense", window=32)
    return ModelConfig(
        name="recurrentgemma-9b-reduced", family="hybrid", d_model=256,
        vocab_size=512,
        blocks=(((rec, rec, loc), 1),),
        num_heads=4, num_kv_heads=1, head_dim=64,
        d_ff=512, mlp_act="silu",
        rglru=RGLRUConfig(d_rnn=256, conv_width=4, c=8.0),
        tie_embeddings=True,
    )
