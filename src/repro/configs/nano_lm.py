"""nano-lm: ~100M-parameter dense LM for CPU-runnable end-to-end examples."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="nano-lm", family="dense", d_model=768, vocab_size=32000,
        blocks=uniform_blocks(Block("attn", "dense"), 12),
        num_heads=12, num_kv_heads=4, head_dim=64,
        d_ff=3072, mlp_act="silu", tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nano-lm-reduced", family="dense", d_model=128, vocab_size=256,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, mlp_act="silu", tie_embeddings=True,
    )


def train_bench() -> ModelConfig:
    """Micro variant for ``benchmarks/run.py --only train``: same block
    structure as nano-lm but small enough (~45k params) that an n=64,
    36-world batched replay of the full per-worker state fits CPU memory."""
    return ModelConfig(
        name="nano-lm-bench", family="dense", d_model=64, vocab_size=128,
        blocks=uniform_blocks(Block("attn", "dense"), 1),
        num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, mlp_act="silu", tie_embeddings=True,
    )
