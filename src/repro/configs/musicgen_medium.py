"""MusicGen-medium: decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284].  Backbone only: the EnCodec/conditioning frontend is
stubbed — input_specs() provides precomputed frame embeddings (B,S,D); the
LM head predicts all 4 codebooks in parallel (delay pattern handled by the
data pipeline)."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio", d_model=1536, vocab_size=2048,
        blocks=uniform_blocks(Block("attn", "dense"), 48),
        num_heads=24, num_kv_heads=24, head_dim=64,
        rope_theta=10_000.0, d_ff=6144, mlp_act="gelu", carry_shard="seq",
        input_mode="embeddings", num_codebooks=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-reduced", family="audio", d_model=256,
        vocab_size=128,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, mlp_act="gelu", input_mode="embeddings", num_codebooks=4,
    )
