"""Snowflake Arctic (480B): dense-MoE hybrid — 128 experts top-2 with a
parallel dense residual MLP [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import Block, MoEConfig, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", family="moe", d_model=7168, vocab_size=32000,
        blocks=uniform_blocks(Block("attn", "moe+dense"), 35),
        num_heads=56, num_kv_heads=8, head_dim=128,
        rope_theta=10_000.0, d_ff=4864, mlp_act="silu",
        moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864,
                      dense_d_ff=4864, capacity_factor=1.25),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-reduced", family="moe", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("attn", "moe+dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, mlp_act="silu",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=512, dense_d_ff=512),
    )
