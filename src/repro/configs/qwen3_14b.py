"""Qwen3-14B: dense decoder, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.models.config import Block, ModelConfig, uniform_blocks


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense", d_model=5120, vocab_size=151936,
        blocks=uniform_blocks(Block("attn", "dense"), 40),
        num_heads=40, num_kv_heads=8, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0, d_ff=17408, mlp_act="silu", carry_shard="seq",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-reduced", family="dense", d_model=256, vocab_size=512,
        blocks=uniform_blocks(Block("attn", "dense"), 2),
        num_heads=4, num_kv_heads=2, head_dim=64, qk_norm=True,
        d_ff=512, mlp_act="silu",
    )
