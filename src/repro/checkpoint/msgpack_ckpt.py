"""Msgpack pytree checkpointing with atomic writes and step retention.

Arrays are gathered to host (fully addressable) before serialization — for
the simulated multi-device runs in this repo that is always possible; a real
multi-host deployment would swap in per-shard files keyed by shard index
(the layout below already namespaces leaves by tree path, so that extension
is additive).
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_DTYPE_KEY = "__np__"


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    return {_DTYPE_KEY: True, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict):
    arr = np.frombuffer(d["data"], dtype=np.dtype(d["dtype"]))
    return jnp.asarray(arr.reshape(d["shape"]))


def save_pytree(path: str, tree: PyTree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "leaves": [_pack_leaf(l) for l in leaves],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    stored = payload["leaves"]
    if len(stored) != len(leaves):
        raise ValueError(f"checkpoint has {len(stored)} leaves, "
                         f"expected {len(leaves)}")
    out = []
    for ref, d in zip(leaves, stored):
        arr = _unpack_leaf(d)
        if tuple(arr.shape) != tuple(jnp.shape(ref)):
            raise ValueError(f"shape mismatch: {arr.shape} vs "
                             f"{jnp.shape(ref)}")
        out.append(arr.astype(ref.dtype))
    return treedef.unflatten(out)


def save(ckpt_dir: str, step: int, state: PyTree, keep: int = 3) -> str:
    """Save ``state`` under ckpt_dir/step_<n>/state.msgpack, keep last N."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "state.msgpack")
    save_pytree(path, state)
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
    return path


def restore(ckpt_dir: str, like: PyTree, step: int | None = None) -> tuple[int, PyTree]:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    chosen = f"step_{step:08d}" if step is not None else steps[-1]
    n = int(chosen.split("_")[1])
    return n, load_pytree(os.path.join(ckpt_dir, chosen, "state.msgpack"),
                          like)
