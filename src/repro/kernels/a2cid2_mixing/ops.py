"""jit'd public wrapper: pytree-level fused gossip event.

On CPU (tests, simulator) the oracle path is used; on TPU the Pallas kernel.
``gossip_event_pytree`` ravels each leaf and applies the fused kernel —
leaves keep their shapes, so this drops into GossipMixer unchanged.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .kernel import mixing_p2p
from .ref import mixing_p2p_ref

PyTree = Any


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def gossip_event(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                 dt, *, eta: float, alpha: float, alpha_t: float,
                 force_pallas: bool = False, interpret: bool = False):
    flat = x.reshape(-1)
    if force_pallas or _use_pallas():
        ox, ot = mixing_p2p(flat, x_tilde.reshape(-1), x_partner.reshape(-1),
                            jnp.asarray(dt), eta=eta, alpha=alpha,
                            alpha_t=alpha_t, interpret=interpret)
        return ox.reshape(x.shape), ot.reshape(x.shape)
    return mixing_p2p_ref(x, x_tilde, x_partner, dt, eta=eta, alpha=alpha,
                          alpha_t=alpha_t)


def gossip_event_pytree(x: PyTree, x_tilde: PyTree, x_partner: PyTree, dt,
                        *, eta: float, alpha: float, alpha_t: float,
                        **kw) -> tuple[PyTree, PyTree]:
    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    flat_p = treedef.flatten_up_to(x_partner)
    outs = [gossip_event(a, b, c, dt, eta=eta, alpha=alpha, alpha_t=alpha_t,
                         **kw) for a, b, c in zip(flat_x, flat_t, flat_p)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))
