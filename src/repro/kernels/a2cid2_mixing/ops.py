"""jit'd public wrappers: fused gossip events at pytree / flat-buffer level.

On CPU (tests, simulator) the oracle path is used; on TPU the Pallas kernel.
``gossip_event_pytree`` ravels each leaf and applies the fused kernel —
leaves keep their shapes, so this drops into GossipMixer unchanged.  The
flat-buffer event engine uses ``gossip_event_stacked`` (worker-stacked
(W, D) buffers, p2p-then-mix order) and ``p2p_mix_event`` (per-worker (D,)
vectors inside shard_map).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .kernel import (channel_gossip_stacked, channel_gossip_worlds,
                     mixing_gossip_stacked, mixing_gossip_worlds,
                     mixing_p2p, p2p_mixing)
from .ref import (channel_gossip_stacked_ref, channel_gossip_worlds_ref,
                  channel_p2p_mixing_ref, mixing_gossip_stacked_ref,
                  mixing_gossip_worlds_ref, mixing_p2p_ref, p2p_mixing_ref)

PyTree = Any


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str = "auto") -> str:
    """'auto' -> 'pallas' on TPU else 'ref'; passthrough otherwise."""
    if backend == "auto":
        return "pallas" if _use_pallas() else "ref"
    if backend not in ("ref", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


def gossip_event(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                 dt, *, eta: float, alpha: float, alpha_t: float,
                 force_pallas: bool = False, interpret: bool = False):
    flat = x.reshape(-1)
    if force_pallas or _use_pallas():
        ox, ot = mixing_p2p(flat, x_tilde.reshape(-1), x_partner.reshape(-1),
                            jnp.asarray(dt), eta=eta, alpha=alpha,
                            alpha_t=alpha_t, interpret=interpret)
        return ox.reshape(x.shape), ot.reshape(x.shape)
    return mixing_p2p_ref(x, x_tilde, x_partner, dt, eta=eta, alpha=alpha,
                          alpha_t=alpha_t)


def gossip_event_pytree(x: PyTree, x_tilde: PyTree, x_partner: PyTree, dt,
                        *, eta: float, alpha: float, alpha_t: float,
                        **kw) -> tuple[PyTree, PyTree]:
    flat_x, treedef = jax.tree_util.tree_flatten(x)
    flat_t = treedef.flatten_up_to(x_tilde)
    flat_p = treedef.flatten_up_to(x_partner)
    outs = [gossip_event(a, b, c, dt, eta=eta, alpha=alpha, alpha_t=alpha_t,
                         **kw) for a, b, c in zip(flat_x, flat_t, flat_p)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


# ------------------------------------------------------- event-engine passes

def p2p_mix_event(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                  dt_next, *, eta: float, alpha: float, alpha_t: float,
                  backend: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Fused p2p-then-mix on flat (D,) vectors (SPMD per-worker path)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return p2p_mixing_ref(x, x_tilde, x_partner, dt_next, eta=eta,
                              alpha=alpha, alpha_t=alpha_t)
    return p2p_mixing(x, x_tilde, x_partner, jnp.asarray(dt_next),
                      eta=eta, alpha=alpha, alpha_t=alpha_t,
                      interpret=(backend == "pallas_interpret"))


def gossip_event_stacked(x: jax.Array, x_tilde: jax.Array,
                         partner: jax.Array, dt_next: jax.Array, *,
                         eta: float, alpha: float, alpha_t: float,
                         backend: str = "auto"
                         ) -> tuple[jax.Array, jax.Array]:
    """Fused coalesced gossip batch on worker-stacked (W, D) buffers."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return mixing_gossip_stacked_ref(x, x_tilde, partner, dt_next,
                                         eta=eta, alpha=alpha,
                                         alpha_t=alpha_t)
    return mixing_gossip_stacked(x, x_tilde, partner, dt_next, eta=eta,
                                 alpha=alpha, alpha_t=alpha_t,
                                 interpret=(backend == "pallas_interpret"))


def gossip_event_worlds(x: jax.Array, x_tilde: jax.Array,
                        partner: jax.Array, dt_next: jax.Array,
                        eta: jax.Array, alpha: jax.Array,
                        alpha_t: jax.Array, *, backend: str = "auto"
                        ) -> tuple[jax.Array, jax.Array]:
    """Fused coalesced gossip batch over B worlds at once: (B, W, D)
    buffers, (B, W) partners/dt, (B,) per-world dynamics (the batched
    many-worlds replay — baseline and accelerated worlds share one
    dispatch)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return mixing_gossip_worlds_ref(x, x_tilde, partner, dt_next,
                                        eta, alpha, alpha_t)
    return mixing_gossip_worlds(x, x_tilde, partner, dt_next, eta, alpha,
                                alpha_t,
                                interpret=(backend == "pallas_interpret"))


def channel_event_worlds(x: jax.Array, x_tilde: jax.Array,
                         x_partner: jax.Array, corrupt: jax.Array,
                         mscale: jax.Array, dt_next: jax.Array,
                         eta: jax.Array, alpha: jax.Array,
                         alpha_t: jax.Array, *,
                         clip: float | None = None, want_rej: bool = False,
                         backend: str = "auto"):
    """World-batched channel gossip batch: pre-gathered (B, W, D) partner
    values, (B, W) corrupt/robust-mscale/dt, (B,) per-world dynamics,
    optional static coordinate ``clip`` (DESIGN.md §10/§11).  With
    ``want_rej`` the kernel also emits the (B, W) rejection mask (§12)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return channel_gossip_worlds_ref(x, x_tilde, x_partner, corrupt,
                                         mscale, dt_next, eta, alpha,
                                         alpha_t, clip=clip,
                                         want_rej=want_rej)
    return channel_gossip_worlds(x, x_tilde, x_partner, corrupt, mscale,
                                 dt_next, eta, alpha, alpha_t, clip=clip,
                                 want_rej=want_rej,
                                 interpret=(backend == "pallas_interpret"))


# --------------------------------------------- unreliable-channel passes

def channel_event_stacked(x: jax.Array, x_tilde: jax.Array,
                          x_partner: jax.Array, corrupt: jax.Array,
                          mscale: jax.Array, dt_next: jax.Array, *,
                          eta: float, alpha: float, alpha_t: float,
                          clip: float | None = None, want_rej: bool = False,
                          backend: str = "auto"):
    """Fused channel gossip batch on (W, D) buffers: pre-gathered partner
    values (fresh or ring-buffer stale), per-worker ``corrupt`` multiplier
    offsets, per-worker robust ``mscale`` (norm trim/clip), optional
    in-kernel coordinate ``clip`` (DESIGN.md §10).  With ``want_rej`` the
    kernel also emits the (W,) rejection mask (§12)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return channel_gossip_stacked_ref(x, x_tilde, x_partner, corrupt,
                                          mscale, dt_next, eta=eta,
                                          alpha=alpha, alpha_t=alpha_t,
                                          clip=clip, want_rej=want_rej)
    return channel_gossip_stacked(x, x_tilde, x_partner, corrupt, mscale,
                                  dt_next, eta=eta, alpha=alpha,
                                  alpha_t=alpha_t, clip=clip,
                                  want_rej=want_rej,
                                  interpret=(backend == "pallas_interpret"))


def channel_event_local(x: jax.Array, x_tilde: jax.Array,
                        x_partner: jax.Array, corrupt, mscale, dt_next, *,
                        eta: float, alpha: float, alpha_t: float,
                        clip: float | None = None, backend: str = "auto"
                        ) -> tuple[jax.Array, jax.Array]:
    """Channel variant of ``p2p_mix_event`` on per-worker (D,) vectors
    (SPMD path): scalar ``corrupt``/``mscale`` for this worker's read.
    The Pallas path reuses the stacked kernel on a (1, D) view."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return channel_p2p_mixing_ref(x, x_tilde, x_partner, corrupt,
                                      mscale, dt_next, eta=eta, alpha=alpha,
                                      alpha_t=alpha_t, clip=clip)
    ox, ot = channel_gossip_stacked(
        x[None], x_tilde[None], x_partner[None],
        jnp.reshape(jnp.asarray(corrupt, jnp.float32), (1,)),
        jnp.reshape(jnp.asarray(mscale, jnp.float32), (1,)),
        jnp.reshape(jnp.asarray(dt_next, jnp.float32), (1,)),
        eta=eta, alpha=alpha, alpha_t=alpha_t, clip=clip,
        interpret=(backend == "pallas_interpret"))
    return ox[0], ot[0]
