"""Fused A2CiD2 gossip-event kernels (Pallas TPU).

One p2p averaging event updates BOTH local buffers from the partner's
parameters (Algo 1 lines 17-19), combined with the lazy continuous mixing
exp(dt*A).  Two fusion orders are provided (see DESIGN.md):

``mixing_p2p`` — mix THEN p2p (xp is the partner's already-mixed x):

    c   = (1 - exp(-2 eta dt)) / 2          # mixing coefficient
    xm  = x  + c * (xt - x)                 # mixed x
    xtm = xt - c * (xt - x)                 # mixed x~
    m   = xm - xp                           # pairwise difference
    out_x  = xm  - alpha   * m
    out_xt = xtm - alpha_t * m

``p2p_mixing`` / ``mixing_gossip_stacked`` — p2p THEN mix-to-next-event.
This is the order the flat-buffer event engine uses: chaining the mixing
segment that precedes event e+1 onto the p2p pass of event e makes xp the
partner's CURRENT (already-mixed) x, so no partner x~ read is needed:

    m   = x - xp
    x1  = x  - alpha   * m
    xt1 = xt - alpha_t * m
    c   = (1 - exp(-2 eta dt_next)) / 2
    out_x  = x1  + c * (xt1 - x1)
    out_xt = xt1 - c * (xt1 - x1)

Unfused, an event is 2 elementwise passes over 3 full parameter-sized
tensors (6 reads + 4 writes of HBM).  Either fused kernel does 3 reads +
2 writes — a 2x HBM-traffic reduction on the gossip step, which matters
because the gossip event IS the paper's unit of communication cost.

Layout: ``mixing_p2p``/``p2p_mixing`` take flat (N,) vectors tiled to
(BLOCK,) VMEM blocks with `dt` a scalar in SMEM.  ``mixing_gossip_stacked``
takes worker-stacked (W, D) buffers on a 2-D grid (workers x D-blocks); the
partner index and per-worker dt vectors are scalar-prefetched so the partner
row gather is resolved to a static block index before each grid step runs.

``channel_gossip_stacked`` is the unreliable-channel variant (DESIGN.md
§10): partner values arrive pre-gathered (fresh row or ring-buffer stale
snapshot — an XLA gather outside the kernel), a prefetched per-worker
``corrupt`` multiplier offset models Byzantine messages, and the robust
aggregation rides in two forms — a prefetched per-worker ``mscale``
(norm-trim rejection / norm-clip rescale, derived by the caller from
||m|| in one fused reduce) and a static coordinate ``clip``:

    m   = clip((x - (1 + corrupt) * xp) * mscale, +-tau)
    ...same p2p-then-mix tail as above...

``mixing_gossip_worlds`` / ``channel_gossip_worlds`` are the world-batched
twins (DESIGN.md §11): the batch is the leading (slowest) grid axis over
(B, W, D) buffers, and the A2CiD2 dynamics (eta, alpha, alpha_t) ride in
as prefetched (B,) per-world scalars instead of static Python floats — so
baseline and accelerated worlds, and every point of a sweep grid, share
ONE kernel trace.  Per world the arithmetic is bitwise the serial
kernel's (f32 param rounding commutes with the power-of-two multiplies).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 64 * 1024  # 64k elems: 3 in + 2 out bf16 blocks = 640 KiB of VMEM

# stacked kernel: (1, BLOCK_D) blocks; 4 in + 2 out f32 blocks = 384 KiB VMEM
BLOCK_D = 16 * 1024


def _mixing_kernel(dt_ref, x_ref, xt_ref, xp_ref, out_x_ref, out_xt_ref, *,
                   eta: float, alpha: float, alpha_t: float):
    x = x_ref[...]
    xt = xt_ref[...]
    xp = xp_ref[...]
    dt = dt_ref[0]
    c = 0.5 * (1.0 - jnp.exp(-2.0 * eta * dt)).astype(x.dtype)
    d = xt - x
    xm = x + c * d
    xtm = xt - c * d
    m = xm - xp
    out_x_ref[...] = xm - alpha * m
    out_xt_ref[...] = xtm - alpha_t * m


@functools.partial(jax.jit,
                   static_argnames=("eta", "alpha", "alpha_t", "interpret"))
def mixing_p2p(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
               dt: jax.Array, *, eta: float, alpha: float, alpha_t: float,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Apply one fused (mix, p2p) event to flat parameter arrays.

    x, x_tilde, x_partner: (N,) same dtype; dt: scalar f32.
    """
    n = x.shape[0]
    block = min(BLOCK, n)
    # pad to a multiple of the block
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        x_tilde = jnp.pad(x_tilde, (0, pad))
        x_partner = jnp.pad(x_partner, (0, pad))
    grid = (x.shape[0] // block,)
    dt_arr = jnp.reshape(dt.astype(jnp.float32), (1,))
    kernel = functools.partial(_mixing_kernel, eta=eta, alpha=alpha,
                               alpha_t=alpha_t)
    out_x, out_xt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # dt scalar, whole array
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        interpret=interpret,
    )(dt_arr, x, x_tilde, x_partner)
    if pad:
        out_x = out_x[:n]
        out_xt = out_xt[:n]
    return out_x, out_xt


# ---------------------------------------------------------------------------
# p2p-then-mix order (flat vectors) — the event-engine group pass
# ---------------------------------------------------------------------------

def _p2p_mixing_kernel(dt_ref, x_ref, xt_ref, xp_ref, out_x_ref, out_xt_ref,
                       *, eta: float, alpha: float, alpha_t: float):
    x = x_ref[...]
    xt = xt_ref[...]
    xp = xp_ref[...]
    dt = dt_ref[0]
    m = x - xp
    x1 = x - alpha * m
    xt1 = xt - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))).astype(x.dtype)
    d = xt1 - x1
    out_x_ref[...] = x1 + c * d
    out_xt_ref[...] = xt1 - c * d


@functools.partial(jax.jit,
                   static_argnames=("eta", "alpha", "alpha_t", "interpret"))
def p2p_mixing(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
               dt_next: jax.Array, *, eta: float, alpha: float,
               alpha_t: float, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Fused p2p update followed by mixing for ``dt_next`` (flat vectors).

    x, x_tilde, x_partner: (N,) same dtype; dt_next: scalar f32.
    """
    n = x.shape[0]
    block = min(BLOCK, n)
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        x_tilde = jnp.pad(x_tilde, (0, pad))
        x_partner = jnp.pad(x_partner, (0, pad))
    grid = (x.shape[0] // block,)
    dt_arr = jnp.reshape(dt_next.astype(jnp.float32), (1,))
    kernel = functools.partial(_p2p_mixing_kernel, eta=eta, alpha=alpha,
                               alpha_t=alpha_t)
    out_x, out_xt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # dt scalar, whole array
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        interpret=interpret,
    )(dt_arr, x, x_tilde, x_partner)
    if pad:
        out_x = out_x[:n]
        out_xt = out_xt[:n]
    return out_x, out_xt


# ---------------------------------------------------------------------------
# worker-stacked fused gossip batch (2-D grid, scalar-prefetched partners)
# ---------------------------------------------------------------------------

def _stacked_kernel(partner_ref, dt_ref, x_ref, xp_ref, xt_ref,
                    out_x_ref, out_xt_ref, *, eta: float, alpha: float,
                    alpha_t: float):
    w = pl.program_id(0)
    x = x_ref[...]
    xp = xp_ref[...]
    xt = xt_ref[...]
    m = x - xp           # partner==w => xp==x => m==0 (idle worker no-op)
    x1 = x - alpha * m
    xt1 = xt - alpha_t * m
    dt = dt_ref[w]
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))).astype(x.dtype)
    d = xt1 - x1
    out_x_ref[...] = x1 + c * d
    out_xt_ref[...] = xt1 - c * d


@functools.partial(jax.jit,
                   static_argnames=("eta", "alpha", "alpha_t", "interpret"))
def mixing_gossip_stacked(x: jax.Array, x_tilde: jax.Array,
                          partner: jax.Array, dt_next: jax.Array, *,
                          eta: float, alpha: float, alpha_t: float,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """One coalesced gossip batch over a worker-stacked flat buffer.

    x, x_tilde: (W, D) same dtype; partner: (W,) int32 (partner[w] == w for
    idle workers); dt_next: (W,) f32 per-worker mixing horizon to the next
    event (p2p-then-mix order, see module docstring).

    The partner gather is resolved via scalar prefetch: the BlockSpec index
    map reads partner[w] before the grid step runs, so the partner row block
    arrives by regular (static-index) pipelining — no in-kernel gather.  Per
    batch the kernel reads 3 state-sized buffers (x twice: self + partner
    rows; x~ once) and writes 2.  x~ only ever reads its own row, so its
    input buffer is aliased to the output in place; x cannot alias (another
    grid step may still read row w as a partner after w is updated).
    """
    w_dim, d_dim = x.shape
    block = min(BLOCK_D, d_dim)
    pad = (-d_dim) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        x_tilde = jnp.pad(x_tilde, ((0, 0), (0, pad)))
    grid = (w_dim, x.shape[1] // block)
    partner = partner.astype(jnp.int32)
    dt_next = dt_next.astype(jnp.float32)
    kernel = functools.partial(_stacked_kernel, eta=eta, alpha=alpha,
                               alpha_t=alpha_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # partner, dt_next
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda w, d, p, t: (w, d)),
            pl.BlockSpec((1, block), lambda w, d, p, t: (p[w], d)),
            pl.BlockSpec((1, block), lambda w, d, p, t: (w, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda w, d, p, t: (w, d)),
            pl.BlockSpec((1, block), lambda w, d, p, t: (w, d)),
        ],
    )
    out_x, out_xt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        # inputs are (partner, dt, x, x, xt): alias xt -> out_xt in place
        input_output_aliases={} if interpret else {4: 1},
        interpret=interpret,
    )(partner, dt_next, x, x, x_tilde)
    if pad:
        out_x = out_x[:, :d_dim]
        out_xt = out_xt[:, :d_dim]
    return out_x, out_xt


# ---------------------------------------------------------------------------
# world-batched fused gossip batch (3-D grid; many-worlds replay, §11)
# ---------------------------------------------------------------------------

def _worlds_kernel(partner_ref, dt_ref, eta_ref, alpha_ref, alphat_ref,
                   x_ref, xp_ref, xt_ref, out_x_ref, out_xt_ref):
    b = pl.program_id(0)
    w = pl.program_id(1)
    x = x_ref[...]
    xp = xp_ref[...]
    xt = xt_ref[...]
    m = x - xp           # partner==w => xp==x => m==0 (idle worker no-op)
    alpha = alpha_ref[b].astype(x.dtype)
    alpha_t = alphat_ref[b].astype(x.dtype)
    x1 = x - alpha * m
    xt1 = xt - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta_ref[b] * dt_ref[b, w]))
         ).astype(x.dtype)
    d = xt1 - x1
    out_x_ref[...] = x1 + c * d
    out_xt_ref[...] = xt1 - c * d


@functools.partial(jax.jit, static_argnames=("interpret",))
def mixing_gossip_worlds(x: jax.Array, x_tilde: jax.Array,
                         partner: jax.Array, dt_next: jax.Array,
                         eta: jax.Array, alpha: jax.Array,
                         alpha_t: jax.Array, *, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """One coalesced gossip batch over B worlds' stacked buffers at once.

    x, x_tilde: (B, W, D) same dtype; partner: (B, W) int32 (per-world
    involutions); dt_next: (B, W) f32; eta/alpha/alpha_t: (B,) f32
    per-world dynamics riding in as prefetched scalars — the batch mixes
    baseline (eta 0) and accelerated worlds in ONE trace, which is what
    makes a whole sweep family one compile + one dispatch.

    Same structure as ``mixing_gossip_stacked`` with the batch as the
    leading (slowest) grid axis: the partner row gather resolves to a
    static (b, partner[b, w], d) block index via scalar prefetch, x~ only
    reads its own row and aliases its output in place, and each grid step
    stays 3 state reads + 2 writes.
    """
    b_dim, w_dim, d_dim = x.shape
    block = min(BLOCK_D, d_dim)
    pad = (-d_dim) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
        x_tilde = jnp.pad(x_tilde, ((0, 0), (0, 0), (0, pad)))
    grid = (b_dim, w_dim, x.shape[2] // block)
    partner = partner.astype(jnp.int32)
    dt_next = dt_next.astype(jnp.float32)
    # eta joins the f32 mixing-coefficient pipeline (what the serial
    # kernel computes c in); alpha/alpha_t keep their precision and cast
    # straight to the buffer dtype in-kernel (weak-scalar semantics)
    pw = [jnp.asarray(eta, jnp.float32), jnp.asarray(alpha),
          jnp.asarray(alpha_t)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,  # partner, dt_next, eta, alpha, alpha_t
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, p, t, e, a, at: (b, w, d)),
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, p, t, e, a, at: (b, p[b, w], d)),
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, p, t, e, a, at: (b, w, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, p, t, e, a, at: (b, w, d)),
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, p, t, e, a, at: (b, w, d)),
        ],
    )
    out_x, out_xt = pl.pallas_call(
        _worlds_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        # inputs are (partner, dt, eta, alpha, alpha_t, x, x, xt):
        # alias xt -> out_xt in place (x cannot alias: later grid steps
        # may still read any row as a partner)
        input_output_aliases={} if interpret else {7: 1},
        interpret=interpret,
    )(partner, dt_next, *pw, x, x, x_tilde)
    if pad:
        out_x = out_x[:, :, :d_dim]
        out_xt = out_xt[:, :, :d_dim]
    return out_x, out_xt


def _channel_worlds_kernel(corrupt_ref, mscale_ref, dt_ref, eta_ref,
                           alpha_ref, alphat_ref, x_ref, xp_ref, xt_ref,
                           out_x_ref, out_xt_ref, *rej_ref, clip):
    b = pl.program_id(0)
    w = pl.program_id(1)
    x = x_ref[...]
    xp = xp_ref[...]
    xt = xt_ref[...]
    cadv = (1.0 + corrupt_ref[b, w]).astype(x.dtype)
    m = (x - cadv * xp) * mscale_ref[b, w].astype(x.dtype)
    if clip is not None:
        m = jnp.clip(m, -clip, clip)
    alpha = alpha_ref[b].astype(x.dtype)
    alpha_t = alphat_ref[b].astype(x.dtype)
    x1 = x - alpha * m
    xt1 = xt - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta_ref[b] * dt_ref[b, w]))
         ).astype(x.dtype)
    d = xt1 - x1
    out_x_ref[...] = x1 + c * d
    out_xt_ref[...] = xt1 - c * d
    if rej_ref:
        # per-event rejection mask (self-healing defense, DESIGN.md §12):
        # 1.0 where the robust scale zeroed the exchange; the (1, 1, 1)
        # output block is constant along the d axis, so every d-step
        # rewrites the same value
        rej_ref[0][...] = (mscale_ref[b, w] == 0.0).astype(
            jnp.float32).reshape(1, 1, 1)


@functools.partial(jax.jit, static_argnames=("clip", "want_rej",
                                             "interpret"))
def channel_gossip_worlds(x: jax.Array, x_tilde: jax.Array,
                          x_partner: jax.Array, corrupt: jax.Array,
                          mscale: jax.Array, dt_next: jax.Array,
                          eta: jax.Array, alpha: jax.Array,
                          alpha_t: jax.Array, *,
                          clip: float | None = None,
                          want_rej: bool = False,
                          interpret: bool = False):
    """World-batched unreliable-channel gossip batch (robust m-term).

    x, x_tilde, x_partner: (B, W, D) same dtype — partner values arrive
    PRE-GATHERED per world (fresh rows or (B, H, W, D) ring snapshots);
    corrupt, mscale, dt_next: (B, W) f32; eta/alpha/alpha_t: (B,) f32
    per-world dynamics; ``clip`` the static coordinate-clip rule.  All
    per-(world, worker) scalars ride the prefetch lane, so every tensor
    operand streams with static block indices exactly like the serial
    channel kernel — 3 state reads + 2 writes per grid step, x~ aliased.
    ``want_rej`` (static) adds a third output: the (B, W) f32 rejection
    mask ``mscale == 0`` the self-healing defense's trust loop consumes
    (a (1, 1, 1)-blocked scalar lane, negligible extra traffic).
    """
    b_dim, w_dim, d_dim = x.shape
    block = min(BLOCK_D, d_dim)
    pad = (-d_dim) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
        x_tilde = jnp.pad(x_tilde, ((0, 0), (0, 0), (0, pad)))
        x_partner = jnp.pad(x_partner, ((0, 0), (0, 0), (0, pad)))
    grid = (b_dim, w_dim, x.shape[2] // block)
    pw = [jnp.asarray(v, jnp.float32)
          for v in (corrupt, mscale, dt_next, eta)]
    pw += [jnp.asarray(alpha), jnp.asarray(alpha_t)]
    kernel = functools.partial(_channel_worlds_kernel, clip=clip)
    out_specs = [
        pl.BlockSpec((1, 1, block),
                     lambda b, w, d, c, s, t, e, a, at: (b, w, d)),
        pl.BlockSpec((1, 1, block),
                     lambda b, w, d, c, s, t, e, a, at: (b, w, d)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(x.shape, x.dtype),
    ]
    if want_rej:
        out_specs.append(pl.BlockSpec(
            (1, 1, 1), lambda b, w, d, c, s, t, e, a, at: (b, w, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b_dim, w_dim, 1), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,  # corrupt, mscale, dt, eta, alpha, alpha_t
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, c, s, t, e, a, at: (b, w, d)),
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, c, s, t, e, a, at: (b, w, d)),
            pl.BlockSpec((1, 1, block),
                         lambda b, w, d, c, s, t, e, a, at: (b, w, d)),
        ],
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # inputs are (corrupt, mscale, dt, eta, alpha, alpha_t, x, xp, xt):
        # alias xt -> out_xt in place
        input_output_aliases={} if interpret else {8: 1},
        interpret=interpret,
    )(*pw, x, x_partner, x_tilde)
    out_x, out_xt = outs[0], outs[1]
    if pad:
        out_x = out_x[:, :, :d_dim]
        out_xt = out_xt[:, :, :d_dim]
    if want_rej:
        return out_x, out_xt, outs[2][:, :, 0]
    return out_x, out_xt


# ---------------------------------------------------------------------------
# unreliable-channel fused batch (robust m-term; DESIGN.md §10)
# ---------------------------------------------------------------------------

def _channel_kernel(corrupt_ref, mscale_ref, dt_ref, x_ref, xp_ref, xt_ref,
                    out_x_ref, out_xt_ref, *rej_ref, eta: float,
                    alpha: float, alpha_t: float, clip):
    w = pl.program_id(0)
    x = x_ref[...]
    xp = xp_ref[...]
    xt = xt_ref[...]
    # received value: (1 + corrupt) * xp — honest rows have corrupt == 0,
    # so the multiply is an exact identity (1.0 * xp == xp bitwise); the
    # robust trim/clip scale (from the delta's norm, computed by the caller
    # in one fused reduce) rides in the same way, 1.0 for accepted deltas
    cadv = (1.0 + corrupt_ref[w]).astype(x.dtype)
    m = (x - cadv * xp) * mscale_ref[w].astype(x.dtype)
    if clip is not None:
        m = jnp.clip(m, -clip, clip)  # in-kernel coordinate-clip rule
    x1 = x - alpha * m
    xt1 = xt - alpha_t * m
    dt = dt_ref[w]
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * dt))).astype(x.dtype)
    d = xt1 - x1
    out_x_ref[...] = x1 + c * d
    out_xt_ref[...] = xt1 - c * d
    if rej_ref:
        # per-event rejection mask (self-healing defense, DESIGN.md §12):
        # the (1, 1) block is constant along the d axis, so every d-step
        # rewrites the same scalar
        rej_ref[0][...] = (mscale_ref[w] == 0.0).astype(
            jnp.float32).reshape(1, 1)


@functools.partial(jax.jit,
                   static_argnames=("eta", "alpha", "alpha_t", "clip",
                                    "want_rej", "interpret"))
def channel_gossip_stacked(x: jax.Array, x_tilde: jax.Array,
                           x_partner: jax.Array, corrupt: jax.Array,
                           mscale: jax.Array, dt_next: jax.Array, *,
                           eta: float, alpha: float, alpha_t: float,
                           clip: float | None = None,
                           want_rej: bool = False,
                           interpret: bool = False):
    """One unreliable-channel gossip batch over worker-stacked buffers.

    x, x_tilde, x_partner: (W, D) same dtype; corrupt, mscale, dt_next:
    (W,) f32.  ``x_partner`` arrives PRE-GATHERED: staleness resolution
    (current row vs ring-buffer snapshot) is a data question the engine
    answers with one XLA gather before the sweep, so the kernel needs no
    in-grid partner indirection — all five tensor operands stream with
    static block indices.  ``corrupt``/``mscale``/``dt_next`` ride in as
    prefetched per-worker scalars (``mscale`` is the norm-trim/clip robust
    scale, 1.0 = accept); ``clip`` (static) is the in-kernel
    coordinate-clip rule.  Traffic is the same 3 reads + 2 writes of state
    as the clean kernel (the caller's norm reduce for mscale adds 2 reads
    when a norm rule is on).  x~ only ever reads its own row and is
    aliased in place; x and x_partner are distinct buffers here, so x
    cannot alias.  ``want_rej`` (static) adds a third output: the (W,)
    f32 rejection mask ``mscale == 0`` the self-healing defense's trust
    loop consumes (a (1, 1)-blocked scalar lane).
    """
    w_dim, d_dim = x.shape
    block = min(BLOCK_D, d_dim)
    pad = (-d_dim) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        x_tilde = jnp.pad(x_tilde, ((0, 0), (0, pad)))
        x_partner = jnp.pad(x_partner, ((0, 0), (0, pad)))
    grid = (w_dim, x.shape[1] // block)
    corrupt = corrupt.astype(jnp.float32)
    mscale = mscale.astype(jnp.float32)
    dt_next = dt_next.astype(jnp.float32)
    kernel = functools.partial(_channel_kernel, eta=eta, alpha=alpha,
                               alpha_t=alpha_t, clip=clip)
    out_specs = [
        pl.BlockSpec((1, block), lambda w, d, c, s, t: (w, d)),
        pl.BlockSpec((1, block), lambda w, d, c, s, t: (w, d)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(x.shape, x.dtype),
    ]
    if want_rej:
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda w, d, c, s, t: (w, 0)))
        out_shape.append(jax.ShapeDtypeStruct((w_dim, 1), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # corrupt, mscale, dt_next
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda w, d, c, s, t: (w, d)),
            pl.BlockSpec((1, block), lambda w, d, c, s, t: (w, d)),
            pl.BlockSpec((1, block), lambda w, d, c, s, t: (w, d)),
        ],
        out_specs=out_specs,
    )
    outs = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        # inputs are (corrupt, mscale, dt, x, xp, xt): alias xt -> out_xt
        input_output_aliases={} if interpret else {5: 1},
        interpret=interpret,
    )(corrupt, mscale, dt_next, x, x_partner, x_tilde)
    out_x, out_xt = outs[0], outs[1]
    if pad:
        out_x = out_x[:, :d_dim]
        out_xt = out_xt[:, :d_dim]
    if want_rej:
        return out_x, out_xt, outs[2][:, 0]
    return out_x, out_xt
