"""Fused A2CiD2 gossip-event kernel (Pallas TPU).

One p2p averaging event updates BOTH local buffers from the partner's
parameters (Algo 1 lines 17-19), after lazily applying the continuous mixing
exp(dt*A):

    c   = (1 - exp(-2 eta dt)) / 2          # mixing coefficient
    xm  = x  + c * (xt - x)                 # mixed x
    xtm = xt - c * (xt - x)                 # mixed x~
    m   = xm - xp                           # pairwise difference
    out_x  = xm  - alpha   * m
    out_xt = xtm - alpha_t * m

Unfused, this is 2 elementwise passes over 3 full parameter-sized tensors
(6 reads + 4 writes of HBM).  The fused kernel does 3 reads + 2 writes — a
2x HBM-traffic reduction on the gossip step, which matters because the
gossip event IS the paper's unit of communication cost.

Layout: parameters are flattened to (N,) and tiled to (BLOCK,) VMEM blocks;
`dt` is a scalar in SMEM (it varies per event — prefetch-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64 * 1024  # 64k elems: 3 in + 2 out bf16 blocks = 640 KiB of VMEM


def _mixing_kernel(dt_ref, x_ref, xt_ref, xp_ref, out_x_ref, out_xt_ref, *,
                   eta: float, alpha: float, alpha_t: float):
    x = x_ref[...]
    xt = xt_ref[...]
    xp = xp_ref[...]
    dt = dt_ref[0]
    c = 0.5 * (1.0 - jnp.exp(-2.0 * eta * dt)).astype(x.dtype)
    d = xt - x
    xm = x + c * d
    xtm = xt - c * d
    m = xm - xp
    out_x_ref[...] = xm - alpha * m
    out_xt_ref[...] = xtm - alpha_t * m


@functools.partial(jax.jit,
                   static_argnames=("eta", "alpha", "alpha_t", "interpret"))
def mixing_p2p(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
               dt: jax.Array, *, eta: float, alpha: float, alpha_t: float,
               interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Apply one fused (mix, p2p) event to flat parameter arrays.

    x, x_tilde, x_partner: (N,) same dtype; dt: scalar f32.
    """
    n = x.shape[0]
    block = min(BLOCK, n)
    # pad to a multiple of the block
    pad = (-n) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        x_tilde = jnp.pad(x_tilde, (0, pad))
        x_partner = jnp.pad(x_partner, (0, pad))
    grid = (x.shape[0] // block,)
    dt_arr = jnp.reshape(dt.astype(jnp.float32), (1,))
    kernel = functools.partial(_mixing_kernel, eta=eta, alpha=alpha,
                               alpha_t=alpha_t)
    out_x, out_xt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # dt scalar, whole array
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        interpret=interpret,
    )(dt_arr, x, x_tilde, x_partner)
    if pad:
        out_x = out_x[:n]
        out_xt = out_xt[:n]
    return out_x, out_xt
