"""Pure-jnp oracle for the fused A2CiD2 gossip-event kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mixing_p2p_ref(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                   dt, *, eta: float, alpha: float, alpha_t: float
                   ) -> tuple[jax.Array, jax.Array]:
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * jnp.asarray(dt, jnp.float32)))
         ).astype(x.dtype)
    d = x_tilde - x
    xm = x + c * d
    xtm = x_tilde - c * d
    m = xm - x_partner
    return xm - alpha * m, xtm - alpha_t * m


def p2p_mixing_ref(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                   dt_next, *, eta: float, alpha: float, alpha_t: float
                   ) -> tuple[jax.Array, jax.Array]:
    """p2p update then mixing for dt_next (the event-engine group order)."""
    m = x - x_partner
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d


def mixing_gossip_stacked_ref(x: jax.Array, x_tilde: jax.Array,
                              partner: jax.Array, dt_next: jax.Array, *,
                              eta: float, alpha: float, alpha_t: float
                              ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the worker-stacked fused batch: x, x~ are (W, D), partner
    (W,) an involution (partner[w]==w for idle workers), dt_next (W,)."""
    xp = jnp.take(x, partner, axis=0)
    m = x - xp
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)[:, None]
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d


def _per_world(v: jax.Array, x: jax.Array) -> jax.Array:
    """(B,) per-world parameter -> broadcastable against (B, W, D) buffers
    at the buffer dtype (mirrors how the serial kernels bind their static
    Python-float params: one conversion straight to the buffer dtype, then
    the multiply — full precision under x64, like a weak scalar)."""
    v = jnp.asarray(v).astype(x.dtype)
    return jnp.reshape(v, v.shape + (1,) * (x.ndim - v.ndim))


def mixing_gossip_worlds_ref(x: jax.Array, x_tilde: jax.Array,
                             partner: jax.Array, dt_next: jax.Array,
                             eta: jax.Array, alpha: jax.Array,
                             alpha_t: jax.Array
                             ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the world-batched fused gossip batch.

    x, x~: (B, W, D); partner, dt_next: (B, W); eta, alpha, alpha_t: (B,)
    f32 per-world dynamics (the batched replay runs baseline AND
    accelerated worlds — different Prop 3.6 params — in one dispatch).
    Per world this is bitwise ``mixing_gossip_stacked_ref``: the f32 param
    pipeline matches the static-scalar binding (rounding to f32 commutes
    with the *2 in the exponent), and idle rows (partner[b, w] == w) stay
    exact no-ops.
    """
    xp = jnp.take_along_axis(x, partner[:, :, None].astype(jnp.int32),
                             axis=1)
    m = x - xp
    x1 = x - _per_world(alpha, x) * m
    xt1 = x_tilde - _per_world(alpha_t, x) * m
    eta32 = jnp.asarray(eta, jnp.float32)[:, None]
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta32
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)[:, :, None]
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d


def channel_gossip_worlds_ref(x: jax.Array, x_tilde: jax.Array,
                              x_partner: jax.Array, corrupt: jax.Array,
                              mscale: jax.Array, dt_next: jax.Array,
                              eta: jax.Array, alpha: jax.Array,
                              alpha_t: jax.Array, *,
                              clip: float | None = None,
                              want_rej: bool = False):
    """Oracle for the world-batched unreliable-channel batch: (B, W, D)
    buffers with PRE-GATHERED partner values (fresh rows or per-world
    ring-buffer snapshots), (B, W) ``corrupt``/``mscale``/``dt_next``, and
    (B,) per-world dynamics; ``clip`` is the static coordinate-clip rule.
    ``want_rej`` adds the (B, W) f32 rejection mask (``mscale == 0``) as a
    third output for the self-healing trust loop.
    """
    m = _robust_m(x, x_partner, corrupt, mscale, clip)
    x1 = x - _per_world(alpha, x) * m
    xt1 = x_tilde - _per_world(alpha_t, x) * m
    eta32 = jnp.asarray(eta, jnp.float32)[:, None]
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta32
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)[:, :, None]
    d = xt1 - x1
    if want_rej:
        rej = (jnp.asarray(mscale, jnp.float32) == 0.0).astype(jnp.float32)
        return x1 + c * d, xt1 - c * d, rej
    return x1 + c * d, xt1 - c * d


def _robust_m(x: jax.Array, x_partner: jax.Array, corrupt: jax.Array,
              mscale: jax.Array | None, clip: float | None) -> jax.Array:
    """Channel m-term: corrupted received value, robustly aggregated.

    ``corrupt`` is the multiplier OFFSET on the received partner value
    (honest = 0 => (1 + 0) * xp == xp bitwise, the exact no-op reduction).
    ``mscale`` is the per-worker robust scale the caller derived from the
    delta's norm (trim: 0/1 rejection; clip: tau/||m|| rescale; 1 = honest
    pass-through, also bitwise exact).  ``clip`` bounds each coordinate
    instead (the in-kernel 'coord' rule).
    """
    cadv = (1.0 + jnp.asarray(corrupt, jnp.float32)).astype(x.dtype)
    cadv = jnp.reshape(cadv, cadv.shape + (1,) * (x.ndim - cadv.ndim))
    m = x - cadv * x_partner
    if mscale is not None:
        s = jnp.asarray(mscale, jnp.float32).astype(x.dtype)
        m = m * jnp.reshape(s, s.shape + (1,) * (x.ndim - s.ndim))
    if clip is not None:
        m = jnp.clip(m, -clip, clip)
    return m


def channel_gossip_stacked_ref(x: jax.Array, x_tilde: jax.Array,
                               x_partner: jax.Array, corrupt: jax.Array,
                               mscale: jax.Array, dt_next: jax.Array, *,
                               eta: float, alpha: float, alpha_t: float,
                               clip: float | None = None,
                               want_rej: bool = False):
    """Oracle for the unreliable-channel fused batch.

    Like ``mixing_gossip_stacked_ref`` but the partner values ``x_partner``
    (W, D) arrive pre-gathered (the engine resolves fresh vs ring-buffer
    stale reads BEFORE the kernel), ``corrupt`` (W,) is the per-worker
    received-value multiplier offset, ``mscale`` (W,) the robust
    trim/clip scale on the delta's norm, and ``clip`` the in-kernel
    coordinate-clip rule.  ``want_rej`` adds the (W,) f32 rejection mask
    (``mscale == 0``) as a third output for the self-healing trust loop.
    """
    m = _robust_m(x, x_partner, corrupt, mscale, clip)
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)[:, None]
    d = xt1 - x1
    if want_rej:
        rej = (jnp.asarray(mscale, jnp.float32) == 0.0).astype(jnp.float32)
        return x1 + c * d, xt1 - c * d, rej
    return x1 + c * d, xt1 - c * d


def channel_p2p_mixing_ref(x: jax.Array, x_tilde: jax.Array,
                           x_partner: jax.Array, corrupt, mscale, dt_next,
                           *, eta: float, alpha: float, alpha_t: float,
                           clip: float | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Per-worker (D,) channel variant of ``p2p_mixing_ref`` (SPMD path):
    scalar ``corrupt`` offset, ``mscale``, and ``dt_next``."""
    m = _robust_m(x, x_partner, jnp.asarray(corrupt),
                  jnp.asarray(mscale), clip)
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d
