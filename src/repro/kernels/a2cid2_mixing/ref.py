"""Pure-jnp oracle for the fused A2CiD2 gossip-event kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mixing_p2p_ref(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                   dt, *, eta: float, alpha: float, alpha_t: float
                   ) -> tuple[jax.Array, jax.Array]:
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * jnp.asarray(dt, jnp.float32)))
         ).astype(x.dtype)
    d = x_tilde - x
    xm = x + c * d
    xtm = x_tilde - c * d
    m = xm - x_partner
    return xm - alpha * m, xtm - alpha_t * m
