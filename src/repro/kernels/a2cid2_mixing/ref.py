"""Pure-jnp oracle for the fused A2CiD2 gossip-event kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mixing_p2p_ref(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                   dt, *, eta: float, alpha: float, alpha_t: float
                   ) -> tuple[jax.Array, jax.Array]:
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta * jnp.asarray(dt, jnp.float32)))
         ).astype(x.dtype)
    d = x_tilde - x
    xm = x + c * d
    xtm = x_tilde - c * d
    m = xm - x_partner
    return xm - alpha * m, xtm - alpha_t * m


def p2p_mixing_ref(x: jax.Array, x_tilde: jax.Array, x_partner: jax.Array,
                   dt_next, *, eta: float, alpha: float, alpha_t: float
                   ) -> tuple[jax.Array, jax.Array]:
    """p2p update then mixing for dt_next (the event-engine group order)."""
    m = x - x_partner
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d


def mixing_gossip_stacked_ref(x: jax.Array, x_tilde: jax.Array,
                              partner: jax.Array, dt_next: jax.Array, *,
                              eta: float, alpha: float, alpha_t: float
                              ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the worker-stacked fused batch: x, x~ are (W, D), partner
    (W,) an involution (partner[w]==w for idle workers), dt_next (W,)."""
    xp = jnp.take(x, partner, axis=0)
    m = x - xp
    x1 = x - alpha * m
    xt1 = x_tilde - alpha_t * m
    c = (0.5 * (1.0 - jnp.exp(-2.0 * eta
                              * jnp.asarray(dt_next, jnp.float32)))
         ).astype(x.dtype)[:, None]
    d = xt1 - x1
    return x1 + c * d, xt1 - c * d
