"""Fused RMSNorm (Pallas TPU): one pass, f32 accumulation in VMEM.

Tiling: rows of the flattened (T, D) activation; each grid step normalizes
BLOCK_T rows entirely in VMEM (D up to 8192 => 2 MiB bf16 per block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...]
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    scale = 1.0 + scale_ref[...].astype(jnp.float32)
    o_ref[...] = (x32 * inv * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm_2d(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
               interpret: bool = False) -> jax.Array:
    """x: (T, D), scale: (D,) stored as deviation-from-1."""
    T, D = x.shape
    pad = (-T) % BLOCK_T
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    grid = (xp.shape[0] // BLOCK_T,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_T, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, scale)
    return out[:T]
