"""jit'd wrapper: any-leading-dims RMSNorm."""
from __future__ import annotations

import jax

from .kernel import rmsnorm_2d
from .ref import rmsnorm_ref


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6, *,
            force_pallas: bool = False, interpret: bool = False) -> jax.Array:
    if force_pallas or jax.default_backend() == "tpu":
        flat = x.reshape(-1, x.shape[-1])
        return rmsnorm_2d(flat, scale, eps=eps,
                          interpret=interpret).reshape(x.shape)
    return rmsnorm_ref(x, scale, eps)
