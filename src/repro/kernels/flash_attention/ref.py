"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None) -> jax.Array:
    """q: (BH, S, hd), k/v: (BH, T, hd)."""
    S, T = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)
