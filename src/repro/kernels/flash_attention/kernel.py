"""Flash attention (Pallas TPU): causal + sliding-window, online softmax.

Grid: (batch*heads, q_blocks, kv_blocks) — the kv dimension is innermost and
sequential on TPU, so the (m, l, acc) running-softmax state lives in VMEM
scratch across kv steps.  BlockSpec tiling:

    q   (1, BLOCK_Q, hd)   revisited across kv steps
    k/v (1, BLOCK_K, hd)   streamed
    out (1, BLOCK_Q, hd)   written at the last kv step

MXU alignment: BLOCK_Q = BLOCK_K = 128, head_dim padded to a multiple of 128
by the wrapper.  f32 accumulation regardless of input dtype.
Sliding-window masking is positional: col > row - window and col <= row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  seq_len: int, kv_len: int, block_q: int, block_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    cols = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                   # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be exp(0))
    alive = m_cur > NEG_INF * 0.5
    p = jnp.where(alive, jnp.exp(s - m_cur), 0.0)
    corr = jnp.where(alive, jnp.exp(m_prev - m_cur), 1.0)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "interpret",
                              "block_q", "block_k"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int | None = None,
                         scale: float | None = None, interpret: bool = False,
                         block_q: int = BLOCK_Q, block_k: int = BLOCK_K
                         ) -> jax.Array:
    """q: (BH, S, hd), k/v: (BH, T, hd) — same head counts (pre-broadcast)."""
    BH, S, hd = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else hd ** -0.5

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    Sp, Tp = S + pad_q, T + pad_k

    grid = (BH, Sp // block_q, Tp // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        seq_len=S, kv_len=T, block_q=block_q, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :S]
