"""jit'd public wrapper for flash attention in model layout (B, S, H, hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd
from .ref import attention_ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    force_pallas: bool = False, interpret: bool = False
                    ) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, T, KV, hd) with H % KV == 0.

    Broadcasts kv heads, flattens (B, H) and dispatches to the Pallas kernel
    on TPU (or interpret mode when forced) else the jnp oracle.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    T = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    if force_pallas or jax.default_backend() == "tpu":
        out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                                   interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
