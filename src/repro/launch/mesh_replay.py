"""Sharded giant-world replay (DESIGN.md §16): the worker axis of the
flat gossip banks split over a device mesh.

``Simulator.run_worlds(..., mesh=MeshReplay(mesh))`` replays the SAME
batched streams the single-device engine consumes, but the (B, W, D)
state banks, the (B, H, W, D) snapshot rings, and every fused
mixing/channel kernel pass live per-shard under ``shard_map`` over a
1-D ``("worker",)`` mesh (``launch.mesh.make_replay_mesh``).  Only one
operation ever crosses a shard boundary: the partner-value fetch of a
cross-shard pair, served by the **bounded-staleness permute ring** —

  * the host-side shard compiler (``events.shard_partition``) splits each
    step's matching into intra-shard pairs (the partner involution
    restricted to a shard is still an involution) and cross-shard
    boundary reads, and precomputes which local rows each shard must
    publish at each step;
  * at every comm step each shard resolves its published boundary rows
    against its OWN local snapshot ring (``engine.publish_rows`` — the
    publisher applies the read's scheduled staleness, so the value that
    crosses the wire is bitwise the single-device ``ring_read``), then
    ``n_shards - 1`` static ``lax.ppermute`` ring hops stack every
    shard's block into an (NS, B, nb, D) pool
    (``flatbuf.ring_pool_exchange``) readers index by (hop, pos);
  * ``MeshReplay.lag > 0`` floors the staleness of every cross-shard
    read at ``lag`` rounds (``events.shard_lag_stale``) — boundary
    exchanges then ride snapshots at least ``lag`` rounds old, which cuts
    the per-step exchange off the critical path in exchange for bounded
    staleness.  Semantically this IS a ``ChannelModel(delay=...)``: the
    lag-L sharded replay is pinned bitwise against the single-device
    replay of ``world.shard_lag_schedule(sched, NS, L)``.

Why the final state stays BITWISE at lag 0: the flat layout is
row-independent (per-worker rows pack identically at any W), every
kernel pass is row-local, cross-shard values are exact copies, and the
per-world key stream is computed redundantly on every shard (each shard
derives the full (B, n) key fan-out and slices its rows).  Only the
TRACE metrics (loss/consensus/mean-norm) cross shards — via ``psum`` of
per-shard partials, floating-point-reassociated but never fed back into
the state — so traces are allclose while states match bit for bit.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.defense import (DefenseState, defense_absorb, defense_comm,
                            defense_grad)
from ..core.engine import FlatGossipEngine
from ..core.flatbuf import ring_pool_exchange
from ..core.simulator import SimState, SimTrace, _jit_pair


@dataclasses.dataclass(frozen=True)
class MeshReplay:
    """Hashable sharded-replay spec: a 1-D device mesh with a worker
    axis, plus the permute ring's staleness lag.  Doubles as a static
    jit argument (``jax.sharding.Mesh`` is hashable), so every distinct
    (mesh, lag) — not every world — costs a trace.

    lag — staleness floor (in rounds) on cross-shard partner reads.
      0 = per-step boundary exchange, bitwise the single-device engine;
      L > 0 = boundary reads ride snapshots >= L rounds old, exactly a
      ``ChannelModel(delay=...)`` on the boundary edges.
    """

    mesh: jax.sharding.Mesh
    lag: int = 0
    axis: str = "worker"

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(f"mesh has no {self.axis!r} axis "
                             f"(axes: {self.mesh.axis_names})")
        if self.lag < 0:
            raise ValueError(f"lag must be >= 0, got {self.lag}")

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    # ------------------------------------------------------------ placement
    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def bank_sharding(self) -> NamedSharding:
        """(B, W, ...) state banks / (B, W) columns: split on workers."""
        return self.sharding(None, self.axis)

    def ring_sharding(self) -> NamedSharding:
        """(B, H, W, D) snapshot rings: split on the worker axis."""
        return self.sharding(None, None, self.axis)

    def place_states(self, states: SimState) -> SimState:
        """Commit a world-batched SimState to the mesh — leaves (B, n,
        ...) split on the worker axis, keys replicated — so a replay
        reads its inputs in place instead of resharding them on entry."""
        bank, rep = self.bank_sharding(), self.sharding()
        put = lambda s: (lambda a: jax.device_put(a, s))
        return SimState(x=jax.tree.map(put(bank), states.x),
                        x_tilde=jax.tree.map(put(bank), states.x_tilde),
                        t_last=jax.device_put(states.t_last, bank),
                        key=jax.device_put(states.key, rep))

    def place_args(self, args: tuple) -> tuple:
        """Commit a sharded twin's argument tuple (as returned by
        ``Simulator.worlds_executable(..., mesh=...)``) to the mesh, so
        benchmark timings measure the replay, not input resharding."""
        sim, states, *mid, arrays, horizon, tel, mr = args
        row, col = self.sharding(None, self.axis), \
            self.sharding(None, None, self.axis)
        pub, rep = self.sharding(None, self.axis), self.sharding()
        specs = (row, col, col, rep, col, rep, rep, col, col, rep,
                 col, col, col, col, pub, pub)
        arrays = tuple(jax.device_put(a, s)
                       for a, s in zip(arrays, specs))
        mid = jax.device_put(tuple(mid), rep)
        return (sim, self.place_states(states), *mid, arrays, horizon,
                tel, mr)


# --------------------------------------------------------------------------
# The sharded scan impls.  Signatures mirror the single-device worlds
# twins (simulator._run_worlds_channel_impl / _run_worlds_defense_impl)
# with the MeshReplay appended as a trailing static argument; ``arrays``
# extends the channel stream arrays with the shard plan:
#   (prologue, partners, dt_next, is_grad, grad_scale, grad_pos, t_final,
#    corrupt, src_slot, ring_pos,
#    local_partner, is_cross, hop, pool_pos, pub_row, pub_slot)
# --------------------------------------------------------------------------

def _sharded_scan(sim, state, pw, gammas, taus, dk, arrays, horizon, tel,
                  mr):
    """Shared body of both sharded flavors; ``dk`` is None for the
    channel flavor, the per-world DefenseKnobs for the self-healing
    one."""
    (prologue, partners, dt_next, is_grad, grad_scale, grad_pos, t_final,
     corrupt, src_slot, ring_pos, lpart, cross, hop, ppos, pub_row,
     pub_slot) = arrays
    engine = FlatGossipEngine.for_pytree(state.x, sim.params,
                                         stacked=True, worlds=True,
                                         backend=sim.backend,
                                         robust_clip=sim.robust_clip,
                                         robust_rule=sim.robust_rule)
    bx = engine.pack_worlds(state.x)
    bxt = engine.pack_worlds(state.x_tilde)
    B, n = prologue.shape
    ns, ax = mr.n_shards, mr.axis
    wloc = n // ns
    defense = dk is not None

    def region(bx, bxt, key, prologue, xs, pw, gammas, taus_t, dk_t):
        taus_l = taus_t[0] if taus_t else None
        dk_l = dk_t[0] if dk_t else None
        bx, bxt = engine.mix_batch(bx, bxt, prologue, pw[0])
        ring = engine.ring_init_worlds(bx, horizon) if horizon else None
        i0 = jax.lax.axis_index(ax) * wloc
        wid = i0 + jnp.arange(wloc)
        init = (bx, bxt, ring, key)
        if defense:
            init = init + (DefenseState(
                qest=jnp.zeros((B,), jnp.float32),
                trust=jnp.ones((B, wloc, n), jnp.float32),
                lastn=jnp.zeros((B, wloc), jnp.float32),
                lastv=jnp.zeros((B, wloc), bool),
                rej_acc=jnp.zeros((B,), jnp.float32),
                quar_acc=jnp.zeros((B,), jnp.float32)),)
        if tel is not None:
            init = init + (sim._tel_zeros((B,)),)

        n_out = (6 if defense else 3) + (4 if tel is not None else 0)

        def step(carry, xs_t):
            (pg, lp, dtn, isg, gsc, cor, slot, rpos, crs, hp, pp, prow,
             pslot) = xs_t

            def comm(args):
                bx, bxt, ring, key = args[:4]
                rest = args[4:]
                if horizon:
                    xp = engine.partner_values_worlds(ring, bx, lp, slot)
                else:
                    xp = jnp.take_along_axis(bx, lp[:, :, None], axis=1)
                # boundary publish -> permute-ring pool -> cross reads
                pv = engine.publish_rows(ring, bx, prow[0], pslot[0])
                pool = ring_pool_exchange(pv, ax, ns)
                xp = engine.pool_partner_values(pool, hp, pp, xp, crs)
                involved = pg != wid[None, :]
                if defense:
                    ds = rest[0]
                    nrm = engine.delta_norms(bx, xp, cor, axes=2)
                    mscale, quar, ds = jax.vmap(defense_comm)(
                        dk_l, ds, pg, involved, nrm)
                    bx, bxt, rej = engine.channel_batch_worlds_scaled(
                        bx, bxt, xp, cor, mscale, dtn, pw)
                    ds = jax.vmap(defense_absorb)(ds, rej, quar, involved)
                    out = (bx, bxt, ring, key, ds)
                else:
                    if tel is not None:
                        nrm = engine.delta_norms(bx, xp, cor, axes=2)
                        rej = sim._tel_rej(nrm, taus_l)
                    bx, bxt = engine.channel_batch_worlds(
                        bx, bxt, xp, cor, dtn, pw, taus_l)
                    out = (bx, bxt, ring, key)
                if tel is not None:
                    acc = sim._tel_step(rest[-1], involved, rej, nrm,
                                        batched=True)
                    out = out + (acc,)
                z = jnp.zeros((B,), jnp.float32)
                return out, (z,) * n_out

            def grad(args):
                bx, bxt, ring, key = args[:4]
                rest = args[4:]
                bx, bxt, key, metrics = _grad_worlds_sharded(
                    sim, engine, n, wloc, ax, bx, bxt, key, gsc, gammas)
                if defense:
                    ds = rest[0]
                    dsg = DefenseState(
                        qest=ds.qest, trust=ds.trust,
                        lastn=jax.lax.all_gather(ds.lastn, ax, axis=1,
                                                 tiled=True),
                        lastv=jax.lax.all_gather(ds.lastv, ax, axis=1,
                                                 tiled=True),
                        rej_acc=jax.lax.psum(ds.rej_acc, ax),
                        quar_acc=jax.lax.psum(ds.quar_acc, ax))
                    ds, dtrace = jax.vmap(defense_grad)(dk_l, dsg)
                    ds = ds._replace(
                        lastn=jnp.zeros((B, wloc), jnp.float32),
                        lastv=jnp.zeros((B, wloc), bool))
                    metrics = metrics + dtrace
                if horizon:
                    ring = engine.ring_push_worlds(ring, bx, rpos)
                bx, bxt = engine.mix_batch(bx, bxt, dtn, pw[0])
                out = (bx, bxt, ring, key)
                if defense:
                    out = out + (ds,)
                if tel is not None:
                    acc = tuple(jax.lax.psum(a, ax) for a in rest[-1])
                    out = out + (sim._tel_zeros((B,)),)
                    metrics = metrics + acc
                return out, metrics

            return jax.lax.cond(isg, grad, comm, carry)

        carry, ys = jax.lax.scan(step, init, xs)
        return carry[0], carry[1], carry[3], ys

    rep = P()
    bank = P(None, ax, None)
    row = P(None, ax)
    col = P(None, None, ax)
    pub = P(None, ax, None, None)
    xs = (partners, lpart, dt_next, is_grad, grad_scale, corrupt,
          src_slot, ring_pos, cross, hop, ppos, pub_row, pub_slot)
    xs_specs = (col, col, col, rep, col, col, col, rep, col, col, col,
                pub, pub)
    taus_t = () if taus is None else (taus,)
    dk_t = () if dk is None else (dk,)
    bx, bxt, key, ys = shard_map(
        region, mesh=mr.mesh,
        in_specs=(bank, bank, rep, row, xs_specs, rep, rep,
                  (rep,) * len(taus_t), (rep,) * len(dk_t)),
        out_specs=(bank, bank, rep, rep),
        check_rep=False,
    )(bx, bxt, state.key, prologue, xs, pw, gammas, taus_t, dk_t)
    final = SimState(engine.unpack_worlds(bx), engine.unpack_worlds(bxt),
                     t_final, key)
    return final, ys, grad_pos


def _grad_worlds_sharded(sim, engine, n, wloc, ax, bx, bxt, key, gscale,
                         gammas):
    """Sharded twin of ``Simulator._grad_worlds``: every shard derives
    the FULL per-world (B, n) key fan-out and slices its own rows, so
    per-worker gradient noise is bitwise the single-device stream; the
    trace metrics are per-shard partial sums ``psum``-ed over the worker
    axis (metrics never feed back into the state)."""
    ks = jax.vmap(jax.random.split)(key)
    key, sub = ks[:, 0], ks[:, 1]
    wkeys = jax.vmap(lambda k: jax.random.split(k, n))(sub)
    i0 = jax.lax.axis_index(ax) * wloc
    wkeys = jax.lax.dynamic_slice_in_dim(wkeys, i0, wloc, axis=1)
    wid = i0 + jnp.arange(wloc)
    losses, grads = jax.vmap(jax.vmap(sim.grad_fn), in_axes=(0, 0, None))(
        engine.unpack_worlds(bx), wkeys, wid)
    g = engine.pack_worlds(grads)
    g = gscale[:, :, None].astype(g.dtype) * g
    gs = jnp.asarray(gammas).astype(g.dtype)[:, None, None]
    bx = bx - gs * g
    bxt = bxt - gs * g
    mean = (jax.lax.psum(jnp.sum(bx, axis=1), ax) / n)[:, None, :]
    loss = (jax.lax.psum(jnp.sum(losses, axis=1), ax) / n
            ).astype(jnp.float32)
    consensus = (jax.lax.psum(jnp.sum((bx - mean) ** 2, axis=(1, 2)), ax)
                 / n).astype(jnp.float32)
    mean_norm = jnp.sum(mean ** 2, axis=(1, 2)).astype(jnp.float32)
    return bx, bxt, key, (loss, consensus, mean_norm)


def _sharded_channel_impl(sim, state, pw, gammas, taus, arrays,
                          horizon: int, tel, mr: MeshReplay
                          ) -> tuple[SimState, SimTrace]:
    final, ys, grad_pos = _sharded_scan(sim, state, pw, gammas, taus,
                                        None, arrays, horizon, tel, mr)
    loss, consensus, mean_norm = ys[:3]
    tcols = None if tel is None else tuple(c[grad_pos].T for c in ys[3:])
    return final, SimTrace(loss[grad_pos].T, consensus[grad_pos].T,
                           mean_norm[grad_pos].T, telemetry=tcols)


def _sharded_defense_impl(sim, state, pw, gammas, dk, arrays,
                          horizon: int, tel, mr: MeshReplay
                          ) -> tuple[SimState, SimTrace]:
    from ..core.defense import DefenseTrace

    final, ys, grad_pos = _sharded_scan(sim, state, pw, gammas, None,
                                        dk, arrays, horizon, tel, mr)
    loss, consensus, mean_norm, tau, rejn, quarn = ys[:6]
    tcols = None if tel is None else tuple(c[grad_pos].T for c in ys[6:])
    return final, SimTrace(
        loss[grad_pos].T, consensus[grad_pos].T, mean_norm[grad_pos].T,
        DefenseTrace(tau[grad_pos].T, rejn[grad_pos].T,
                     quarn[grad_pos].T),
        telemetry=tcols)


# (plain, donating) jit twins, created once per process — grids of worlds
# on one (mesh, lag) share ONE trace exactly like the single-device
# flavors (self, horizon, tel, mr are the static arguments)
_TWINS: dict = {}


def sharded_twin(flavor: str, donate: bool = False):
    """The jitted sharded scan for ``flavor`` in {'channel', 'defense'};
    ``Simulator._twin_fn`` resolves ``"@sharded_*"`` plan names here."""
    if not _TWINS:
        _TWINS["channel"] = _jit_pair(_sharded_channel_impl,
                                      static=(0, 6, 7, 8))
        _TWINS["defense"] = _jit_pair(_sharded_defense_impl,
                                      static=(0, 6, 7, 8))
    return _TWINS[flavor][1 if donate else 0]
