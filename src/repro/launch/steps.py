"""jit-able train / prefill / serve steps with explicit shardings.

These are the functions the dry-run lowers for every (arch x shape x mesh)
combination, and the ones launch/train.py executes for real.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .. import sharding as shardlib
from ..models.config import ModelConfig
from ..models.transformer import Model
from ..optim import clip_by_global_norm, sgd
from ..optim.optimizers import Optimizer, OptState
from . import shardings as S

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: OptState


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything needed to lower/run one (arch x shape) step."""

    fn: Callable                      # jit-able step function
    in_shardings: tuple
    state_specs: PyTree | None        # ShapeDtypeStructs of carried state
    donate_argnums: tuple = ()


# ---------------------------------------------------------------- factories

def make_train_step(model: Model, optimizer: Optimizer | None = None,
                    lr: float = 1e-2, remat: bool = True,
                    grad_clip: float | None = None,
                    num_microbatches: int = 1,
                    accum_dtype=None):
    """num_microbatches > 1 scans gradient accumulation over batch slices —
    activation temp memory scales with batch/num_microbatches.  Gradients
    accumulate in ``accum_dtype`` (default: the param dtype — an f32
    accumulator doubles the per-device gradient footprint of large MoEs)."""
    optimizer = optimizer or sgd()  # the paper's optimizer

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        if num_microbatches == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            # batch leaves arrive with a leading (num_microbatches,) axis —
            # shaped by the data pipeline / input specs, NOT reshaped here
            # (reshaping a data-sharded batch axis would force a reshard).
            def body(acc, micro):
                (loss, metrics), g = grads_of(state.params, micro)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                   acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype or p.dtype),
                state.params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, batch)
            grads = jax.tree.map(lambda g: (g / num_microbatches).astype(
                jax.tree.leaves(state.params)[0].dtype), grads)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        if grad_clip is not None:
            grads = clip_by_global_norm(grads, grad_clip)
        params, opt = optimizer.update(grads, state.opt, state.params,
                                       jnp.asarray(lr, jnp.float32))
        out_metrics = {"loss": loss, **{k: v for k, v in metrics.items()}}
        return TrainState(params, opt), out_metrics

    return train_step, optimizer


def make_prefill_step(model: Model):
    def prefill_step(params: PyTree, batch: dict):
        with shardlib.forward_only():
            logits, _, _ = model.forward(params, batch["inputs"])
        return logits

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params: PyTree, caches: list, inputs, pos):
        logits, caches = model.decode_step(params, inputs, pos, caches)
        return logits, caches

    return serve_step


# -------------------------------------------------------- dry-run assembly

def abstract_params(model: Model, key=None) -> PyTree:
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda: model.init(k))


def abstract_train_state(model: Model, optimizer: Optimizer) -> PyTree:
    params = abstract_params(model)
    opt = jax.eval_shape(lambda: optimizer.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params)))
    return TrainState(params, opt)


def bundle_for(cfg: ModelConfig, shape, mesh, rules,
               train_microbatches: int = 4,
               serve_param_mode: str = "fsdp") -> "LoweredSpec":
    """Build the (fn, shardings, arg specs) for one arch x shape on a mesh.

    serve_param_mode: "fsdp" shards serve params over data+model (memory-
    optimal but all-gathers weights layer-by-layer every decoded token);
    "tp_only" replicates serve params over data (TP-sharded only) — the
    decode-shape optimization validated in EXPERIMENTS.md §Perf."""
    from ..shapes import adapt_config, decode_input_specs, train_input_specs

    cfg = adapt_config(cfg, shape)
    model = Model(cfg)

    if shape.kind == "train":
        m = train_microbatches
        train_step, optimizer = make_train_step(model, num_microbatches=m)
        state = abstract_train_state(model, optimizer)
        batch = train_input_specs(cfg, shape)
        if m > 1:
            batch = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (m, s.shape[0] // m) + s.shape[1:], s.dtype), batch)
        state_sh = TrainState(
            S.param_shardings(state.params, mesh, rules),
            OptState(S.replicated(mesh),
                     S.param_shardings(state.opt.mu, mesh, rules),
                     None if state.opt.nu is None else
                     S.param_shardings(state.opt.nu, mesh, rules)))
        batch_sh = S.batch_shardings(batch, mesh, rules,
                                     leading_microbatch=(m > 1))
        return LoweredSpec(train_step, (state, batch),
                           (state_sh, batch_sh), donate=(0,))

    params = abstract_params(model)
    serve_rules = dict(rules)
    if serve_param_mode == "tp_only":
        serve_rules["fsdp"] = None
    params_sh = S.param_shardings(params, mesh, serve_rules)
    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        batch = train_input_specs(cfg, shape)
        batch = {"inputs": batch["inputs"]}
        return LoweredSpec(fn, (params, batch),
                           (params_sh, S.batch_shardings(batch, mesh, rules)),
                           donate=())

    # decode
    fn = make_serve_step(model)
    dspecs = decode_input_specs(cfg, shape)
    caches = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    caches_sh = S.cache_shardings(caches, mesh, rules)
    inputs_sh = S.batch_shardings({"inputs": dspecs["inputs"]}, mesh,
                                  rules)["inputs"]
    return LoweredSpec(
        fn, (params, caches, dspecs["inputs"], dspecs["pos"]),
        (params_sh, caches_sh, inputs_sh, S.replicated(mesh)), donate=(1,))


@dataclasses.dataclass(frozen=True)
class LoweredSpec:
    fn: Callable
    args: tuple                 # ShapeDtypeStruct pytrees
    arg_shardings: tuple
    donate: tuple

    def lower(self, mesh, rules):
        with shardlib.use_mesh(mesh, rules):
            jitted = jax.jit(self.fn, in_shardings=self.arg_shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.args)
