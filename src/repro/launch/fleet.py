"""Gossip-serving fleet: continuous-batching replicas that never stop
averaging (DESIGN.md §14).

The paper's core property — workers continuously process work while a p2p
averaging routine runs in parallel — applied to INFERENCE: every replica of
a ``GossipFleet`` is simultaneously

  (a) a continuous-batching decode server (one ``SlotScheduler`` per
      replica, all replicas stepped by ONE vmapped jitted decode over the
      fleet's (W, D) flat parameter bank), and
  (b) a gossip worker in a declarative ``World``: its parameters drift
      (online fine-tuning ticks or injected perturbations) and re-contract
      via the compiled A²CiD²/ADPSGD event schedule.

The fleet's parameter bank is ``FlatLayout``-packed, so the gossip side IS
``Simulator._round_channel`` — the per-event channel replay the whole test
pyramid pins — run one compiled round at a time on the single-leaf flat
buffer.  Stale partner reads, drops, Byzantine edges, and robust
aggregation (the PR 4/PR 6 channel machinery) therefore apply to the
serving fleet unchanged, and ``tests/test_fleet.py`` pins the fleet's bank
trajectory to ``Simulator.run_schedule`` on the identical schedule.

Timeline semantics: round r = [gossip events of schedule round r] -> [one
decode step on every alive, un-stalled replica] -> [drift tick folded into
the same gossip round].  Churn kills (``ChurnProcess`` / ``PhaseSwitch``
aliveness) evict the dead replica's queued AND in-flight requests for
re-admission on the least-loaded survivor — in-flight work restarts from
scratch (the KV rows died with the replica): graceful degradation counted
as ``restarts``, never loss.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import nullcontext
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.a2cid2 import consensus_distance
from ..core.flatbuf import FlatLayout
from ..core.simulator import Simulator
from ..core.world import World
from ..models.transformer import Model
from .batching import Request, SlotScheduler, gate_caches

# rng-stream tag for prompt-token draws — like the trace itself, identical
# across every fleet sharing a seed
_PROMPT_TAG = 0x9A0527


def make_fleet_step(model: Model, layout: FlatLayout) -> Callable:
    """One greedy decode step for ALL replicas: unpack the (W, D) bank and
    vmap the per-replica slot-batch step over the worker axis.

    (bank (W, D), caches [leaves (W, ...)], tokens (W, B, 1) i32,
     positions (W, B) i32, active (W, B) bool)
    -> (next_tokens (W, B) i32, new caches).
    """
    V = model.cfg.vocab_size

    def one(params, caches, tokens, positions, active):
        logits, new_caches = model.decode_step(params, tokens, positions,
                                               caches)
        nxt = jnp.argmax(logits[:, 0, :V], axis=-1)
        # inactive slots fed padding must not touch their cache state —
        # a stalled replica's whole batch goes through as padding while
        # its slots hold in-flight KV rows and recurrent states
        return (jnp.where(active, nxt, 0).astype(jnp.int32),
                gate_caches(active, caches, new_caches))

    def step(bank, caches, tokens, positions, active):
        return jax.vmap(one)(layout.unpack(bank), caches, tokens,
                             positions, active)

    return step


def flat_grad_fn(layout: FlatLayout, tree_grad_fn: Callable) -> Callable:
    """Lift a pytree-level grad_fn (the Simulator signature) onto flat
    (D,) rows — the online fine-tuning drift model."""

    def fn(xrow, key, wid):
        loss, grads = tree_grad_fn(layout.unpack_local(xrow), key, wid)
        return loss, layout.pack_local(grads)

    return fn


def _perturb_grad(xrow, key, wid):
    """Injected-perturbation drift: a unit Gaussian "gradient" per round —
    replicas perform independent random walks (scaled by the fleet's
    ``drift_scale`` via the simulator's gamma), which is what pulls their
    consensus apart unless gossip pulls it back."""
    return jnp.zeros((), jnp.float32), jax.random.normal(
        key, xrow.shape, xrow.dtype)


def _zero_grad(xrow, key, wid):
    return jnp.zeros((), jnp.float32), jnp.zeros_like(xrow)


@dataclasses.dataclass
class FleetReport:
    """What one ``GossipFleet.run`` produced."""

    requests_total: int
    completed: list                  # finished Requests (out/rounds filled)
    lost: int                        # never completed (drain cap / no fleet)
    restarted: int                   # churn re-admissions (degradation)
    latencies: np.ndarray            # (C,) decode-round latency per request
    ttft: np.ndarray                 # (C,) rounds from arrival to 1st token
    ttft_wait: np.ndarray            # (C,) rounds waiting for a slot
    ttft_decode: np.ndarray          # (C,) rounds streaming the prompt
    consensus: np.ndarray            # (R + drain,) consensus per round —
    #   gossip stops at round R, so the drain tail is constant by
    #   construction (the bank is frozen while queues empty)
    rounds: int                      # scheduled (gossip-active) rounds
    drain_rounds: int                # extra decode-only rounds to drain
    tokens_generated: int
    stall_skips: int                 # decode rounds skipped to pay comm debt
    wall_seconds: float
    final_bank: jax.Array            # (W, D) parameter bank after the run

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) \
            if self.latencies.size else float("nan")

    def ttft_percentile(self, p: float) -> float:
        return float(np.percentile(self.ttft, p)) \
            if self.ttft.size else float("nan")

    @property
    def tokens_per_round(self) -> float:
        total = self.rounds + self.drain_rounds
        return self.tokens_generated / max(total, 1)

    def summary(self, hist_bins: int = 12) -> dict:
        """JSON-able digest for ``BENCH_serve.json``."""
        lat = self.latencies
        if lat.size:
            hist, edges = np.histogram(lat, bins=hist_bins)
        else:
            hist, edges = np.zeros(hist_bins, int), np.arange(hist_bins + 1)
        return {
            "requests_total": self.requests_total,
            "completed": len(self.completed),
            "lost": self.lost,
            "restarted": self.restarted,
            "tokens_generated": self.tokens_generated,
            "throughput_tokens_per_round": self.tokens_per_round,
            "tokens_per_second": self.tokens_generated
            / max(self.wall_seconds, 1e-9),
            "latency_mean": float(lat.mean()) if lat.size else None,
            "latency_p50": self.percentile(50),
            "latency_p95": self.percentile(95),
            "latency_p99": self.percentile(99),
            "latency_hist": {"counts": [int(c) for c in hist],
                             "edges": [float(e) for e in edges]},
            "ttft_mean": float(self.ttft.mean()) if self.ttft.size
            else None,
            "ttft_p50": self.ttft_percentile(50),
            "ttft_p95": self.ttft_percentile(95),
            "ttft_p99": self.ttft_percentile(99),
            "ttft_wait_mean": float(self.ttft_wait.mean())
            if self.ttft_wait.size else None,
            "ttft_decode_mean": float(self.ttft_decode.mean())
            if self.ttft_decode.size else None,
            "stall_skips": self.stall_skips,
            "rounds": self.rounds,
            "drain_rounds": self.drain_rounds,
            "consensus_final": float(self.consensus[-1])
            if self.consensus.size else 0.0,
        }


class GossipFleet:
    """W model replicas that serve a shared request trace while gossiping.

    world — a ``World`` with ``serve=ServeLoad(...)``; its topology size is
      the fleet width W.  Channel/defense/algorithm/fault axes all apply.
    drift — "perturb" (Gaussian random walk, scale ``drift_scale`` per
      round), "none" (frozen params), or pass ``grad_fn`` (pytree-level
      Simulator signature) for real online fine-tuning ticks with learning
      rate ``drift_scale``.
    stall_per_event — decode-rounds of debt one gossip event costs its
      replica (communication steals compute); debt >= 1 skips that
      replica's next decode step.  0 = free communication.
    decode_step_fn — share one jitted ``make_fleet_step`` across fleets
      (the benchmark's 9 arms differ only in schedule data).
    """

    def __init__(self, model: Model, params, world: World, *,
                 max_batch: int = 4, max_len: int = 64,
                 drift: str = "perturb", drift_scale: float = 0.01,
                 grad_fn: Callable | None = None,
                 stall_per_event: float = 0.0,
                 accelerated: bool | None = None,
                 robust_clip: float | None = None,
                 robust_rule: str = "trim",
                 decode_step_fn: Callable | None = None):
        if world.serve is None:
            raise ValueError("GossipFleet needs a World with serve="
                             "ServeLoad(...) — the arrival trace axis")
        lo_p, hi_p = world.serve.prompt_len
        lo_g, hi_g = world.serve.gen_len
        if max_len < hi_p + hi_g + 1:
            raise ValueError(
                f"max_len={max_len} cannot hold a worst-case request "
                f"(prompt {hi_p} + gen {hi_g}); raise max_len or shrink "
                "the ServeLoad ranges")
        self.model = model
        self.world = world
        self.n = world.n
        self.max_batch = max_batch
        self.max_len = max_len
        self.stall_per_event = float(stall_per_event)

        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n,) + a.shape), params)
        self.layout = FlatLayout.from_pytree(stacked, stacked=True)
        self._bank0 = self.layout.pack(stacked)
        self._caches0 = model.init_cache(max_batch, max_len)

        # gossip dynamics come from the fault-free twin: chi of a churned
        # world is only defined per phase, but the fleet's mixing dynamic
        # is a design-time constant of the NOMINAL topology
        nominal = dataclasses.replace(
            world, faults=(),
            workers=dataclasses.replace(world.workers, active=None))
        algo_params = nominal.algorithm_params(accelerated)

        if grad_fn is not None:
            drift_fn = flat_grad_fn(self.layout, grad_fn)
        elif drift == "perturb":
            drift_fn = _perturb_grad
        elif drift == "none":
            drift_fn = _zero_grad
        else:
            raise ValueError(f"drift must be 'perturb'/'none' or pass "
                             f"grad_fn, got {drift!r}")
        gamma = float(drift_scale) if (grad_fn is not None
                                       or drift == "perturb") else 0.0
        self.sim = Simulator(grad_fn=drift_fn, params=algo_params,
                             gamma=gamma, robust_clip=robust_clip,
                             robust_rule=robust_rule)
        self._decode_step = decode_step_fn if decode_step_fn is not None \
            else jax.jit(make_fleet_step(model, self.layout))

    # ----------------------------------------------------------------- run
    def _route(self, scheds: list[SlotScheduler], alive: np.ndarray,
               reqs: list[Request], unrouted: list[Request]) -> None:
        """Assign each request to the least-loaded alive replica (ties to
        the lowest id); park it in ``unrouted`` when nobody is alive."""
        for req in reqs:
            cand = [w for w in range(self.n) if alive[w]]
            if not cand:
                unrouted.append(req)
                continue
            w = min(cand, key=lambda i: (scheds[i].load(), i))
            scheds[w].submit(req)

    def run(self, rounds: int, seed: int = 0,
            max_drain_rounds: int = 2000, tracer=None,
            metrics=None) -> FleetReport:
        """Serve the world's arrival trace for ``rounds`` gossip rounds.

        tracer — optional ``analysis.SpanTracer``: emits ``fleet.round``
          and ``fleet.decode`` spans, queue-depth / slot-occupancy /
          consensus counter tracks, ``churn.kill`` instants, and one
          ``fleet.drain`` span (DESIGN.md §15).
        metrics — optional ``analysis.MetricsRegistry``: request/token/
          restart counters plus TTFT and latency histograms, filled once
          at the end of the run.
        """
        world, model = self.world, self.model
        sched = world.compile(rounds, seed)
        R = sched.rounds
        trace = world.serve.sample_trace(R, seed)
        vocab = model.cfg.vocab_size
        prng = np.random.default_rng(
            np.random.SeedSequence([int(seed), _PROMPT_TAG]))
        requests = [
            Request(uid=i,
                    prompt=prng.integers(0, vocab, size=int(pl)
                                         ).astype(np.int32),
                    max_new=int(gl), arrive_round=int(ar))
            for i, (ar, pl, gl) in enumerate(zip(
                trace.arrival_round, trace.prompt_len, trace.gen_len))]

        arrays, horizon = self.sim.channel_reference_arrays(sched)
        arrays = [np.asarray(a) for a in arrays]
        alive = np.asarray(sched.alive_arr())
        idx = np.arange(self.n)
        events = ((sched.partners != idx[None, None, :])
                  & sched.event_mask[:, :, None]).sum(axis=1)  # (R, n)

        bank = self._bank0
        ring = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (horizon,) + a.shape), bank) \
            if horizon else None
        carry = (bank, jnp.array(bank), jnp.zeros((self.n,)), ring,
                 jax.random.PRNGKey(seed))
        round_fn = jax.jit(partial(self.sim._round_channel, horizon))
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n,) + a.shape),
            self._caches0)

        scheds = [SlotScheduler(self.max_batch, self.max_len)
                  for _ in range(self.n)]
        unrouted: list[Request] = []
        completed: list[Request] = []
        consensus: list = []
        debt = np.zeros(self.n)
        stall_skips = 0
        cursor = 0
        prev_alive = np.ones(self.n, bool)
        t0 = time.time()

        def decode_round(decode_mask: np.ndarray, r: int):
            nonlocal caches
            toks = np.zeros((self.n, self.max_batch), np.int32)
            pos = np.zeros((self.n, self.max_batch), np.int32)
            act = np.zeros((self.n, self.max_batch), bool)
            for w in range(self.n):
                if not decode_mask[w]:
                    continue
                tw, pw, aw = scheds[w].prepare(r)
                toks[w], pos[w], act[w] = tw, pw, aw
            if not act.any():
                return False
            with (tracer.span("fleet.decode", process="fleet",
                              lane="decode",
                              args={"round": r,
                                    "active_slots": int(act.sum())})
                  if tracer is not None else nullcontext()):
                nxt, caches = self._decode_step(
                    carry[0], caches, jnp.asarray(toks)[:, :, None],
                    jnp.asarray(pos), jnp.asarray(act))
                nxt = np.asarray(jax.device_get(nxt))
            for w in range(self.n):
                if decode_mask[w]:
                    completed.extend(scheds[w].absorb(nxt[w], r))
            return True

        for r in range(R):
            t_round = tracer.now_us() if tracer is not None else 0.0
            al = alive[r]
            # churn: evict the newly-dead replicas' work to survivors
            evicted: list[Request] = []
            for w in range(self.n):
                if prev_alive[w] and not al[w]:
                    evicted.extend(scheds[w].evict_all())
                    debt[w] = 0.0
                    if tracer is not None:
                        tracer.instant("churn.kill", process="fleet",
                                       lane="churn",
                                       args={"worker": w, "round": r})
            # arrivals of round r, then re-admissions (and anything parked
            # while the whole fleet was down)
            arrivals = []
            while cursor < len(requests) \
                    and requests[cursor].arrive_round <= r:
                arrivals.append(requests[cursor])
                cursor += 1
            parked, unrouted = unrouted, []
            self._route(scheds, al, arrivals + evicted + parked, unrouted)

            # gossip events + drift tick of round r on the flat bank
            carry, mets = round_fn(carry, tuple(a[r] for a in arrays))
            consensus.append(mets["consensus"])

            # decode: alive replicas that aren't paying communication debt
            debt[al] += self.stall_per_event * events[r][al]
            decode_mask = al & (debt < 1.0)
            stalled = al & ~decode_mask
            debt[stalled] -= 1.0
            stall_skips += int(stalled.sum())
            decode_round(decode_mask, r)
            prev_alive = al
            if tracer is not None:
                tracer.complete(
                    "fleet.round", t_round, tracer.now_us() - t_round,
                    process="fleet", lane="rounds",
                    args={"round": r, "alive": int(al.sum()),
                          "stalled": int(stalled.sum())})
                tracer.counter(
                    "fleet.queue",
                    {"queue_depth": sum(len(scheds[w].queue)
                                        for w in range(self.n))
                     + len(unrouted),
                     "slot_occupancy": sum(
                         s.req is not None for w in range(self.n)
                         for s in scheds[w].slots)},
                    process="fleet")
                tracer.counter("fleet.consensus",
                               {"consensus": float(mets["consensus"])},
                               process="fleet")

        # drain: gossip stopped, decode-only rounds until every queue and
        # slot is empty (aliveness frozen at the last scheduled round)
        drain = 0
        al = alive[-1] if R else np.ones(self.n, bool)
        t_drain = tracer.now_us() if tracer is not None else 0.0
        while drain < max_drain_rounds:
            if not unrouted and not any(
                    scheds[w].pending() for w in range(self.n) if al[w]):
                break
            if not al.any():
                break  # nobody alive: parked requests are unrecoverable
            parked, unrouted = unrouted, []
            self._route(scheds, al, parked, unrouted)
            if not decode_round(al, R + drain) and not unrouted:
                break
            drain += 1
        if tracer is not None:
            tracer.complete("fleet.drain", t_drain,
                            tracer.now_us() - t_drain, process="fleet",
                            lane="rounds", args={"drain_rounds": drain})
        # the bank is frozen once gossip stops, so the drain tail of the
        # consensus trace is one value repeated — computed, not assumed
        if drain:
            consensus.extend([consensus_distance(carry[0])] * drain)

        wall = time.time() - t0
        lost = len(requests) - len(completed)
        restarted = sum(q.restarts for q in requests)
        lat = np.asarray([q.done_round - q.arrive_round + 1
                          for q in completed], np.float64)
        ttft = np.asarray([q.first_token_round - q.arrive_round + 1
                           for q in completed], np.float64)
        ttft_wait = np.asarray([q.admit_round - q.arrive_round
                                for q in completed], np.float64)
        ttft_decode = np.asarray([q.first_token_round - q.admit_round + 1
                                  for q in completed], np.float64)
        tokens = sum(len(q.out) for q in completed)
        if metrics is not None:
            metrics.counter("fleet_requests_total",
                            "requests in the arrival trace"
                            ).inc(len(requests))
            metrics.counter("fleet_completed_total",
                            "requests served to completion"
                            ).inc(len(completed))
            metrics.counter("fleet_restarts_total",
                            "churn re-admissions").inc(restarted)
            metrics.counter("fleet_tokens_total",
                            "tokens generated").inc(tokens)
            metrics.counter("fleet_stall_skips_total",
                            "decode rounds skipped to pay comm debt"
                            ).inc(stall_skips)
            metrics.gauge("fleet_drain_rounds",
                          "decode-only rounds after the schedule"
                          ).set(drain)
            h = metrics.histogram(
                "fleet_ttft_rounds", "rounds from arrival to first token",
                buckets=(1, 2, 4, 8, 16, 32, 64))
            for v in ttft:
                h.observe(v)
            h = metrics.histogram(
                "fleet_latency_rounds", "rounds from arrival to last token",
                buckets=(2, 4, 8, 16, 32, 64, 128))
            for v in lat:
                h.observe(v)
        return FleetReport(
            requests_total=len(requests), completed=completed, lost=lost,
            restarted=restarted, latencies=lat, ttft=ttft,
            ttft_wait=ttft_wait, ttft_decode=ttft_decode,
            consensus=np.asarray(jax.device_get(consensus), np.float64),
            rounds=R, drain_rounds=drain,
            tokens_generated=tokens,
            stall_skips=stall_skips, wall_seconds=wall, final_bank=carry[0])
