"""Serving launcher: batched greedy decoding against the KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models.transformer import Model


def generate(model: Model, params, prompts: jax.Array, gen: int,
             temperature: float = 0.0, key=None):
    """prompts: (B, P) int32 — returns (B, P+gen) generated ids."""
    cfg = model.cfg
    B, P = prompts.shape
    total = P + gen
    caches = model.init_cache(B, total)
    dec = jax.jit(model.decode_step)

    # chunked prefill: ONE dispatch for the whole prompt instead of P
    # device round-trips, exact to the old token-by-token loop (the scan
    # body IS decode_step; tests/test_serve.py pins the ids)
    toks = prompts
    logits, caches = jax.jit(model.prefill)(params, toks, caches)
    key = key if key is not None else jax.random.PRNGKey(0)
    out = [toks]
    cur = None
    for t in range(P, total):
        lg = logits[:, 0, : cfg.vocab_size]
        if temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            cur = jnp.argmax(lg, axis=-1)
        cur = cur[:, None].astype(jnp.int32)
        out.append(cur)
        logits, caches = dec(params, cur, jnp.int32(t), caches)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    key = jax.random.PRNGKey(args.seed + 1)
    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, jnp.int32)
    else:
        raise SystemExit(f"{args.arch} has an embeddings frontend; serve "
                         "demo supports token models")
    t0 = time.time()
    out = generate(model, params, prompts, args.gen,
                   temperature=args.temperature)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] {args.arch}: generated {n_new} tokens in {dt:.1f}s "
          f"({n_new/dt:.1f} tok/s, batch {args.batch})")
    print("sample ids:", jax.device_get(out[0, -16:]).tolist())


if __name__ == "__main__":
    main()
