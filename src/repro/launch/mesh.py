"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (jax locks the device count on first init, and only
launch/dryrun.py is allowed to fake 512 host devices).
"""
from __future__ import annotations

import jax

from .. import sharding

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_gossip_mesh(n_workers: int = 8, data: int = 8, model: int = 8):
    """Decentralized mesh: `n_workers` pod-slices on a gossip graph, each an
    FSDP(data) x TP(model) synchronous island.  Default (8, 8, 8) = 512 chips,
    8 workers — a ring of 8 has chi1 ~ 3.5 >> chi2 ~ 0.9, so A2CiD2 bites."""
    return jax.make_mesh((n_workers, data, model), ("worker", "data", "model"))


def rules_for(mesh) -> dict:
    axes = mesh.axis_names
    if "pod" in axes:
        return dict(sharding.MULTI_POD_RULES)
    if "worker" in axes:
        return dict(sharding.GOSSIP_RULES)
    return dict(sharding.SINGLE_POD_RULES)


def mesh_devices(mesh) -> int:
    return mesh.devices.size
