"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (jax locks the device count on first init, and only
launch/dryrun.py is allowed to fake 512 host devices).
"""
from __future__ import annotations

import jax

from .. import sharding

# TPU v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_gossip_mesh(n_workers: int = 8, data: int = 8, model: int = 8):
    """Decentralized mesh: `n_workers` pod-slices on a gossip graph, each an
    FSDP(data) x TP(model) synchronous island.  Default (8, 8, 8) = 512 chips,
    8 workers — a ring of 8 has chi1 ~ 3.5 >> chi2 ~ 0.9, so A2CiD2 bites."""
    return jax.make_mesh((n_workers, data, model), ("worker", "data", "model"))


def make_replay_mesh(n_shards: int | None = None, *, axis: str = "worker"):
    """Host-aware 1-D replay mesh: the sharded worlds replay
    (``launch/mesh_replay.py``) splits the worker axis of the flat
    (B, W, D) gossip banks over this mesh's devices.

    Sized from ``jax.local_device_count()`` — never a hardcoded chip
    count like ``make_gossip_mesh``'s 512 — so the same call works on one
    CPU, a TPU host, or a forced-host-device test process.  Only
    ``launch/dryrun.py`` may fake the device count; this function always
    reports what the runtime actually has."""
    avail = jax.local_device_count()
    if n_shards is None:
        n_shards = avail
    if not 1 <= n_shards <= avail:
        raise ValueError(f"make_replay_mesh needs 1 <= n_shards <= "
                         f"{avail} local devices, got {n_shards}")
    return jax.make_mesh((n_shards,), (axis,),
                         devices=jax.local_devices()[:n_shards])


def rules_for(mesh) -> dict:
    axes = mesh.axis_names
    if "pod" in axes:
        return dict(sharding.MULTI_POD_RULES)
    if "worker" in axes:
        # a pure replay mesh (worker axis only) shards the flat worker
        # banks and replicates everything else; a (worker, data, model)
        # gossip mesh keeps the model-sharding rules
        if axes == ("worker",):
            return dict(sharding.REPLAY_RULES)
        return dict(sharding.GOSSIP_RULES)
    return dict(sharding.SINGLE_POD_RULES)


def mesh_devices(mesh) -> int:
    return mesh.devices.size
