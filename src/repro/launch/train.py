"""Training launcher.

Three modes, CPU-runnable at reduced scale and mesh-ready at full scale:

  # single-process decentralized simulation (the faithful paper repro)
  PYTHONPATH=src python -m repro.launch.train --mode sim --arch nano-lm \
      --workers 8 --graph ring --acid --steps 200

  # data-parallel synchronous training (AR-SGD reference)
  PYTHONPATH=src python -m repro.launch.train --mode sync --arch nano-lm \
      --steps 100

Full-scale meshes are exercised by launch/dryrun.py (this container has one
real CPU device).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import save
from ..configs import get_config
from ..core import (Simulator, allreduce_sgd, build_graph, make_schedule,
                    params_from_graph)
from ..data import LMTaskStream, WorkerStream
from ..models.transformer import Model
from ..optim import sgd
from .steps import TrainState, make_train_step


def build_model(arch: str, reduced: bool):
    cfg = get_config(arch, reduced=reduced)
    return cfg, Model(cfg)


def run_sim(args) -> None:
    """Decentralized asynchronous training via the event simulator."""
    cfg, model = build_model(args.arch, reduced=not args.full)
    stream = LMTaskStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          batch_size=args.batch_size, seed=args.seed)
    ws = WorkerStream(base_seed=args.seed)

    def grad_fn(params, key, wid):
        batch = stream.sample(jax.random.fold_in(key, wid))
        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss
        return jax.value_and_grad(loss_fn)(params)

    graph = build_graph(args.graph, args.workers)
    acid = params_from_graph(graph, accelerated=args.acid)
    sim = Simulator(grad_fn, acid, gamma=args.lr)
    params0 = model.init(jax.random.PRNGKey(args.seed))
    state = sim.init(params0, args.workers, jax.random.PRNGKey(args.seed + 1))
    sched = make_schedule(graph, rounds=args.steps,
                          comms_per_grad=args.comms_per_grad, seed=args.seed)
    t0 = time.time()
    state, trace = sim.run_schedule(state, sched)
    dt = time.time() - t0
    print(f"[train/sim] {args.workers} workers, {args.graph} graph, "
          f"acid={args.acid}: {args.steps} rounds in {dt:.1f}s")
    print(f"  final loss {float(trace.loss[-1]):.4f}  "
          f"consensus {float(trace.consensus[-1]):.3e}  "
          f"bayes-CE {stream.bayes_ce():.4f}")
    if args.ckpt:
        save(args.ckpt, args.steps, jax.device_get(state.x))
        print(f"  checkpoint -> {args.ckpt}")


def run_sync(args) -> None:
    """Synchronous single-device training (AR-SGD semantics)."""
    cfg, model = build_model(args.arch, reduced=not args.full)
    stream = LMTaskStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          batch_size=args.batch_size, seed=args.seed)
    train_step, optimizer = make_train_step(model, sgd(), lr=args.lr,
                                            remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))
    state = TrainState(params, optimizer.init(params))
    step = jax.jit(train_step)
    key = jax.random.PRNGKey(args.seed + 1)
    t0 = time.time()
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        batch = stream.sample(sub)
        state, metrics = step(state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"[train/sync] step {i:5d} loss {float(metrics['loss']):.4f}")
    print(f"[train/sync] {args.steps} steps in {time.time()-t0:.1f}s, "
          f"bayes-CE {stream.bayes_ce():.4f}")
    if args.ckpt:
        save(args.ckpt, args.steps, jax.device_get(state.params))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "sync"), default="sim")
    ap.add_argument("--arch", default="nano-lm")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config")
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--graph", default="ring",
                    choices=("ring", "complete", "exponential", "star",
                             "torus"))
    ap.add_argument("--acid", action="store_true",
                    help="enable the A2CiD2 continuous momentum")
    ap.add_argument("--comms-per-grad", type=float, default=1.0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", type=str, default=None)
    args = ap.parse_args()
    (run_sim if args.mode == "sim" else run_sync)(args)


if __name__ == "__main__":
    main()
