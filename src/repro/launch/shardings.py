"""Sharding specs for params, optimizer state, caches and batches.

Strategy (single- and multi-pod): FSDP over "data" (every matrix's input dim)
x TP over "model" (heads / ffn / vocab / experts), batch over ("pod","data").
The gossip mesh adds a "worker" axis that parameters never use — each worker
slice holds a full replica, FSDP/TP-sharded over the remaining axes.

Every axis assignment is divisibility-checked against the mesh; a dim that
does not divide falls back to replication for that axis (recorded by the
dry-run as part of memory analysis).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on the param path, spec by *logical* axes per trailing dims)
# 2-D default:  in-dim -> fsdp("data"), out-dim -> tp("model")
# logical axes: "fsdp" -> data (droppable for serving), "tp" -> model
_PARAM_RULES: list[tuple[str, tuple] ] = [
    (r"embed/tok$",                 ("tp", "fsdp")),      # (V, D)
    (r"head/w$",                    ("fsdp", "tp")),      # (D, V)
    (r"(wq|wk|wv|w_uq|w_uk|w_uv)$", ("fsdp", "tp")),
    (r"(wo|out_proj|w_out|w_down)$", ("tp", "fsdp")),
    (r"(w_up|w_gate)$",             ("fsdp", "tp")),
    (r"(w_in_rnn|w_in_gate|in_proj|w_a|w_x)$", ("fsdp", "tp")),
    (r"(w_dq|w_dkv)$",              ("fsdp", None)),      # latent kept whole
    (r"router$",                    ("fsdp", None)),      # (D, E) E small
    (r"conv_w$",                    (None, "tp")),        # (W, C)
    (r"mtp/proj$",                  ("fsdp", "tp")),
]
# MoE expert tensors are 3-D (E, in, out): expert-parallel over "model",
# FSDP over "data" on the in-dim.
_MOE_RULES: list[tuple[str, tuple]] = [
    (r"(moe_up|moe_gate|moe_down)$", ("expert", "fsdp", None)),
]


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def _resolve(logical: Optional[str], mesh: Mesh, rules: dict):
    if logical is None:
        return None
    if logical in rules:
        return rules[logical]
    # literal mesh axis names pass through ("data"/"model" in the rules above)
    return logical if logical in mesh.axis_names else None


def _fit(spec: tuple, shape: tuple, mesh: Mesh, rules: dict) -> P:
    """Map logical spec -> mesh axes, dropping axes that don't divide."""
    out = []
    for logical, dim in zip(spec, shape):
        ax = _resolve(logical, mesh, rules)
        if ax is None:
            out.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= _axis_size(mesh, a)
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_spec(path_str: str, leaf, mesh: Mesh, rules: dict) -> P:
    shape = tuple(leaf.shape)
    nd = len(shape)
    for pat, spec in _MOE_RULES:
        if re.search(pat, path_str) and nd >= 3:
            lead = nd - 3
            return _fit((None,) * lead + spec, shape, mesh, rules)
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_str) and nd >= 2:
            lead = nd - 2
            return _fit((None,) * lead + spec, shape, mesh, rules)
    # norms / biases / 1-D leaves and anything unmatched: replicate
    return P()


def param_shardings(params: PyTree, mesh: Mesh, rules: dict) -> PyTree:
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf, mesh,
                                              rules))
    return jax.tree_util.tree_map_with_path(one, params)


def stacked_param_shardings(params: PyTree, mesh: Mesh, rules: dict,
                            axis: str = "worker") -> PyTree:
    """Shardings for worker-stacked params: leading dim over ``axis``, the
    rest per the normal param rules (used by StackedGossipTrainer)."""
    def one(path, leaf):
        inner = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
        base = param_spec(_path_str(path), inner, mesh, rules)
        lead = axis if leaf.shape[0] % mesh.shape[axis] == 0 else None
        return NamedSharding(mesh, P(lead, *base))
    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch: PyTree, mesh: Mesh, rules: dict,
                    leading_microbatch: bool = False) -> PyTree:
    """Batch arrays: shard the batch dim over the batch axes (if divisible).
    With ``leading_microbatch`` the batch dim is dim 1 (dim 0 = microbatch
    slices, scanned sequentially — never sharded)."""
    def one(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, P())
        prefix = (None, "batch") if leading_microbatch else ("batch",)
        spec = _fit(prefix + (None,) * (len(shape) - len(prefix)), shape,
                    mesh, rules)
        return NamedSharding(mesh, spec)
    return jax.tree.map(one, batch)


# cache leaves: (B, S, KV, hd) / (B, S, rank) -> batch over data, seq over
# model; state leaves (B, H, P, N) / (B, W) -> batch over data, dim 1 over
# model; slot_pos replicated.  Leading stacked-layer axis handled by ndim.
def cache_spec(path_str: str, leaf, mesh: Mesh, rules: dict) -> P:
    shape = tuple(leaf.shape)
    nd = len(shape)
    if path_str.endswith("slot_pos") or nd <= 1:
        return P()
    base_nd = nd - 1  # caches are stacked over layers (leading axis)
    if path_str.endswith("conv") and base_nd >= 3:        # (B, W-1, C)
        spec = (None, "batch", None, "heads") + (None,) * (base_nd - 3)
    elif re.search(r"(^|/)(k|v|c|k_rope|h)$", path_str) and base_nd >= 2:
        # (B, S, ...) kv caches: seq over "model"; (B, H/W, ...) states:
        # heads/width over "model" — both are dim 1 of the per-layer leaf
        spec = (None, "batch", "heads") + (None,) * (base_nd - 2)
    else:
        spec = (None, "batch") + (None,) * (base_nd - 1)
    return _fit(spec, shape, mesh, rules)


def cache_shardings(cache: PyTree, mesh: Mesh, rules: dict) -> PyTree:
    def one(path, leaf):
        return NamedSharding(mesh, cache_spec(_path_str(path), leaf, mesh,
                                              rules))
    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
