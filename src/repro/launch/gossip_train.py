"""Decentralized gossip training step — the paper's technique on a TPU mesh.

Mesh: ("worker", "data", "model").  Each worker slice holds a full replica
(FSDP over "data" x TP over "model" inside); A2CiD2 gossip runs across the
"worker" axis:

  super-step =  (1) lazy continuous mixing exp(dt*A) of {x, x~}
                (2) one local SGD step on the worker's own batch shard
                (3) E gossip events: random matching from the static bank,
                    p2p parameter averaging via collective_permute

With eta=0, alpha=alpha_t=1/2 and no momentum buffer updates this is the
asynchronous baseline (Eq 6, ~AD-PSGD); with Prop 3.6 parameters it is
A2CiD2.  ``ar_train_step`` (worker-axis all-reduce each step) is the AR-SGD
baseline at equal mesh.

The asynchronous event *schedule* (who gossips when, per-worker event clocks)
is sampled with jax.random inside the step — matching ``events.make_schedule``
in the laws the consensus theory consumes (see DESIGN.md on the SPMD
event-driven adaptation): per-worker gradient clocks are the same Poisson
rate processes (Exp(1)/rate_i gaps here vs. tick thinning there, DESIGN.md
§8), gossip events arrive with Exp inter-event gaps at the declared
per-step intensity, and matchings are drawn with the bank's per-edge rates.
The joint matching law differs — the in-step sampler draws whole matchings
from the static edge-coloring bank, the schedule sampler greedy-maximal
matchings from random edge orders — so only these marginals, not the full
joint distribution, are shared.  ``tests/test_algorithms.py`` pins exactly
which laws agree (KS on the clock gaps, chi-squared on the per-edge rates).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.a2cid2 import A2CiD2Params
from ..core.channel import ChannelModel
from ..core.gossip import GossipMixer, check_mesh_channel
from ..core.graphs import Graph
from ..optim.optimizers import Optimizer

PyTree = Any


def _comms_per_step(world) -> int:
    """The world's effective comm intensity as the trainers' whole-event
    count — an ``Algorithm`` with a decoupled gossip clock (DADAO)
    replaces ``comms_per_grad`` here exactly as it does in
    ``World.compile``.

    The mesh trainers run an integer number of gossip events per super-step,
    so a fractional declared rate cannot be honored silently."""
    cps = float(world.comms_per_grad)
    if world.algorithm is not None:
        cps = world.algorithm.comm_rate(cps)
    if abs(cps - round(cps)) > 1e-9:
        raise ValueError(
            f"the world's effective comms per step is {cps}, not an "
            "integer; the mesh trainers run a whole number of gossip "
            "events per step — pass comms_per_step explicitly to choose "
            "one")
    return int(round(cps))


def _world_dynamics(world, accelerated: bool | None):
    """Resolve a World's algorithm spec to the trainers' (graph, acid,
    grad_rates) triple.

    ``accelerated=None`` takes the algorithm's own arm — canonical
    accelerated A²CiD² when the world declares no algorithm, which is the
    trainers' historical default; a bool overrides the arm (the
    benchmarks' base/accelerated sweep).  A DADAO decoupled gradient
    clock folds into the per-worker rate vector: ``grad_rate`` scales
    every worker's Poisson rate, the time-dilation realization of the
    same rate process the compiled schedule expresses by tick thinning
    (DESIGN.md §8/§13).  Its gossip clock feeds ``_comms_per_step``.
    """
    from ..core.a2cid2 import Algorithm

    graph = world.static_graph()
    algo = world.algorithm if world.algorithm is not None else Algorithm()
    if accelerated is not None:
        algo = dataclasses.replace(algo, accelerated=bool(accelerated))
    acid = algo.params_for(graph)
    grad_rates = world.workers.grad_rates
    if algo.kind == "dadao" and float(algo.grad_rate) != 1.0:
        base = grad_rates if grad_rates is not None else (1.0,) * graph.n
        grad_rates = tuple(float(r) * float(algo.grad_rate) for r in base)
    return graph, acid, grad_rates


def _rate_vec(grad_rates, n: int) -> jax.Array | None:
    """Validated per-worker gradient-rate vector (None = homogeneous).

    A mis-sized tuple must raise here: a short vector would otherwise
    gather with silent index clamping inside the step."""
    if grad_rates is None:
        return None
    if len(grad_rates) != n:
        raise ValueError(f"grad_rates must have {n} entries, "
                         f"got {len(grad_rates)}")
    return jnp.asarray(grad_rates, jnp.float32)


class GossipTrainState(NamedTuple):
    params: PyTree       # x   — per-worker replica (sharded over data/model)
    momentum: PyTree     # x~  — the A2CiD2 continuous-momentum buffer
    opt: Any             # local optimizer state (SGD momentum)
    t_last: jax.Array    # worker-local event clock
    key: jax.Array
    # bounded-staleness permute ring (gossip.DelayRing) when the channel
    # carries a DelayProcess; None otherwise — a defaulted tail field so
    # every existing 5-tuple construction/unpacking site stays valid
    ring: Any = None


@dataclasses.dataclass(frozen=True)
class GossipTrainer:
    """Builds the shard_map'd decentralized step for a (worker, data, model)
    mesh.  loss_fn(params, batch) -> (loss, metrics)."""

    loss_fn: Callable
    optimizer: Optimizer
    graph: Graph
    acid: A2CiD2Params
    lr: float = 0.1
    comms_per_step: int = 1
    axis_name: str = "worker"
    backend: str = "auto"  # fused gossip-kernel backend for the event loop
    # per-worker gradient rates (straggler clocks): worker i's grad events
    # arrive at Poisson rate grad_rates[i] — its inter-event gaps are
    # Exp(1)/rate, the time-dilation realization of the same rate process
    # the simulator expresses by tick thinning (DESIGN.md §8).  None = all 1.
    grad_rates: tuple[float, ...] | None = None
    # unreliable channel (DESIGN.md §10): mesh trainers model the adversary
    # (static per-matching corruption) and drop axes; message delay is
    # simulator-only and rejected at construction.  robust_clip/robust_rule
    # engage the trimmed/clipped m-term defense in the channel kernel.
    channel: ChannelModel | None = None
    robust_clip: float | None = None
    robust_rule: str = "trim"

    def __post_init__(self):
        # the mixer carries the bounded-staleness permute ring, so a
        # DelayProcess is routed (supported kinds) instead of rejected
        check_mesh_channel(self.channel, permute_ring=True)

    def _mixer(self) -> GossipMixer:
        return GossipMixer(self.graph, self.acid, self.axis_name,
                           backend=self.backend, channel=self.channel,
                           robust_clip=self.robust_clip,
                           robust_rule=self.robust_rule)

    @classmethod
    def from_world(cls, world, loss_fn: Callable, optimizer: Optimizer, *,
                   accelerated: bool | None = None, **kw) -> "GossipTrainer":
        """Build the trainer from a declarative ``core.world.World``.

        The world must be static (fault-free Graph topology —
        ``World.static_graph``); its link model sets the gossip graph's edge
        rates, its worker model the straggler clocks, its effective comm
        intensity the per-step gossip-event count, and the dynamics come
        from ``world.algorithm`` (``accelerated`` overrides the arm; None =
        the algorithm's own, canonical accelerated A²CiD² when the world
        declares none — see ``_world_dynamics``).  A ``world.channel``
        rides along (adversary + drops; delayed worlds are rejected —
        ``check_mesh_channel``).
        """
        graph, acid, grad_rates = _world_dynamics(world, accelerated)
        if "comms_per_step" not in kw:  # explicit override skips the check
            kw["comms_per_step"] = _comms_per_step(world)
        kw.setdefault("channel", world.channel)
        return cls(loss_fn, optimizer, graph, acid,
                   grad_rates=grad_rates, **kw)

    def init(self, params: PyTree, key: jax.Array) -> GossipTrainState:
        delayed = self.channel is not None and self.channel.horizon > 0
        return GossipTrainState(
            params=params,
            momentum=jax.tree.map(jnp.copy, params),
            opt=self.optimizer.init(params),
            t_last=jnp.zeros(()),
            key=key,
            ring=self._mixer().init_ring(params) if delayed else None,
        )

    # ------------------------------------------------------------- the step
    def make_step(self, mesh):
        mixer = self._mixer()
        n_events = self.comms_per_step
        rates = _rate_vec(self.grad_rates, self.graph.n)

        def step(state: GossipTrainState, batch: PyTree):
            k_st = None
            if mixer.delay is not None:
                # extra split only on delayed channels — a delay-free
                # trainer keeps the seeded event stream bit-for-bit
                key, k_st = jax.random.split(state.key)
            else:
                key = state.key
            key, k_ev, k_dt = jax.random.split(key, 3)
            x, xt = state.params, state.momentum

            # (1) + (2): gradient event at this worker's clock.  dt ~ Exp(1)
            # models the unit-rate gradient Poisson process, independently
            # per worker (key folded with the worker index); gossip events
            # (k_ev) are global and shared by construction.
            wid = jax.lax.axis_index(self.axis_name)
            dt_grad = jax.random.exponential(jax.random.fold_in(k_dt, wid), ())
            if rates is not None:
                dt_grad = dt_grad / rates[wid]
            x, xt = mixer.mix(x, xt, dt_grad)
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(x, batch)
            # local SGD step updates BOTH buffers (Eq 4)
            x, opt = self.optimizer.update(grads, state.opt, x,
                                           jnp.asarray(self.lr, jnp.float32))
            delta = jax.tree.map(lambda new, old: new - old, x, state.params)
            xt = jax.tree.map(lambda t, d: t + d, xt, delta)

            # (3): E gossip events with Exp inter-event gaps; a delayed
            # channel snapshots the post-gradient replica onto this
            # worker's permute ring first (the simulator's grad-tick
            # cadence), then serves stale sends from it
            idxs, dts = mixer.sample_event_batch(k_ev, n_events)
            ring = stale = None
            if mixer.delay is not None:
                ring = mixer.push_ring(state.ring, x)
                stale = mixer.sample_stale(k_st, n_events)
            x, xt = mixer.gossip_events(x, xt, idxs, dts, ring=ring,
                                        stale=stale)

            new_state = GossipTrainState(x, xt, opt,
                                         state.t_last + dt_grad + jnp.sum(dts),
                                         key, ring)
            return new_state, {"loss": jax.lax.pmean(loss, self.axis_name),
                               **metrics}

        return step

    def make_ar_step(self):
        """AR-SGD baseline: synchronous all-reduce of grads over workers."""

        def step(state: GossipTrainState, batch: PyTree):
            key, _ = jax.random.split(state.key)
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(state.params, batch)
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g, self.axis_name), grads)
            x, opt = self.optimizer.update(grads, state.opt, state.params,
                                           jnp.asarray(self.lr, jnp.float32))
            return GossipTrainState(x, x, opt, state.t_last + 1.0, key), \
                {"loss": jax.lax.pmean(loss, self.axis_name), **metrics}

        return step

    # -------------------------------------------------------------- wiring
    def shard_mapped_step(self, mesh, step_fn, state_specs, batch_spec):
        """Wrap a step in shard_map over the worker axis (data/model axes are
        handled by the in-shard sharding of params/batch via `auto`)."""
        from jax import shard_map

        return shard_map(
            step_fn, mesh=mesh,
            in_specs=(state_specs, batch_spec),
            out_specs=(state_specs, P()),
            check_vma=False,
            axis_names={self.axis_name},
        )


# --------------------------------------------------------------------------
# Stacked (pjit-native) formulation
# --------------------------------------------------------------------------
class StackedGossipState(NamedTuple):
    x: PyTree            # leaves (W, ...) — worker-stacked replicas
    x_tilde: PyTree
    opt: Any             # stacked optimizer state
    key: jax.Array
    # (H, W, D) snapshot ring (gossip.DelayRing) on delayed channels;
    # the stacked form holds every worker's history locally, so reads
    # resolve per READER — the exact DelayProcess law
    ring: Any = None


@dataclasses.dataclass(frozen=True)
class StackedGossipTrainer:
    """Decentralized A2CiD2 trainer with an explicit leading worker axis.

    Every state leaf carries a leading (n_workers,) dim sharded over the
    mesh "worker" axis; the per-worker gradient step is a vmap and a gossip
    event is ``jnp.take(x, partner, axis=0)`` — XLA lowers the gather along
    the sharded worker dim to a collective-permute.  This is the same code
    path as core.simulator (the faithful repro) but partitioned over real
    devices, and it avoids the shard_map(manual=worker)+auto(data,model)
    combination that crashes XLA's SPMD partitioner (see DESIGN.md).

    grad_fn(params_i, batch_i) -> (loss, grads) for ONE worker; vmapped.
    """

    grad_fn: Callable
    optimizer: Optimizer
    graph: Graph
    acid: A2CiD2Params
    lr: float = 0.1
    comms_per_step: int = 1
    backend: str = "auto"  # fused gossip-kernel backend for the event loop
    # per-worker gradient rates (straggler clocks) — see GossipTrainer;
    # matches events.make_schedule(grad_rates=...) in distribution
    grad_rates: tuple[float, ...] | None = None
    # unreliable channel — see GossipTrainer: adversary + drops, plus
    # message delay via the stacked (H, W, D) snapshot ring
    channel: ChannelModel | None = None
    robust_clip: float | None = None
    robust_rule: str = "trim"

    def __post_init__(self):
        check_mesh_channel(self.channel, permute_ring=True)

    @classmethod
    def from_world(cls, world, grad_fn: Callable, optimizer: Optimizer, *,
                   accelerated: bool | None = None,
                   **kw) -> "StackedGossipTrainer":
        """Build the trainer from a declarative ``core.world.World`` (static
        Graph topology, algorithm-zoo aware; see
        ``GossipTrainer.from_world``)."""
        graph, acid, grad_rates = _world_dynamics(world, accelerated)
        if "comms_per_step" not in kw:  # explicit override skips the check
            kw["comms_per_step"] = _comms_per_step(world)
        kw.setdefault("channel", world.channel)
        return cls(grad_fn, optimizer, graph, acid,
                   grad_rates=grad_rates, **kw)

    def init(self, params0: PyTree, key: jax.Array) -> StackedGossipState:
        from ..core.engine import FlatGossipEngine
        from ..core.gossip import DelayRing

        n = self.graph.n
        stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), params0)
        ring = None
        if self.channel is not None and self.channel.horizon > 0:
            engine = FlatGossipEngine.for_pytree(stack, self.acid,
                                                 stacked=True,
                                                 backend=self.backend)
            bx = engine.pack(stack)
            ring = DelayRing(
                jnp.tile(bx[None], (self.channel.horizon, 1, 1)),
                jnp.asarray(-1, jnp.int32))
        return StackedGossipState(
            x=stack, x_tilde=jax.tree.map(jnp.copy, stack),
            opt=jax.vmap(self.optimizer.init)(stack), key=key, ring=ring)

    def make_step(self):
        from ..core.a2cid2 import apply_mixing
        from ..core.engine import FlatGossipEngine
        from ..core.gossip import (bank_corruption, bank_edge_rates,
                                   matching_bank)

        bank_np = np.asarray(matching_bank(self.graph))         # (M, W)
        probs = jnp.asarray(
            bank_edge_rates(self.graph, bank_np), jnp.float32)
        n = self.graph.n
        E = self.comms_per_step
        acid = self.acid

        rate_vec = _rate_vec(self.grad_rates, n)
        # unreliable-channel statics: per-matching corruption vectors (the
        # Byzantine edge set is fixed, so each bank branch carries its own
        # constant corrupt vector), drop probability, robust clip
        corrupt_np = bank_corruption(
            bank_np, None if self.channel is None else self.channel.adversary)
        drop_prob = 0.0 if self.channel is None else self.channel.drop_prob
        delay = None if self.channel is None else self.channel.delay
        delay_on = delay is not None and not delay.is_trivial
        channel_on = (self.robust_clip is not None
                      or bool(corrupt_np.any()) or drop_prob > 0.0
                      or delay_on)

        def step(state: StackedGossipState, batch: PyTree):
            from ..core.gossip import DelayRing

            k_st = None
            if delay_on:
                # extra split only on delayed channels — a delay-free
                # trainer keeps the seeded event stream bit-for-bit
                key, k_st = jax.random.split(state.key)
            else:
                key = state.key
            key, k_dt, k_ev, k_gap = jax.random.split(key, 4)
            x, xt = state.x, state.x_tilde
            # per-worker gradient-event clocks ~ Exp(1)/rate_i: stragglers
            # (rate < 1) see longer inter-gradient gaps — the same rate
            # process the simulator's schedule expresses by tick thinning
            dts = jax.random.exponential(k_dt, (n,))
            if rate_vec is not None:
                dts = dts / rate_vec
            x, xt = apply_mixing(x, xt, acid.eta, dts)
            (losses, _aux), grads = jax.vmap(self.grad_fn)(x, batch)
            x2, opt = jax.vmap(
                lambda g, o, p: self.optimizer.update(
                    g, o, p, jnp.asarray(self.lr, jnp.float32))
            )(grads, state.opt, x)
            delta = jax.tree.map(lambda a, b: a - b, x2, x)
            x = x2
            xt = jax.tree.map(lambda t, d: t + d, xt, delta)
            # E gossip events: sampled matchings + Exp inter-event mixing,
            # run on the flat-buffer engine: pack once, one fused
            # [p2p, mix-to-next-event] sweep per event (see DESIGN.md),
            # unpack once — no per-leaf dispatch inside the scan.
            k_drop = None
            if drop_prob > 0.0:
                # extra split only when drops can occur — a drop-free world
                # keeps the pre-channel event stream bit-for-bit
                k_ev, k_drop = jax.random.split(k_ev)
            idxs = jax.random.categorical(k_ev, jnp.log(probs), shape=(E,))
            gaps = jax.random.exponential(k_gap, (E, n)) / max(E, 1)

            engine = FlatGossipEngine.for_pytree(
                x, acid, stacked=True, backend=self.backend,
                robust_clip=self.robust_clip, robust_rule=self.robust_rule)
            ring = state.ring
            if delay_on:
                # snapshot the post-gradient stack at the grad tick (the
                # simulator's ring cadence), then per-READER staleness
                # draws — the exact DelayProcess law
                r = ring.round + 1
                ring = DelayRing(
                    ring.buf.at[r % delay.horizon].set(engine.pack(x)), r)
                k_s1, k_s2 = jax.random.split(k_st)
                hit = jax.random.bernoulli(k_s1, delay.prob, (E, n))
                if delay.kind == "fixed":
                    offs = jnp.full((E, n), delay.horizon, jnp.int32)
                else:
                    offs = jax.random.randint(k_s2, (E, n), 1,
                                              delay.horizon + 1,
                                              dtype=jnp.int32)
                stales = jnp.where(hit, offs, 0).astype(jnp.int32)
            if E == 0:
                return (StackedGossipState(x, xt, opt, key, ring),
                        {"loss": jnp.mean(losses)})

            bx, bxt = engine.pack(x), engine.pack(xt)
            bx, bxt = engine.mix(bx, bxt, gaps[0])
            gaps_next = jnp.concatenate(
                [gaps[1:], jnp.zeros((1, n), gaps.dtype)], axis=0)

            # the matching bank is STATIC — dispatch via lax.switch so each
            # branch gathers with a constant permutation.  A traced partner
            # (bank[idx] then take) defeats XLA's permutation analysis and
            # lowers to an all-gather of every worker's shard (n x the bytes
            # of a p2p exchange; measured in EXPERIMENTS.md §Perf C).
            def make_branch(k: int):
                perm = jnp.asarray(bank_np[k], jnp.int32)
                inv = jnp.asarray(bank_np[k] != np.arange(n))

                def branch(operand):
                    bx, bxt, dtn = operand[:3]
                    if channel_on:
                        xp = jnp.take(bx, perm, axis=0)
                        if delay_on:
                            # reader-resolved stale reads off the stacked
                            # ring; idle workers (perm i -> i) stay fresh
                            # so an idle event remains an exact no-op
                            s = jnp.where(
                                inv,
                                jnp.minimum(operand[3],
                                            jnp.maximum(ring.round, 0)),
                                0)
                            slot = jnp.where(
                                s > 0, (ring.round - s) % delay.horizon, 0)
                            xp = jnp.where((s > 0)[:, None],
                                           ring.buf[slot, perm], xp)
                        return engine.channel_batch(
                            bx, bxt, xp, jnp.asarray(corrupt_np[k]), dtn)
                    return engine.batch(bx, bxt, perm, dtn)

                return branch

            branches = [make_branch(k) for k in range(bank_np.shape[0])]
            if channel_on:
                # dropped events keep only their mix segment: one extra
                # static branch with an identity matching (m = 0)
                branches.append(lambda op: engine.mix(op[0], op[1], op[2]))
                if drop_prob > 0.0:
                    dropped = jax.random.bernoulli(k_drop, drop_prob, (E,))
                    idxs = jnp.where(dropped, bank_np.shape[0], idxs)

            ev_xs = (idxs, gaps_next, stales) if delay_on \
                else (idxs, gaps_next)

            def ev(carry, inp):
                bx, bxt = carry
                bx, bxt = jax.lax.switch(inp[0], branches,
                                         (bx, bxt) + inp[1:])
                return (bx, bxt), None

            (bx, bxt), _ = jax.lax.scan(ev, (bx, bxt), ev_xs)
            return (StackedGossipState(engine.unpack(bx), engine.unpack(bxt),
                                       opt, key, ring),
                    {"loss": jnp.mean(losses)})

        return step

    def make_pair_ring_step(self):
        """Ring-graph gossip with pair-local collectives (§Perf C it3).

        A ring's two maximal matchings pair adjacent workers; with the worker
        axis factored as (wpair=W/2, wside=2), the even matching's pairwise
        average is a 2-device all-reduce (pmean over "wside" after reshaping
        the stacked worker dim to (W/2, 2)), and the odd matching is the same
        after a roll(1) of the worker axis (one collective-permute).  The
        A2CiD2 x~ update needs only m = 2*(x - pairmean) — no extra traffic.
        Per-event bytes drop from an all-gather of all W shards to ~1 shard.
        """
        assert self.graph.name == "ring" and self.graph.n % 2 == 0
        n = self.graph.n
        E = self.comms_per_step
        acid = self.acid

        def pair_mean(t):  # t: (W, ...) -> mean over adjacent even pairs
            r = t.reshape((n // 2, 2) + t.shape[1:])
            m = jnp.mean(r, axis=1, keepdims=True)
            return jnp.broadcast_to(m, r.shape).reshape(t.shape)

        def p2p(x, xt, odd):
            def upd(a, at):
                a2 = jnp.roll(a, -1, axis=0) if odd else a
                mean = pair_mean(a2)
                mdiff = 2.0 * (a2 - mean)          # = a_i - a_partner
                new_a = a2 - acid.alpha * mdiff    # = pairwise mean
                if odd:
                    new_a = jnp.roll(new_a, 1, axis=0)
                    mdiff = jnp.roll(mdiff, 1, axis=0)
                return new_a, at - acid.alpha_tilde * mdiff

            flat_x, treedef = jax.tree_util.tree_flatten(x)
            flat_t = treedef.flatten_up_to(xt)
            out = [upd(a, at) for a, at in zip(flat_x, flat_t)]
            return (treedef.unflatten([o[0] for o in out]),
                    treedef.unflatten([o[1] for o in out]))

        from ..core.a2cid2 import apply_mixing

        rate_vec = _rate_vec(self.grad_rates, n)

        def step(state: StackedGossipState, batch: PyTree):
            key, k_dt, k_ev, k_gap = jax.random.split(state.key, 4)
            x, xt = state.x, state.x_tilde
            dts = jax.random.exponential(k_dt, (n,))
            if rate_vec is not None:
                dts = dts / rate_vec
            x, xt = apply_mixing(x, xt, acid.eta, dts)
            (losses, _aux), grads = jax.vmap(self.grad_fn)(x, batch)
            x2, opt = jax.vmap(
                lambda g, o, p: self.optimizer.update(
                    g, o, p, jnp.asarray(self.lr, jnp.float32))
            )(grads, state.opt, x)
            delta = jax.tree.map(lambda a, b: a - b, x2, x)
            x = x2
            xt = jax.tree.map(lambda t, d: t + d, xt, delta)
            odds = jax.random.bernoulli(k_ev, 0.5, (E,))
            gaps = jax.random.exponential(k_gap, (E, n)) / max(E, 1)

            def ev(carry, inp):
                x, xt = carry
                odd, gap = inp
                x, xt = apply_mixing(x, xt, acid.eta, gap)
                x, xt = jax.lax.cond(
                    odd,
                    lambda c: p2p(c[0], c[1], True),
                    lambda c: p2p(c[0], c[1], False),
                    (x, xt))
                return (x, xt), None

            (x, xt), _ = jax.lax.scan(ev, (x, xt), (odds, gaps))
            return (StackedGossipState(x, xt, opt, key),
                    {"loss": jnp.mean(losses)})

        return step

    def make_ar_step(self):
        """AR-SGD baseline at the same mesh: every step all-reduces gradients
        across the worker axis (the paper's synchronous reference)."""
        n = self.graph.n

        def step(state: StackedGossipState, batch: PyTree):
            key, _ = jax.random.split(state.key)
            (losses, _aux), grads = jax.vmap(self.grad_fn)(state.x, batch)
            # all-reduce over workers: mean along the stacked worker axis
            grads = jax.tree.map(
                lambda g: jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True),
                                           g.shape), grads)
            x, opt = jax.vmap(
                lambda g, o, p: self.optimizer.update(
                    g, o, p, jnp.asarray(self.lr, jnp.float32))
            )(grads, state.opt, state.x)
            return (StackedGossipState(x, jax.tree.map(jnp.copy, x), opt,
                                       key),
                    {"loss": jnp.mean(losses)})

        return step
